//! The Mixer-seam refactor's bitwise contract.
//!
//! PR history: the consensus step used to be an inline Push-Vector
//! sequence inside the trial loop — `reset_weighted` / `run_rounds` /
//! per-node `estimate_into` + projection. The Mixer refactor moved that
//! sequence behind the object-safe [`gadget::gossip::Mixer`] trait so
//! alternative backends (gradient-flow) plug into the same seam. The
//! acceptance criterion is that the default backend is a **pure
//! refactor**: `--mixer push-sum` must reproduce the pre-refactor
//! pipeline bit for bit — same consensus weights, same iteration
//! counts, same per-node accuracies — on every scheduler and pool size.
//!
//! Like `store_equivalence.rs`, the golden values are recomputed from a
//! frozen reference loop built on public primitives, not from a number
//! dump, so the pin survives refactors of the harness itself. `ci.sh`
//! re-runs this suite with `GADGET_POOL_THREADS` pinned to 1 and 4.

use gadget::config::{ExperimentConfig, SchedulerKind};
use gadget::coordinator::{
    GadgetRunner, GossipProtocol, NativeBackend, NodeState, ProtocolParams, GRAPH_SEED,
};
use gadget::data::partition::horizontal_split;
use gadget::gossip::{MixerKind, PushVector};
use gadget::metrics;
use gadget::rng::Rng;
use gadget::topology::{mixing_time, Graph, TopologyKind, TransitionMatrix};

/// Seed label the runner mixes into the trial seed for graph generation
/// (re-exported frozen constant of the trial loop).
const TEST_SPLIT_LABEL: u64 = 0x7e57;

fn cfg() -> ExperimentConfig {
    ExperimentConfig::builder()
        .dataset("synthetic-usps")
        .scale(0.05)
        .nodes(5)
        .trials(1)
        .max_iterations(150)
        .epsilon(5e-3)
        .seed(29)
        .build()
        .unwrap()
}

/// Pool sizes the sweep runs at; `GADGET_POOL_THREADS=n` pins one size
/// (`ci.sh` re-runs at 1 and 4).
fn pool_threads() -> Vec<usize> {
    match std::env::var("GADGET_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("GADGET_POOL_THREADS must be an integer")],
        Err(_) => vec![1, 4],
    }
}

fn bits(w: &[f64]) -> Vec<u64> {
    w.iter().map(|x| x.to_bits()).collect()
}

/// The pre-refactor trial loop, reproduced from public primitives: the
/// inline Push-Vector consensus sequence exactly as the runner executed
/// it before the Mixer seam existed.
/// Returns `(consensus_w, iterations, node_accuracy, epsilon_final)`.
fn pre_refactor_reference(
    cfg: &ExperimentConfig,
) -> (Vec<f64>, usize, Vec<f64>, f64) {
    let runner = GadgetRunner::new(cfg.clone()).unwrap();
    let train = runner.train_data().clone();
    let test = runner.test_data().clone();
    let lambda = runner.lambda();
    let m = cfg.nodes;
    let d = train.dim;
    let seed = cfg.seed; // trial 0's root seed

    let graph = Graph::generate(cfg.topology, m, seed ^ GRAPH_SEED);
    let b = TransitionMatrix::from_graph(&graph, cfg.weights);
    let rounds = if cfg.gossip_rounds > 0 {
        cfg.gossip_rounds
    } else {
        mixing_time(&b, cfg.gamma).min(10_000)
    };

    let train_shards = horizontal_split(&train, m, seed).unwrap();
    let test_shards = horizontal_split(&test, m, seed ^ TEST_SPLIT_LABEL).unwrap();
    let shard_sizes: Vec<f64> = train_shards.iter().map(|s| s.len() as f64).collect();
    let root = Rng::new(seed);
    let mut nodes: Vec<NodeState> = test_shards
        .into_iter()
        .enumerate()
        .map(|(i, te)| NodeState::new(i, te, d, root.substream(i as u64)))
        .collect();

    let protocol = GossipProtocol::new(ProtocolParams::from_config(cfg, lambda));
    let mut backend = NativeBackend::default();
    let mut pv = PushVector::new_weighted(&vec![vec![0.0; d]; m], &shard_sizes);
    let mut iterations = 0usize;
    for t in 1..=cfg.max_iterations {
        iterations = t;
        for i in 0..m {
            protocol
                .local_step(&mut backend, train_shards[i].view(), &mut nodes[i], t)
                .unwrap();
        }
        // the pre-seam consensus step, inline: weighted reset, fixed
        // synchronous rounds, per-node estimate + step-(h) projection
        pv.reset_weighted(nodes.iter().map(|n| n.w.as_slice()), &shard_sizes);
        pv.run_rounds(&b, rounds);
        for (i, node) in nodes.iter_mut().enumerate() {
            pv.estimate_into(i, &mut node.w);
            if cfg.project_consensus {
                gadget::linalg::project_to_ball(&mut node.w, 1.0 / lambda.sqrt());
            }
            node.check_convergence(cfg.epsilon);
        }
        if nodes.iter().all(|n| n.converged) {
            break;
        }
    }

    let node_accuracy: Vec<f64> = nodes
        .iter()
        .map(|n| {
            metrics::accuracy(&n.w, if n.test_shard.is_empty() { &test } else { &n.test_shard })
        })
        .collect();
    let epsilon_final = nodes.iter().map(|n| n.last_delta).fold(0.0f64, f64::max);
    let mut consensus = vec![0.0; d];
    for n in &nodes {
        for (c, &x) in consensus.iter_mut().zip(&n.w) {
            *c += 1.0 * x; // mirror linalg::add_assign (axpy with a = 1)
        }
    }
    // mirror the runner's average_w: multiply by the reciprocal
    let inv = 1.0 / m as f64;
    for c in consensus.iter_mut() {
        *c *= inv;
    }
    (consensus, iterations, node_accuracy, epsilon_final)
}

fn assert_matches_reference(
    report: &gadget::coordinator::GadgetReport,
    golden: &(Vec<f64>, usize, Vec<f64>, f64),
    label: &str,
) {
    let t = &report.trials[0];
    assert_eq!(t.iterations, golden.1, "{label}: iteration count diverged");
    assert_eq!(
        bits(&t.consensus_w),
        bits(&golden.0),
        "{label}: consensus_w diverged from the pre-refactor pipeline"
    );
    assert_eq!(
        bits(&t.node_accuracy),
        bits(&golden.2),
        "{label}: node accuracies diverged"
    );
    assert_eq!(
        t.epsilon_final.to_bits(),
        golden.3.to_bits(),
        "{label}: epsilon diverged"
    );
}

#[test]
fn push_sum_mixer_is_bitwise_the_pre_refactor_loop() {
    // Sequential and parallel schedulers, explicit `--mixer push-sum`,
    // every swept pool size: all bit-for-bit the inline reference.
    let cfg = cfg();
    let golden = pre_refactor_reference(&cfg);
    let seq = GadgetRunner::new(ExperimentConfig {
        mixer: MixerKind::PushSum,
        ..cfg.clone()
    })
    .unwrap()
    .run()
    .unwrap();
    assert_matches_reference(&seq, &golden, "sequential");
    for threads in pool_threads() {
        let par = GadgetRunner::new(ExperimentConfig {
            mixer: MixerKind::PushSum,
            scheduler: SchedulerKind::Parallel,
            threads,
            ..cfg.clone()
        })
        .unwrap()
        .run()
        .unwrap();
        assert_matches_reference(&par, &golden, &format!("parallel/threads={threads}"));
    }
}

#[test]
fn push_sum_pin_holds_on_the_ring() {
    // The ring B has no rank-1 fast path and needs many rounds per
    // iteration — the pin must not depend on the overlay's spectrum.
    let cfg = ExperimentConfig {
        topology: TopologyKind::Ring,
        max_iterations: 80,
        ..cfg()
    };
    let golden = pre_refactor_reference(&cfg);
    let seq = GadgetRunner::new(cfg.clone()).unwrap().run().unwrap();
    assert_matches_reference(&seq, &golden, "ring/sequential");
    for threads in pool_threads() {
        let par = GadgetRunner::new(ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads,
            ..cfg.clone()
        })
        .unwrap()
        .run()
        .unwrap();
        assert_matches_reference(&par, &golden, &format!("ring/parallel/threads={threads}"));
    }
}

#[test]
fn default_mixer_is_push_sum() {
    // An unset `[mixing] backend` must mean "the paper's consensus":
    // the default-config run and the explicit push-sum run are the same
    // run, bit for bit.
    assert_eq!(MixerKind::default(), MixerKind::PushSum);
    let dflt = GadgetRunner::new(cfg()).unwrap().run().unwrap();
    let expl = GadgetRunner::new(ExperimentConfig {
        mixer: MixerKind::PushSum,
        ..cfg()
    })
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(dflt.iterations, expl.iterations);
    assert_eq!(
        bits(&dflt.trials[0].consensus_w),
        bits(&expl.trials[0].consensus_w)
    );
}

#[test]
fn gradient_flow_genuinely_changes_the_consensus_path() {
    // Sanity guard on the pin itself: swapping the backend must change
    // the trajectory (so the equalities above are not vacuous), while
    // both backends still drive the run to a comparable solution.
    let cfg = ExperimentConfig { topology: TopologyKind::Ring, ..cfg() };
    let ps = GadgetRunner::new(cfg.clone()).unwrap().run().unwrap();
    let gf = GadgetRunner::new(ExperimentConfig {
        mixer: MixerKind::GradientFlow,
        ..cfg
    })
    .unwrap()
    .run()
    .unwrap();
    assert_ne!(
        bits(&ps.trials[0].consensus_w),
        bits(&gf.trials[0].consensus_w),
        "gradient-flow run unexpectedly identical to push-sum"
    );
    assert!(gf.test_accuracy > 0.7, "gradient-flow accuracy {}", gf.test_accuracy);
    assert!(
        (ps.test_accuracy - gf.test_accuracy).abs() < 0.15,
        "backends disagree too much: push-sum {} vs gradient-flow {}",
        ps.test_accuracy,
        gf.test_accuracy
    );
}
