//! Steady-state allocation pin for the Parallel-scheduler hot path.
//!
//! The iteration loop is allocation-free once warm: indexed pool dispatch
//! (`ParallelExec::run_indexed`) enqueues plain `{fn, index}` jobs into a
//! retained-capacity queue, the scaled-step solver scratch
//! (`coordinator::backend::StepScratch`) and the mixer's mass buffers are
//! built once and reused, and node state (`w`, `w_prev`, RNG) never
//! reallocates. This test drives the exact per-iteration sequence of
//! `GadgetRunner::run_trial` — local-step fan-out, mixer consensus with
//! the pool as panel executor, estimate/convergence fan-out — under a
//! counting global allocator and pins the steady-state allocation count
//! per iteration to **zero**.
//!
//! The hard assertion is release-only (`cargo test --release`; `ci.sh`
//! runs it via the release test pass): debug builds share the allocation
//! behavior but we keep the gate conservative so unoptimized std
//! internals can never flake the tier-1 debug run. The measurement takes
//! the *minimum* over several windows, so a one-off allocation from the
//! test harness' own threads (stdout capture etc.) cannot produce a
//! false positive — a true per-iteration allocation shows up in every
//! window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use gadget::coordinator::sched::{GossipProtocol, ProtocolParams, Scheduler};
use gadget::coordinator::{NodeState, Parallel};
use gadget::data::synthetic::{generate, DatasetSpec};
use gadget::data::{Dataset, ShardStore, StaticStore};
use gadget::gossip::{Mixer, PushSumMixer};
use gadget::rng::Rng;
use gadget::topology::stochastic::WeightScheme;
use gadget::topology::{Graph, TransitionMatrix};

/// Forwards to the system allocator, counting every allocation
/// (`alloc`/`alloc_zeroed`/`realloc`) from **all** threads — pool workers
/// included, which is the point: a per-iteration allocation on a worker
/// is just as much a regression as one on the caller.
struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Serializes the measuring tests: the counter is global, so a second
/// test allocating concurrently would show up in this one's windows.
static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn train_set() -> Dataset {
    let spec = DatasetSpec {
        name: "alloc-pin".into(),
        train_size: 240,
        test_size: 32,
        features: 64,
        nnz_per_row: 10,
        noise: 0.05,
        positive_rate: 0.5,
        lambda: 1e-3,
    };
    generate(&spec, 41, 1.0).train
}

/// One GADGET iteration, exactly the `run_trial` sequence: (a)–(f) local
/// steps fanned over the pool, (g) push-sum mixing with the pool as panel
/// executor, (g)-consume/(h)/ε per node.
fn iteration(
    sched: &mut Parallel,
    protocol: &GossipProtocol,
    store: &StaticStore,
    nodes: &mut [NodeState],
    ids: &[usize],
    mixer: &mut PushSumMixer,
    sizes: &[f64],
    t: usize,
) {
    let store_ref: &dyn ShardStore = store;
    sched
        .for_each_node(nodes, ids, &|backend, _slot, node| {
            protocol.local_step(backend, store_ref.shard(node.id), node, t)
        })
        .unwrap();
    mixer.mix(
        &mut nodes.iter().map(|n| n.w.as_slice()),
        sizes,
        sched.panel_exec(),
        sched.kernel(),
    );
    let mixer_ref: &dyn Mixer = mixer;
    sched
        .for_each_node(nodes, ids, &|_backend, slot, node| {
            protocol.apply_estimate(mixer_ref, slot, node);
            protocol.check_convergence(node);
            Ok(())
        })
        .unwrap();
}

#[test]
fn parallel_hot_path_is_allocation_free_at_steady_state() {
    let _gate = GATE.lock().unwrap();
    let train = train_set();
    let m = 4usize;
    let d = train.dim;
    let seed = 9u64;

    let store = StaticStore::split(&train, m, seed).unwrap();
    let mut sizes = vec![0.0f64; m];
    store.sizes_into(&mut sizes);
    let mut nodes: Vec<NodeState> = (0..m)
        .map(|id| NodeState::new(id, Dataset::default(), d, Rng::new(seed ^ id as u64)))
        .collect();
    let ids: Vec<usize> = (0..m).collect();
    let protocol = GossipProtocol::new(ProtocolParams {
        lambda: 1e-3,
        batch_size: 2,
        local_steps: 1,
        project_local: true,
        project_consensus: true,
        epsilon: 1e-12, // never trips on this short run — the check still executes
    });
    let b = TransitionMatrix::from_graph(&Graph::complete(m), WeightScheme::MetropolisHastings);
    let mut mixer = PushSumMixer::new(b, 4, d, &sizes);
    let mut sched = Parallel::native(2);

    // Warm-up: first iterations build the per-backend solver scratch, the
    // mixer mass buffers, node `w_prev`, and grow the pool queue to its
    // peak depth. All of that is one-time.
    let mut t = 1usize;
    for _ in 0..6 {
        iteration(&mut sched, &protocol, &store, &mut nodes, &ids, &mut mixer, &sizes, t);
        t += 1;
    }

    const WINDOWS: usize = 3;
    const ITERS_PER_WINDOW: usize = 20;
    let mut min_window_allocs = usize::MAX;
    for _ in 0..WINDOWS {
        let before = ALLOCATIONS.load(Ordering::SeqCst);
        for _ in 0..ITERS_PER_WINDOW {
            iteration(&mut sched, &protocol, &store, &mut nodes, &ids, &mut mixer, &sizes, t);
            t += 1;
        }
        let delta = ALLOCATIONS.load(Ordering::SeqCst) - before;
        min_window_allocs = min_window_allocs.min(delta);
    }

    // Sanity on the run itself: weights moved and stayed finite.
    for node in &nodes {
        assert!(node.w.iter().all(|x| x.is_finite()));
        assert!(node.w.iter().any(|&x| x != 0.0), "node {} never trained", node.id);
    }

    #[cfg(not(debug_assertions))]
    assert_eq!(
        min_window_allocs, 0,
        "steady-state Parallel iteration allocated ({min_window_allocs} allocations \
         over the best {ITERS_PER_WINDOW}-iteration window)"
    );
    #[cfg(debug_assertions)]
    eprintln!(
        "alloc_regression (debug, not asserted): best window = {min_window_allocs} \
         allocations / {ITERS_PER_WINDOW} iterations"
    );
}

/// Reads exactly one `Content-Length`-framed HTTP response into `buf`
/// and returns its total byte length. Allocation-free by construction —
/// fixed caller-owned buffer, head scanned and parsed in place — so the
/// client side of the measurement loop below cannot pollute the count.
fn read_response(stream: &mut std::net::TcpStream, buf: &mut [u8]) -> usize {
    use std::io::Read;
    let mut got = 0usize;
    let head_end = loop {
        if let Some(p) = buf[..got].windows(4).position(|w| w == b"\r\n\r\n") {
            break p + 4;
        }
        let n = stream.read(&mut buf[got..]).expect("read head");
        assert!(n > 0, "peer closed mid-response");
        got += n;
    };
    let head = std::str::from_utf8(&buf[..head_end]).expect("utf8 head");
    let mut body_len = usize::MAX;
    for line in head.split("\r\n") {
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                body_len = v.trim().parse().expect("content-length");
            }
        }
    }
    assert_ne!(body_len, usize::MAX, "no Content-Length in response head");
    let total = head_end + body_len;
    while got < total {
        let n = stream.read(&mut buf[got..total]).expect("read body");
        assert!(n > 0, "peer closed mid-body");
        got += n;
    }
    total
}

/// The serve-path twin of the pin above: a **warm keep-alive `/score`
/// request allocates nothing** — connection arenas (head/body reader,
/// response buffer, parsed-row scratch) and the sharded scorer's
/// per-shard scratch are all built during warm-up and only reused after.
/// Same methodology: counting global allocator over every thread (the
/// HTTP worker included), minimum over several windows, hard assert in
/// release only.
#[test]
fn warm_keep_alive_score_request_is_allocation_free() {
    use gadget::serve::{
        HttpConfig, HttpServer, ModelArtifact, ScalingMeta, ServeOptions, ShardedScorer,
    };
    use std::io::Write;

    let _gate = GATE.lock().unwrap();

    let model =
        ModelArtifact::new(3, vec![vec![1.0, -1.0, 0.5]], vec![0.0], ScalingMeta::default())
            .unwrap();
    let scorer = ShardedScorer::new(model, 1);
    let opts = ServeOptions { shards: 1, batch: 2, ..Default::default() };
    let server = HttpServer::start(
        "127.0.0.1:0",
        HttpConfig { queue_depth: 4, deadline_ms: 30_000, workers: 1 },
        Some((scorer, opts)),
        None,
    )
    .unwrap();
    let addr = server.local_addr();

    // Three rows across two internal batches (--batch 2), libsvm format.
    let body = "1:0.5 3:1.25\n2:0.75\n1:1 2:1 3:1\n";
    let request = format!(
        "POST /score HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes();

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut buf = [0u8; 4096];

    // Warm-up: the connection's arenas reach their high-water capacity
    // and we learn the exact frame length — identical requests get
    // byte-identical responses, so the length is stable.
    stream.write_all(&request).unwrap();
    let expected = read_response(&mut stream, &mut buf);
    assert!(
        buf.starts_with(b"HTTP/1.1 200 OK\r\n"),
        "{:?}",
        String::from_utf8_lossy(&buf[..expected])
    );
    let first: Vec<u8> = buf[..expected].to_vec();
    for _ in 0..8 {
        stream.write_all(&request).unwrap();
        let n = read_response(&mut stream, &mut buf);
        assert_eq!(&buf[..n], &first[..], "warm responses diverged");
    }

    const WINDOWS: usize = 3;
    const REQS_PER_WINDOW: usize = 16;
    let mut min_window_allocs = usize::MAX;
    for _ in 0..WINDOWS {
        let before = ALLOCATIONS.load(Ordering::SeqCst);
        for _ in 0..REQS_PER_WINDOW {
            stream.write_all(&request).unwrap();
            let n = read_response(&mut stream, &mut buf);
            assert_eq!(n, expected);
        }
        let delta = ALLOCATIONS.load(Ordering::SeqCst) - before;
        min_window_allocs = min_window_allocs.min(delta);
    }
    // The last measured response is still byte-identical to the first.
    assert_eq!(&buf[..expected], &first[..], "steady-state response drifted");

    drop(stream);
    let stats = server.shutdown_and_join().unwrap();
    assert_eq!(stats.scored_rows, 3 * (1 + 8 + WINDOWS * REQS_PER_WINDOW));

    #[cfg(not(debug_assertions))]
    assert_eq!(
        min_window_allocs, 0,
        "warm keep-alive /score allocated ({min_window_allocs} allocations over the \
         best {REQS_PER_WINDOW}-request window)"
    );
    #[cfg(debug_assertions)]
    eprintln!(
        "serve alloc_regression (debug, not asserted): best window = {min_window_allocs} \
         allocations / {REQS_PER_WINDOW} requests"
    );
}
