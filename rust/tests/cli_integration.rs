//! CLI integration: spawns the actual `gadget` binary (CARGO_BIN_EXE) and
//! checks every subcommand's surface behaviour — exit codes, report
//! fields, error messages, config-file handling, result files.

use std::process::Command;

fn gadget() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gadget"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = gadget().args(args).output().expect("spawn gadget");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for word in ["train", "baseline", "experiment", "inspect"] {
        assert!(stdout.contains(word), "help missing {word}");
    }
    // no-arg invocation prints help too
    let (ok2, stdout2, _) = run(&[]);
    assert!(ok2);
    assert!(stdout2.contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails_with_hint() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"), "{stderr}");
}

#[test]
fn train_small_run_reports_accuracy() {
    let (ok, stdout, stderr) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "1",
        "--max-iterations",
        "100",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("test accuracy"), "{stdout}");
    assert!(stdout.contains("gossip (trial 0)"), "{stdout}");
}

#[test]
fn train_from_config_file() {
    let dir = std::env::temp_dir().join(format!("gadget-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("t.toml");
    std::fs::write(
        &cfg,
        "dataset = \"synthetic-usps\"\nscale = 0.02\nnodes = 3\ntrials = 1\nmax_iterations = 80\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["train", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("== GADGET report =="));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn baseline_solvers_run() {
    for solver in ["pegasos", "svm-sgd", "dcd"] {
        let (ok, stdout, stderr) = run(&[
            "baseline",
            "--solver",
            solver,
            "--dataset",
            "synthetic-usps",
            "--scale",
            "0.02",
        ]);
        assert!(ok, "{solver} stderr: {stderr}");
        assert!(stdout.contains("test accuracy"), "{solver}: {stdout}");
    }
}

#[test]
fn bad_option_value_is_clear_error() {
    let (ok, _, stderr) = run(&["train", "--scale", "banana"]);
    assert!(!ok);
    assert!(stderr.contains("scale"), "{stderr}");
}

#[test]
fn experiment_writes_result_files() {
    let dir = std::env::temp_dir().join(format!("gadget-exp-{}", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "experiment",
        "table3",
        "--scale",
        "0.02",
        "--trials",
        "1",
        "--nodes",
        "3",
        "--max-iterations",
        "60",
        "--only",
        "usps",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("Table 3"));
    assert!(dir.join("table3.csv").is_file());
    assert!(dir.join("table3.json").is_file());
    let json = std::fs::read_to_string(dir.join("table3.json")).unwrap();
    assert!(json.contains("gadget_acc"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn inspect_reports_dataset_and_spectrum() {
    let (ok, stdout, stderr) = run(&[
        "inspect",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "4",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("features"), "{stdout}");
    assert!(stdout.contains("lambda2"), "{stdout}");
}

#[test]
fn experiment_churn_and_topology_drivers() {
    let (ok, stdout, stderr) = run(&[
        "experiment",
        "churn",
        "--scale",
        "0.02",
        "--nodes",
        "4",
        "--max-iterations",
        "80",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("p_fail"), "{stdout}");

    let (ok2, stdout2, stderr2) = run(&[
        "experiment",
        "topology",
        "--scale",
        "0.02",
        "--m",
        "8",
        "--max-iterations",
        "80",
    ]);
    assert!(ok2, "stderr: {stderr2}");
    assert!(stdout2.contains("Overlay"), "{stdout2}");
}
