//! CLI integration: spawns the actual `gadget` binary (CARGO_BIN_EXE) and
//! checks every subcommand's surface behaviour — exit codes, report
//! fields, error messages, config-file handling, result files.

use std::io::Write;
use std::process::{Command, Stdio};

fn gadget() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gadget"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = gadget().args(args).output().expect("spawn gadget");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// Runs the binary with `input` piped to stdin (the serve protocol).
fn run_piped(args: &[&str], input: &str) -> (bool, String, String) {
    let mut child = gadget()
        .args(args)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn gadget");
    // ignore write errors: a child that fails fast (bad --model) may
    // close the pipe before the batch is written
    let _ = child.stdin.take().expect("piped stdin").write_all(input.as_bytes());
    let out = child.wait_with_output().expect("wait gadget");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for word in ["train", "baseline", "experiment", "inspect"] {
        assert!(stdout.contains(word), "help missing {word}");
    }
    // no-arg invocation prints help too
    let (ok2, stdout2, _) = run(&[]);
    assert!(ok2);
    assert!(stdout2.contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails_with_hint() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"), "{stderr}");
}

#[test]
fn train_small_run_reports_accuracy() {
    let (ok, stdout, stderr) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "1",
        "--max-iterations",
        "100",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("test accuracy"), "{stdout}");
    assert!(stdout.contains("gossip (trial 0)"), "{stdout}");
}

#[test]
fn train_stream_smoke_and_flag_defaults() {
    // Explicit streaming options: the startup line echoes the resolved
    // [stream] section and the run completes with a report.
    let (ok, stdout, stderr) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.05",
        "--nodes",
        "3",
        "--trials",
        "1",
        "--max-iterations",
        "120",
        "--stream-rate",
        "2",
        "--stream-max-rows",
        "20",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(
        stdout.contains("stream: rate=2 schedule=uniform max-rows=20 initial=0.5"),
        "{stdout}"
    );
    assert!(stdout.contains("test accuracy"), "{stdout}");

    // `--stream` alone enables the data plane at the default rate.
    let (ok2, stdout2, stderr2) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.05",
        "--nodes",
        "3",
        "--trials",
        "1",
        "--max-iterations",
        "60",
        "--stream",
        "--stream-max-rows",
        "10",
    ]);
    assert!(ok2, "stderr: {stderr2}");
    assert!(stdout2.contains("stream: rate=1"), "{stdout2}");

    // bad schedule is a clear error, not a silent static run
    let (ok3, _, stderr3) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--stream-rate",
        "1",
        "--stream-schedule",
        "poisson",
    ]);
    assert!(!ok3);
    assert!(stderr3.contains("stream-schedule"), "{stderr3}");

    // stream options without a rate are rejected, not silently ignored
    // (a "streaming" benchmark must never secretly run the static path)
    let (ok4, _, stderr4) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--stream-schedule",
        "uniform",
    ]);
    assert!(!ok4);
    assert!(stderr4.contains("streaming is off"), "{stderr4}");

    // `--stream` + an explicit zero rate is a contradiction
    let (ok5, _, stderr5) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--stream",
        "--stream-rate",
        "0",
    ]);
    assert!(!ok5);
    assert!(stderr5.contains("contradicts"), "{stderr5}");
}

#[test]
fn train_from_config_file() {
    let dir = std::env::temp_dir().join(format!("gadget-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("t.toml");
    std::fs::write(
        &cfg,
        "dataset = \"synthetic-usps\"\nscale = 0.02\nnodes = 3\ntrials = 1\nmax_iterations = 80\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["train", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("== GADGET report =="));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn baseline_solvers_run() {
    for solver in ["pegasos", "svm-sgd", "dcd"] {
        let (ok, stdout, stderr) = run(&[
            "baseline",
            "--solver",
            solver,
            "--dataset",
            "synthetic-usps",
            "--scale",
            "0.02",
        ]);
        assert!(ok, "{solver} stderr: {stderr}");
        assert!(stdout.contains("test accuracy"), "{solver}: {stdout}");
    }
}

#[test]
fn bad_option_value_is_clear_error() {
    let (ok, _, stderr) = run(&["train", "--scale", "banana"]);
    assert!(!ok);
    assert!(stderr.contains("scale"), "{stderr}");
}

#[test]
fn zero_trials_is_a_clear_config_error() {
    // `--trials 0` would make every report consumer index a missing
    // trial 0 — it must die at config validation with a message naming
    // the field, through both the flag and the config-file path.
    let (ok, _, stderr) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "0",
    ]);
    assert!(!ok, "trials = 0 must fail");
    assert!(stderr.contains("trials"), "{stderr}");

    let dir = std::env::temp_dir().join(format!("gadget-trials0-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("t.toml");
    std::fs::write(&cfg, "dataset = \"synthetic-usps\"\ntrials = 0\n").unwrap();
    let (ok2, _, stderr2) = run(&["train", "--config", cfg.to_str().unwrap()]);
    assert!(!ok2);
    assert!(stderr2.contains("trials"), "{stderr2}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn parallel_scheduler_cli_reports_identical_accuracy() {
    // End-to-end through the binary: the pooled parallel scheduler (here
    // trials = 2 ⇒ trial fan-out) must print the exact accuracy line the
    // sequential reference prints.
    let base = [
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "2",
        "--max-iterations",
        "60",
    ];
    let (ok_seq, out_seq, err_seq) = run(&base);
    assert!(ok_seq, "stderr: {err_seq}");
    let mut par_args: Vec<&str> = base.to_vec();
    par_args.extend_from_slice(&["--scheduler", "parallel", "--threads", "3"]);
    let (ok_par, out_par, err_par) = run(&par_args);
    assert!(ok_par, "stderr: {err_par}");
    let acc = |s: &str| {
        s.lines()
            .find(|l| l.contains("test accuracy"))
            .map(str::to_string)
            .unwrap_or_else(|| panic!("no accuracy line in: {s}"))
    };
    assert_eq!(acc(&out_seq), acc(&out_par));
}

#[test]
fn experiment_writes_result_files() {
    let dir = std::env::temp_dir().join(format!("gadget-exp-{}", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "experiment",
        "table3",
        "--scale",
        "0.02",
        "--trials",
        "1",
        "--nodes",
        "3",
        "--max-iterations",
        "60",
        "--only",
        "usps",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("Table 3"));
    assert!(dir.join("table3.csv").is_file());
    assert!(dir.join("table3.json").is_file());
    let json = std::fs::read_to_string(dir.join("table3.json")).unwrap();
    assert!(json.contains("gadget_acc"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn inspect_reports_dataset_and_spectrum() {
    let (ok, stdout, stderr) = run(&[
        "inspect",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "4",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("features"), "{stdout}");
    assert!(stdout.contains("lambda2"), "{stdout}");
}

#[test]
fn train_save_then_serve_scores_a_piped_batch() {
    let dir = std::env::temp_dir().join(format!("gadget-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    let model_path = model.to_str().unwrap();

    // end-to-end: train tiny, persist the consensus model
    let (ok, stdout, stderr) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "1",
        "--max-iterations",
        "60",
        "--save",
        model_path,
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("model saved"), "{stdout}");
    assert!(model.is_file());

    // serve a piped batch: labeled libsvm, unlabeled libsvm, dense
    let batch = "+1 1:0.5 3:1.25\n2:0.75 5:0.5\n0.1 0.2 0.3\n";
    let (ok, stdout, stderr) =
        run_piped(&["serve", "--model", model_path, "--shards", "2", "--batch", "2"], batch);
    assert!(ok, "stderr: {stderr}");
    let labels: Vec<&str> = stdout.lines().collect();
    assert_eq!(labels.len(), 3, "{stdout}");
    for l in &labels {
        assert!(*l == "+1" || *l == "-1", "unexpected prediction {l:?}");
    }
    assert!(stderr.contains("served 3 rows"), "{stderr}");

    // the acceptance contract: --shards 4 output is byte-identical to
    // --shards 1, scores included
    let (ok1, out1, err1) = run_piped(
        &["serve", "--model", model_path, "--shards", "1", "--scores"],
        batch,
    );
    let (ok4, out4, err4) = run_piped(
        &["serve", "--model", model_path, "--shards", "4", "--scores"],
        batch,
    );
    assert!(ok1, "stderr: {err1}");
    assert!(ok4, "stderr: {err4}");
    assert_eq!(out1, out4, "shard count changed the predictions");
    assert!(out1.lines().all(|l| l.contains('\t')), "missing score column: {out1}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_kernel_flag_reports_backend_and_gates_simd() {
    let dir = std::env::temp_dir().join(format!("gadget-serve-k-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    let model_path = model.to_str().unwrap();
    std::fs::write(
        &model,
        r#"{"format":"gadget-model","version":2,"dim":3,"classes":1,"weights":[[1,-1,0.5]],"bias":[0]}"#,
    )
    .unwrap();

    // the startup line names the active backend (self-describing logs)
    let (ok, out, stderr) =
        run_piped(&["serve", "--model", model_path, "--kernel", "scalar"], "1:2\n");
    assert!(ok, "stderr: {stderr}");
    assert_eq!(out, "+1\n");
    assert!(stderr.contains("kernel=scalar"), "{stderr}");

    // --kernel simd: selectable exactly when the feature is compiled in,
    // a clear error naming the missing feature otherwise (never a silent
    // scalar fallback)
    let (ok, out, stderr) =
        run_piped(&["serve", "--model", model_path, "--kernel", "simd"], "1:2\n");
    if cfg!(feature = "simd") {
        assert!(ok, "stderr: {stderr}");
        assert_eq!(out, "+1\n");
        assert!(stderr.contains("kernel=simd"), "{stderr}");
    } else {
        assert!(!ok, "simd selection must fail without --features simd");
        assert!(stderr.contains("--features simd"), "{stderr}");
    }

    // unknown kernel name: parse error listing the choices
    let (ok, _, stderr) =
        run_piped(&["serve", "--model", model_path, "--kernel", "warp"], "");
    assert!(!ok);
    assert!(stderr.contains("scalar | simd | auto"), "{stderr}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_rejects_malformed_input_and_bad_artifacts() {
    let dir = std::env::temp_dir().join(format!("gadget-serve-neg-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    let model_path = model.to_str().unwrap();

    // a tiny valid artifact, written directly (dim 3, binary)
    std::fs::write(
        &model,
        r#"{"format":"gadget-model","version":2,"dim":3,"classes":1,"weights":[[1,-1,0.5]],"bias":[0]}"#,
    )
    .unwrap();

    // malformed row: non-zero exit, message names the input line
    let (ok, _, stderr) = run_piped(&["serve", "--model", model_path], "1:1\n1:banana\n");
    assert!(!ok, "malformed input must fail");
    assert!(stderr.contains("input line 2"), "{stderr}");

    // feature index beyond the model dim: clear dim-mismatch error
    let (ok, _, stderr) = run_piped(&["serve", "--model", model_path], "1:1 9:2\n");
    assert!(!ok);
    assert!(stderr.contains("model dim 3"), "{stderr}");

    // --model is required
    let (ok, _, stderr) = run_piped(&["serve"], "");
    assert!(!ok);
    assert!(stderr.contains("--model"), "{stderr}");

    // missing file
    let (ok, _, stderr) = run_piped(&["serve", "--model", "/no/such/model.json"], "");
    assert!(!ok);
    assert!(stderr.contains("model"), "{stderr}");

    // wrong format version: error names both versions
    std::fs::write(
        &model,
        r#"{"format":"gadget-model","version":9,"dim":1,"classes":1,"weights":[[1]],"bias":[0]}"#,
    )
    .unwrap();
    let (ok, _, stderr) = run_piped(&["serve", "--model", model_path], "1:1\n");
    assert!(!ok);
    assert!(stderr.contains("version 9"), "{stderr}");
    assert!(stderr.contains("version 2"), "{stderr}");

    // legacy v1 single-vector file: upgrade hint
    std::fs::write(&model, r#"{"format":"gadget-linear-v1","dim":1,"w":[1]}"#).unwrap();
    let (ok, _, stderr) = run_piped(&["serve", "--model", model_path], "1:1\n");
    assert!(!ok);
    assert!(stderr.contains("gadget-linear-v1"), "{stderr}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Spawns the binary with stderr piped and waits for the HTTP front
/// end's startup line, returning the child, the resolved bind address,
/// and the stderr reader (kept open so the child never blocks on a full
/// pipe).
fn spawn_http(args: &[&str]) -> (std::process::Child, String, impl std::io::BufRead) {
    use std::io::BufRead;
    let mut child = gadget()
        .args(args)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn gadget");
    let mut stderr = std::io::BufReader::new(child.stderr.take().expect("piped stderr"));
    let mut seen = String::new();
    let addr = loop {
        let mut line = String::new();
        if stderr.read_line(&mut line).expect("read child stderr") == 0 {
            let _ = child.kill();
            panic!("child exited before the listening line; stderr so far:\n{seen}");
        }
        seen.push_str(&line);
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split_whitespace().next().expect("addr token").to_string();
        }
    };
    (child, addr, stderr)
}

/// One-shot HTTP/1.1 request against `addr`; returns the raw response
/// (status line + headers + body). Sends `Connection: close` so the
/// read-to-EOF below terminates — keep-alive is the 1.1 default now.
fn http_request(addr: &str, path: &str, body: &str) -> String {
    use std::io::Read;
    let mut s = std::net::TcpStream::connect(addr).expect("connect");
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut r = String::new();
    s.read_to_string(&mut r).expect("read response");
    r
}

/// Writes one keep-alive request on an already-open connection.
fn send_keep_alive(s: &mut std::net::TcpStream, path: &str, body: &str) {
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    s.flush().unwrap();
}

/// Reads exactly one `Content-Length`-framed response off a keep-alive
/// connection and returns (status line, body).
fn read_framed(s: &mut std::net::TcpStream) -> (String, String) {
    use std::io::Read;
    let mut raw = Vec::new();
    let mut byte = [0u8; 1];
    // read the head byte-by-byte so we never consume the next response
    while !raw.ends_with(b"\r\n\r\n") {
        let n = s.read(&mut byte).expect("read head");
        assert!(n > 0, "peer closed mid-head: {:?}", String::from_utf8_lossy(&raw));
        raw.push(byte[0]);
    }
    let head = String::from_utf8(raw).expect("utf8 head");
    let status = head.lines().next().expect("status line").to_string();
    let len: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .unwrap_or_else(|| panic!("no Content-Length in {head:?}"));
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).expect("read body");
    (status, String::from_utf8(body).expect("utf8 body"))
}

fn http_body(response: &str) -> &str {
    response.split("\r\n\r\n").nth(1).unwrap_or_else(|| panic!("no body: {response}"))
}

#[test]
fn serve_http_scores_byte_identical_to_stdin() {
    let dir = std::env::temp_dir().join(format!("gadget-http-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    let model_path = model.to_str().unwrap();

    let (ok, _, stderr) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "1",
        "--max-iterations",
        "60",
        "--save",
        model_path,
    ]);
    assert!(ok, "stderr: {stderr}");

    // HTTP at 4 shards vs stdin at 1 shard: equality pins both the
    // transport (HTTP ≡ stdin, same bytes) and shard invariance at once.
    // --scores makes the check bit-strength (shortest-roundtrip floats).
    let (mut child, addr, _stderr) = spawn_http(&[
        "serve", "--model", model_path, "--http", "127.0.0.1:0", "--shards", "4",
        "--batch", "2", "--scores",
    ]);
    let batch = "+1 1:0.5 3:1.25\n2:0.75 5:0.5\n0.1 0.2 0.3\n";
    let response = http_request(&addr, "/score", batch);
    assert!(response.starts_with("HTTP/1.1 200 OK\r\n"), "{response}");

    let (ok1, stdin_out, err1) = run_piped(
        &["serve", "--model", model_path, "--shards", "1", "--batch", "2", "--scores"],
        batch,
    );
    assert!(ok1, "stderr: {err1}");
    assert_eq!(http_body(&response), stdin_out, "HTTP and stdin predictions diverged");

    // malformed rows answer 400 with the stdin path's globally-numbered
    // error, and do not kill the server
    let bad = http_request(&addr, "/score", "1:1\n2:1\n1:1\n1:banana\n");
    assert!(bad.starts_with("HTTP/1.1 400 "), "{bad}");
    assert!(http_body(&bad).contains("input line 4"), "{bad}");
    let again = http_request(&addr, "/score", "1:2\n");
    assert!(again.starts_with("HTTP/1.1 200 OK\r\n"), "{again}");

    let bye = http_request(&addr, "/shutdown", "");
    assert!(bye.starts_with("HTTP/1.1 200 OK\r\n"), "{bye}");
    let status = child.wait().expect("wait serve");
    assert!(status.success(), "serve exited with {status}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_http_queue_overflow_answers_503_with_retry_after() {
    use std::io::Read;
    let dir = std::env::temp_dir().join(format!("gadget-http-ovf-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    std::fs::write(
        &model,
        r#"{"format":"gadget-model","version":2,"dim":3,"classes":1,"weights":[[1,-1,0.5]],"bias":[0]}"#,
    )
    .unwrap();

    // --workers 1 pins the single-executor queue arithmetic this test
    // relies on (auto would resolve to the shard count).
    let (mut child, addr, _stderr) = spawn_http(&[
        "serve", "--model", model.to_str().unwrap(), "--http", "127.0.0.1:0",
        "--shards", "1", "--queue-depth", "1", "--deadline-ms", "30000",
        "--workers", "1",
    ]);

    // c1 occupies the worker: the headers promise a body that is not
    // sent yet, so the worker blocks reading it on c1's deadline budget.
    let hold_body = "1:1\n";
    let mut c1 = std::net::TcpStream::connect(&addr).unwrap();
    write!(
        c1,
        "POST /score HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n",
        hold_body.len()
    )
    .unwrap();
    c1.flush().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(200));
    // c2 fills the depth-1 queue
    let mut c2 = std::net::TcpStream::connect(&addr).unwrap();
    write!(c2, "POST /score HTTP/1.1\r\nConnection: close\r\nContent-Length: 4\r\n\r\n2:1\n").unwrap();
    c2.flush().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(200));
    // c3/c4 must overflow — refused with 503 + Retry-After, never dropped
    let r3 = http_request(&addr, "/score", "3:1\n");
    let r4 = http_request(&addr, "/score", "3:1\n");
    for r in [&r3, &r4] {
        assert!(r.starts_with("HTTP/1.1 "), "dropped response: {r:?}");
    }
    let refusals: Vec<&String> = [&r3, &r4]
        .into_iter()
        .filter(|r| r.starts_with("HTTP/1.1 503 "))
        .collect();
    assert!(!refusals.is_empty(), "expected an overflow 503; got:\n{r3}\n---\n{r4}");
    for r in &refusals {
        assert!(r.contains("Retry-After: 1"), "503 without Retry-After: {r}");
    }

    // everything admitted is still served: c1 completes its body → 200,
    // then the worker drains c2 → 200
    write!(c1, "{hold_body}").unwrap();
    c1.flush().unwrap();
    let mut resp1 = String::new();
    c1.read_to_string(&mut resp1).unwrap();
    assert!(resp1.starts_with("HTTP/1.1 200 OK\r\n"), "{resp1}");
    assert_eq!(http_body(&resp1), "+1\n");
    let mut resp2 = String::new();
    c2.read_to_string(&mut resp2).unwrap();
    assert!(resp2.starts_with("HTTP/1.1 200 OK\r\n"), "{resp2}");
    assert_eq!(http_body(&resp2), "-1\n");

    let bye = http_request(&addr, "/shutdown", "");
    assert!(bye.starts_with("HTTP/1.1 200 OK\r\n"), "{bye}");
    assert!(child.wait().expect("wait serve").success());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_http_keep_alive_matches_close_and_stdin() {
    let dir = std::env::temp_dir().join(format!("gadget-http-ka-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    std::fs::write(
        &model,
        r#"{"format":"gadget-model","version":2,"dim":3,"classes":1,"weights":[[1,-1,0.5]],"bias":[0]}"#,
    )
    .unwrap();
    let model_path = model.to_str().unwrap();

    let (mut child, addr, _stderr) = spawn_http(&[
        "serve", "--model", model_path, "--http", "127.0.0.1:0", "--shards", "2",
        "--batch", "2", "--scores",
    ]);
    let batches = ["1:1\n2:0.5\n", "1:-2 3:4\n", "2:1 3:-1\n1:0.25\n"];

    // three requests down one keep-alive connection
    let mut ka = std::net::TcpStream::connect(&addr).unwrap();
    let mut ka_bodies = Vec::new();
    for b in &batches {
        send_keep_alive(&mut ka, "/score", b);
        let (status, body) = read_framed(&mut ka);
        assert!(status.starts_with("HTTP/1.1 200"), "{status}");
        ka_bodies.push(body);
    }
    drop(ka);

    // keep-alive ≡ one fresh `Connection: close` request per batch
    for (b, ka_body) in batches.iter().zip(&ka_bodies) {
        let r = http_request(&addr, "/score", b);
        assert!(r.starts_with("HTTP/1.1 200 OK\r\n"), "{r}");
        assert_eq!(http_body(&r), ka_body, "keep-alive and close responses diverged");
    }

    // keep-alive ≡ the stdin loop over the concatenated row stream
    // (--scores makes this bit-strength: shortest-roundtrip floats)
    let all: String = batches.concat();
    let (ok, stdin_out, err) = run_piped(
        &["serve", "--model", model_path, "--shards", "1", "--batch", "2", "--scores"],
        &all,
    );
    assert!(ok, "stderr: {err}");
    assert_eq!(ka_bodies.concat(), stdin_out, "keep-alive and stdin predictions diverged");

    let bye = http_request(&addr, "/shutdown", "");
    assert!(bye.starts_with("HTTP/1.1 200 OK\r\n"), "{bye}");
    assert!(child.wait().expect("wait serve").success());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_http_mid_keep_alive_malformed_row_recovers() {
    let dir = std::env::temp_dir().join(format!("gadget-http-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    std::fs::write(
        &model,
        r#"{"format":"gadget-model","version":2,"dim":3,"classes":1,"weights":[[1,-1,0.5]],"bias":[0]}"#,
    )
    .unwrap();

    let (mut child, addr, _stderr) = spawn_http(&[
        "serve", "--model", model.to_str().unwrap(), "--http", "127.0.0.1:0",
        "--shards", "1", "--batch", "2",
    ]);

    let mut c = std::net::TcpStream::connect(&addr).unwrap();
    send_keep_alive(&mut c, "/score", "1:1\n");
    let (s1, b1) = read_framed(&mut c);
    assert!(s1.starts_with("HTTP/1.1 200"), "{s1}");
    assert_eq!(b1, "+1\n");

    // line 4 sits in the second internal batch (--batch 2): the error
    // must carry the request-global line number, not the batch-local one
    send_keep_alive(&mut c, "/score", "1:1\n2:1\n1:1\n1:banana\n");
    let (s2, b2) = read_framed(&mut c);
    assert!(s2.starts_with("HTTP/1.1 400"), "{s2}");
    assert!(b2.contains("input line 4"), "{b2}");

    // a row-level 400 does not poison the connection
    send_keep_alive(&mut c, "/score", "2:1\n");
    let (s3, b3) = read_framed(&mut c);
    assert!(s3.starts_with("HTTP/1.1 200"), "{s3}");
    assert_eq!(b3, "-1\n");
    drop(c);

    let bye = http_request(&addr, "/shutdown", "");
    assert!(bye.starts_with("HTTP/1.1 200 OK\r\n"), "{bye}");
    assert!(child.wait().expect("wait serve").success());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_http_workers_invariant_under_concurrent_load() {
    let dir = std::env::temp_dir().join(format!("gadget-http-wrk-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    std::fs::write(
        &model,
        r#"{"format":"gadget-model","version":2,"dim":3,"classes":1,"weights":[[1,-1,0.5]],"bias":[0]}"#,
    )
    .unwrap();
    let model_path = model.to_str().unwrap().to_string();

    let rows: Vec<String> =
        (0..8).map(|i| format!("1:0.{} 3:-0.{}\n", i + 1, 8 - i)).collect();
    // stdin-loop reference scores for every row, one line each
    let reference: Vec<String> = {
        let all: String = rows.concat();
        let (ok, out, err) = run_piped(
            &["serve", "--model", &model_path, "--shards", "1", "--scores"],
            &all,
        );
        assert!(ok, "stderr: {err}");
        out.lines().map(|l| format!("{l}\n")).collect()
    };
    assert_eq!(reference.len(), rows.len());

    for workers in ["1", "4"] {
        let (mut child, addr, _stderr) = spawn_http(&[
            "serve", "--model", &model_path, "--http", "127.0.0.1:0", "--shards", "2",
            "--workers", workers, "--scores",
        ]);
        let handles: Vec<_> = rows
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, row)| {
                let addr = addr.clone();
                std::thread::spawn(move || (i, http_request(&addr, "/score", &row)))
            })
            .collect();
        for h in handles {
            let (i, resp) = h.join().expect("client thread");
            assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "workers={workers}: {resp}");
            assert_eq!(
                http_body(&resp),
                reference[i],
                "workers={workers} diverged from the stdin loop on row {i}"
            );
        }
        let bye = http_request(&addr, "/shutdown", "");
        assert!(bye.starts_with("HTTP/1.1 200 OK\r\n"), "{bye}");
        assert!(child.wait().expect("wait serve").success());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn train_http_ingest_accepts_rows_and_drains_on_shutdown() {
    // train-while-serving: rows POSTed to /ingest join the shards at the
    // next ingestion boundary; /shutdown closes the stream, lifting the
    // convergence veto so the run can finish.
    let (mut child, addr, _stderr) = spawn_http(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "1",
        "--max-iterations",
        "400",
        "--http-ingest",
        "127.0.0.1:0",
    ]);
    let ok = http_request(&addr, "/ingest", "+1 1:0.5 3:0.25\n-1 2:0.75\n");
    assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
    assert_eq!(http_body(&ok), "accepted 2 rows\n");
    // a malformed batch is refused whole, naming the line
    let bad = http_request(&addr, "/ingest", "+1 1:0.5\n-1 2:banana\n");
    assert!(bad.starts_with("HTTP/1.1 400 "), "{bad}");
    assert!(http_body(&bad).contains("input line 2"), "{bad}");
    // scoring is not served on an ingest-only endpoint
    let score = http_request(&addr, "/score", "1:1\n");
    assert!(score.starts_with("HTTP/1.1 404 "), "{score}");

    let bye = http_request(&addr, "/shutdown", "");
    assert!(bye.starts_with("HTTP/1.1 200 OK\r\n"), "{bye}");
    let out = child.wait_with_output().expect("wait train");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "train failed:\n{stdout}");
    assert!(stdout.contains("2 rows accepted"), "{stdout}");
    assert!(stdout.contains("== GADGET report =="), "{stdout}");

    // a live stream cannot be replayed across trials — rejected loudly
    let (ok2, _, stderr2) = run(&[
        "train",
        "--dataset",
        "synthetic-usps",
        "--scale",
        "0.02",
        "--nodes",
        "3",
        "--trials",
        "2",
        "--max-iterations",
        "40",
        "--http-ingest",
        "127.0.0.1:0",
    ]);
    assert!(!ok2, "--http-ingest with trials = 2 must fail");
    assert!(stderr2.contains("trials = 1"), "{stderr2}");
}

#[test]
fn experiment_churn_and_topology_drivers() {
    let (ok, stdout, stderr) = run(&[
        "experiment",
        "churn",
        "--scale",
        "0.02",
        "--nodes",
        "4",
        "--max-iterations",
        "80",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("p_fail"), "{stdout}");

    // the mixer-seam sweep: one overlay filtered via --only, both
    // backends in the table, CSV/JSON artifacts written under --out
    let dir = std::env::temp_dir().join(format!("gadget-topo-{}", std::process::id()));
    let (ok2, stdout2, stderr2) = run(&[
        "experiment",
        "topology",
        "--scale",
        "0.02",
        "--nodes",
        "4",
        "--max-iterations",
        "80",
        "--only",
        "ring",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(ok2, "stderr: {stderr2}");
    assert!(stdout2.contains("Overlay"), "{stdout2}");
    assert!(stdout2.contains("push-sum") && stdout2.contains("gradient-flow"), "{stdout2}");
    let json = std::fs::read_to_string(dir.join("topology.json")).unwrap();
    assert!(json.contains("topology_sweep"), "{json}");
    assert!(dir.join("topology.csv").is_file());
    let _ = std::fs::remove_dir_all(&dir);
}
