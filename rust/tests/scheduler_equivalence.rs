//! Equivalence and conservation guarantees of the unified execution
//! runtime (`coordinator::sched`).
//!
//! * The **parallel** scheduler must be *bitwise identical* to the
//!   sequential reference: per-node RNG substreams isolate all randomness
//!   and backends re-initialize scratch from `w` on every call, so the
//!   consensus trajectory cannot depend on worker count or interleaving.
//! * The **async** scheduler must conserve push-sum mass: `Σ nᵢ` exactly
//!   and `Σ nᵢwᵢ` across every drain/halve/absorb, which is the invariant
//!   that makes each node's estimate converge to the shard-weighted
//!   average.

use gadget::config::{ExperimentConfig, SchedulerKind, StreamSchedule};
use gadget::coordinator::sched::{AsyncParams, AsyncScheduler};
use gadget::coordinator::{GadgetRunner, MassState};
use gadget::data::partition::horizontal_split;
use gadget::data::synthetic::{generate, DatasetSpec};
use gadget::rng::Rng;
use gadget::topology::{Graph, TopologyKind};

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig::builder()
        .dataset("synthetic-usps")
        .scale(0.05)
        .nodes(6)
        .trials(2)
        .max_iterations(150)
        .epsilon(5e-3)
        .seed(23)
        .kernel(test_kernel())
        .build()
        .unwrap()
}

/// Kernel backend the sweep trains on. `GADGET_KERNEL=scalar|simd|auto`
/// pins it (`ci.sh` re-runs the suite under `scalar` explicitly, and may
/// run `simd` on `--features simd` builds); default scalar. The
/// `Parallel ≡ Sequential` bitwise contract holds **per kernel** — both
/// schedulers compute on the same backend, and parallelism only moves
/// work — so every equivalence assertion below is valid for any pinned
/// kernel, even though cross-kernel results differ (that contract lives
/// in `kernel_equivalence.rs`).
fn test_kernel() -> gadget::config::KernelKind {
    match std::env::var("GADGET_KERNEL") {
        Ok(v) => v.parse().expect("GADGET_KERNEL must be scalar|simd|auto"),
        Err(_) => gadget::config::KernelKind::Scalar,
    }
}

fn bits(w: &[f64]) -> Vec<u64> {
    w.iter().map(|x| x.to_bits()).collect()
}

/// Pool sizes the equivalence sweep runs at. `GADGET_POOL_THREADS=n`
/// pins a single size — `ci.sh` uses this to re-run the suite at pool
/// sizes 1 and 4, proving the contract is worker-count-invariant.
fn pool_threads() -> Vec<usize> {
    match std::env::var("GADGET_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("GADGET_POOL_THREADS must be an integer")],
        Err(_) => vec![1, 2, 3, 8],
    }
}

#[test]
fn parallel_is_bitwise_identical_to_sequential() {
    let seq = GadgetRunner::new(base_cfg()).unwrap().run().unwrap();
    for threads in pool_threads() {
        let cfg = ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads,
            ..base_cfg()
        };
        let par = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert_eq!(seq.trials.len(), par.trials.len());
        for (ts, tp) in seq.trials.iter().zip(&par.trials) {
            assert_eq!(ts.iterations, tp.iterations, "threads={threads}");
            assert_eq!(
                bits(&ts.consensus_w),
                bits(&tp.consensus_w),
                "threads={threads}: consensus_w diverged"
            );
            assert_eq!(
                bits(&ts.node_accuracy),
                bits(&tp.node_accuracy),
                "threads={threads}: node accuracies diverged"
            );
            assert_eq!(
                ts.epsilon_final.to_bits(),
                tp.epsilon_final.to_bits(),
                "threads={threads}: epsilon diverged"
            );
        }
        assert_eq!(seq.iterations, par.iterations);
        assert_eq!(seq.test_accuracy.to_bits(), par.test_accuracy.to_bits());
    }
}

#[test]
fn parallel_equivalence_holds_on_sparse_topologies() {
    // A ring forces many gossip rounds per iteration; the equivalence must
    // not depend on the overlay.
    let mk = |scheduler, threads| {
        let cfg = ExperimentConfig {
            topology: TopologyKind::Ring,
            scheduler,
            threads,
            max_iterations: 80,
            trials: 1,
            ..base_cfg()
        };
        GadgetRunner::new(cfg).unwrap().run().unwrap()
    };
    let seq = mk(SchedulerKind::Sequential, 0);
    let par = mk(SchedulerKind::Parallel, 4);
    assert_eq!(seq.iterations, par.iterations);
    assert_eq!(bits(&seq.trials[0].consensus_w), bits(&par.trials[0].consensus_w));
}

#[test]
fn panel_parallel_mixing_is_bitwise_identical() {
    // d = 784 spans several mixing panels and the ring B has no rank-1
    // fast path, so the pooled run (trials = 1 ⇒ node fan-out) takes the
    // panel-parallel Bᵀ-apply; the result must stay bitwise identical.
    let mk = |scheduler, threads| {
        let cfg = ExperimentConfig {
            dataset: "synthetic-mnist".into(),
            scale: 0.01,
            topology: TopologyKind::Ring,
            scheduler,
            threads,
            max_iterations: 25,
            trials: 1,
            ..base_cfg()
        };
        GadgetRunner::new(cfg).unwrap().run().unwrap()
    };
    let seq = mk(SchedulerKind::Sequential, 0);
    for threads in pool_threads() {
        let par = mk(SchedulerKind::Parallel, threads);
        assert_eq!(seq.iterations, par.iterations, "threads={threads}");
        assert_eq!(
            bits(&seq.trials[0].consensus_w),
            bits(&par.trials[0].consensus_w),
            "threads={threads}"
        );
        assert_eq!(seq.test_accuracy.to_bits(), par.test_accuracy.to_bits());
    }
}

#[test]
fn trial_fanout_is_bitwise_identical() {
    // The trial fan-out path engages when trials ≥ threads > 1, so pin
    // trials = threads at every swept pool size (size 1 never fans out
    // and is skipped — the headline test covers the node path there).
    // Shorter runs than base_cfg: trials grows with the pool size.
    for threads in pool_threads() {
        if threads < 2 {
            continue;
        }
        let mk = |scheduler, t| {
            let cfg = ExperimentConfig {
                scheduler,
                threads: t,
                trials: threads,
                max_iterations: 60,
                ..base_cfg()
            };
            GadgetRunner::new(cfg).unwrap().run().unwrap()
        };
        let seq = mk(SchedulerKind::Sequential, 0);
        let par = mk(SchedulerKind::Parallel, threads);
        assert_eq!(seq.trials.len(), par.trials.len(), "threads={threads}");
        assert_eq!(seq.test_accuracy.to_bits(), par.test_accuracy.to_bits(), "threads={threads}");
        assert_eq!(seq.iterations, par.iterations, "threads={threads}");
        for (ts, tp) in seq.trials.iter().zip(&par.trials) {
            assert_eq!(bits(&ts.consensus_w), bits(&tp.consensus_w), "threads={threads}");
        }
    }
}

/// The streaming arrival schedule the equivalence sweep extends to:
/// rate 3 with a 36-row cap over the usps stand-in means arrivals land at
/// iterations 2..=13 and then the pool-fed stream dries up.
fn streaming_cfg() -> ExperimentConfig {
    ExperimentConfig {
        stream_rate: 3.0,
        stream_max_rows: 36,
        stream_initial: 0.5,
        ..base_cfg()
    }
}

#[test]
fn streaming_parallel_is_bitwise_identical_to_sequential() {
    // Arrivals are store-internal and seeded — a pure function of the
    // trial seed, never of worker interleaving — so the bitwise
    // `Parallel ≡ Sequential` contract extends verbatim to streaming
    // runs. trials (2) ≥ threads also sweeps the trial fan-out path with
    // per-trial store reconstruction.
    let seq = GadgetRunner::new(streaming_cfg()).unwrap().run().unwrap();
    for threads in pool_threads() {
        let cfg = ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads,
            ..streaming_cfg()
        };
        let par = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert_eq!(seq.trials.len(), par.trials.len());
        for (ts, tp) in seq.trials.iter().zip(&par.trials) {
            assert_eq!(ts.iterations, tp.iterations, "threads={threads}");
            assert_eq!(
                bits(&ts.consensus_w),
                bits(&tp.consensus_w),
                "threads={threads}: streaming consensus_w diverged"
            );
            assert_eq!(
                bits(&ts.node_accuracy),
                bits(&tp.node_accuracy),
                "threads={threads}: streaming node accuracies diverged"
            );
        }
        assert_eq!(seq.test_accuracy.to_bits(), par.test_accuracy.to_bits());
    }
}

#[test]
fn streaming_random_schedule_is_bitwise_scheduler_invariant() {
    // The random node-assignment schedule draws from the store's own
    // seeded RNG — still deterministic, still scheduler-invariant.
    let mk = |scheduler, threads| {
        let cfg = ExperimentConfig {
            stream_schedule: StreamSchedule::Random,
            scheduler,
            threads,
            max_iterations: 80,
            trials: 1,
            ..streaming_cfg()
        };
        GadgetRunner::new(cfg).unwrap().run().unwrap()
    };
    let seq = mk(SchedulerKind::Sequential, 0);
    let par = mk(SchedulerKind::Parallel, 4);
    assert_eq!(seq.iterations, par.iterations);
    assert_eq!(bits(&seq.trials[0].consensus_w), bits(&par.trials[0].consensus_w));
}

#[test]
fn streaming_convergence_is_drift_aware() {
    // The ε test may not declare convergence while rows still arrive:
    // with arrivals at iterations 2..=13, every one of those iterations
    // has at least one ingesting (vetoed) node, so the earliest
    // all-converged stop is t = 14. Once the stream dries up the anytime
    // criterion takes over and the run still terminates inside the
    // budget with a finite ε.
    let report = GadgetRunner::new(streaming_cfg()).unwrap().run().unwrap();
    for t in &report.trials {
        assert!(
            t.iterations > 13,
            "run stopped at iteration {} while rows were still arriving",
            t.iterations
        );
        assert!(t.epsilon_final.is_finite());
    }
    assert!(report.test_accuracy > 0.7, "accuracy {}", report.test_accuracy);
}

fn async_problem(m: usize, seed: u64) -> (Vec<gadget::data::Dataset>, f64) {
    let spec = DatasetSpec {
        name: "mass".into(),
        train_size: 420,
        test_size: 60,
        features: 18,
        nnz_per_row: 5,
        noise: 0.03,
        positive_rate: 0.5,
        lambda: 1e-2,
    };
    let shards = horizontal_split(&generate(&spec, seed, 1.0).train, m, seed).unwrap();
    let total_n: f64 = shards.iter().map(|s| s.len() as f64).sum();
    (shards, total_n)
}

#[test]
fn async_path_conserves_total_mass_across_drains() {
    for (topo, cycles, cooldown) in
        [(Graph::complete(5), 300usize, 40usize), (Graph::ring(5), 500, 100)]
    {
        let (shards, total_n) = async_problem(5, 77);
        let res = AsyncScheduler::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 2,
            cycles,
            cooldown,
            local_steps: 1,
            project: true,
            seed: 11,
            max_lag: 4,
            link_latency: 0,
            link_drop: 0.0,
        })
        .run(shards, &topo)
        .unwrap();
        // Σ nᵢ: the push-sum weight is never created or destroyed, only
        // halved and shipped — the total must match the sample count to
        // f64 re-association error.
        let w_sum: f64 = res.mass_weights.iter().sum();
        assert!(
            (w_sum - total_n).abs() < 1e-9 * total_n,
            "total weight drifted: {w_sum} vs {total_n}"
        );
        // Σ nᵢ·wᵢ: the final mass vectors must equal estimate·weight
        // slot-for-slot (the estimate is exactly v/w), and the total mass
        // must be finite and consistent with the reported estimates.
        for (i, (v, w)) in res.mass_v.iter().zip(&res.mass_weights).enumerate() {
            for (k, (&vk, &ek)) in v.iter().zip(&res.estimates[i]).enumerate() {
                let back = ek * *w;
                assert!(
                    (vk - back).abs() <= 1e-9 * (1.0 + vk.abs()),
                    "node {i} slot {k}: v {vk} vs est*w {back}"
                );
            }
        }
    }
}

#[test]
fn pure_gossip_conserves_mass_vector_exactly() {
    // With zero active cycles (cycles == cooldown) no local drift is ever
    // folded in: Σ vᵢ stays the initial zero vector while Σ weights stays
    // Σ nᵢ — conservation across *every* drain with no confound.
    let (shards, total_n) = async_problem(4, 5);
    let g = Graph::complete(4);
    let res = AsyncScheduler::new(AsyncParams {
        lambda: 1e-2,
        batch_size: 1,
        cycles: 200,
        cooldown: 200,
        local_steps: 1,
        project: true,
        seed: 3,
        max_lag: 2,
        link_latency: 0,
        link_drop: 0.0,
    })
    .run(shards, &g)
    .unwrap();
    let w_sum: f64 = res.mass_weights.iter().sum();
    assert!((w_sum - total_n).abs() < 1e-9 * total_n, "weight drift {w_sum}");
    for v in &res.mass_v {
        for &x in v {
            assert_eq!(x, 0.0, "mass appeared from nowhere");
        }
    }
}

#[test]
fn mass_state_invariants_under_random_exchange() {
    // Protocol-level property sweep: any sequence of halve/ship/absorb
    // over any membership keeps Σ v and Σ w invariant.
    let mut rng = Rng::new(900);
    for case in 0..40 {
        let m = rng.range(2, 8);
        let d = rng.range(1, 6);
        let mut masses: Vec<MassState> =
            (0..m).map(|_| MassState::new(d, rng.range(1, 50) as f64)).collect();
        // give each node a nonzero folded vector
        for mass in masses.iter_mut() {
            let w_est: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            mass.fold(&w_est);
        }
        let total_w: f64 = masses.iter().map(|s| s.w).sum();
        let total_v: Vec<f64> =
            (0..d).map(|k| masses.iter().map(|s| s.v[k]).sum()).collect();
        // random exchange sequence, including self-sends
        for _ in 0..rng.range(10, 120) {
            let from = rng.below(m);
            let to = rng.below(m);
            let (hv, hw) = masses[from].split_half();
            masses[to].absorb(&hv, hw);
        }
        let now_w: f64 = masses.iter().map(|s| s.w).sum();
        assert!(
            (now_w - total_w).abs() < 1e-9 * total_w,
            "case {case}: weight drift"
        );
        for k in 0..d {
            let now: f64 = masses.iter().map(|s| s.v[k]).sum();
            assert!(
                (now - total_v[k]).abs() < 1e-9 * (1.0 + total_v[k].abs()),
                "case {case} slot {k}: mass drift"
            );
        }
    }
}

#[test]
fn async_end_to_end_through_runner_learns() {
    let cfg = ExperimentConfig {
        scheduler: SchedulerKind::Async,
        max_iterations: 400,
        trials: 1,
        ..base_cfg()
    };
    let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
    assert!(report.test_accuracy > 0.7, "async accuracy {}", report.test_accuracy);
    assert!(report.trials[0].gossip.messages > 0);
}
