//! Integration tests for the three-layer stack: L3 coordinator running the
//! AOT-compiled JAX/Pallas artifact through PJRT.
//!
//! Skipped (with a message) when `artifacts/` has not been built — run
//! `make artifacts` first. CI runs `make test`, which builds them.

use gadget::config::{Backend, ExperimentConfig};
use gadget::coordinator::GadgetRunner;
use gadget::runtime::{artifacts_dir, ArtifactRegistry};

fn artifacts_ready() -> bool {
    match ArtifactRegistry::load(artifacts_dir()) {
        Ok(reg) => reg.check_files().is_ok(),
        Err(_) => {
            eprintln!("skipping xla integration: run `make artifacts` first");
            false
        }
    }
}

fn cfg(backend: Backend, batch: usize, steps: usize) -> ExperimentConfig {
    ExperimentConfig::builder()
        .dataset("synthetic-usps") // d = 256, exact artifact dim
        .scale(0.05)
        .nodes(3)
        .batch_size(batch)
        .local_steps(steps)
        .trials(1)
        .max_iterations(120)
        .seed(31)
        .backend(backend)
        .build()
        .unwrap()
}

#[test]
fn gadget_with_xla_backend_learns() {
    if !artifacts_ready() {
        return;
    }
    let report = GadgetRunner::new(cfg(Backend::Xla, 8, 4)).unwrap().run().unwrap();
    assert!(
        report.test_accuracy > 0.7,
        "xla-backend accuracy {}",
        report.test_accuracy
    );
}

#[test]
fn xla_and_native_backends_agree_end_to_end() {
    if !artifacts_ready() {
        return;
    }
    let xla = GadgetRunner::new(cfg(Backend::Xla, 1, 1)).unwrap().run().unwrap();
    let native = GadgetRunner::new(cfg(Backend::Native, 1, 1)).unwrap().run().unwrap();
    // identical batch streams, f32-vs-f64 rounding only
    assert!(
        (xla.test_accuracy - native.test_accuracy).abs() < 0.05,
        "xla {} vs native {}",
        xla.test_accuracy,
        native.test_accuracy
    );
    assert!(
        (xla.objective - native.objective).abs() < 0.05 * native.objective.max(0.1),
        "objective xla {} vs native {}",
        xla.objective,
        native.objective
    );
}

#[test]
fn padding_path_works() {
    if !artifacts_ready() {
        return;
    }
    // adult has d = 123 → pads to the 256 artifact
    let cfg = ExperimentConfig::builder()
        .dataset("synthetic-adult")
        .scale(0.02)
        .nodes(3)
        .batch_size(1)
        .local_steps(1)
        .trials(1)
        .max_iterations(100)
        .seed(5)
        .backend(Backend::Xla)
        .build()
        .unwrap();
    let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
    assert!(report.test_accuracy > 0.6, "padded accuracy {}", report.test_accuracy);
}

#[test]
fn oversize_dimension_is_clear_error() {
    if !artifacts_ready() {
        return;
    }
    // reuters d = 8315 exceeds every shipped artifact dim
    let cfg = ExperimentConfig::builder()
        .dataset("synthetic-reuters")
        .scale(0.02)
        .nodes(2)
        .trials(1)
        .backend(Backend::Xla)
        .build()
        .unwrap();
    let err = GadgetRunner::new(cfg).unwrap().run().unwrap_err().to_string();
    assert!(err.contains("no artifact"), "{err}");
}

#[test]
fn objective_eval_artifact_roundtrip() {
    if !artifacts_ready() {
        return;
    }
    // Execute the objective_eval artifact directly and compare against the
    // rust metrics on the same block.
    use gadget::data::synthetic::{generate, spec_by_name};
    use gadget::runtime::PjrtExecutable;
    let reg = ArtifactRegistry::load(artifacts_dir()).unwrap();
    let entry = reg.select("objective_eval", 256, 256, 1).unwrap();
    let mut exe = PjrtExecutable::compile_file(reg.resolve(entry)).unwrap();

    let split = generate(&spec_by_name("usps").unwrap(), 9, 0.05);
    let ds = &split.train;
    let n = 256usize;
    let idx: Vec<usize> = (0..n).map(|i| i % ds.len()).collect();
    let (x, y) = ds.dense_batch(&idx, 256);
    let mut rng = gadget::rng::Rng::new(4);
    let w: Vec<f64> = (0..256).map(|_| 0.1 * rng.normal()).collect();
    let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
    let lam = [1e-3f32];

    let out = exe
        .execute_f32(&[
            (&w32, &[256]),
            (&x, &[n as i64, 256]),
            (&y, &[n as i64]),
            (&lam, &[1]),
        ])
        .unwrap();
    assert_eq!(out.len(), 2);
    // rust-side reference on the same block
    let rows: Vec<gadget::linalg::SparseVec> = idx
        .iter()
        .map(|&i| ds.rows[i].clone())
        .collect();
    let labels: Vec<i8> = idx.iter().map(|&i| ds.labels[i]).collect();
    let block = gadget::data::Dataset::new("block", 256, rows, labels);
    let want_obj = gadget::metrics::objective(&w, &block, 1e-3);
    let want_err = gadget::metrics::zero_one_error(&w, &block);
    assert!(
        (out[0][0] as f64 - want_obj).abs() < 1e-4 * (1.0 + want_obj),
        "objective {} vs {}",
        out[0][0],
        want_obj
    );
    assert!(
        (out[1][0] as f64 - want_err).abs() < 1e-6,
        "error {} vs {}",
        out[1][0],
        want_err
    );
}
