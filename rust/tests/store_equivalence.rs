//! The streaming-data-plane refactor's bitwise contract for the *static*
//! path.
//!
//! PR history: the data plane used to be "load once, `horizontal_split`
//! once, every `NodeState` owns its shard". The `ShardStore` refactor
//! moved row ownership into a store and made every consumer borrow
//! `ShardView`s instead. The acceptance criterion is that the static
//! path is **bit-for-bit unchanged** — so this suite re-implements the
//! pre-refactor trial loop (one-shot split, owned shards, plain
//! ε-check) from public primitives and pins the `StaticStore`-driven
//! runner against it: same consensus weights, same iteration count,
//! same per-node accuracies, bit for bit.
//!
//! This is a *golden* test in the only form that survives refactors of
//! the harness itself: the golden values are recomputed from the frozen
//! reference loop, not from a checked-in number dump, so any divergence
//! of the new data plane from the old pipeline fails loudly.

use gadget::config::ExperimentConfig;
use gadget::coordinator::{
    GadgetRunner, GossipProtocol, NativeBackend, NodeState, ProtocolParams,
};
use gadget::data::partition::horizontal_split;
use gadget::data::{ShardStore, StaticStore};
use gadget::gossip::PushVector;
use gadget::metrics;
use gadget::rng::Rng;
use gadget::topology::{mixing_time, Graph, TransitionMatrix};

/// Seed labels the runner mixes in (frozen constants of the trial loop —
/// `coordinator/gadget.rs` uses the same literals).
const GRAPH_SEED: u64 = 0x6772_6170_6800;
const TEST_SPLIT_LABEL: u64 = 0x7e57;

fn cfg() -> ExperimentConfig {
    ExperimentConfig::builder()
        .dataset("synthetic-usps")
        .scale(0.05)
        .nodes(5)
        .trials(1)
        .max_iterations(150)
        .epsilon(5e-3)
        .seed(11)
        .build()
        .unwrap()
}

/// The pre-refactor trial loop, reproduced: one-shot horizontal split,
/// per-node owned shards, sequential id-order stepping, plain ε-check.
/// Returns `(consensus_w, iterations, node_accuracy, epsilon_final)`.
fn pre_refactor_reference(
    cfg: &ExperimentConfig,
) -> (Vec<f64>, usize, Vec<f64>, f64) {
    let runner = GadgetRunner::new(cfg.clone()).unwrap();
    let train = runner.train_data().clone();
    let test = runner.test_data().clone();
    let lambda = runner.lambda();
    let m = cfg.nodes;
    let d = train.dim;
    let seed = cfg.seed; // trial 0's root seed

    let graph = Graph::generate(cfg.topology, m, seed ^ GRAPH_SEED);
    let b = TransitionMatrix::from_graph(&graph, cfg.weights);
    let rounds = if cfg.gossip_rounds > 0 {
        cfg.gossip_rounds
    } else {
        mixing_time(&b, cfg.gamma).min(10_000)
    };

    // the old data path: split everything before iteration 0
    let train_shards = horizontal_split(&train, m, seed).unwrap();
    let test_shards = horizontal_split(&test, m, seed ^ TEST_SPLIT_LABEL).unwrap();
    let shard_sizes: Vec<f64> = train_shards.iter().map(|s| s.len() as f64).collect();
    let root = Rng::new(seed);
    let mut nodes: Vec<NodeState> = test_shards
        .into_iter()
        .enumerate()
        .map(|(i, te)| NodeState::new(i, te, d, root.substream(i as u64)))
        .collect();

    let protocol = GossipProtocol::new(ProtocolParams::from_config(cfg, lambda));
    let mut backend = NativeBackend::default();
    let mut pv = PushVector::new_weighted(&vec![vec![0.0; d]; m], &shard_sizes);
    let mut iterations = 0usize;
    for t in 1..=cfg.max_iterations {
        iterations = t;
        for i in 0..m {
            protocol
                .local_step(&mut backend, train_shards[i].view(), &mut nodes[i], t)
                .unwrap();
        }
        pv.reset_weighted(nodes.iter().map(|n| n.w.as_slice()), &shard_sizes);
        pv.run_rounds(&b, rounds);
        for (i, node) in nodes.iter_mut().enumerate() {
            // inline consume side (the Mixer seam post-dates this frozen
            // reference loop): estimate, then the step-(h) projection
            pv.estimate_into(i, &mut node.w);
            if cfg.project_consensus {
                gadget::linalg::project_to_ball(&mut node.w, 1.0 / lambda.sqrt());
            }
            node.check_convergence(cfg.epsilon);
        }
        if nodes.iter().all(|n| n.converged) {
            break;
        }
    }

    let node_accuracy: Vec<f64> = nodes
        .iter()
        .map(|n| {
            metrics::accuracy(&n.w, if n.test_shard.is_empty() { &test } else { &n.test_shard })
        })
        .collect();
    let epsilon_final = nodes.iter().map(|n| n.last_delta).fold(0.0f64, f64::max);
    let mut consensus = vec![0.0; d];
    for n in &nodes {
        for (c, &x) in consensus.iter_mut().zip(&n.w) {
            *c += 1.0 * x; // mirror linalg::add_assign (axpy with a = 1)
        }
    }
    // mirror the runner's average_w: multiply by the reciprocal (a
    // division here would round differently and break the bitwise pin)
    let inv = 1.0 / m as f64;
    for c in consensus.iter_mut() {
        *c *= inv;
    }
    (consensus, iterations, node_accuracy, epsilon_final)
}

fn bits(w: &[f64]) -> Vec<u64> {
    w.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn static_store_training_is_bitwise_equal_to_pre_refactor_pipeline() {
    let cfg = cfg();
    let (golden_w, golden_iters, golden_acc, golden_eps) = pre_refactor_reference(&cfg);
    let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
    let t = &report.trials[0];
    assert_eq!(t.iterations, golden_iters, "iteration count diverged");
    assert_eq!(
        bits(&t.consensus_w),
        bits(&golden_w),
        "consensus_w diverged from the pre-refactor pipeline"
    );
    assert_eq!(
        bits(&t.node_accuracy),
        bits(&golden_acc),
        "node accuracies diverged"
    );
    assert_eq!(t.epsilon_final.to_bits(), golden_eps.to_bits(), "epsilon diverged");
}

#[test]
fn static_store_shards_are_exactly_the_horizontal_split() {
    // The store level of the same pin: `StaticStore::split` must expose
    // precisely the rows `horizontal_split` dealt, in order.
    let cfg = cfg();
    let runner = GadgetRunner::new(cfg.clone()).unwrap();
    let shards = horizontal_split(runner.train_data(), cfg.nodes, cfg.seed).unwrap();
    let store = StaticStore::split(runner.train_data(), cfg.nodes, cfg.seed).unwrap();
    assert_eq!(store.nodes(), cfg.nodes);
    let mut total = 0usize;
    for (i, sh) in shards.iter().enumerate() {
        let v = store.shard(i);
        let rows: Vec<_> = v.rows.iter().map(|r| r.to_owned()).collect();
        assert_eq!(rows, sh.rows, "node {i} rows");
        assert_eq!(v.labels, &sh.labels[..], "node {i} labels");
        total += v.len();
    }
    assert_eq!(total, runner.train_data().len());
}

// ---------------------------------------------------------------------------
// Out-of-core tier: the `pack:` data plane's bitwise contract.
//
// The mmap store serves borrowed CSR windows straight off the artifact;
// `store = "static"` materializes those *same* windows into heap shards.
// Training both on the same pack therefore pins the zero-copy kernels
// against the materialized path bit for bit — the mmap analogue of the
// pre-refactor golden above.
// ---------------------------------------------------------------------------

/// Packs the usps stand-in's training rows into a temp artifact, returning
/// the directory guard alongside the path (dropping it deletes the file).
fn packed_corpus() -> (gadget::util::TempDir, std::path::PathBuf) {
    use gadget::data::synthetic::{generate, spec_by_name};
    let spec = spec_by_name("synthetic-usps").unwrap();
    // same generation the `synthetic-usps` loader performs for seed 11
    let split = generate(&spec, 11 ^ 0xda7a, 0.05);
    let td = gadget::util::TempDir::new().unwrap();
    let path = td.path().join("usps.gpack");
    gadget::data::pack::pack_dataset(&split.train, &path).unwrap();
    (td, path)
}

fn pack_cfg(path: &std::path::Path, store: gadget::data::StoreKind) -> ExperimentConfig {
    ExperimentConfig {
        dataset: format!("pack:{}", path.display()),
        store,
        ..cfg()
    }
}

#[test]
fn mmap_store_training_is_bitwise_equal_to_materialized_static() {
    use gadget::data::StoreKind;
    let (_td, path) = packed_corpus();
    let mm = GadgetRunner::new(pack_cfg(&path, StoreKind::Mmap)).unwrap().run().unwrap();
    let st = GadgetRunner::new(pack_cfg(&path, StoreKind::Static)).unwrap().run().unwrap();
    let (a, b) = (&mm.trials[0], &st.trials[0]);
    assert_eq!(a.iterations, b.iterations, "iteration count diverged");
    assert_eq!(
        bits(&a.consensus_w),
        bits(&b.consensus_w),
        "mmap consensus_w diverged from the materialized static run"
    );
    assert_eq!(bits(&a.node_accuracy), bits(&b.node_accuracy), "node accuracies diverged");
    assert_eq!(a.epsilon_final.to_bits(), b.epsilon_final.to_bits(), "epsilon diverged");
    // `auto` on a pack resolves to the mmap plane
    let auto = GadgetRunner::new(pack_cfg(&path, StoreKind::Auto)).unwrap().run().unwrap();
    assert_eq!(bits(&auto.trials[0].consensus_w), bits(&a.consensus_w));
    // and the run actually learned something
    assert!(mm.test_accuracy > 0.7, "pack accuracy {}", mm.test_accuracy);
}

#[test]
fn mmap_training_is_deterministic_across_reopens() {
    use gadget::data::StoreKind;
    let (_td, path) = packed_corpus();
    let a = GadgetRunner::new(pack_cfg(&path, StoreKind::Mmap)).unwrap().run().unwrap();
    let b = GadgetRunner::new(pack_cfg(&path, StoreKind::Mmap)).unwrap().run().unwrap();
    assert_eq!(bits(&a.trials[0].consensus_w), bits(&b.trials[0].consensus_w));
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn damaged_packs_fail_loudly_not_quietly() {
    use gadget::data::PackFile;
    let (td, path) = packed_corpus();
    let full = std::fs::read(&path).unwrap();

    // truncated mid-payload: the header's byte count no longer matches
    let t = td.path().join("truncated.gpack");
    std::fs::write(&t, &full[..full.len() - 8]).unwrap();
    let err = PackFile::open(&t).unwrap_err().to_string();
    assert!(err.contains("truncated"), "unexpected error: {err}");

    // one flipped payload byte: caught by the checksum
    let mut corrupt = full.clone();
    let mid = 64 + (corrupt.len() - 64) / 2;
    corrupt[mid] ^= 0x40;
    let c = td.path().join("corrupt.gpack");
    std::fs::write(&c, &corrupt).unwrap();
    let err = PackFile::open(&c).unwrap_err().to_string();
    assert!(err.contains("checksum"), "unexpected error: {err}");

    // future format version: refused with a re-pack hint
    let mut vers = full.clone();
    vers[8] = 99;
    let v = td.path().join("version.gpack");
    std::fs::write(&v, &vers).unwrap();
    let err = PackFile::open(&v).unwrap_err().to_string();
    assert!(err.contains("version"), "unexpected error: {err}");

    // and a training run on a damaged pack dies at load, not mid-train
    let run = GadgetRunner::new(pack_cfg(&c, gadget::data::StoreKind::Auto));
    assert!(run.is_err(), "corrupt pack must fail at dataset load");
}

#[test]
fn streaming_store_differs_but_static_rerun_does_not() {
    // Sanity guard on the pin itself: re-running the static config is
    // stable, while turning the stream on genuinely changes the data
    // plane (so the equality above is not vacuous).
    let a = GadgetRunner::new(cfg()).unwrap().run().unwrap();
    let b = GadgetRunner::new(cfg()).unwrap().run().unwrap();
    assert_eq!(bits(&a.trials[0].consensus_w), bits(&b.trials[0].consensus_w));
    let streaming = ExperimentConfig { stream_rate: 3.0, stream_max_rows: 30, ..cfg() };
    let s = GadgetRunner::new(streaming).unwrap().run().unwrap();
    assert_ne!(
        bits(&a.trials[0].consensus_w),
        bits(&s.trials[0].consensus_w),
        "streaming run unexpectedly identical to the static run"
    );
}
