//! The two-tier kernel equivalence contract (`linalg::kernel`).
//!
//! Tier 1 — **bitwise**: the scalar backend is bit-for-bit the reference
//! loops (pinned transitively by `scheduler_equivalence`), and the
//! element-wise operations (`axpy`, `scale_add`, `axpy_sparse`,
//! `gemv_panel`) are bitwise identical on *every* backend because they
//! have exactly one evaluation order per output element.
//!
//! Tier 2 — **ULP-bounded**: the SIMD backend's reductions (`dot`,
//! `dot_sparse`, and everything built on them) reassociate, so instead of
//! bit equality they carry the documented bound
//!
//! ```text
//! |simd − scalar| ≤ 4·n·ε·Σ|products|        (ε = f64::EPSILON)
//! ```
//!
//! — within `4n` ulps of the absolute-product mass (see
//! `rust/src/linalg/kernel/simd.rs` for the derivation). This suite pins
//! both tiers on adversarial inputs: denormals, `-0.0`, mixed magnitudes
//! with heavy cancellation, and non-multiple-of-lane lengths. It runs in
//! the default build too — the SIMD *type* always compiles; only runtime
//! selection is feature-gated — so `--features simd` and the default
//! tier-1 run exercise identical arithmetic.

use gadget::linalg::kernel;
use gadget::linalg::SparseVec;
use gadget::rng::Rng;
use gadget::serve::{ModelArtifact, ScalingMeta, ShardedScorer};

/// The documented reduction bound: |a − b| ≤ 4·n·ε·mass (plus one
/// denormal quantum so zero-mass cases compare exactly-equal-or-equal).
fn assert_dot_bound(label: &str, n: usize, simd: f64, scalar: f64, abs_mass: f64) {
    let tol = 4.0 * n as f64 * f64::EPSILON * abs_mass + f64::MIN_POSITIVE;
    assert!(
        (simd - scalar).abs() <= tol,
        "{label}: n={n} |{simd} − {scalar}| = {} > {tol}",
        (simd - scalar).abs()
    );
}

/// Adversarial dense vector families, keyed by `family`.
fn adversarial(n: usize, family: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n)
        .map(|i| match family {
            // plain gaussian
            0 => rng.normal(),
            // mixed magnitudes with cancellation pressure
            1 => rng.normal() * 10f64.powi((i as i32 % 13) * 47 - 280),
            // denormals and negative zero interleaved
            2 => match i % 4 {
                0 => f64::MIN_POSITIVE * rng.normal(),
                1 => -0.0,
                2 => f64::MIN_POSITIVE / 8.0,
                _ => rng.normal() * 1e-300,
            },
            // alternating huge/tiny so lane partial sums straddle scales
            _ => {
                if i % 2 == 0 {
                    rng.normal() * 1e150
                } else {
                    rng.normal() * 1e-150
                }
            }
        })
        .collect()
}

#[test]
fn dense_dot_within_ulp_bound_on_adversarial_inputs() {
    let (s, v) = (kernel::scalar(), kernel::simd());
    let mut rng = Rng::new(41);
    // lengths straddle every lane phase of both backends (4- and 8-lane)
    for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 100, 1021] {
        for family in 0..4 {
            let x = adversarial(n, family, &mut rng);
            let y = adversarial(n, (family + 1) % 4, &mut rng);
            let mass: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
            assert_dot_bound(
                &format!("dot family {family}"),
                n,
                v.dot(&x, &y),
                s.dot(&x, &y),
                mass,
            );
        }
    }
}

#[test]
fn sparse_dot_within_ulp_bound_on_adversarial_inputs() {
    let (s, v) = (kernel::scalar(), kernel::simd());
    let mut rng = Rng::new(43);
    for nnz in [0usize, 1, 3, 4, 5, 7, 8, 9, 13, 40, 77] {
        for family in 0..4 {
            let dim = (nnz * 3).max(8);
            let w = adversarial(dim, family, &mut rng);
            let idx: Vec<u32> = if nnz == 0 {
                Vec::new()
            } else {
                rng.sorted_subset(dim, nnz)
            };
            let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
            let x = SparseVec::new(idx.clone(), vals.clone());
            let mass: f64 = idx
                .iter()
                .zip(&vals)
                .map(|(&i, &val)| (w[i as usize] * val as f64).abs())
                .sum();
            assert_dot_bound(
                &format!("dot_sparse family {family}"),
                nnz,
                v.dot_sparse(&x, &w),
                s.dot_sparse(&x, &w),
                mass,
            );
        }
    }
}

#[test]
fn element_wise_ops_are_bitwise_backend_invariant() {
    let (s, v) = (kernel::scalar(), kernel::simd());
    let mut rng = Rng::new(47);
    for n in [1usize, 7, 8, 23, 129] {
        for family in 0..4 {
            let x = adversarial(n, family, &mut rng);
            let base = adversarial(n, (family + 2) % 4, &mut rng);
            let (mut ys, mut yv) = (base.clone(), base.clone());
            s.axpy(-1.75, &x, &mut ys);
            v.axpy(-1.75, &x, &mut yv);
            for (a, b) in ys.iter().zip(&yv) {
                assert_eq!(a.to_bits(), b.to_bits(), "axpy n={n} family={family}");
            }
            s.scale_add(0.3, &mut ys, 2.5, &x);
            v.scale_add(0.3, &mut yv, 2.5, &x);
            for (a, b) in ys.iter().zip(&yv) {
                assert_eq!(a.to_bits(), b.to_bits(), "scale_add n={n} family={family}");
            }
            let nnz = (n / 2).max(1).min(n);
            let idx = rng.sorted_subset(n, nnz);
            let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
            let sp = SparseVec::new(idx, vals);
            s.axpy_sparse(0.6, &sp, &mut ys);
            v.axpy_sparse(0.6, &sp, &mut yv);
            for (a, b) in ys.iter().zip(&yv) {
                assert_eq!(a.to_bits(), b.to_bits(), "axpy_sparse n={n} family={family}");
            }
        }
    }
}

#[test]
fn gemv_panel_is_bitwise_backend_invariant() {
    let (s, v) = (kernel::scalar(), kernel::simd());
    let mut rng = Rng::new(53);
    let (rows, stride) = (6usize, 64usize);
    let src: Vec<f64> = (0..rows * stride).map(|_| rng.normal()).collect();
    // strided coefficient view with embedded zeros (the skip path)
    let mut coeffs: Vec<f64> = (0..rows * 3).map(|_| rng.normal()).collect();
    coeffs[3] = 0.0; // row 1 at stride 3
    for (off, width) in [(0usize, 64usize), (5, 17), (40, 24), (63, 1)] {
        let mut ds = vec![1.0f64; width];
        let mut dv = vec![1.0f64; width];
        s.gemv_panel(&mut ds, &coeffs, 3, rows, &src, stride, off);
        v.gemv_panel(&mut dv, &coeffs, 3, rows, &src, stride, off);
        for (a, b) in ds.iter().zip(&dv) {
            assert_eq!(a.to_bits(), b.to_bits(), "gemv_panel off={off} width={width}");
        }
    }
}

#[test]
fn hinge_violator_sets_agree_away_from_the_threshold() {
    // Margins are reductions, so the two backends may disagree on a
    // violator only when its margin sits within the dot bound of exactly
    // 1 — on generic data that band is empty and the sets must be equal.
    let (s, v) = (kernel::scalar(), kernel::simd());
    let mut rng = Rng::new(59);
    for case in 0..20 {
        let dim = rng.range(4, 40);
        let n = rng.range(3, 30);
        let rows: Vec<SparseVec> = (0..n)
            .map(|_| {
                let nnz = rng.range(1, dim.min(9));
                let idx = rng.sorted_subset(dim, nnz);
                let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
                SparseVec::new(idx, vals)
            })
            .collect();
        let labels: Vec<i8> = (0..n).map(|_| if rng.below(2) == 0 { 1 } else { -1 }).collect();
        let w: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let scale = 0.75;
        let batch: Vec<usize> = (0..n * 2).map(|_| rng.below(n)).collect();
        let (mut viol_s, mut viol_v) = (Vec::new(), Vec::new());
        let rv = gadget::linalg::RowsView::Vecs(&rows);
        s.hinge_subgrad_accum(&w, scale, rv, &labels, &batch, &mut viol_s);
        v.hinge_subgrad_accum(&w, scale, rv, &labels, &batch, &mut viol_v);
        // knife-edge guard: only accept a set mismatch if some margin is
        // within 1e-9 of the threshold (never happens on this data)
        if viol_s != viol_v {
            let near_edge = batch.iter().any(|&i| {
                let m = labels[i] as f64 * (scale * s.dot_sparse(&rows[i], &w));
                (m - 1.0).abs() < 1e-9
            });
            assert!(near_edge, "case {case}: violator sets diverged off-threshold");
        }
    }
}

fn toy_model(dim: usize, classes: usize, rng: &mut Rng) -> ModelArtifact {
    let weights: Vec<Vec<f64>> = (0..classes)
        .map(|_| (0..dim).map(|_| rng.normal()).collect())
        .collect();
    let bias = vec![0.0; classes];
    ModelArtifact::new(dim, weights, bias, ScalingMeta::default()).unwrap()
}

#[test]
fn serve_predictions_agree_across_kernels_on_synthetic_rows() {
    // The serve smoke contract: `--kernel scalar` and `--kernel simd`
    // decode the same labels on the synthetic corpus (scores differ only
    // within the dot bound; label flips require a knife-edge margin).
    let mut rng = Rng::new(61);
    for &classes in &[1usize, 3] {
        let model = toy_model(24, classes, &mut rng);
        let rows: Vec<SparseVec> = (0..60)
            .map(|_| {
                let nnz = rng.range(1, 10);
                let idx = rng.sorted_subset(24, nnz);
                let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
                SparseVec::new(idx, vals)
            })
            .collect();
        let scalar = ShardedScorer::new(model.clone(), 2);
        let simd = ShardedScorer::with_kernel(model, 3, kernel::simd());
        let a = scalar.score_batch(&rows).unwrap();
        let b = simd.score_batch(&rows).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            if x.label != y.label && classes == 1 {
                // a binary flip requires a margin within the dot bound of 0
                assert!(x.score.abs() < 1e-9, "row {i}: label flipped at |score| {}", x.score);
            }
            // Winning scores stay within the bound. (When multiclass labels
            // differ the winners are near-tied classes, so this covers the
            // "no flip on a clear margin" claim there too.)
            assert!(
                (x.score - y.score).abs() <= 1e-9 * (1.0 + x.score.abs()),
                "row {i}: score drift {} vs {}",
                x.score,
                y.score
            );
        }
    }
}

#[test]
fn scalar_backend_is_bitwise_the_reference_loops() {
    // ScalarKernel::dot/dot_sparse must be the exact free functions the
    // rest of the crate (linalg::dense::dot, SparseVec::dot_dense) runs —
    // the anchor of the tier-1 bitwise contract.
    let s = kernel::scalar();
    let mut rng = Rng::new(67);
    for n in [0usize, 1, 5, 7, 64, 257] {
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        assert_eq!(s.dot(&x, &y).to_bits(), gadget::linalg::dot(&x, &y).to_bits());
        if n > 0 {
            let nnz = (n / 2).max(1);
            let idx = rng.sorted_subset(n, nnz);
            let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
            let sp = SparseVec::new(idx, vals);
            assert_eq!(s.dot_sparse(&sp, &x).to_bits(), sp.dot_dense(&x).to_bits());
        }
    }
}

#[cfg(feature = "simd")]
mod simd_selected_end_to_end {
    //! Runs only under `--features simd`: the full trainer with
    //! `[runtime] kernel = "simd"` selected the supported way.

    use gadget::config::{ExperimentConfig, KernelKind, SchedulerKind};
    use gadget::coordinator::GadgetRunner;

    fn cfg(kernel: KernelKind) -> ExperimentConfig {
        ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.05)
            .nodes(4)
            .trials(1)
            .max_iterations(80)
            .epsilon(5e-3)
            .seed(7)
            .kernel(kernel)
            .build()
            .unwrap()
    }

    #[test]
    fn simd_kernel_trains_end_to_end_and_tracks_scalar() {
        let scalar = GadgetRunner::new(cfg(KernelKind::Scalar)).unwrap().run().unwrap();
        let simd = GadgetRunner::new(cfg(KernelKind::Simd)).unwrap().run().unwrap();
        // Different association ⇒ not bitwise; but the trajectory must
        // stay statistically equivalent on a learnable problem.
        assert!(simd.test_accuracy > 0.75, "simd accuracy {}", simd.test_accuracy);
        assert!(
            (simd.test_accuracy - scalar.test_accuracy).abs() < 0.1,
            "simd {} vs scalar {}",
            simd.test_accuracy,
            scalar.test_accuracy
        );
    }

    #[test]
    fn simd_parallel_is_bitwise_identical_to_simd_sequential() {
        // The Parallel ≡ Sequential contract holds per-kernel: parallelism
        // only moves work, whichever backend computes it.
        let seq = GadgetRunner::new(cfg(KernelKind::Simd)).unwrap().run().unwrap();
        let par_cfg = ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads: 3,
            ..cfg(KernelKind::Simd)
        };
        let par = GadgetRunner::new(par_cfg).unwrap().run().unwrap();
        assert_eq!(seq.iterations, par.iterations);
        for (a, b) in seq.trials[0].consensus_w.iter().zip(&par.trials[0].consensus_w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn auto_resolves_to_simd_under_the_feature() {
        assert_eq!(KernelKind::Auto.build().unwrap().name(), "simd");
    }
}
