//! Scaled-iterate ≡ dense step equivalence (`[runtime] step`).
//!
//! The scaled representation `w = s·v` (see `linalg::scaled`) computes
//! the *same* Pegasos/SVM-SGD recursion as the plain dense loop, but
//! factors every shrink into the scalar `s`. Each shrink therefore
//! rounds once in `s` instead of once per component, and each sparse
//! update divides by `s` before multiplying it back — so the two paths
//! are NOT bitwise identical; they are pinned within a **documented
//! error bound** (DESIGN.md §Scaled-iterate step): after `T` steps of a
//! sane schedule the per-component relative divergence is
//! O(T·ε_machine), asserted here as `1e-9` relative for runs up to ~10³
//! steps. What IS exact:
//!
//! * decoded predictions agree wherever the margin is off the decision
//!   threshold (the divergence is orders of magnitude below any real
//!   margin);
//! * the renormalization trigger (`|s| < RESCALE_THRESHOLD`) depends
//!   only on the shrink-factor sequence, so it fires at the same step
//!   index on every run — determinism asserted on adversarial
//!   denormal-range schedules;
//! * the dense path itself is scheduler-invariant *bitwise* — it rides
//!   the same per-node RNG-substream isolation as the scaled default,
//!   re-run by `ci.sh` at pool sizes 1 and 4 via `GADGET_POOL_THREADS`.

use gadget::config::{ExperimentConfig, KernelKind, SchedulerKind, StepKind};
use gadget::coordinator::GadgetRunner;
use gadget::data::synthetic::{generate, DatasetSpec};
use gadget::data::Dataset;
use gadget::linalg::scaled::RESCALE_THRESHOLD;
use gadget::linalg::{ScaledIterate, SparseVec};
use gadget::solver::{Pegasos, PegasosParams, Solver, SvmSgd, SvmSgdParams};

/// Relative per-component bound the scaled path is pinned to against the
/// dense reference, for runs up to ~10³ steps (DESIGN.md §Scaled-iterate
/// step derives the O(T·ε) shape).
const STEP_REL_BOUND: f64 = 1e-9;

fn problem(seed: u64) -> (Dataset, Dataset) {
    let spec = DatasetSpec {
        name: "step-eq".into(),
        train_size: 600,
        test_size: 300,
        features: 48,
        nnz_per_row: 9,
        noise: 0.03,
        positive_rate: 0.5,
        lambda: 1e-3,
    };
    let s = generate(&spec, seed, 1.0);
    (s.train, s.test)
}

/// Asserts per-component closeness under the documented relative bound
/// (absolute floor covers components that are themselves ~0).
fn assert_within_bound(scaled: &[f64], dense: &[f64], ctx: &str) {
    assert_eq!(scaled.len(), dense.len(), "{ctx}: dim mismatch");
    for (k, (&a, &b)) in scaled.iter().zip(dense).enumerate() {
        let tol = STEP_REL_BOUND * (1.0 + a.abs().max(b.abs()));
        assert!(
            (a - b).abs() <= tol,
            "{ctx}: slot {k} diverged beyond the documented bound: {a} vs {b}"
        );
    }
}

/// Pool sizes the end-to-end sweep runs at; `GADGET_POOL_THREADS=n` pins
/// one (ci.sh re-runs at 1 and 4, mirroring `scheduler_equivalence`).
fn pool_threads() -> Vec<usize> {
    match std::env::var("GADGET_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("GADGET_POOL_THREADS must be an integer")],
        Err(_) => vec![1, 2, 4],
    }
}

/// Step kind the scheduler-invariance sweep pins. Defaults to `dense` —
/// the newly-written path whose invariance is not already covered by
/// `scheduler_equivalence` (which runs the scaled default). Override
/// with `GADGET_STEP=dense|scaled|auto`.
fn sweep_step() -> StepKind {
    match std::env::var("GADGET_STEP") {
        Ok(v) => v.parse().expect("GADGET_STEP must be dense|scaled|auto"),
        Err(_) => StepKind::Dense,
    }
}

#[test]
fn pegasos_scaled_tracks_dense_within_documented_bound() {
    let (train, _) = problem(11);
    for batch_size in [1usize, 4] {
        let params = PegasosParams {
            lambda: 1e-3,
            iterations: 800,
            batch_size,
            project: true,
            seed: 5,
        };
        let scalar = gadget::linalg::kernel::scalar();
        let scaled =
            Pegasos::with_options(params.clone(), scalar, StepKind::Scaled).fit(&train);
        let dense =
            Pegasos::with_options(params.clone(), scalar, StepKind::Dense).fit(&train);
        assert_within_bound(&scaled.w, &dense.w, &format!("pegasos batch={batch_size}"));
        // identical parameters ⇒ each path is individually deterministic
        let again =
            Pegasos::with_options(params, scalar, StepKind::Dense).fit(&train);
        assert_eq!(
            dense.w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            again.w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "dense path must be deterministic (batch={batch_size})"
        );
    }
}

#[test]
fn svm_sgd_scaled_tracks_dense_within_documented_bound() {
    let (train, _) = problem(13);
    let params = SvmSgdParams { lambda: 1e-3, epochs: 2, seed: 7 };
    let scalar = gadget::linalg::kernel::scalar();
    let scaled =
        SvmSgd::with_options(params.clone(), scalar, StepKind::Scaled).fit(&train);
    let dense = SvmSgd::with_options(params, scalar, StepKind::Dense).fit(&train);
    assert_within_bound(&scaled.w, &dense.w, "svm-sgd");
}

#[test]
fn predictions_identical_off_threshold() {
    let (train, test) = problem(17);
    let params = PegasosParams {
        lambda: 1e-3,
        iterations: 4000,
        batch_size: 1,
        project: true,
        seed: 3,
    };
    let scalar = gadget::linalg::kernel::scalar();
    let m_scaled =
        Pegasos::with_options(params.clone(), scalar, StepKind::Scaled).fit(&train);
    let m_dense = Pegasos::with_options(params, scalar, StepKind::Dense).fit(&train);
    let mut compared = 0usize;
    for i in 0..test.len() {
        let (x, _) = test.sample(i);
        let s = m_dense.score(x);
        // off-threshold: margin far above the paths' divergence bound
        if s.abs() > 1e-6 {
            compared += 1;
            assert_eq!(
                m_dense.predict(x),
                m_scaled.predict(x),
                "row {i}: labels diverged at margin {s}"
            );
        }
    }
    // the threshold must not have vacuously excluded the whole test set
    assert!(compared > test.len() / 2, "only {compared} rows off-threshold");
}

#[test]
fn adversarial_denormal_schedule_matches_dense_mirror() {
    // Long shrink runs drive |s| through RESCALE_THRESHOLD repeatedly:
    // scale_by(1e-3) crosses 1e-120 every 40 steps. Sparse adds keep the
    // represented values O(1) so the dense mirror never underflows, and
    // the inputs mix magnitudes (1e-8 … 1e8) plus a −0.0.
    let d = 8;
    let init = [-0.0, 0.0, 1e-8, -1e8, 3.5, -2.25e-4, 7e6, 1.0];
    let x_a = SparseVec::new(vec![0, 2, 5], vec![1.0, -0.5, 2.0e4]);
    let x_b = SparseVec::new(vec![1, 3, 6, 7], vec![1e-6, 0.75, -3.0, 0.125]);
    let mut sv = ScaledIterate::from_dense(&init);
    let mut mirror = init.to_vec();
    let mut rescales = 0usize;
    let mut prev_scale = sv.scale();
    for step in 0..200 {
        sv.scale_by(1e-3);
        for m in mirror.iter_mut() {
            *m *= 1e-3;
        }
        // detect the fold: |s| jumps back to 1 after crossing the bound
        if sv.scale().abs() > prev_scale.abs() {
            rescales += 1;
            assert_eq!(sv.scale(), 1.0, "step {step}: fold must reset the scale to 1");
        }
        assert!(
            sv.scale().abs() >= RESCALE_THRESHOLD,
            "step {step}: scale {} left the documented range",
            sv.scale()
        );
        prev_scale = sv.scale();
        let (c, x) = if step % 2 == 0 { (0.5, &x_a) } else { (-0.25, &x_b) };
        sv.add_sparse(c, x);
        for (&idx, &val) in x.indices.iter().zip(&x.values) {
            mirror[idx as usize] += c * val as f64;
        }
    }
    // the schedule crossed the threshold several times (200 / 40 = 5)
    assert!(rescales >= 4, "only {rescales} rescues on a 200-step 1e-3 schedule");
    let got = sv.to_dense();
    assert_within_bound(&got, &mirror, "denormal schedule");
    assert_eq!(got.len(), d);
    for v in &got {
        assert!(v.is_finite());
    }
}

#[test]
fn renormalization_trigger_is_deterministic() {
    // Two identical op sequences must produce bit-identical states and
    // fold at the same step indices — the trigger depends only on the
    // shrink-factor sequence, never on data or timing.
    let run = || {
        let mut sv = ScaledIterate::from_dense(&[1.0, -0.5, 2.0]);
        let x = SparseVec::new(vec![0, 2], vec![1.0, -1.0]);
        let mut scale_trace = Vec::new();
        for step in 0..150 {
            sv.scale_by(1e-2);
            if step % 3 == 0 {
                sv.add_sparse(0.125, &x);
            }
            scale_trace.push(sv.scale().to_bits());
        }
        let dense: Vec<u64> = sv.to_dense().iter().map(|x| x.to_bits()).collect();
        (scale_trace, dense)
    };
    let (trace_a, dense_a) = run();
    let (trace_b, dense_b) = run();
    assert_eq!(trace_a, trace_b, "scale trajectory must be deterministic");
    assert_eq!(dense_a, dense_b, "materialized state must be deterministic");
    // the 1e-2 schedule crosses 1e-120 at step 60 and every 60 thereafter
    let folds: Vec<usize> = trace_a
        .iter()
        .enumerate()
        .filter(|(_, &bits)| f64::from_bits(bits) == 1.0)
        .map(|(i, _)| i)
        .collect();
    assert!(!folds.is_empty(), "no fold on a 150-step 1e-2 schedule");
}

#[test]
fn negative_zero_and_exact_scales_roundtrip_bitwise() {
    // Power-of-two scale factors are exact, so a scale-up/scale-down
    // pair must return the *bits* of the original vector — including the
    // sign of −0.0 (x · 1.0 preserves it).
    let init = [-0.0f64, 0.0, 1.5, -3.25, 1e-300, -1e150];
    let sv0 = ScaledIterate::from_dense(&init);
    let mut out = vec![0.0; init.len()];
    sv0.materialize_into(&mut out);
    for (k, (&a, &b)) in out.iter().zip(&init).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "slot {k} not preserved verbatim");
    }
    let mut sv = ScaledIterate::from_dense(&init);
    sv.scale_by(2.0);
    sv.scale_by(0.5);
    for (k, (&a, &b)) in sv.to_dense().iter().zip(&init).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "slot {k} changed under exact scales");
    }
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig::builder()
        .dataset("synthetic-usps")
        .scale(0.05)
        .nodes(5)
        .trials(1)
        .max_iterations(80)
        .epsilon(5e-3)
        .seed(31)
        .kernel(KernelKind::Scalar)
        .build()
        .unwrap()
}

#[test]
fn runner_step_is_scheduler_invariant_bitwise() {
    // The per-step representation is orthogonal to WHERE steps run: for
    // the pinned step kind, parallel must stay bitwise identical to
    // sequential (per-node RNG substreams isolate all randomness either
    // way). ci.sh re-runs this at pool sizes 1 and 4.
    let step = sweep_step();
    let seq = {
        let cfg = ExperimentConfig { step, ..base_cfg() };
        GadgetRunner::new(cfg).unwrap().run().unwrap()
    };
    for threads in pool_threads() {
        let cfg = ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads,
            step,
            ..base_cfg()
        };
        let par = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert_eq!(seq.iterations, par.iterations, "step={step} threads={threads}");
        for (ts, tp) in seq.trials.iter().zip(&par.trials) {
            assert_eq!(
                ts.consensus_w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                tp.consensus_w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "step={step} threads={threads}: consensus diverged"
            );
        }
    }
}

#[test]
fn runner_dense_and_scaled_agree_end_to_end() {
    // Full GADGET runs under the two representations: the per-step
    // divergence compounds through gossip, so the pin here is behavioral
    // — both converge, to the same accuracy within a loose band.
    let scaled = GadgetRunner::new(ExperimentConfig { step: StepKind::Scaled, ..base_cfg() })
        .unwrap()
        .run()
        .unwrap();
    let dense = GadgetRunner::new(ExperimentConfig { step: StepKind::Dense, ..base_cfg() })
        .unwrap()
        .run()
        .unwrap();
    assert!(scaled.test_accuracy > 0.7, "scaled: {}", scaled.test_accuracy);
    assert!(dense.test_accuracy > 0.7, "dense: {}", dense.test_accuracy);
    assert!(
        (scaled.test_accuracy - dense.test_accuracy).abs() < 0.05,
        "accuracies diverged: scaled {} vs dense {}",
        scaled.test_accuracy,
        dense.test_accuracy
    );
}

#[test]
fn async_scheduler_rejects_dense_step_loudly() {
    // The thread-per-node engine embeds scaled-step learners; a run
    // labeled step=dense must fail, not silently train scaled.
    let cfg = ExperimentConfig {
        scheduler: SchedulerKind::Async,
        step: StepKind::Dense,
        ..base_cfg()
    };
    let err = GadgetRunner::new(cfg).unwrap().run().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("step"), "{msg}");
    assert!(msg.contains("async"), "{msg}");
}
