//! Property-based tests over the coordinator's invariants.
//!
//! `proptest` is unavailable in the offline build, so these are hand-rolled
//! seeded property sweeps: each case draws many random instances from the
//! in-tree RNG and asserts the invariant on every draw. A failing seed is
//! printed, so cases reproduce exactly.

use gadget::data::synthetic::{generate, DatasetSpec};
use gadget::data::{partition, Dataset};
use gadget::gossip::{PushSum, PushVector, RandomizedGossip};
use gadget::linalg::SparseVec;
use gadget::rng::Rng;
use gadget::serve::{ModelArtifact, ScalingMeta, ShardedScorer};
use gadget::solver::ScaledVector;
use gadget::topology::stochastic::WeightScheme;
use gadget::topology::{Graph, TopologyKind, TransitionMatrix};

const CASES: u64 = 60;

fn random_connected_graph(rng: &mut Rng) -> Graph {
    let kinds = [
        TopologyKind::Complete,
        TopologyKind::Ring,
        TopologyKind::Torus,
        TopologyKind::KRegular,
        TopologyKind::SmallWorld,
        TopologyKind::ErdosRenyi,
    ];
    let kind = *rng.choose(&kinds);
    let n = rng.range(5, 24);
    Graph::generate(kind, n, rng.next_u64())
}

/// Property: every weight scheme on every generated graph produces a
/// transition matrix that is (a) stochastic as claimed, (b) supported only
/// on graph edges.
#[test]
fn prop_transition_matrices_are_valid() {
    let mut rng = Rng::new(100);
    for case in 0..CASES {
        let g = random_connected_graph(&mut rng);
        for scheme in [WeightScheme::MetropolisHastings, WeightScheme::MaxDegree] {
            let b = TransitionMatrix::from_graph(&g, scheme);
            assert!(
                b.is_doubly_stochastic(1e-9),
                "case {case}: {scheme:?} not doubly stochastic on n={}",
                g.n
            );
            assert!(b.respects_graph(&g), "case {case}: support violation");
        }
        let rw = TransitionMatrix::from_graph(&g, WeightScheme::RandomWalk);
        assert!(rw.row_error() < 1e-9, "case {case}: random walk not row-stochastic");
    }
}

/// Property: Push-Sum conserves total mass and weight for any graph, any
/// initial values, any number of rounds.
#[test]
fn prop_pushsum_mass_conservation() {
    let mut rng = Rng::new(200);
    for case in 0..CASES {
        let g = random_connected_graph(&mut rng);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let x: Vec<f64> = (0..g.n).map(|_| rng.normal() * 100.0).collect();
        let total: f64 = x.iter().sum();
        let mut ps = PushSum::new(&x);
        let rounds = rng.range(1, 60);
        for _ in 0..rounds {
            ps.round(&b);
        }
        assert!(
            (ps.total_sum() - total).abs() < 1e-8 * (1.0 + total.abs()),
            "case {case}: mass drift"
        );
        assert!(
            (ps.total_weight() - g.n as f64).abs() < 1e-9,
            "case {case}: weight drift"
        );
    }
}

/// Property: Push-Vector estimates converge toward the weighted average —
/// error after 4×τ rounds is strictly smaller than at the start, and the
/// conserved target equals the hand-computed weighted mean.
#[test]
fn prop_pushvector_converges_to_weighted_mean() {
    let mut rng = Rng::new(300);
    for case in 0..30 {
        let g = random_connected_graph(&mut rng);
        let d = rng.range(1, 8);
        let vectors: Vec<Vec<f64>> =
            (0..g.n).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let weights: Vec<f64> = (0..g.n).map(|_| rng.range(1, 50) as f64).collect();
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let mut pv = PushVector::new_weighted(&vectors, &weights);
        // hand-computed target
        let wsum: f64 = weights.iter().sum();
        let mut want = vec![0.0; d];
        for (v, &a) in vectors.iter().zip(&weights) {
            for k in 0..d {
                want[k] += a * v[k] / wsum;
            }
        }
        let target = pv.target();
        for k in 0..d {
            assert!((target[k] - want[k]).abs() < 1e-9, "case {case}: target mismatch");
        }
        let e0 = pv.max_rel_error();
        pv.run_rounds(&b, 80);
        let e1 = pv.max_rel_error();
        assert!(e1 < e0.max(1e-12), "case {case}: error {e0} -> {e1} did not shrink");
    }
}

/// Property: the randomized engine also conserves mass on arbitrary graphs.
#[test]
fn prop_randomized_gossip_mass_conservation() {
    let mut rng = Rng::new(400);
    for case in 0..30 {
        let g = random_connected_graph(&mut rng);
        let vectors: Vec<Vec<f64>> =
            (0..g.n).map(|_| vec![rng.normal() * 10.0, rng.normal()]).collect();
        let mut rgos = RandomizedGossip::new(&vectors, rng.next_u64());
        let t0 = rgos.target();
        for _ in 0..rng.range(1, 80) {
            rgos.round(&g);
        }
        let t1 = rgos.target();
        for k in 0..2 {
            assert!((t0[k] - t1[k]).abs() < 1e-9, "case {case}: target drift");
        }
    }
}

/// Property: `reset_weighted` with *grown* shard sizes — the streaming
/// data plane's re-weight rule — re-seeds the Push-Sum mass exactly:
/// Σwᵢ equals the new Σnᵢ bit for bit (both are the same ascending-`i`
/// summation of the same values), and estimates stay finite through any
/// interleaving of mixing rounds and re-weights. Extends the
/// `MassState::estimate_into` guard suite to the synchronous engine.
#[test]
fn prop_reset_weighted_reweight_conserves_mass_and_stays_finite() {
    let mut rng = Rng::new(4500);
    for case in 0..CASES {
        let g = random_connected_graph(&mut rng);
        let m = g.n;
        let d = rng.range(1, 8);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let mut sizes: Vec<f64> = (0..m).map(|_| rng.range(1, 40) as f64).collect();
        let vectors: Vec<Vec<f64>> =
            (0..m).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let mut pv = PushVector::new_weighted(&vectors, &sizes);
        for round in 0..rng.range(1, 5) {
            // mix, then "ingest": some shards grow, and the next
            // iteration re-weights the mass with the new sizes
            pv.run_rounds(&b, rng.range(1, 6));
            for s in sizes.iter_mut() {
                if rng.flip(0.5) {
                    *s += rng.range(1, 20) as f64;
                }
            }
            let fresh: Vec<Vec<f64>> =
                (0..m).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
            pv.reset_weighted(fresh.iter().map(|v| v.as_slice()), &sizes);
            // exact mass re-conservation across the re-weight
            let expect: f64 = sizes.iter().sum();
            assert_eq!(
                pv.total_weight().to_bits(),
                expect.to_bits(),
                "case {case} round {round}: Σnᵢ not re-seeded exactly"
            );
            // the re-weighted target is the new-size weighted mean
            let mut want = vec![0.0; d];
            for (v, &a) in fresh.iter().zip(&sizes) {
                for k in 0..d {
                    want[k] += a * v[k] / expect;
                }
            }
            let target = pv.target();
            for k in 0..d {
                assert!(
                    (target[k] - want[k]).abs() < 1e-9 * (1.0 + want[k].abs()),
                    "case {case} round {round}: target mismatch at {k}"
                );
            }
            // estimates remain finite after further mixing
            pv.run_rounds(&b, 3);
            for i in 0..m {
                assert!(
                    pv.estimate(i).iter().all(|x| x.is_finite()),
                    "case {case} round {round}: node {i} estimate not finite"
                );
                assert!(pv.weight(i).is_finite());
            }
        }
    }
}

/// Property: horizontal partitioning is a permutation — every sample
/// appears exactly once across shards, shard sizes differ by ≤ 1.
#[test]
fn prop_partition_is_permutation() {
    let mut rng = Rng::new(500);
    for case in 0..CASES {
        let n = rng.range(10, 400);
        let m = rng.range(1, n.min(20) + 1);
        let rows: Vec<SparseVec> =
            (0..n).map(|i| SparseVec::new(vec![0], vec![i as f32])).collect();
        let labels: Vec<i8> = (0..n).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let ds = Dataset::new("p", 1, rows, labels);
        let shards = partition::horizontal_split(&ds, m, rng.next_u64()).unwrap();
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), n, "case {case}");
        let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(hi - lo <= 1, "case {case}: imbalance {sizes:?}");
        let mut seen: Vec<f32> =
            shards.iter().flat_map(|s| s.rows.iter().map(|r| r.values[0])).collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(seen.len(), n);
        for (i, v) in seen.iter().enumerate() {
            assert_eq!(*v, i as f32, "case {case}: sample lost/duplicated");
        }
    }
}

/// Property: the scaled-vector representation tracks a naive dense vector
/// through arbitrary operation sequences.
#[test]
fn prop_scaled_vector_equals_naive() {
    let mut rng = Rng::new(600);
    for case in 0..CASES {
        let d = rng.range(1, 64);
        let mut sv = ScaledVector::zeros(d);
        let mut naive = vec![0.0f64; d];
        for _ in 0..rng.range(1, 60) {
            match rng.below(4) {
                0 => {
                    // random sparse add
                    let nnz = rng.range(1, d + 1);
                    let idx = rng.sorted_subset(d, nnz);
                    let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
                    let x = SparseVec::new(idx, vals);
                    let c = rng.normal();
                    sv.add_sparse(c, &x);
                    x.axpy_into(c, &mut naive);
                }
                1 => {
                    let c = 0.05 + rng.uniform(); // keep away from 0
                    sv.scale_by(c);
                    gadget::linalg::scale_assign(c, &mut naive);
                }
                2 => {
                    let r = 0.1 + 10.0 * rng.uniform();
                    sv.project_to_ball(r);
                    gadget::linalg::project_to_ball(&mut naive, r);
                }
                _ => {
                    sv.rescale();
                }
            }
        }
        let dense = sv.to_dense();
        let scale = gadget::linalg::l2_norm(&naive).max(1.0);
        for k in 0..d {
            assert!(
                (dense[k] - naive[k]).abs() < 1e-9 * scale,
                "case {case} slot {k}: {} vs {}",
                dense[k],
                naive[k]
            );
        }
        assert!(
            (sv.norm_sq() - gadget::linalg::l2_norm_sq(&naive)).abs() < 1e-7 * scale * scale,
            "case {case}: norm cache drift"
        );
    }
}

/// Property: GADGET node weight norms never exceed the Pegasos ball, at any
/// snapshot, for random small configs (the Algorithm 2 (f)/(h) invariant).
#[test]
fn prop_gadget_ball_invariant() {
    use gadget::config::ExperimentConfig;
    use gadget::coordinator::GadgetRunner;
    let mut rng = Rng::new(700);
    for case in 0..6 {
        let lambda = 10f64.powi(-(rng.range(2, 5) as i32));
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.02)
            .nodes(rng.range(2, 6))
            .lambda(lambda)
            .trials(1)
            .max_iterations(40)
            .snapshot_every(5)
            .seed(rng.next_u64())
            .build()
            .unwrap();
        let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
        // The consensus average of ball-bounded vectors is ball-bounded; the
        // recorded objective must therefore be finite and the run sane.
        assert!(report.objective.is_finite(), "case {case}");
        for p in &report.trials[0].trace.points {
            assert!(p.objective.is_finite() && p.objective >= 0.0, "case {case}");
        }
    }
}

/// Property: synthetic generation at different scales draws from the same
/// distribution family — feature stats stay put while N scales.
#[test]
fn prop_synthetic_scale_invariance() {
    let mut rng = Rng::new(800);
    for _ in 0..10 {
        let spec = DatasetSpec {
            name: "si".into(),
            train_size: 4000,
            test_size: 400,
            features: rng.range(16, 256),
            nnz_per_row: 8,
            noise: 0.05,
            positive_rate: 0.5,
            lambda: 1e-3,
        };
        let seed = rng.next_u64();
        let big = generate(&spec, seed, 0.5);
        let small = generate(&spec, seed, 0.1);
        assert_eq!(big.train.dim, small.train.dim);
        let nnz_big = big.train.total_nnz() as f64 / big.train.len() as f64;
        let nnz_small = small.train.total_nnz() as f64 / small.train.len() as f64;
        assert!((nnz_big - nnz_small).abs() < 0.5);
        assert_eq!(big.train.len(), 2000);
        assert_eq!(small.train.len(), 400);
    }
}

/// Shard counts the serve-equivalence sweep runs at. `GADGET_POOL_THREADS=n`
/// pins a single count — `ci.sh` uses this to re-run the sweep at pool
/// sizes 1 and 4, matching the scheduler-equivalence matrix.
fn serve_shard_counts() -> Vec<usize> {
    match std::env::var("GADGET_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("GADGET_POOL_THREADS must be an integer")],
        Err(_) => vec![1, 2, 3, 7],
    }
}

/// A random model artifact: binary (one weight row) or multiclass
/// (2–5 rows), random dimension, random finite weights and biases.
fn random_artifact(rng: &mut Rng) -> ModelArtifact {
    let dim = rng.range(1, 40);
    let classes = if rng.flip(0.5) { 1 } else { rng.range(2, 6) };
    let weights: Vec<Vec<f64>> = (0..classes)
        .map(|_| (0..dim).map(|_| rng.normal()).collect())
        .collect();
    let bias: Vec<f64> = (0..classes).map(|_| rng.normal() * 0.1).collect();
    ModelArtifact::new(dim, weights, bias, ScalingMeta::default()).unwrap()
}

/// A random scoring batch over `dim` features (possibly empty rows).
fn random_batch(rng: &mut Rng, dim: usize, n: usize) -> Vec<SparseVec> {
    (0..n)
        .map(|_| {
            let nnz = rng.below(dim + 1);
            let idx = if nnz == 0 { Vec::new() } else { rng.sorted_subset(dim, nnz) };
            let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
            SparseVec::new(idx, vals)
        })
        .collect()
}

/// Property: batch scoring through N shards is bitwise identical to
/// single-shard sequential scoring for any shard count — including
/// shards > rows and empty batches — on both binary and multiclass
/// models. (The serve acceptance contract; `ci.sh` re-runs this at
/// `GADGET_POOL_THREADS` 1 and 4.)
#[test]
fn prop_sharded_scoring_matches_single_shard_bitwise() {
    let mut rng = Rng::new(1000);
    let shard_counts = serve_shard_counts();
    for case in 0..12 {
        let model = random_artifact(&mut rng);
        let dim = model.dim;
        // batch sizes stress the chunking: empty, 1, below/above shard
        // counts, and a larger remainder-heavy size
        for n in [0usize, 1, 3, 8, 41] {
            let batch = random_batch(&mut rng, dim, n);
            let reference =
                ShardedScorer::new(model.clone(), 1).score_batch(&batch).unwrap();
            assert_eq!(reference.len(), n);
            for &shards in &shard_counts {
                // the swept count, and a count strictly above the row
                // count so surplus replicas must idle harmlessly
                let narrow = ShardedScorer::new(model.clone(), shards);
                let wide = ShardedScorer::new(model.clone(), shards.max(n + 3));
                for scorer in [&narrow, &wide] {
                    let got = scorer.score_batch(&batch).unwrap();
                    assert_eq!(got.len(), n, "case {case} shards {}", scorer.shards());
                    for (r, g) in reference.iter().zip(&got) {
                        assert_eq!(r.label, g.label, "case {case} shards {}", scorer.shards());
                        assert_eq!(
                            r.score.to_bits(),
                            g.score.to_bits(),
                            "case {case} shards {}: score bits diverged",
                            scorer.shards()
                        );
                    }
                }
            }
        }
    }
}

/// Property: argmax decoding is invariant under row order — scoring a
/// permuted batch equals permuting the scored batch, for any model,
/// batch and shard count.
#[test]
fn prop_argmax_decoding_invariant_under_row_order() {
    let mut rng = Rng::new(1100);
    for case in 0..10 {
        let model = random_artifact(&mut rng);
        let n = rng.range(2, 30);
        let batch = random_batch(&mut rng, model.dim, n);
        // a random permutation via seeded shuffle
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        let permuted: Vec<SparseVec> = perm.iter().map(|&i| batch[i].clone()).collect();
        let shards = *rng.choose(&[1usize, 2, 5]);
        let scorer = ShardedScorer::new(model, shards);
        let direct = scorer.score_batch(&batch).unwrap();
        let shuffled = scorer.score_batch(&permuted).unwrap();
        for (slot, &src) in perm.iter().enumerate() {
            assert_eq!(
                direct[src], shuffled[slot],
                "case {case}: row {src} changed under permutation"
            );
        }
    }
}

/// Property: asynchronous runs never emit non-finite estimates or mass —
/// for any topology, cycle budget, staleness bound and seed, every
/// reported weight vector and push-sum weight is finite. (The guard in
/// `MassState::estimate_into` freezes a node at its last finite estimate
/// if its push-sum weight ever collapses to zero/denormal instead of
/// letting inf/NaN propagate into `consensus_w`.)
#[test]
fn prop_async_runs_never_emit_non_finite_weights() {
    use gadget::coordinator::sched::{AsyncParams, AsyncScheduler};
    let mut rng = Rng::new(900);
    for case in 0..8 {
        let g = random_connected_graph(&mut rng);
        let m = g.n;
        let spec = DatasetSpec {
            name: "finite".into(),
            train_size: 40 * m,
            test_size: 20,
            features: rng.range(8, 24),
            nnz_per_row: 4,
            noise: 0.03,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        let shards =
            partition::horizontal_split(&generate(&spec, rng.next_u64(), 1.0).train, m, case)
                .unwrap();
        let cycles = rng.range(50, 300);
        let res = AsyncScheduler::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 2,
            cycles,
            cooldown: cycles / 8,
            local_steps: 1,
            project: true,
            seed: rng.next_u64(),
            max_lag: rng.range(1, 6),
            link_latency: 0,
            link_drop: 0.0,
        })
        .run(shards, &g)
        .unwrap();
        for (i, w) in res.estimates.iter().enumerate() {
            assert!(
                w.iter().all(|x| x.is_finite()),
                "case {case}: node {i} estimate not finite"
            );
        }
        for (i, (v, w)) in res.mass_v.iter().zip(&res.mass_weights).enumerate() {
            assert!(w.is_finite(), "case {case}: node {i} mass weight {w}");
            assert!(
                v.iter().all(|x| x.is_finite()),
                "case {case}: node {i} mass vector not finite"
            );
        }
    }
}
