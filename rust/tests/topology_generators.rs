//! Contracts of the overlay generators behind `--topology`, including
//! the adversarial families the mixer seam sweeps (`power-law`,
//! `partition`).
//!
//! Three properties make a family usable as a gossip overlay scenario:
//!
//! * **seeded determinism** — the same `(n, seed)` must reproduce the
//!   same wiring (trial reproducibility), and varying the seed must
//!   actually vary the wiring for the random families;
//! * **doubly-stochastic `B`** — Theorem 1 needs rows *and* columns of
//!   the transition matrix to sum to one on whatever graph the
//!   generator emits, for both general-graph weight schemes;
//! * **spectral ordering** — the families must span the mixing range
//!   they are advertised for (`λ₂` ring > complete), and the
//!   partition-prone overlay must actually fracture when its single
//!   bridge is cut and heal when it returns.

use gadget::topology::stochastic::WeightScheme;
use gadget::topology::{mixing_time, second_eigenvalue, Graph, TopologyKind, TransitionMatrix};

/// Every family `Graph::generate` dispatches, including the seeded ones.
const ALL_KINDS: [TopologyKind; 8] = [
    TopologyKind::Complete,
    TopologyKind::Ring,
    TopologyKind::Torus,
    TopologyKind::KRegular,
    TopologyKind::SmallWorld,
    TopologyKind::ErdosRenyi,
    TopologyKind::PowerLaw,
    TopologyKind::Partition,
];

/// The families whose wiring depends on the seed.
const SEEDED_KINDS: [TopologyKind; 5] = [
    TopologyKind::KRegular,
    TopologyKind::SmallWorld,
    TopologyKind::ErdosRenyi,
    TopologyKind::PowerLaw,
    TopologyKind::Partition,
];

#[test]
fn generators_are_seed_deterministic_and_seed_sensitive() {
    for kind in ALL_KINDS {
        let a = Graph::generate(kind, 16, 42);
        let b = Graph::generate(kind, 16, 42);
        assert_eq!(a.adj, b.adj, "{kind}: same seed must reproduce the wiring");
        assert!(a.is_connected(), "{kind}: generator must emit a connected graph");
    }
    // varying the seed varies the wiring — some seed in a small window
    // must differ from seed 42's graph (a fixed pair could collide)
    for kind in SEEDED_KINDS {
        let base = Graph::generate(kind, 16, 42);
        let differs = (0..20u64).any(|s| Graph::generate(kind, 16, s).adj != base.adj);
        assert!(differs, "{kind}: 21 seeds produced identical wiring");
    }
}

#[test]
fn transition_matrices_are_doubly_stochastic_on_every_family() {
    // Theorem 1's consensus target is the uniform average only when B is
    // doubly stochastic — which MH and max-degree must deliver on *any*
    // emitted graph, hubs and near-bisections included.
    for kind in ALL_KINDS {
        let g = Graph::generate(kind, 18, 7);
        for scheme in [WeightScheme::MetropolisHastings, WeightScheme::MaxDegree] {
            let b = TransitionMatrix::from_graph(&g, scheme);
            assert!(
                b.is_doubly_stochastic(1e-9),
                "{kind}/{scheme:?}: row err {} col err {}",
                b.row_error(),
                b.col_error()
            );
            assert!(b.respects_graph(&g), "{kind}/{scheme:?}: B off the overlay support");
        }
    }
}

#[test]
fn power_law_concentrates_degree() {
    let g = Graph::power_law(80, 11);
    assert!(g.is_connected());
    // BA attachment: seed edge + 2 edges per arriving node
    assert_eq!(g.edge_count(), 1 + 2 * 78);
    // hubs exist: the max degree clears the attachment minimum widely,
    // while ring/torus never exceed degree 4
    assert!(g.max_degree() >= 8, "max degree {}", g.max_degree());
}

#[test]
fn partition_prone_fractures_on_bridge_cut_and_heals() {
    let n = 16;
    let g = Graph::partition_prone(n, 5);
    assert!(g.is_connected());
    let bridge = Graph::partition_bridge(n);

    // collect the undirected edge list, drop the bridge: disconnected
    let edges: Vec<(usize, usize)> = (0..n)
        .flat_map(|i| g.adj[i].iter().map(move |&j| (i, j)))
        .filter(|&(i, j)| i < j)
        .collect();
    assert!(edges.contains(&bridge), "bridge edge missing from the overlay");
    let cut: Vec<(usize, usize)> =
        edges.iter().copied().filter(|&e| e != bridge).collect();
    let fractured = Graph::from_edges(n, &cut);
    assert!(!fractured.is_connected(), "cutting the bridge must partition");
    // both halves stay internally connected (the damage is the cut, not
    // a shattered cluster): each cluster's ring guarantees this
    assert_eq!(fractured.diameter(), usize::MAX);

    // heal: re-add exactly the bridge and connectivity returns
    let mut healed = cut;
    healed.push(bridge);
    assert!(Graph::from_edges(n, &healed).is_connected(), "re-adding the bridge must heal");
}

#[test]
fn spectral_ordering_ring_vs_complete() {
    // the sweep's premise: ring is the worst mixer, complete the best
    let mh = |g: &Graph| TransitionMatrix::from_graph(g, WeightScheme::MetropolisHastings);
    let ring = mh(&Graph::ring(16));
    let complete = mh(&Graph::complete(16));
    let l2_ring = second_eigenvalue(&ring, 300);
    let l2_complete = second_eigenvalue(&complete, 300);
    assert!(
        l2_ring > l2_complete,
        "λ₂ ordering violated: ring {l2_ring} vs complete {l2_complete}"
    );
    assert!(l2_ring > 0.9 && l2_ring < 1.0, "ring λ₂ {l2_ring}");
    assert!(mixing_time(&ring, 0.01) > mixing_time(&complete, 0.01));
    // the adversarial families sit between the extremes but mix worse
    // than the complete graph
    for kind in [TopologyKind::PowerLaw, TopologyKind::Partition] {
        let b = mh(&Graph::generate(kind, 16, 3));
        let l2 = second_eigenvalue(&b, 300);
        assert!(
            l2 > l2_complete && l2 < 1.0,
            "{kind}: λ₂ {l2} outside (complete {l2_complete}, 1)"
        );
    }
}

#[test]
fn small_n_degenerate_cases_stay_sane() {
    // the documented degenerations: BA needs ≥3 nodes, partition ≥4
    for n in 1..4usize {
        let pl = Graph::power_law(n, 1);
        let pp = Graph::partition_prone(n, 1);
        assert!(pl.is_connected(), "power-law n={n}");
        assert!(pp.is_connected(), "partition n={n}");
    }
    // the bridge endpoint formula holds even on the smallest real case
    assert_eq!(Graph::partition_bridge(4), (0, 2));
}
