//! Integration tests: the full GADGET pipeline through the public API —
//! data generation → partitioning → topology → gossip training →
//! evaluation — plus config-file and LIBSVM entry points.

use gadget::config::ExperimentConfig;
use gadget::coordinator::GadgetRunner;
use gadget::data::libsvm;
use gadget::data::synthetic::{generate, spec_by_name};
use gadget::metrics;
use gadget::solver::{Pegasos, PegasosParams, Solver};
use gadget::topology::TopologyKind;

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig::builder()
        .dataset("synthetic-usps")
        .scale(0.05)
        .nodes(5)
        .trials(1)
        .max_iterations(400)
        .seed(11)
        .build()
        .unwrap()
}

#[test]
fn end_to_end_accuracy_parity_with_centralized() {
    let runner = GadgetRunner::new(base_cfg()).unwrap();
    let report = runner.run().unwrap();
    let mut peg = Pegasos::new(PegasosParams {
        lambda: runner.lambda(),
        iterations: 10_000,
        batch_size: 1,
        project: true,
        seed: 11,
    });
    let central = peg.fit(runner.train_data());
    let central_acc = metrics::accuracy(&central.w, runner.test_data());
    assert!(
        (report.test_accuracy - central_acc).abs() < 0.10,
        "gadget {} vs centralized {central_acc}",
        report.test_accuracy
    );
}

#[test]
fn every_topology_trains() {
    for topo in [
        TopologyKind::Complete,
        TopologyKind::Ring,
        TopologyKind::Torus,
        TopologyKind::KRegular,
        TopologyKind::SmallWorld,
        TopologyKind::ErdosRenyi,
    ] {
        let cfg = ExperimentConfig { topology: topo, ..base_cfg() };
        let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert!(
            report.test_accuracy > 0.6,
            "{topo}: accuracy {}",
            report.test_accuracy
        );
    }
}

#[test]
fn batch_and_fused_step_configs_train() {
    for (batch, steps) in [(4usize, 1usize), (1, 4), (8, 4)] {
        let cfg = ExperimentConfig {
            batch_size: batch,
            local_steps: steps,
            max_iterations: 200,
            ..base_cfg()
        };
        let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert!(
            report.test_accuracy > 0.6,
            "batch {batch} steps {steps}: accuracy {}",
            report.test_accuracy
        );
    }
}

#[test]
fn node_count_sweep_preserves_learning() {
    for nodes in [2usize, 5, 10, 20] {
        let cfg = ExperimentConfig { nodes, ..base_cfg() };
        let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert!(report.test_accuracy > 0.6, "m={nodes}: {}", report.test_accuracy);
    }
}

#[test]
fn libsvm_file_roundtrip_through_runner() {
    // Write a synthetic set as LIBSVM, then train via the `path:` loader.
    let tmp = gadget::util::TempDir::new().unwrap();
    let split = generate(&spec_by_name("usps").unwrap(), 3, 0.05);
    let path = tmp.path().join("usps_small.libsvm");
    libsvm::write_libsvm(&split.train, &path).unwrap();

    let cfg = ExperimentConfig::builder()
        .dataset(format!("path:{}", path.display()))
        .nodes(4)
        .lambda(1e-3) // file datasets carry no Table-2 default
        .trials(1)
        .max_iterations(300)
        .seed(1)
        .build()
        .unwrap();
    let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
    assert!(report.test_accuracy > 0.6, "accuracy {}", report.test_accuracy);
}

#[test]
fn missing_lambda_for_file_dataset_is_error() {
    let tmp = gadget::util::TempDir::new().unwrap();
    let split = generate(&spec_by_name("usps").unwrap(), 3, 0.02);
    let path = tmp.path().join("x.libsvm");
    libsvm::write_libsvm(&split.train, &path).unwrap();
    let cfg = ExperimentConfig::builder()
        .dataset(format!("path:{}", path.display()))
        .nodes(2)
        .trials(1)
        .build()
        .unwrap();
    assert!(GadgetRunner::new(cfg).is_err());
}

#[test]
fn config_file_to_training_pipeline() {
    let tmp = gadget::util::TempDir::new().unwrap();
    let cfg_path = tmp.path().join("run.toml");
    std::fs::write(
        &cfg_path,
        r#"
dataset = "synthetic-usps"
scale = 0.05
nodes = 4
trials = 1
max_iterations = 300
seed = 9
topology = "torus"
"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_toml_file(&cfg_path).unwrap();
    assert_eq!(cfg.topology, TopologyKind::Torus);
    let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
    assert!(report.test_accuracy > 0.6);
}

#[test]
fn unknown_dataset_is_helpful_error() {
    let cfg = ExperimentConfig::builder().dataset("synthetic-imagenet").build().unwrap();
    let err = match GadgetRunner::new(cfg) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected unknown-dataset error"),
    };
    assert!(err.contains("unknown dataset"), "{err}");
}

#[test]
fn anytime_property_objective_improves_with_budget() {
    // Doubling the iteration budget must not worsen the final objective.
    let short = ExperimentConfig { max_iterations: 60, epsilon: 1e-9, ..base_cfg() };
    let long = ExperimentConfig { max_iterations: 600, epsilon: 1e-9, ..base_cfg() };
    let r_short = GadgetRunner::new(short).unwrap().run().unwrap();
    let r_long = GadgetRunner::new(long).unwrap().run().unwrap();
    assert!(
        r_long.objective <= r_short.objective * 1.05,
        "objective {} -> {}",
        r_short.objective,
        r_long.objective
    );
}

#[test]
fn gisette_standin_is_hard() {
    // The paper's Gisette row is near-chance (55%/50%): our stand-in must
    // stay well below the easy datasets.
    let cfg = ExperimentConfig::builder()
        .dataset("synthetic-gisette")
        .scale(0.05)
        .nodes(4)
        .trials(1)
        .max_iterations(200)
        .seed(2)
        .build()
        .unwrap();
    let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
    assert!(
        report.test_accuracy < 0.75,
        "gisette should be hard, got {}",
        report.test_accuracy
    );
}
