//! Convergence vs topology: the mixer-seam sweep.
//!
//! Runs the same GADGET problem for every (overlay scenario × mixing
//! backend) pair and reports what the consensus layer actually cost:
//! GADGET iterations to ε, total consensus messages and bytes, and the
//! measured mixing rounds per iteration next to the spectral prediction
//! `τ(γ) = ln(m/γ)/(1 − λ₂)`. The overlay set deliberately spans the
//! spectral range — complete (best mixing) through ring (worst) plus the
//! adversarial families (`power-law` hubs, `partition` near-bisection) —
//! so the table shows how each backend degrades as λ₂ → 1.
//!
//! `gadget experiment topology` renders the table and writes
//! `results/topology.{csv,json}` (see EXPERIMENTS.md §Convergence vs
//! topology for the recipe).

use super::ExperimentOpts;
use crate::config::ExperimentConfig;
use crate::coordinator::{GadgetRunner, GRAPH_SEED};
use crate::gossip::MixerKind;
use crate::topology::stochastic::WeightScheme;
use crate::topology::{mixing_time, second_eigenvalue, Graph, TopologyKind, TransitionMatrix};
use crate::util::table::TextTable;
use crate::util::Json;
use crate::Result;

/// One (overlay, mixer) cell of the sweep.
#[derive(Clone, Debug)]
pub struct TopologySweepRow {
    /// Overlay family.
    pub topology: TopologyKind,
    /// Mixing backend.
    pub mixer: MixerKind,
    /// λ₂ of the MH transition matrix on the trial-0 graph.
    pub lambda2: f64,
    /// Spectral prediction `τ(γ)` for the config's γ.
    pub predicted_rounds: usize,
    /// Mixing rounds per GADGET iteration actually executed (measured:
    /// total consensus rounds / iterations).
    pub measured_rounds: f64,
    /// GADGET iterations to ε (mean over trials).
    pub iterations: f64,
    /// Final mean test accuracy (%).
    pub accuracy: f64,
    /// Total consensus messages in trial 0 (unified counting: one
    /// directed payload per edge per round — see `gossip::GossipStats`).
    pub messages: usize,
    /// Total consensus bytes in trial 0.
    pub bytes: usize,
}

/// The default overlay scenarios, ordered roughly best-to-worst mixing.
pub const SWEEP_TOPOLOGIES: [TopologyKind; 6] = [
    TopologyKind::Complete,
    TopologyKind::SmallWorld,
    TopologyKind::Torus,
    TopologyKind::Ring,
    TopologyKind::PowerLaw,
    TopologyKind::Partition,
];

/// The mixing backends under comparison.
pub const SWEEP_MIXERS: [MixerKind; 2] = [MixerKind::PushSum, MixerKind::GradientFlow];

/// Runs the full sweep. `opts.only` filters overlay names (e.g.
/// `--only ring,torus`), not datasets, for this experiment.
pub fn run(opts: &ExperimentOpts) -> Result<Vec<TopologySweepRow>> {
    sweep(opts, &SWEEP_TOPOLOGIES, &SWEEP_MIXERS)
}

/// Sweep driver over explicit scenario/backend sets (tests use a
/// reduced grid; `run` passes the defaults).
pub fn sweep(
    opts: &ExperimentOpts,
    topologies: &[TopologyKind],
    mixers: &[MixerKind],
) -> Result<Vec<TopologySweepRow>> {
    let mut rows = Vec::new();
    for &topo in topologies {
        if !opts.selected(&topo.to_string()) {
            continue;
        }
        // The spectral figures describe the trial-0 overlay, seeded
        // exactly as the runner seeds it.
        let cfg_probe = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(opts.scale)
            .nodes(opts.nodes)
            .topology(topo)
            .trials(1)
            .max_iterations(opts.max_iterations.min(500))
            .seed(opts.seed)
            .build()?;
        let g = Graph::generate(topo, cfg_probe.nodes, cfg_probe.seed ^ GRAPH_SEED);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let lambda2 = second_eigenvalue(&b, 300);
        let predicted = mixing_time(&b, cfg_probe.gamma);
        for &mixer in mixers {
            let cfg = ExperimentConfig { mixer, ..cfg_probe.clone() };
            let report = GadgetRunner::new(cfg)?.run()?;
            let gsp = report.trials[0].gossip;
            let iters = report.iterations.max(1.0);
            rows.push(TopologySweepRow {
                topology: topo,
                mixer,
                lambda2,
                predicted_rounds: predicted,
                measured_rounds: gsp.rounds as f64 / iters,
                iterations: report.iterations,
                accuracy: 100.0 * report.test_accuracy,
                messages: gsp.messages,
                bytes: gsp.bytes,
            });
        }
    }
    Ok(rows)
}

/// Renders the sweep table.
pub fn render(rows: &[TopologySweepRow]) -> TextTable {
    let mut t = TextTable::new(&[
        "Overlay",
        "Mixer",
        "lambda2",
        "tau pred",
        "rounds/iter",
        "iterations",
        "acc (%)",
        "messages",
        "gossip MB",
    ]);
    for r in rows {
        t.row(vec![
            r.topology.to_string(),
            r.mixer.to_string(),
            format!("{:.4}", r.lambda2),
            r.predicted_rounds.to_string(),
            format!("{:.1}", r.measured_rounds),
            format!("{:.0}", r.iterations),
            format!("{:.2}", r.accuracy),
            r.messages.to_string(),
            format!("{:.2}", r.bytes as f64 / 1e6),
        ]);
    }
    t
}

/// JSON artifact for `results/topology.json`.
pub fn to_json(rows: &[TopologySweepRow]) -> Json {
    Json::obj(vec![(
        "topology_sweep",
        Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj(vec![
                        ("topology", Json::Str(r.topology.to_string())),
                        ("mixer", Json::Str(r.mixer.to_string())),
                        ("lambda2", Json::Num(r.lambda2)),
                        ("predicted_rounds", Json::Num(r.predicted_rounds as f64)),
                        ("measured_rounds", Json::Num(r.measured_rounds)),
                        ("iterations", Json::Num(r.iterations)),
                        ("accuracy", Json::Num(r.accuracy)),
                        ("messages", Json::Num(r.messages as f64)),
                        ("bytes", Json::Num(r.bytes as f64)),
                    ])
                })
                .collect(),
        ),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> ExperimentOpts {
        ExperimentOpts {
            scale: 0.02,
            nodes: 6,
            trials: 1,
            seed: 9,
            max_iterations: 150,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_compares_mixers_on_one_overlay() {
        let rows = sweep(&opts(), &[TopologyKind::Ring], &SWEEP_MIXERS).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.accuracy > 70.0, "{}/{}: accuracy {}", r.topology, r.mixer, r.accuracy);
            assert!(r.messages > 0 && r.bytes > r.messages);
            assert!(r.measured_rounds > 0.0);
        }
        // both backends see the same overlay spectrum
        assert_eq!(rows[0].lambda2, rows[1].lambda2);
        assert_eq!(rows[0].predicted_rounds, rows[1].predicted_rounds);
        let text = render(&rows).render();
        assert!(text.contains("push-sum") && text.contains("gradient-flow"), "{text}");
        let json = to_json(&rows).to_pretty();
        assert!(json.contains("topology_sweep"), "{json}");
    }

    #[test]
    fn only_filter_selects_overlays() {
        let o = ExperimentOpts { only: vec!["ring".into()], ..opts() };
        let rows =
            sweep(&o, &[TopologyKind::Ring, TopologyKind::Complete], &[MixerKind::PushSum])
                .unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].topology, TopologyKind::Ring);
    }
}
