//! Figures 4.1–4.3: primal objective and zero-one test error versus
//! training wall-time, GADGET (node-average) against centralized Pegasos.
//!
//! Emits one CSV per dataset under `results/` plus an ASCII rendering so
//! the convergence shape is visible directly in the terminal — the paper's
//! qualitative claim is that the distributed objective decays to (near)
//! the centralized curve and the algorithm is *anytime*.

use super::ExperimentOpts;
use crate::config::ExperimentConfig;
use crate::coordinator::GadgetRunner;
use crate::data::synthetic::paper_specs;
use crate::metrics::{self, Trace, TracePoint};
use crate::solver::{Pegasos, PegasosParams};
use crate::util::Stopwatch;
use crate::Result;

/// Convergence traces for one dataset.
#[derive(Clone, Debug)]
pub struct FigureSeries {
    /// Dataset name.
    pub dataset: String,
    /// GADGET node-average trace.
    pub gadget: Trace,
    /// Centralized Pegasos trace.
    pub pegasos: Trace,
}

/// Runs the figure experiment on every (selected) dataset.
pub fn run(opts: &ExperimentOpts) -> Result<Vec<FigureSeries>> {
    let mut out = Vec::new();
    for spec in paper_specs() {
        if spec.name.contains("gisette") || !opts.selected(&spec.name) {
            continue;
        }
        let cfg = ExperimentConfig::builder()
            .dataset(&spec.name)
            .scale(opts.scale)
            .nodes(opts.nodes)
            .trials(1)
            .seed(opts.seed)
            .max_iterations(opts.max_iterations)
            .snapshot_every(snapshot_cadence(opts.max_iterations))
            .build()?;
        out.push(run_dataset(&cfg)?);
    }
    Ok(out)
}

/// ≈ 40 snapshot points across the run.
pub fn snapshot_cadence(max_iterations: usize) -> usize {
    (max_iterations / 40).max(1)
}

/// Runs one dataset's pair of traces.
pub fn run_dataset(cfg: &ExperimentConfig) -> Result<FigureSeries> {
    let runner = GadgetRunner::new(cfg.clone())?;
    let report = runner.run()?;
    let gadget = report.trials[0].trace.clone();

    // Centralized Pegasos trace at a matching snapshot budget.
    let train = runner.train_data();
    let test = runner.test_data();
    let iters = super::table3::centralized_iterations(train.len());
    let peg = Pegasos::new(PegasosParams {
        lambda: runner.lambda(),
        iterations: iters,
        batch_size: 1,
        project: true,
        seed: cfg.seed,
    });
    let mut pegasos = Trace::new(format!("pegasos-{}", cfg.dataset));
    let sw = Stopwatch::new();
    peg.fit_with_snapshots(train.view(), (iters / 40).max(1), |step, w| {
        pegasos.push(TracePoint {
            time_secs: sw.secs(),
            step,
            objective: metrics::objective(w, train, runner.lambda()),
            test_error: metrics::zero_one_error(w, test),
        });
    });

    Ok(FigureSeries { dataset: cfg.dataset.clone(), gadget, pegasos })
}

/// Merges both traces into one CSV document.
pub fn to_csv(s: &FigureSeries) -> String {
    let mut out = s.gadget.to_csv();
    // skip the second header
    let peg = s.pegasos.to_csv();
    if let Some(ix) = peg.find('\n') {
        out.push_str(&peg[ix + 1..]);
    }
    out
}

/// ASCII plot: objective (log-ish autoscale) vs time for both series.
pub fn ascii_plot(s: &FigureSeries, width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64, char)> = s
        .gadget
        .points
        .iter()
        .map(|p| (p.time_secs, p.objective, 'g'))
        .chain(s.pegasos.points.iter().map(|p| (p.time_secs, p.objective, 'p')))
        .collect();
    if pts.is_empty() {
        return String::from("(no points)\n");
    }
    let tmax = pts.iter().map(|p| p.0).fold(0.0f64, f64::max).max(1e-12);
    let ymin = pts.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let ymax = pts.iter().map(|p| p.1).fold(0.0f64, f64::max).max(ymin + 1e-12);
    let mut grid = vec![vec![' '; width]; height];
    for (t, y, c) in pts {
        let x = ((t / tmax) * (width - 1) as f64).round() as usize;
        let ry = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
        let row = height - 1 - ry.min(height - 1);
        let cell = &mut grid[row][x.min(width - 1)];
        *cell = if *cell == ' ' || *cell == c { c } else { '*' };
    }
    let mut out = format!(
        "{}: objective vs time  [g = GADGET, p = Pegasos, * = both]  y∈[{:.4},{:.4}] t∈[0,{:.2}s]\n",
        s.dataset, ymin, ymax, tmax
    );
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_decay_and_render() {
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.02)
            .nodes(3)
            .trials(1)
            .seed(8)
            .max_iterations(150)
            .epsilon(1e-4) // force full run for a long trace
            .snapshot_every(10)
            .build()
            .unwrap();
        let s = run_dataset(&cfg).unwrap();
        assert!(s.gadget.points.len() >= 3, "gadget points {}", s.gadget.points.len());
        assert!(s.pegasos.points.len() >= 3);
        // the anytime claim: late objective ≤ early objective for GADGET
        let first = s.gadget.points.first().unwrap().objective;
        let last = s.gadget.points.last().unwrap().objective;
        assert!(last <= first * 1.05, "objective rose: {first} -> {last}");
        // renderers don't panic and contain both series
        let csv = to_csv(&s);
        assert!(csv.contains("gadget-") && csv.contains("pegasos-"));
        let plot = ascii_plot(&s, 60, 12);
        assert!(plot.contains('g') || plot.contains('*'));
    }

    #[test]
    fn ascii_plot_empty_series() {
        let s = FigureSeries {
            dataset: "x".into(),
            gadget: Trace::new("g"),
            pegasos: Trace::new("p"),
        };
        assert_eq!(ascii_plot(&s, 10, 5), "(no points)\n");
    }
}
