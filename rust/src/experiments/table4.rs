//! Table 4: GADGET vs the online baselines SVM-Perf and SVM-SGD.
//!
//! Per the paper's protocol (§4.5.2), the baselines run *independently on
//! each node's shard* — a "distributed execution without communication" —
//! and we report the node-averaged test accuracy and per-node training
//! time. GADGET columns come from the same runner as Table 3.

use super::ExperimentOpts;
use crate::config::ExperimentConfig;
use crate::coordinator::GadgetRunner;
use crate::data::synthetic::paper_specs;
use crate::data::{partition, ShardStore, StaticStore};
use crate::metrics::{self, node_trial_std};
use crate::solver::{Solver, SvmPerf, SvmPerfParams, SvmSgd, SvmSgdParams};
use crate::util::table::{pm, TextTable};
use crate::util::{Json, Stopwatch};
use crate::Result;

/// One Table-4 row.
#[derive(Clone, Debug)]
pub struct Table4Row {
    /// Dataset name.
    pub dataset: String,
    /// GADGET (time s, std), (acc %, std).
    pub gadget: (f64, f64, f64, f64),
    /// SVM-Perf per-node (time s, std), (acc %, std).
    pub svm_perf: (f64, f64, f64, f64),
    /// SVM-SGD per-node (time s, std), (acc %, std).
    pub svm_sgd: (f64, f64, f64, f64),
}

/// Runs Table 4 for every (selected) paper dataset.
pub fn run(opts: &ExperimentOpts) -> Result<Vec<Table4Row>> {
    let mut rows = Vec::new();
    for spec in paper_specs() {
        if spec.name.contains("gisette") || !opts.selected(&spec.name) {
            continue;
        }
        let cfg = ExperimentConfig::builder()
            .dataset(&spec.name)
            .scale(opts.scale)
            .nodes(opts.nodes)
            .trials(opts.trials)
            .seed(opts.seed)
            .max_iterations(opts.max_iterations)
            .build()?;
        rows.push(run_dataset(&cfg)?);
    }
    Ok(rows)
}

/// Per-node baseline protocol: split train/test across `m` nodes (one
/// [`StaticStore`] per trial — the same shared `validate_split` rule as
/// the runner), fit the solver on each shard *view*, evaluate on the
/// node's test shard. Returns `(time mean, time std, acc mean, acc std)`
/// with the paper's node+trial variance rule for accuracy.
fn per_node_baseline<S: Solver>(
    make: impl Fn(u64) -> S,
    runner: &GadgetRunner,
    cfg: &ExperimentConfig,
) -> Result<(f64, f64, f64, f64)> {
    let mut acc_matrix: Vec<Vec<f64>> = Vec::new();
    let mut times: Vec<f64> = Vec::new();
    for trial in 0..cfg.trials {
        let seed = cfg.seed.wrapping_add(trial as u64 * 0x51);
        let train_store = StaticStore::split(runner.train_data(), cfg.nodes, seed)?;
        let test_shards =
            partition::horizontal_split(runner.test_data(), cfg.nodes, seed ^ 0x7e57)?;
        let mut node_acc = Vec::with_capacity(cfg.nodes);
        let mut node_secs = Vec::with_capacity(cfg.nodes);
        for (node, te) in test_shards.iter().enumerate() {
            let mut solver = make(seed);
            let sw = Stopwatch::new();
            let model = solver.fit_view(train_store.shard(node));
            node_secs.push(sw.secs());
            node_acc.push(100.0 * metrics::accuracy(&model.w, te));
        }
        times.push(node_secs.iter().sum::<f64>() / node_secs.len() as f64);
        acc_matrix.push(node_acc);
    }
    let (t_mean, t_std) = crate::util::timer::mean_std(&times);
    let (a_mean, a_std) = node_trial_std(&acc_matrix);
    Ok((t_mean, t_std, a_mean, a_std))
}

/// Runs one dataset's three-way comparison.
pub fn run_dataset(cfg: &ExperimentConfig) -> Result<Table4Row> {
    let runner = GadgetRunner::new(cfg.clone())?;
    let report = runner.run()?;
    let lambda = runner.lambda();

    let perf = per_node_baseline(
        |_| {
            SvmPerf::new(SvmPerfParams {
                lambda,
                epsilon: 1e-3,
                max_cuts: 150,
                qp_sweeps: 100,
            })
        },
        &runner,
        cfg,
    )?;
    let sgd = per_node_baseline(
        |seed| SvmSgd::new(SvmSgdParams { lambda, epochs: 10, seed }),
        &runner,
        cfg,
    )?;

    Ok(Table4Row {
        dataset: cfg.dataset.clone(),
        gadget: (
            report.train_secs,
            report.train_secs_std,
            100.0 * report.test_accuracy,
            100.0 * report.test_accuracy_std,
        ),
        svm_perf: perf,
        svm_sgd: sgd,
    })
}

/// Renders the paper's Table-4 layout.
pub fn render(rows: &[Table4Row]) -> TextTable {
    let mut t = TextTable::new(&[
        "Dataset",
        "GADGET T(s)",
        "GADGET Acc%",
        "SVMPerf T(s)",
        "SVMPerf Acc%",
        "SVM-SGD T(s)",
        "SVM-SGD Acc%",
    ]);
    for r in rows {
        t.row(vec![
            r.dataset.clone(),
            pm(r.gadget.0, r.gadget.1, 3),
            pm(r.gadget.2, r.gadget.3, 2),
            pm(r.svm_perf.0, r.svm_perf.1, 3),
            pm(r.svm_perf.2, r.svm_perf.3, 2),
            pm(r.svm_sgd.0, r.svm_sgd.1, 3),
            pm(r.svm_sgd.2, r.svm_sgd.3, 2),
        ]);
    }
    t
}

/// JSON report.
pub fn to_json(rows: &[Table4Row]) -> Json {
    let quad = |(a, b, c, d): (f64, f64, f64, f64)| {
        Json::obj(vec![
            ("secs", Json::Num(a)),
            ("secs_std", Json::Num(b)),
            ("acc", Json::Num(c)),
            ("acc_std", Json::Num(d)),
        ])
    };
    Json::obj(vec![(
        "table4",
        Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj(vec![
                        ("dataset", Json::Str(r.dataset.clone())),
                        ("gadget", quad(r.gadget)),
                        ("svm_perf", quad(r.svm_perf)),
                        ("svm_sgd", quad(r.svm_sgd)),
                    ])
                })
                .collect(),
        ),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_way_comparison_shape() {
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.02)
            .nodes(3)
            .trials(1)
            .seed(9)
            .max_iterations(120)
            .epsilon(5e-3)
            .build()
            .unwrap();
        let row = run_dataset(&cfg).unwrap();
        // All three must beat chance clearly on the separable stand-in.
        assert!(row.gadget.2 > 65.0, "gadget {}", row.gadget.2);
        assert!(row.svm_perf.2 > 65.0, "svm-perf {}", row.svm_perf.2);
        assert!(row.svm_sgd.2 > 65.0, "svm-sgd {}", row.svm_sgd.2);
        let text = render(&[row.clone()]).render();
        assert!(text.contains("usps"));
        assert!(to_json(&[row]).to_string().contains("svm_perf"));
    }
}
