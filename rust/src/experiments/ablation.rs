//! Beyond-paper ablations grounding the theory sections:
//!
//! * [`pushsum_topology`] — measured Push-Sum rounds-to-γ across topology
//!   families vs the spectral estimate `τ(γ) = ln(m/γ)/(1 − λ₂)`,
//!   validating the `O(τ_mix · log 1/γ)` convergence claim (paper §3 /
//!   Lemma 2) and the qualitative ordering complete < expander < torus <
//!   ring.
//! * [`bound_check`] — Theorem 2's sub-optimality bound
//!   `f(w̄/T) − f(w*) ≤ 2c/√λ + c²log T/(2Tλ) + (2/√λ)(γR/√λ + γR)`
//!   evaluated empirically: `f(w*)` from the DCD reference solver, `f(w̄)`
//!   from a GADGET run's averaged iterates. The bound is loose (as the
//!   paper's constants are); the check asserts the *gap is positive and
//!   shrinking in T*, which is the falsifiable content.
//! * [`gossip_rounds_sweep`] — accuracy/time as a function of the number of
//!   Push-Sum rounds per GADGET iteration (the paper fixes this via
//!   Peersim cycles; the sweep shows the communication/consensus tradeoff).

use crate::config::ExperimentConfig;
use crate::coordinator::GadgetRunner;
use crate::gossip::PushSum;
use crate::rng::Rng;
use crate::topology::stochastic::WeightScheme;
use crate::topology::{mixing_time, second_eigenvalue, Graph, TopologyKind, TransitionMatrix};
use crate::util::table::TextTable;
use crate::Result;

/// One topology's mixing measurement.
#[derive(Clone, Debug)]
pub struct MixingRow {
    /// Topology family.
    pub topology: TopologyKind,
    /// Network size.
    pub m: usize,
    /// Second-largest eigenvalue modulus of `B`.
    pub lambda2: f64,
    /// Spectral rounds estimate for the γ target.
    pub predicted_rounds: usize,
    /// Measured rounds to reach max-relative-error ≤ γ.
    pub measured_rounds: usize,
}

/// Measures Push-Sum convergence across topology families.
pub fn pushsum_topology(m: usize, gamma: f64, seed: u64) -> Result<Vec<MixingRow>> {
    let kinds = [
        TopologyKind::Complete,
        TopologyKind::KRegular,
        TopologyKind::Torus,
        TopologyKind::Ring,
    ];
    let mut rng = Rng::new(seed);
    let x: Vec<f64> = (0..m).map(|_| rng.normal() * 10.0).collect();
    let mut rows = Vec::new();
    for kind in kinds {
        let g = Graph::generate(kind, m, seed);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let lambda2 = second_eigenvalue(&b, 300);
        let predicted = mixing_time(&b, gamma);
        let mut ps = PushSum::new(&x);
        let measured = ps.run_to_gamma(&b, gamma, 200_000);
        rows.push(MixingRow {
            topology: kind,
            m,
            lambda2,
            predicted_rounds: predicted,
            measured_rounds: measured,
        });
    }
    Ok(rows)
}

/// Renders the mixing table.
pub fn render_mixing(rows: &[MixingRow]) -> TextTable {
    let mut t = TextTable::new(&["Topology", "m", "lambda2", "predicted rounds", "measured rounds"]);
    for r in rows {
        t.row(vec![
            r.topology.to_string(),
            r.m.to_string(),
            format!("{:.4}", r.lambda2),
            r.predicted_rounds.to_string(),
            r.measured_rounds.to_string(),
        ]);
    }
    t
}

/// Theorem-2 check result.
#[derive(Clone, Debug)]
pub struct BoundCheck {
    /// Iterations T of the GADGET run.
    pub t: usize,
    /// Empirical sub-optimality `f(w̄) − f(w*)`.
    pub gap: f64,
    /// Theorem 2 right-hand side (with c = 1, R = 1, γ = gossip γ).
    pub bound: f64,
}

/// Runs GADGET at several iteration budgets and reports the empirical
/// sub-optimality against the Theorem-2 bound.
pub fn bound_check(cfg_base: &ExperimentConfig, budgets: &[usize]) -> Result<Vec<BoundCheck>> {
    let mut out = Vec::new();
    for &t_budget in budgets {
        let cfg = ExperimentConfig {
            max_iterations: t_budget,
            epsilon: 1e-12, // force the full budget
            trials: 1,
            snapshot_every: 0,
            ..cfg_base.clone()
        };
        let runner = GadgetRunner::new(cfg.clone())?;
        let report = runner.run()?;
        let lambda = runner.lambda();
        // f(w̄): mean node objective at stop (node vectors ≈ consensus).
        let f_gadget = report.objective;
        // f(w*): DCD reference optimum.
        let mut dcd = crate::solver::DualCoordinateDescent::new(lambda, 400, 1e-10, cfg.seed);
        let opt = crate::solver::Solver::fit(&mut dcd, runner.train_data());
        let f_star = crate::metrics::objective(&opt.w, runner.train_data(), lambda);
        let gap = f_gadget - f_star;
        // Theorem 2 RHS with c = 1 (unit-norm rows ⇒ sub-gradient bound ≈ 1
        // after projection), R = 1, γ = cfg.gamma.
        let (c, r) = (1.0f64, 1.0f64);
        let t = t_budget as f64;
        let bound = 2.0 * c / lambda.sqrt()
            + c * c * t.ln() / (2.0 * t * lambda)
            + (2.0 / lambda.sqrt()) * (cfg.gamma * r / lambda.sqrt() + cfg.gamma * r);
        out.push(BoundCheck { t: t_budget, gap, bound });
    }
    Ok(out)
}

/// Renders the bound table.
pub fn render_bound(rows: &[BoundCheck]) -> TextTable {
    let mut t = TextTable::new(&["T", "f(w̄) − f(w*)", "Theorem-2 bound", "bound holds"]);
    for r in rows {
        t.row(vec![
            r.t.to_string(),
            format!("{:.6}", r.gap),
            format!("{:.3}", r.bound),
            (r.gap <= r.bound).to_string(),
        ]);
    }
    t
}

/// One gossip-rounds sweep point.
#[derive(Clone, Debug)]
pub struct RoundsSweepRow {
    /// Push-Sum rounds per GADGET iteration.
    pub rounds: usize,
    /// Final mean accuracy (%).
    pub accuracy: f64,
    /// Mean training seconds.
    pub secs: f64,
    /// Gossip bytes shipped in trial 0.
    pub bytes: usize,
}

/// Sweeps the per-iteration gossip rounds.
pub fn gossip_rounds_sweep(
    cfg_base: &ExperimentConfig,
    rounds: &[usize],
) -> Result<Vec<RoundsSweepRow>> {
    let mut out = Vec::new();
    for &r in rounds {
        let cfg = ExperimentConfig { gossip_rounds: r, ..cfg_base.clone() };
        let report = GadgetRunner::new(cfg)?.run()?;
        out.push(RoundsSweepRow {
            rounds: r,
            accuracy: 100.0 * report.test_accuracy,
            secs: report.train_secs,
            bytes: report.trials[0].gossip.bytes,
        });
    }
    Ok(out)
}

/// Renders the sweep table.
pub fn render_sweep(rows: &[RoundsSweepRow]) -> TextTable {
    let mut t = TextTable::new(&["rounds/iter", "accuracy (%)", "time (s)", "gossip MB"]);
    for r in rows {
        t.row(vec![
            r.rounds.to_string(),
            format!("{:.2}", r.accuracy),
            format!("{:.3}", r.secs),
            format!("{:.2}", r.bytes as f64 / 1e6),
        ]);
    }
    t
}

/// One row of the churn-resilience study (paper §5: "resilience to node
/// failures").
#[derive(Clone, Debug)]
pub struct ChurnRow {
    /// Per-iteration failure probability.
    pub p_fail: f64,
    /// Accuracy under churn (%).
    pub accuracy: f64,
    /// Minimum simultaneous alive nodes.
    pub min_alive: usize,
    /// Membership changes applied.
    pub events: usize,
    /// Final consensus disagreement among alive nodes.
    pub disagreement: f64,
}

/// Sweeps transient-failure intensity.
pub fn churn_resilience(cfg_base: &ExperimentConfig, p_fails: &[f64]) -> Result<Vec<ChurnRow>> {
    use crate::coordinator::churn::{run_with_churn, ChurnSchedule};
    let mut rows = Vec::new();
    for &p in p_fails {
        let schedule = if p > 0.0 {
            ChurnSchedule::random(cfg_base.nodes, cfg_base.max_iterations, p, 5.0 * p, cfg_base.seed)
        } else {
            ChurnSchedule::default()
        };
        let report = run_with_churn(cfg_base, &schedule)?;
        rows.push(ChurnRow {
            p_fail: p,
            accuracy: 100.0 * report.test_accuracy,
            min_alive: report.min_alive,
            events: report.events_applied,
            disagreement: report.disagreement,
        });
    }
    Ok(rows)
}

/// Renders the churn table.
pub fn render_churn(rows: &[ChurnRow]) -> TextTable {
    let mut t =
        TextTable::new(&["p_fail/iter", "acc (%)", "min alive", "events", "disagreement"]);
    for r in rows {
        t.row(vec![
            format!("{:.3}", r.p_fail),
            format!("{:.2}", r.accuracy),
            r.min_alive.to_string(),
            r.events.to_string(),
            format!("{:.4}", r.disagreement),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixing_ordering_matches_theory() {
        let rows = pushsum_topology(16, 1e-3, 3).unwrap();
        let get = |k: TopologyKind| rows.iter().find(|r| r.topology == k).unwrap().measured_rounds;
        let complete = get(TopologyKind::Complete);
        let torus = get(TopologyKind::Torus);
        let ring = get(TopologyKind::Ring);
        assert!(complete <= torus, "complete {complete} vs torus {torus}");
        assert!(torus < ring, "torus {torus} vs ring {ring}");
        // the spectral estimate is a sane upper-ballpark: within ~10x
        for r in &rows {
            if r.predicted_rounds != usize::MAX && r.measured_rounds > 0 {
                let ratio = r.predicted_rounds as f64 / r.measured_rounds as f64;
                assert!(ratio > 0.1 && ratio < 50.0, "{:?}: ratio {ratio}", r.topology);
            }
        }
        assert!(render_mixing(&rows).render().contains("ring"));
    }

    #[test]
    fn theorem2_bound_holds_and_gap_positive() {
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.02)
            .nodes(3)
            .seed(12)
            .build()
            .unwrap();
        let checks = bound_check(&cfg, &[50, 200]).unwrap();
        for c in &checks {
            assert!(c.gap >= -1e-6, "negative gap {}", c.gap);
            assert!(c.gap <= c.bound, "bound violated: gap {} > bound {}", c.gap, c.bound);
        }
        // gap shrinks (or stays) with bigger T
        assert!(checks[1].gap <= checks[0].gap + 0.05);
    }

    #[test]
    fn churn_sweep_degrades_gracefully() {
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.02)
            .nodes(6)
            .trials(1)
            .max_iterations(200)
            .seed(6)
            .build()
            .unwrap();
        let rows = churn_resilience(&cfg, &[0.0, 0.02]).unwrap();
        assert_eq!(rows[0].events, 0);
        assert!(rows[1].events > 0);
        // churn costs a bounded number of points, not collapse
        assert!(
            rows[1].accuracy > rows[0].accuracy - 20.0,
            "collapse under churn: {} -> {}",
            rows[0].accuracy,
            rows[1].accuracy
        );
        assert!(render_churn(&rows).render().contains("p_fail"));
    }

    #[test]
    fn rounds_sweep_monotone_bytes() {
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.02)
            .nodes(4)
            .trials(1)
            .seed(13)
            .max_iterations(60)
            .build()
            .unwrap();
        let rows = gossip_rounds_sweep(&cfg, &[1, 4]).unwrap();
        assert!(rows[1].bytes > rows[0].bytes);
        assert!(render_sweep(&rows).render().contains("rounds/iter"));
    }
}
