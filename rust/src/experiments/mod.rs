//! Experiment harness: one driver per table / figure of the paper's
//! evaluation section (see DESIGN.md per-experiment index).
//!
//! * [`table3`] — GADGET vs centralized Pegasos (model-build time, accuracy).
//! * [`table4`] — GADGET vs SVM-Perf vs SVM-SGD run per-node.
//! * [`table5`] — Table 3 including data-loading time + speed-up factor,
//!   with the Gisette stand-in added.
//! * [`figures`] — objective & 0/1-error vs wall-time traces (Figs 4.1–4.3).
//! * [`ablation`] — beyond-paper studies: Push-Sum rounds-to-γ vs topology
//!   (validating the `O(τ_mix log 1/γ)` claim) and the Theorem-2
//!   sub-optimality bound check against the DCD optimum.
//! * [`topology`] — convergence vs topology: mixing backends (push-sum,
//!   gradient-flow) swept over the overlay scenarios, with measured vs
//!   spectrally-predicted rounds and message/byte budgets.
//!
//! Every driver prints the paper's rows as an aligned table and writes
//! CSV/JSON under `results/`.

pub mod ablation;
pub mod figures;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod topology;

use crate::Result;
use std::path::{Path, PathBuf};

/// Common options for experiment drivers.
#[derive(Clone, Debug)]
pub struct ExperimentOpts {
    /// Sample-count scale for the synthetic corpora (1.0 = paper size;
    /// the default keeps a full table run in minutes on one core).
    pub scale: f64,
    /// Nodes in the network (paper: 10).
    pub nodes: usize,
    /// Trials per dataset (paper: 5).
    pub trials: usize,
    /// Root seed.
    pub seed: u64,
    /// Output directory for CSV/JSON.
    pub out_dir: PathBuf,
    /// Restrict to these dataset names (empty = all).
    pub only: Vec<String>,
    /// Iteration cap per trial.
    pub max_iterations: usize,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        Self {
            scale: 0.05,
            nodes: 10,
            trials: 5,
            seed: 17,
            out_dir: PathBuf::from("results"),
            only: Vec::new(),
            max_iterations: 1_500,
        }
    }
}

impl ExperimentOpts {
    /// True when `name` passes the `only` filter.
    pub fn selected(&self, name: &str) -> bool {
        self.only.is_empty()
            || self.only.iter().any(|o| {
                let o = o.strip_prefix("synthetic-").unwrap_or(o);
                let n = name.strip_prefix("synthetic-").unwrap_or(name);
                o == n
            })
    }

    /// Ensures the output directory exists and returns a file path in it.
    pub fn out_file(&self, name: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)?;
        Ok(self.out_dir.join(name))
    }
}

/// Writes text to a file, creating parents.
pub fn write_output(path: &Path, text: &str) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(path, text)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_filter() {
        let mut o = ExperimentOpts::default();
        assert!(o.selected("synthetic-usps"));
        o.only = vec!["usps".into()];
        assert!(o.selected("synthetic-usps"));
        assert!(o.selected("usps"));
        assert!(!o.selected("synthetic-adult"));
        o.only = vec!["synthetic-adult".into()];
        assert!(o.selected("adult"));
    }

    #[test]
    fn out_file_creates_dir() {
        let tmp = crate::util::TempDir::new().unwrap();
        let o = ExperimentOpts { out_dir: tmp.path().join("r"), ..Default::default() };
        let p = o.out_file("x.csv").unwrap();
        assert!(p.parent().unwrap().is_dir());
    }
}
