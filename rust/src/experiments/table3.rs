//! Table 3: GADGET SVM vs centralized Pegasos — classification accuracy and
//! model-construction time (data loading excluded), k = 10 nodes, 5 trials,
//! ε = 0.001, λ per Table 2.

use super::ExperimentOpts;
use crate::config::ExperimentConfig;
use crate::coordinator::GadgetRunner;
use crate::data::synthetic::paper_specs;
use crate::metrics;
use crate::solver::{Pegasos, PegasosParams, Solver};
use crate::util::table::{pm, TextTable};
use crate::util::timer::mean_std;
use crate::util::{Json, Stopwatch};
use crate::Result;

/// One Table-3 row.
#[derive(Clone, Debug)]
pub struct Table3Row {
    /// Dataset name.
    pub dataset: String,
    /// GADGET mean model-build time (s).
    pub gadget_secs: f64,
    /// Std over trials.
    pub gadget_secs_std: f64,
    /// GADGET mean accuracy (%) over nodes × trials.
    pub gadget_acc: f64,
    /// Combined `sqrt(Var(Nodes)+Var(Trials))` std (%).
    pub gadget_acc_std: f64,
    /// Centralized Pegasos mean time (s).
    pub pegasos_secs: f64,
    /// Std over trials.
    pub pegasos_secs_std: f64,
    /// Centralized Pegasos mean accuracy (%).
    pub pegasos_acc: f64,
    /// Std over trials.
    pub pegasos_acc_std: f64,
    /// GADGET ε at convergence (mean over trials).
    pub epsilon_final: f64,
    /// Data-loading seconds (reused by Table 5).
    pub load_secs: f64,
}

/// Centralized-Pegasos iteration budget for a dataset of `n` samples: the
/// paper runs Pegasos to its convergence regime; `max(10k, 2n)` single-
/// sample steps lands in the `O(1/λδ)` band for every Table-2 λ at the
/// scales we run.
pub fn centralized_iterations(n: usize) -> usize {
    (2 * n).max(10_000)
}

/// Runs the Table-3 comparison for every (selected) paper dataset.
pub fn run(opts: &ExperimentOpts) -> Result<Vec<Table3Row>> {
    let mut rows = Vec::new();
    for spec in paper_specs() {
        if spec.name.contains("gisette") {
            continue; // gisette appears only in Table 5
        }
        if !opts.selected(&spec.name) {
            continue;
        }
        let cfg = ExperimentConfig::builder()
            .dataset(&spec.name)
            .scale(opts.scale)
            .nodes(opts.nodes)
            .trials(opts.trials)
            .seed(opts.seed)
            .max_iterations(opts.max_iterations)
            .build()?;
        rows.push(run_dataset(&cfg)?);
    }
    Ok(rows)
}

/// Runs one dataset's GADGET-vs-Pegasos comparison.
pub fn run_dataset(cfg: &ExperimentConfig) -> Result<Table3Row> {
    let runner = GadgetRunner::new(cfg.clone())?;
    let report = runner.run()?;

    // Centralized Pegasos: same data, one model per trial.
    let train = runner.train_data();
    let test = runner.test_data();
    let iters = centralized_iterations(train.len());
    let mut peg_secs = Vec::new();
    let mut peg_acc = Vec::new();
    for trial in 0..cfg.trials {
        let mut peg = Pegasos::new(PegasosParams {
            lambda: runner.lambda(),
            iterations: iters,
            batch_size: 1,
            project: true,
            seed: cfg.seed.wrapping_add(trial as u64 * 31),
        });
        let sw = Stopwatch::new();
        let model = peg.fit(train);
        peg_secs.push(sw.secs());
        peg_acc.push(100.0 * metrics::accuracy(&model.w, test));
    }
    let (pt, pt_std) = mean_std(&peg_secs);
    let (pa, pa_std) = mean_std(&peg_acc);

    Ok(Table3Row {
        dataset: cfg.dataset.clone(),
        gadget_secs: report.train_secs,
        gadget_secs_std: report.train_secs_std,
        gadget_acc: 100.0 * report.test_accuracy,
        gadget_acc_std: 100.0 * report.test_accuracy_std,
        pegasos_secs: pt,
        pegasos_secs_std: pt_std,
        pegasos_acc: pa,
        pegasos_acc_std: pa_std,
        epsilon_final: report.epsilon_final,
        load_secs: report.load_secs,
    })
}

/// Renders rows in the paper's Table-3 layout.
pub fn render(rows: &[Table3Row]) -> TextTable {
    let mut t = TextTable::new(&[
        "Dataset",
        "GADGET Time (s)",
        "GADGET Acc (%)",
        "Pegasos Time (s)",
        "Pegasos Acc (%)",
        "eps@conv",
    ]);
    for r in rows {
        t.row(vec![
            r.dataset.clone(),
            pm(r.gadget_secs, r.gadget_secs_std, 3),
            pm(r.gadget_acc, r.gadget_acc_std, 2),
            pm(r.pegasos_secs, r.pegasos_secs_std, 3),
            pm(r.pegasos_acc, r.pegasos_acc_std, 2),
            format!("{:.6}", r.epsilon_final),
        ]);
    }
    t
}

/// JSON report (for `results/table3.json`).
pub fn to_json(rows: &[Table3Row]) -> Json {
    Json::obj(vec![(
        "table3",
        Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj(vec![
                        ("dataset", Json::Str(r.dataset.clone())),
                        ("gadget_secs", Json::Num(r.gadget_secs)),
                        ("gadget_secs_std", Json::Num(r.gadget_secs_std)),
                        ("gadget_acc", Json::Num(r.gadget_acc)),
                        ("gadget_acc_std", Json::Num(r.gadget_acc_std)),
                        ("pegasos_secs", Json::Num(r.pegasos_secs)),
                        ("pegasos_secs_std", Json::Num(r.pegasos_secs_std)),
                        ("pegasos_acc", Json::Num(r.pegasos_acc)),
                        ("pegasos_acc_std", Json::Num(r.pegasos_acc_std)),
                        ("epsilon_final", Json::Num(r.epsilon_final)),
                        ("load_secs", Json::Num(r.load_secs)),
                    ])
                })
                .collect(),
        ),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(name: &str) -> ExperimentConfig {
        ExperimentConfig::builder()
            .dataset(name)
            .scale(0.02)
            .nodes(4)
            .trials(2)
            .seed(5)
            .max_iterations(400)
            .epsilon(1e-3)
            .build()
            .unwrap()
    }

    #[test]
    fn usps_row_shape_holds() {
        // The Table-3 qualitative shape: GADGET accuracy within a few points
        // of centralized Pegasos.
        let row = run_dataset(&quick_cfg("synthetic-usps")).unwrap();
        assert!(row.gadget_acc > 70.0, "gadget acc {}", row.gadget_acc);
        assert!(
            (row.gadget_acc - row.pegasos_acc).abs() < 12.0,
            "gadget {} vs pegasos {}",
            row.gadget_acc,
            row.pegasos_acc
        );
        assert!(row.gadget_secs > 0.0 && row.pegasos_secs > 0.0);
    }

    #[test]
    fn render_and_json() {
        let row = Table3Row {
            dataset: "d".into(),
            gadget_secs: 0.08,
            gadget_secs_std: 0.01,
            gadget_acc: 77.04,
            gadget_acc_std: 0.03,
            pegasos_secs: 0.02,
            pegasos_secs_std: 0.002,
            pegasos_acc: 68.79,
            pegasos_acc_std: 0.18,
            epsilon_final: 8.6e-4,
            load_secs: 1.0,
        };
        let text = render(&[row.clone()]).render();
        assert!(text.contains("77.04"));
        let json = to_json(&[row]).to_string();
        assert!(json.contains("\"gadget_acc\":77.04"));
    }

    #[test]
    fn only_filter_limits_datasets() {
        let opts = ExperimentOpts {
            scale: 0.02,
            nodes: 3,
            trials: 1,
            seed: 2,
            only: vec!["usps".into()],
            max_iterations: 60,
            ..Default::default()
        };
        let rows = run(&opts).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].dataset.contains("usps"));
    }
}
