//! Table 5 (Appendix B): GADGET vs centralized Pegasos *including data
//! loading time*, plus the speed-up factor
//! `Speed-up = T_distributed / T_centralized` (paper Eq. 25) and the
//! Gisette dataset.
//!
//! Accounting: the distributed side loads shards in parallel across nodes,
//! so its load time is `load(full)/m + partition`; the centralized side
//! pays the full load. This reproduces the paper's qualitative claim that
//! GADGET wins when instances ≫ features and loses on dense
//! high-dimensional data (Gisette).

use super::table3::{centralized_iterations, Table3Row};
use super::ExperimentOpts;
use crate::config::ExperimentConfig;
use crate::coordinator::GadgetRunner;
use crate::data::synthetic::paper_specs;
use crate::metrics;
use crate::solver::{Pegasos, PegasosParams, Solver};
use crate::util::table::{pm, TextTable};
use crate::util::timer::mean_std;
use crate::util::{Json, Stopwatch};
use crate::Result;

/// One Table-5 row.
#[derive(Clone, Debug)]
pub struct Table5Row {
    /// The timing/accuracy core (times here *include* loading).
    pub core: Table3Row,
    /// `T_gadget / T_pegasos` (− < 1 ⇒ distributed faster).
    pub speedup: f64,
}

/// Runs Table 5 over all (selected) datasets, Gisette included.
pub fn run(opts: &ExperimentOpts) -> Result<Vec<Table5Row>> {
    let mut rows = Vec::new();
    for spec in paper_specs() {
        if !opts.selected(&spec.name) {
            continue;
        }
        let cfg = ExperimentConfig::builder()
            .dataset(&spec.name)
            .scale(opts.scale)
            .nodes(opts.nodes)
            .trials(opts.trials)
            .seed(opts.seed)
            .max_iterations(opts.max_iterations)
            .build()?;
        rows.push(run_dataset(&cfg)?);
    }
    Ok(rows)
}

/// Runs one dataset with load-time accounting.
pub fn run_dataset(cfg: &ExperimentConfig) -> Result<Table5Row> {
    let runner = GadgetRunner::new(cfg.clone())?;
    let report = runner.run()?;
    // Distributed: each node loads its shard concurrently → full-load/m,
    // plus the training time.
    let dist_load = report.load_secs / cfg.nodes as f64;
    let gadget_total = dist_load + report.train_secs;

    // Centralized: full load + fit.
    let train = runner.train_data();
    let test = runner.test_data();
    let iters = centralized_iterations(train.len());
    let mut secs = Vec::new();
    let mut accs = Vec::new();
    for trial in 0..cfg.trials {
        let mut peg = Pegasos::new(PegasosParams {
            lambda: runner.lambda(),
            iterations: iters,
            batch_size: 1,
            project: true,
            seed: cfg.seed.wrapping_add(trial as u64 * 31),
        });
        let sw = Stopwatch::new();
        let model = peg.fit(train);
        secs.push(report.load_secs + sw.secs());
        accs.push(100.0 * metrics::accuracy(&model.w, test));
    }
    let (pt, pt_std) = mean_std(&secs);
    let (pa, pa_std) = mean_std(&accs);

    let core = Table3Row {
        dataset: cfg.dataset.clone(),
        gadget_secs: gadget_total,
        gadget_secs_std: report.train_secs_std,
        gadget_acc: 100.0 * report.test_accuracy,
        gadget_acc_std: 100.0 * report.test_accuracy_std,
        pegasos_secs: pt,
        pegasos_secs_std: pt_std,
        pegasos_acc: pa,
        pegasos_acc_std: pa_std,
        epsilon_final: report.epsilon_final,
        load_secs: report.load_secs,
    };
    let speedup = if pt > 0.0 { gadget_total / pt } else { f64::NAN };
    Ok(Table5Row { core, speedup })
}

/// Renders the paper's Table-5 layout.
pub fn render(rows: &[Table5Row]) -> TextTable {
    let mut t = TextTable::new(&[
        "Dataset",
        "GADGET Time (s)",
        "GADGET Acc (%)",
        "Pegasos Time (s)",
        "Pegasos Acc (%)",
        "Speedup",
    ]);
    for r in rows {
        t.row(vec![
            r.core.dataset.clone(),
            pm(r.core.gadget_secs, r.core.gadget_secs_std, 3),
            pm(r.core.gadget_acc, r.core.gadget_acc_std, 2),
            pm(r.core.pegasos_secs, r.core.pegasos_secs_std, 3),
            pm(r.core.pegasos_acc, r.core.pegasos_acc_std, 2),
            format!("{:.2}", r.speedup),
        ]);
    }
    t
}

/// JSON report.
pub fn to_json(rows: &[Table5Row]) -> Json {
    Json::obj(vec![(
        "table5",
        Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj(vec![
                        ("dataset", Json::Str(r.core.dataset.clone())),
                        ("gadget_secs", Json::Num(r.core.gadget_secs)),
                        ("gadget_acc", Json::Num(r.core.gadget_acc)),
                        ("pegasos_secs", Json::Num(r.core.pegasos_secs)),
                        ("pegasos_acc", Json::Num(r.core.pegasos_acc)),
                        ("speedup", Json::Num(r.speedup)),
                        ("load_secs", Json::Num(r.core.load_secs)),
                    ])
                })
                .collect(),
        ),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn includes_gisette_and_computes_speedup() {
        let opts = ExperimentOpts {
            scale: 0.02,
            nodes: 3,
            trials: 1,
            seed: 4,
            only: vec!["gisette".into()],
            max_iterations: 40,
            ..Default::default()
        };
        let rows = run(&opts).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].core.dataset.contains("gisette"));
        assert!(rows[0].speedup.is_finite() && rows[0].speedup > 0.0);
        let text = render(&rows).render();
        assert!(text.contains("Speedup"));
    }
}
