//! Evaluation metrics and the paper's aggregation conventions.
//!
//! * primal objective (paper Eq. 1), hinge loss, 0/1 error, accuracy;
//! * per-node aggregation with the Table-3 standard-deviation rule
//!   `σ = sqrt(Var(Nodes) + Var(Trials))`;
//! * trace recording for the figures (objective / error vs wall-time).

use crate::data::{Dataset, ShardView};

/// Average hinge loss `(1/N) Σ max{0, 1 − y⟨w,x⟩}` over a borrowed row
/// window. The view cores are the canonical loops — the `&Dataset`
/// wrappers borrow the whole set as a view, so evaluating an out-of-core
/// pack window and evaluating its heap materialization is the same
/// arithmetic in the same order, bit for bit.
pub fn hinge_loss_view(w: &[f64], v: ShardView<'_>) -> f64 {
    assert!(!v.is_empty(), "hinge_loss: empty dataset");
    let mut s = 0.0;
    for i in 0..v.len() {
        let (x, y) = v.sample(i);
        s += (1.0 - y * x.dot_dense(w)).max(0.0);
    }
    s / v.len() as f64
}

/// Average hinge loss of a whole dataset.
pub fn hinge_loss(w: &[f64], ds: &Dataset) -> f64 {
    hinge_loss_view(w, ds.view())
}

/// Primal SVM objective (paper Eq. 1) over a borrowed row window:
/// `(λ/2)‖w‖² + hinge_loss`.
pub fn objective_view(w: &[f64], v: ShardView<'_>, lambda: f64) -> f64 {
    0.5 * lambda * crate::linalg::l2_norm_sq(w) + hinge_loss_view(w, v)
}

/// Primal SVM objective (paper Eq. 1): `(λ/2)‖w‖² + hinge_loss`.
pub fn objective(w: &[f64], ds: &Dataset, lambda: f64) -> f64 {
    objective_view(w, ds.view(), lambda)
}

/// Fraction of misclassified samples (`sign(⟨w,x⟩) ≠ y`) over a borrowed
/// row window; zero scores count as positive predictions, matching
/// `LinearModel::predict`.
pub fn zero_one_error_view(w: &[f64], v: ShardView<'_>) -> f64 {
    assert!(!v.is_empty(), "zero_one_error: empty dataset");
    let mut wrong = 0usize;
    for i in 0..v.len() {
        let (x, y) = v.sample(i);
        let pred = if x.dot_dense(w) >= 0.0 { 1.0 } else { -1.0 };
        if pred != y {
            wrong += 1;
        }
    }
    wrong as f64 / v.len() as f64
}

/// Fraction of misclassified samples of a whole dataset.
pub fn zero_one_error(w: &[f64], ds: &Dataset) -> f64 {
    zero_one_error_view(w, ds.view())
}

/// `1 − zero_one_error` over a borrowed row window.
pub fn accuracy_view(w: &[f64], v: ShardView<'_>) -> f64 {
    1.0 - zero_one_error_view(w, v)
}

/// `1 − zero_one_error`.
pub fn accuracy(w: &[f64], ds: &Dataset) -> f64 {
    1.0 - zero_one_error(w, ds)
}

/// The paper's Table-3 deviation rule: per-metric variance across nodes and
/// across trials combined as `sqrt(Var(Nodes) + Var(Trials))`.
///
/// `values[trial][node]` — returns `(grand_mean, combined_std)`.
pub fn node_trial_std(values: &[Vec<f64>]) -> (f64, f64) {
    assert!(!values.is_empty(), "node_trial_std: no trials");
    let trials = values.len();
    let nodes = values[0].len();
    assert!(values.iter().all(|t| t.len() == nodes), "ragged trials");
    // trial means
    let trial_means: Vec<f64> =
        values.iter().map(|t| t.iter().sum::<f64>() / nodes as f64).collect();
    let grand = trial_means.iter().sum::<f64>() / trials as f64;
    // Var(Trials): variance of trial means
    let var_trials = if trials > 1 {
        trial_means.iter().map(|m| (m - grand).powi(2)).sum::<f64>() / (trials - 1) as f64
    } else {
        0.0
    };
    // Var(Nodes): mean within-trial variance across nodes
    let var_nodes = if nodes > 1 {
        values
            .iter()
            .zip(&trial_means)
            .map(|(t, m)| t.iter().map(|v| (v - m).powi(2)).sum::<f64>() / (nodes - 1) as f64)
            .sum::<f64>()
            / trials as f64
    } else {
        0.0
    };
    (grand, (var_nodes + var_trials).sqrt())
}

/// Binary classification report beyond accuracy: the skewed paper corpora
/// (reuters at 9% positives, mnist at 10%) make accuracy alone misleading,
/// so the experiment harness can report the full confusion breakdown.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BinaryReport {
    /// True positives.
    pub tp: usize,
    /// False positives.
    pub fp: usize,
    /// True negatives.
    pub tn: usize,
    /// False negatives.
    pub fn_: usize,
}

impl BinaryReport {
    /// Computes the confusion counts of `sign(⟨w,x⟩)` on `ds`.
    pub fn compute(w: &[f64], ds: &Dataset) -> Self {
        let mut r = Self::default();
        for i in 0..ds.len() {
            let (x, y) = ds.sample(i);
            let pred = x.dot_dense(w) >= 0.0;
            match (pred, y > 0.0) {
                (true, true) => r.tp += 1,
                (true, false) => r.fp += 1,
                (false, false) => r.tn += 1,
                (false, true) => r.fn_ += 1,
            }
        }
        r
    }

    /// `tp / (tp + fp)`; 0 when undefined.
    pub fn precision(&self) -> f64 {
        let d = self.tp + self.fp;
        if d == 0 {
            0.0
        } else {
            self.tp as f64 / d as f64
        }
    }

    /// `tp / (tp + fn)`; 0 when undefined.
    pub fn recall(&self) -> f64 {
        let d = self.tp + self.fn_;
        if d == 0 {
            0.0
        } else {
            self.tp as f64 / d as f64
        }
    }

    /// Harmonic mean of precision and recall; 0 when undefined.
    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    /// Overall accuracy.
    pub fn accuracy(&self) -> f64 {
        let total = self.tp + self.fp + self.tn + self.fn_;
        if total == 0 {
            0.0
        } else {
            (self.tp + self.tn) as f64 / total as f64
        }
    }
}

/// Area under the ROC curve of the raw scores `⟨w, x⟩` (rank statistic via
/// the Mann–Whitney U; ties get half credit).
pub fn auc(w: &[f64], ds: &Dataset) -> f64 {
    let mut scored: Vec<(f64, bool)> = (0..ds.len())
        .map(|i| {
            let (x, y) = ds.sample(i);
            (x.dot_dense(w), y > 0.0)
        })
        .collect();
    let pos = scored.iter().filter(|(_, y)| *y).count();
    let neg = scored.len() - pos;
    if pos == 0 || neg == 0 {
        return 0.5;
    }
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // average ranks with tie handling
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0usize;
    while i < scored.len() {
        let mut j = i;
        while j + 1 < scored.len() && scored[j + 1].0 == scored[i].0 {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for item in scored.iter().take(j + 1).skip(i) {
            if item.1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    (rank_sum_pos - pos as f64 * (pos as f64 + 1.0) / 2.0) / (pos as f64 * neg as f64)
}

/// One point of a convergence trace (figures 4.1–4.3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    /// Seconds of training wall-time when the snapshot was taken.
    pub time_secs: f64,
    /// GADGET iteration (or solver step) index.
    pub step: usize,
    /// Primal objective (Eq. 1) on the training data.
    pub objective: f64,
    /// Zero-one error on the test data.
    pub test_error: f64,
}

/// A named convergence trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Series label (e.g. "gadget-node-avg", "pegasos").
    pub label: String,
    /// Chronological points.
    pub points: Vec<TracePoint>,
}

impl Trace {
    /// Creates an empty trace.
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    /// Appends a point.
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    /// Renders as CSV (`label,time_secs,step,objective,test_error`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("label,time_secs,step,objective,test_error\n");
        for p in &self.points {
            out.push_str(&format!(
                "{},{:.6},{},{:.8},{:.6}\n",
                self.label, p.time_secs, p.step, p.objective, p.test_error
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SparseVec;

    fn toy() -> Dataset {
        // 2 samples in R²: x0=(1,0) y=+1, x1=(0,1) y=−1
        Dataset::new(
            "toy",
            2,
            vec![SparseVec::new(vec![0], vec![1.0]), SparseVec::new(vec![1], vec![1.0])],
            vec![1, -1],
        )
    }

    #[test]
    fn hinge_and_objective_by_hand() {
        let ds = toy();
        let w = vec![2.0, -2.0];
        // margins: +1·2 = 2 (loss 0), −1·(−2)=2 (loss 0)
        assert_eq!(hinge_loss(&w, &ds), 0.0);
        let lambda = 0.5;
        // obj = 0.25·(4+4) = 2
        assert!((objective(&w, &ds, lambda) - 2.0).abs() < 1e-12);
        // w = 0: hinge = 1 each
        assert_eq!(hinge_loss(&[0.0, 0.0], &ds), 1.0);
    }

    #[test]
    fn zero_one_and_accuracy() {
        let ds = toy();
        assert_eq!(zero_one_error(&[1.0, -1.0], &ds), 0.0);
        assert_eq!(zero_one_error(&[-1.0, 1.0], &ds), 1.0);
        // w = 0: score 0 ⇒ predict +1 ⇒ one of two wrong
        assert_eq!(zero_one_error(&[0.0, 0.0], &ds), 0.5);
        assert_eq!(accuracy(&[1.0, -1.0], &ds), 1.0);
    }

    #[test]
    fn node_trial_std_hand_example() {
        // 2 trials × 2 nodes
        let values = vec![vec![1.0, 3.0], vec![2.0, 4.0]];
        // trial means: 2, 3 ⇒ grand 2.5, Var(Trials) = 0.5
        // within-trial vars: 2, 2 ⇒ Var(Nodes) = 2
        let (mean, std) = node_trial_std(&values);
        assert!((mean - 2.5).abs() < 1e-12);
        assert!((std - 2.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn node_trial_std_single_trial_single_node() {
        let (mean, std) = node_trial_std(&[vec![7.0]]);
        assert_eq!((mean, std), (7.0, 0.0));
    }

    #[test]
    fn binary_report_by_hand() {
        let ds = Dataset::new(
            "t",
            1,
            vec![
                SparseVec::new(vec![0], vec![1.0]),  // score +1, y +1 -> tp
                SparseVec::new(vec![0], vec![1.0]),  // score +1, y -1 -> fp
                SparseVec::new(vec![0], vec![-1.0]), // score -1, y -1 -> tn
                SparseVec::new(vec![0], vec![-1.0]), // score -1, y +1 -> fn
            ],
            vec![1, -1, -1, 1],
        );
        let r = BinaryReport::compute(&[1.0], &ds);
        assert_eq!((r.tp, r.fp, r.tn, r.fn_), (1, 1, 1, 1));
        assert!((r.precision() - 0.5).abs() < 1e-12);
        assert!((r.recall() - 0.5).abs() < 1e-12);
        assert!((r.f1() - 0.5).abs() < 1e-12);
        assert!((r.accuracy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn binary_report_degenerate_cases() {
        let r = BinaryReport::default();
        assert_eq!(r.precision(), 0.0);
        assert_eq!(r.recall(), 0.0);
        assert_eq!(r.f1(), 0.0);
        assert_eq!(r.accuracy(), 0.0);
    }

    #[test]
    fn auc_perfect_and_random() {
        // perfectly-ranked scores
        let ds = Dataset::new(
            "t",
            1,
            (0..8).map(|i| SparseVec::new(vec![0], vec![i as f32])).collect(),
            vec![-1, -1, -1, -1, 1, 1, 1, 1],
        );
        assert!((auc(&[1.0], &ds) - 1.0).abs() < 1e-12);
        assert!((auc(&[-1.0], &ds) - 0.0).abs() < 1e-12);
        // all scores tied ⇒ 0.5
        let tied = Dataset::new(
            "t",
            1,
            (0..6).map(|_| SparseVec::new(vec![0], vec![1.0])).collect(),
            vec![1, -1, 1, -1, 1, -1],
        );
        assert!((auc(&[1.0], &tied) - 0.5).abs() < 1e-12);
        // single-class ⇒ 0.5 by convention
        let one = Dataset::new("t", 1, vec![SparseVec::new(vec![0], vec![1.0])], vec![1]);
        assert_eq!(auc(&[1.0], &one), 0.5);
    }

    #[test]
    fn trace_csv_shape() {
        let mut t = Trace::new("test");
        t.push(TracePoint { time_secs: 0.5, step: 10, objective: 1.25, test_error: 0.1 });
        let csv = t.to_csv();
        assert!(csv.starts_with("label,"));
        assert!(csv.contains("test,0.500000,10,1.25000000,0.100000"));
    }
}
