//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `gadget <subcommand> [--key value]... [--flag]...`.
//! Every subcommand documents itself via `gadget help`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand word (empty for none).
    pub command: String,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

/// Boolean switches — needed to disambiguate `--flag positional` from
/// `--option value` without a full schema.
pub const KNOWN_FLAGS: &[&str] =
    &["help", "verbose", "artifacts", "quiet", "csv", "scores", "stream"];

impl Args {
    /// Parses an argument vector (without `argv[0]`).
    pub fn parse(argv: &[String]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bad argument '--'".into());
                }
                // --key=value | --known-flag | --key value | trailing --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&key) {
                    out.flags.push(key.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Typed option with default.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(&sv(&[
            "train",
            "--dataset",
            "usps",
            "--nodes=4",
            "--verbose",
            "extra",
        ]))
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("dataset"), Some("usps"));
        assert_eq!(a.get("nodes"), Some("4"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_options_with_defaults() {
        let a = Args::parse(&sv(&["x", "--n", "7"])).unwrap();
        assert_eq!(a.get_parsed("n", 1usize).unwrap(), 7);
        assert_eq!(a.get_parsed("missing", 5usize).unwrap(), 5);
        assert!(Args::parse(&sv(&["x", "--n", "abc"]))
            .unwrap()
            .get_parsed("n", 1usize)
            .is_err());
    }

    #[test]
    fn list_option() {
        let a = Args::parse(&sv(&["x", "--only", "usps, adult"])).unwrap();
        assert_eq!(a.get_list("only"), vec!["usps", "adult"]);
        assert!(a.get_list("none").is_empty());
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(&sv(&["--help"])).unwrap();
        assert_eq!(a.command, "");
        assert!(a.has_flag("help"));
    }

    #[test]
    fn scores_is_a_known_flag() {
        // `serve --scores --model m.json`: the known-flag list is what
        // keeps `--scores` from eating the next token as its value.
        let a = Args::parse(&sv(&["serve", "--scores", "positional", "--model", "m.json"]))
            .unwrap();
        assert!(a.has_flag("scores"));
        assert_eq!(a.get("model"), Some("m.json"));
        assert_eq!(a.positional, vec!["positional"]);
    }

    #[test]
    fn negative_number_as_value() {
        // "--seed -3" — the next token starts with '-' but not '--'
        let a = Args::parse(&sv(&["x", "--label", "-3"])).unwrap();
        assert_eq!(a.get("label"), Some("-3"));
    }
}
