//! A persistent parked worker pool — the dispatch substrate of the
//! node-parallel runtime.
//!
//! PR-1's `Parallel` scheduler spawned scoped threads on every
//! `for_each_node` call: 2 phases × `threads` spawns per GADGET
//! iteration, tens of microseconds each — noise against a large local
//! step, but the dominant cost once per-node work shrinks (small
//! `d`·`batch`; measured in `benches/table5_speedup.rs` §dispatch
//! overhead). This module replaces that with workers that spawn **once**,
//! park on a condvar between dispatches, and receive work through a
//! shared injector queue.
//!
//! ## Dispatch protocol
//!
//! [`WorkerPool::run_tasks`] is a *scoped* dispatch:
//!
//! 1. the caller enqueues its tasks (type-erased to `'static`; see
//!    Safety) under one per-call completion latch ([`ScopeState`]);
//! 2. parked workers wake, pop tasks FIFO, run each under
//!    `catch_unwind`, and decrement the latch — a panicking task is
//!    converted into an error on the latch instead of a poisoned thread,
//!    so parked peers and concurrent scopes are never deadlocked;
//! 3. the caller *helps*: it drains the queue LIFO (most-recently
//!    enqueued first, so nested dispatches service their own sub-tasks
//!    before stealing unrelated work) instead of idling, then blocks on
//!    the latch until the in-flight remainder completes.
//!
//! The help-running step is what makes nested dispatch — a pool task that
//! itself calls `run_tasks`, e.g. a fanned-out GADGET trial whose mixing
//! round fans column panels — deadlock-free: progress never depends on a
//! free worker, because every waiting dispatcher is also an executor.
//!
//! ## Safety
//!
//! Tasks borrow the caller's stack (`&mut NodeState` slabs, `&PushVector`
//! buffers), so they are erased from `'env` to `'static` when enqueued —
//! the same erasure scoped threads perform. Soundness rests on one
//! invariant, maintained by [`WorkerPool::run_tasks`]: **it does not
//! return until the latch counts every task of its scope as finished, and
//! a task is consumed (its captures dropped, by return or by unwind)
//! before it is counted** — so no `'env` borrow survives the call that
//! created it.
//!
//! [`ParallelExec`] is the object-safe facade over "run these disjoint
//! tasks to completion": [`SerialExec`] runs them inline (the sequential
//! scheduler's executor), [`WorkerPool`] fans them out. Consumers
//! (`gossip::PushVector::round_with`, `Scheduler::panel_exec`, and the
//! inference service's `serve::ShardedScorer` batch fan-out) are
//! executor-agnostic; results must be — and are — bitwise identical
//! either way.
//!
//! ## Indexed dispatch
//!
//! [`ParallelExec::run_indexed`] is the allocation-free sibling of
//! `run_tasks`: instead of a `Vec` of boxed closures the caller passes
//! one shared `Fn(usize)` plus a count, and the pool enqueues
//! lightweight index jobs (a fat pointer and a `usize`) into its
//! retained-capacity queue. Every dispatch — boxed or indexed — checks
//! a completion latch out of a pool-owned freelist and recycles it when
//! its scope completes, so a steady-state indexed dispatch performs
//! **zero heap allocations** (cloning the recycled latch's `Arc` per
//! job is a refcount bump) — the property
//! `rust/tests/alloc_regression.rs` pins for the iteration hot path.
//! Because each dispatch owns its own latch and no pool-wide lock is
//! held while help-running, dispatches nest freely in every combination
//! (boxed-under-boxed, indexed-under-boxed, indexed-under-indexed) and
//! concurrent dispatches from unrelated threads never serialize behind
//! one another.

use crate::Result;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// A unit of work for one dispatch: runs once, on whichever thread picks
/// it up. May borrow the dispatching caller's stack (`'env`).
pub type Task<'env> = Box<dyn FnOnce() -> Result<()> + Send + 'env>;

/// A [`Task`] after lifetime erasure (queue representation).
type ErasedTask = Box<dyn FnOnce() -> Result<()> + Send + 'static>;

/// The shared work function of one `run_indexed` dispatch, after the same
/// lifetime erasure (every index job of the dispatch borrows this one
/// function — nothing per-job is boxed).
type IndexedFn = &'static (dyn Fn(usize) -> Result<()> + Sync);

/// Object-safe executor for a batch of disjoint tasks.
///
/// The contract mirrors the scheduler's: every task runs exactly once and
/// `run_tasks` returns only after all of them finished (even when some
/// failed — the first error is returned after the batch completes, so
/// borrowed data is never still in flight). Implementations may only
/// change *where* tasks run, never *what* they compute.
pub trait ParallelExec: Sync {
    /// Worker parallelism available to a batch (1 for inline execution).
    fn threads(&self) -> usize;

    /// Runs all tasks to completion; first task error (or panic,
    /// converted) wins.
    fn run_tasks<'env>(&self, tasks: Vec<Task<'env>>) -> Result<()>;

    /// Runs `f(0), f(1), …, f(count-1)`, each exactly once, to
    /// completion; first error (or panic, converted) wins — the same
    /// contract as [`Self::run_tasks`], in a dispatch shape that lets
    /// the pool executor stay allocation-free at steady state.
    ///
    /// The default (inline, in order) serves [`SerialExec`] and keeps
    /// the trait's bitwise-equivalence promise trivially.
    fn run_indexed(&self, count: usize, f: &(dyn Fn(usize) -> Result<()> + Sync)) -> Result<()> {
        // Run the whole range even after an error — identical semantics
        // to the pool, which cannot recall already-queued index jobs.
        let mut first_error = None;
        for i in 0..count {
            if let Err(e) = f(i) {
                if first_error.is_none() {
                    first_error = Some(e);
                }
            }
        }
        match first_error {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

/// Inline executor: runs every task on the calling thread, in order.
pub struct SerialExec;

/// Shared [`SerialExec`] instance (the default `Scheduler::panel_exec`).
pub static SERIAL_EXEC: SerialExec = SerialExec;

impl ParallelExec for SerialExec {
    fn threads(&self) -> usize {
        1
    }

    fn run_tasks<'env>(&self, tasks: Vec<Task<'env>>) -> Result<()> {
        // Run the whole batch even after an error — identical semantics
        // to the pool, which cannot recall already-queued tasks.
        let mut first_error = None;
        for task in tasks {
            if let Err(e) = task() {
                if first_error.is_none() {
                    first_error = Some(e);
                }
            }
        }
        match first_error {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

/// Completion latch for one `run_tasks` call.
struct ScopeState {
    progress: Mutex<ScopeProgress>,
    done: Condvar,
}

struct ScopeProgress {
    /// Tasks of this scope not yet finished.
    remaining: usize,
    /// First task error (or panic, converted) observed.
    first_error: Option<anyhow::Error>,
}

/// What a queued job executes.
enum Work {
    /// A boxed one-shot closure (`run_tasks`).
    Boxed(ErasedTask),
    /// One index of a shared work function (`run_indexed`) — a fat
    /// pointer plus an index, nothing heap-owned.
    Indexed { f: IndexedFn, index: usize },
}

/// One queued unit of work plus the latch it reports to. The `Arc` clone
/// each job carries is a refcount bump, not an allocation — latches are
/// recycled through the pool's freelist (see [`WorkerPool::latches`]).
struct Job {
    work: Work,
    scope: Arc<ScopeState>,
}

/// State shared between the pool handle and its workers.
struct Shared {
    queue: Mutex<Queue>,
    /// Signaled when jobs are enqueued or shutdown is requested.
    available: Condvar,
}

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Locks a mutex, pressing on through poisoning: the pool never panics
/// while holding a lock (task panics are caught *before* locking), but a
/// poisoned latch must not turn into a second panic that would leak
/// in-flight borrows out of `run_tasks`.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Runs one job and reports it to its scope's latch. The task is consumed
/// (captures dropped) by the call or its unwind before the latch is
/// decremented — the soundness invariant of the lifetime erasure.
fn run_job(job: Job) {
    let Job { work, scope } = job;
    let outcome = match catch_unwind(AssertUnwindSafe(move || match work {
        Work::Boxed(task) => task(),
        Work::Indexed { f, index } => f(index),
    })) {
        Ok(Ok(())) => None,
        Ok(Err(e)) => Some(e),
        Err(payload) => Some(anyhow::anyhow!(
            "pool: worker task panicked: {}",
            panic_message(payload.as_ref())
        )),
    };
    let mut p = lock(&scope.progress);
    if let Some(e) = outcome {
        if p.first_error.is_none() {
            p.first_error = Some(e);
        }
    }
    p.remaining -= 1;
    if p.remaining == 0 {
        scope.done.notify_all();
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = lock(&shared.queue);
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared
                    .available
                    .wait(q)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
            }
        };
        run_job(job);
    }
}

/// The persistent pool: `threads` workers spawned at construction, parked
/// between dispatches, joined on drop.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Freelist of recycled completion latches. Every dispatch checks
    /// one out (allocating only when the list is empty — warm-up, or
    /// deeper dispatch concurrency than ever seen before) and returns
    /// it on completion, so steady-state dispatch is allocation-free:
    /// the `Arc::clone` per enqueued job is a refcount bump and the
    /// `Vec` retains its capacity. No pool-wide lock is ever held
    /// across job execution, so dispatches nest and interleave freely.
    latches: Mutex<Vec<Arc<ScopeState>>>,
}

impl WorkerPool {
    /// Spawns a pool of `threads` parked workers (clamped to ≥ 1; callers
    /// resolve `0 = all cores` themselves, see
    /// `coordinator::sched::resolve_threads`).
    pub fn new(threads: usize) -> Self {
        let t = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let workers = (0..t)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gadget-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("pool: failed to spawn worker thread")
            })
            .collect();
        Self { shared, workers, latches: Mutex::new(Vec::new()) }
    }

    /// Checks a completion latch out of the freelist (allocating only
    /// when it is empty), armed for `n` jobs.
    fn checkout_latch(&self, n: usize) -> Arc<ScopeState> {
        let scope = lock(&self.latches).pop().unwrap_or_else(|| {
            Arc::new(ScopeState {
                progress: Mutex::new(ScopeProgress { remaining: 0, first_error: None }),
                done: Condvar::new(),
            })
        });
        {
            let mut p = lock(&scope.progress);
            debug_assert_eq!(p.remaining, 0, "recycled latch still in flight");
            p.remaining = n;
            p.first_error = None;
        }
        scope
    }

    /// Help-runs queued jobs until this scope's work is done, blocks on
    /// the latch for the in-flight remainder, recycles the latch, and
    /// returns the scope's outcome.
    ///
    /// The help loop pops LIFO (most-recently enqueued first, so a
    /// nested dispatch services its own freshly-queued sub-jobs before
    /// stealing unrelated work) and exits as soon as this scope's
    /// `remaining` hits zero — it never keeps draining other scopes'
    /// jobs after its own work is finished (their dispatchers and the
    /// workers make that progress), so a dispatch cannot be held
    /// hostage by a long foreign task enqueued after its own jobs.
    fn finish_scope(&self, scope: Arc<ScopeState>) -> Result<()> {
        loop {
            if lock(&scope.progress).remaining == 0 {
                break;
            }
            let job = lock(&self.shared.queue).jobs.pop_back();
            match job {
                Some(job) => run_job(job),
                None => break,
            }
        }
        // Whatever is left of this scope is running on other threads;
        // wait for the latch.
        let mut p = lock(&scope.progress);
        while p.remaining > 0 {
            p = scope.done.wait(p).unwrap_or_else(|poisoned| poisoned.into_inner());
        }
        let outcome = match p.first_error.take() {
            None => Ok(()),
            Some(e) => Err(e),
        };
        drop(p);
        // Recycle: `remaining == 0` means every job of this scope has
        // reported. A worker that just reported may still hold a dying
        // `Arc` clone, but it never touches the scope again, so the
        // latch is safe to re-arm immediately.
        lock(&self.latches).push(scope);
        outcome
    }
}

impl ParallelExec for WorkerPool {
    fn threads(&self) -> usize {
        self.workers.len()
    }

    fn run_tasks<'env>(&self, tasks: Vec<Task<'env>>) -> Result<()> {
        let n = tasks.len();
        if n == 0 {
            return Ok(());
        }
        let scope = self.checkout_latch(n);
        {
            let mut q = lock(&self.shared.queue);
            for task in tasks {
                // SAFETY: the `'env` borrows inside `task` outlive every
                // use, because (a) this function does not return before
                // the latch below reaches zero, and (b) `run_job` consumes
                // the task — dropping its captures — before decrementing
                // the latch. No `'env` borrow survives this call.
                let task = unsafe { std::mem::transmute::<Task<'env>, ErasedTask>(task) };
                q.jobs.push_back(Job {
                    work: Work::Boxed(task),
                    scope: Arc::clone(&scope),
                });
            }
            self.shared.available.notify_all();
        }
        // Help-run instead of idling (progress never requires a free
        // worker), then block on the latch for the in-flight remainder.
        self.finish_scope(scope)
    }

    fn run_indexed(&self, count: usize, f: &(dyn Fn(usize) -> Result<()> + Sync)) -> Result<()> {
        if count == 0 {
            return Ok(());
        }
        let scope = self.checkout_latch(count);
        // SAFETY: same erasure argument as `run_tasks` — this call does
        // not return before the latch counts every index job finished,
        // and `run_job` finishes its use of `f` before decrementing, so
        // no borrow of `f`'s captures survives this call.
        let f = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) -> Result<()> + Sync), IndexedFn>(f)
        };
        {
            let mut q = lock(&self.shared.queue);
            for index in 0..count {
                q.jobs.push_back(Job {
                    work: Work::Indexed { f, index },
                    scope: Arc::clone(&scope),
                });
            }
            self.shared.available.notify_all();
        }
        self.finish_scope(scope)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        lock(&self.shared.queue).shutdown = true;
        self.shared.available.notify_all();
        for handle in self.workers.drain(..) {
            // A worker that panicked outside a task (impossible today —
            // run_job catches task panics) just reports a join error;
            // swallowing it keeps drop panic-free.
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn counting_tasks(n: usize, hits: &AtomicUsize) -> Vec<Task<'_>> {
        (0..n)
            .map(|_| {
                Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                }) as Task<'_>
            })
            .collect()
    }

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        pool.run_tasks(counting_tasks(64, &hits)).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn borrows_mutable_stack_data() {
        // The scoped-dispatch property: tasks may write disjoint &mut
        // slices of the caller's stack.
        let pool = WorkerPool::new(3);
        let mut data = vec![0usize; 10];
        {
            let tasks: Vec<Task<'_>> = data
                .chunks_mut(3)
                .enumerate()
                .map(|(c, chunk)| {
                    Box::new(move || {
                        for x in chunk.iter_mut() {
                            *x = c + 1;
                        }
                        Ok(())
                    }) as Task<'_>
                })
                .collect();
            pool.run_tasks(tasks).unwrap();
        }
        assert_eq!(data, vec![1, 1, 1, 2, 2, 2, 3, 3, 3, 4]);
    }

    #[test]
    fn task_error_is_returned_after_batch_completes() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        let hits_ref = &hits;
        let mut tasks: Vec<Task<'_>> = Vec::new();
        for i in 0..8 {
            tasks.push(Box::new(move || {
                hits_ref.fetch_add(1, Ordering::SeqCst);
                if i == 3 {
                    anyhow::bail!("task {i} failed");
                }
                Ok(())
            }));
        }
        let err = pool.run_tasks(tasks).unwrap_err();
        assert!(err.to_string().contains("task 3 failed"), "{err}");
        // the batch still ran to completion (no early abandon of borrows)
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn worker_panic_becomes_error_and_pool_stays_usable() {
        // A panicking task must neither deadlock parked peers nor kill
        // the pool: the next dispatch has to work.
        let pool = WorkerPool::new(2);
        let tasks: Vec<Task<'_>> = vec![
            Box::new(|| Ok(())),
            Box::new(|| panic!("deliberate test panic")),
            Box::new(|| Ok(())),
        ];
        let err = pool.run_tasks(tasks).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("deliberate test panic"), "{err}");

        let hits = AtomicUsize::new(0);
        pool.run_tasks(counting_tasks(16, &hits)).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn nested_dispatch_does_not_deadlock() {
        // Every task of the outer batch dispatches an inner batch on the
        // same pool while all workers may already be busy with outer
        // tasks — help-running must keep this live even at pool size 1.
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let hits = AtomicUsize::new(0);
            let outer: Vec<Task<'_>> = (0..6)
                .map(|_| {
                    let pool = &pool;
                    let hits = &hits;
                    Box::new(move || {
                        let inner: Vec<Task<'_>> = (0..5)
                            .map(|_| {
                                Box::new(move || {
                                    hits.fetch_add(1, Ordering::SeqCst);
                                    Ok(())
                                }) as Task<'_>
                            })
                            .collect();
                        pool.run_tasks(inner)
                    }) as Task<'_>
                })
                .collect();
            pool.run_tasks(outer).unwrap();
            assert_eq!(hits.load(Ordering::SeqCst), 30, "threads={threads}");
        }
    }

    #[test]
    fn pool_larger_than_task_count_and_cores() {
        // Oversubscription (threads ≫ cores) and underfill (tasks <
        // workers) are both fine: extra workers just stay parked.
        let pool = WorkerPool::new(64);
        assert_eq!(pool.threads(), 64);
        let hits = AtomicUsize::new(0);
        pool.run_tasks(counting_tasks(3, &hits)).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn empty_dispatch_is_a_noop() {
        let pool = WorkerPool::new(2);
        pool.run_tasks(Vec::new()).unwrap();
    }

    #[test]
    fn run_indexed_covers_every_index_exactly_once() {
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
            pool.run_indexed(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}, threads {threads}");
            }
        }
    }

    #[test]
    fn run_indexed_error_is_returned_after_range_completes() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        let err = pool
            .run_indexed(8, &|i| {
                hits.fetch_add(1, Ordering::SeqCst);
                if i == 3 {
                    anyhow::bail!("index {i} failed");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("index 3 failed"), "{err}");
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn run_indexed_panic_becomes_error_and_pool_stays_usable() {
        let pool = WorkerPool::new(2);
        let err = pool
            .run_indexed(3, &|i| {
                if i == 1 {
                    panic!("deliberate indexed panic");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("deliberate indexed panic"), "{err}");
        // The reusable latch must be clean for the next dispatch.
        let hits = AtomicUsize::new(0);
        pool.run_indexed(16, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn run_indexed_nested_under_run_tasks() {
        // The trial → mixing-round shape: boxed tasks on the pool each
        // dispatch an indexed batch on the same pool, concurrently (each
        // checks its own latch out of the freelist); help-running keeps
        // every caller live even at pool size 1. This also exercises the
        // reentrancy the old single-latch design deadlocked on: a
        // dispatcher's help loop popping a sibling boxed task that
        // itself calls run_indexed.
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let hits = AtomicUsize::new(0);
            let outer: Vec<Task<'_>> = (0..6)
                .map(|_| {
                    let pool = &pool;
                    let hits = &hits;
                    Box::new(move || {
                        pool.run_indexed(5, &|_| {
                            hits.fetch_add(1, Ordering::SeqCst);
                            Ok(())
                        })
                    }) as Task<'_>
                })
                .collect();
            pool.run_tasks(outer).unwrap();
            assert_eq!(hits.load(Ordering::SeqCst), 30, "threads={threads}");
        }
    }

    #[test]
    fn run_indexed_nested_under_run_indexed() {
        // Per-dispatch latches make indexed-under-indexed nesting legal
        // (the single-latch design forbade it: an indexed job dispatching
        // run_indexed would have blocked on the latch it was counted in).
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let hits = AtomicUsize::new(0);
            pool.run_indexed(6, &|_| {
                pool.run_indexed(5, &|_| {
                    hits.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                })
            })
            .unwrap();
            assert_eq!(hits.load(Ordering::SeqCst), 30, "threads={threads}");
        }
    }

    #[test]
    fn concurrent_run_indexed_from_multiple_threads() {
        // Indexed dispatches from unrelated threads no longer serialize
        // behind a pool-wide mutex; each runs under its own latch.
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    pool.run_indexed(25, &|_| {
                        hits.fetch_add(1, Ordering::SeqCst);
                        Ok(())
                    })
                    .unwrap();
                });
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_indexed_writes_disjoint_stack_slices() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0usize; 10];
        let base = data.as_mut_ptr() as usize;
        pool.run_indexed(4, &|c| {
            let lo = c * 3;
            let hi = (lo + 3).min(10);
            // SAFETY: each index owns the disjoint range [lo, hi).
            let chunk = unsafe {
                std::slice::from_raw_parts_mut((base as *mut usize).add(lo), hi - lo)
            };
            for x in chunk.iter_mut() {
                *x = c + 1;
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(data, vec![1, 1, 1, 2, 2, 2, 3, 3, 3, 4]);
    }

    #[test]
    fn run_indexed_empty_is_a_noop() {
        let pool = WorkerPool::new(2);
        pool.run_indexed(0, &|_| anyhow::bail!("never called")).unwrap();
        SERIAL_EXEC.run_indexed(0, &|_| anyhow::bail!("never called")).unwrap();
    }

    #[test]
    fn serial_run_indexed_matches_pool_semantics() {
        let hits = AtomicUsize::new(0);
        let err = SERIAL_EXEC
            .run_indexed(4, &|i| {
                hits.fetch_add(1, Ordering::SeqCst);
                if i == 1 {
                    anyhow::bail!("boom");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn serial_exec_matches_pool_semantics() {
        let hits = AtomicUsize::new(0);
        let hits_ref = &hits;
        let mut tasks: Vec<Task<'_>> = Vec::new();
        for i in 0..4 {
            tasks.push(Box::new(move || {
                hits_ref.fetch_add(1, Ordering::SeqCst);
                if i == 1 {
                    anyhow::bail!("boom");
                }
                Ok(())
            }));
        }
        let err = SERIAL_EXEC.run_tasks(tasks).unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        assert_eq!(SERIAL_EXEC.threads(), 1);
    }
}
