//! The scalar reference backend: the canonical hot-loop implementations.
//!
//! Every loop here is **the** determinism reference — `linalg::dense` and
//! `linalg::sparse` delegate their public functions to these free
//! functions, so there is exactly one implementation of each hot loop in
//! the crate and [`ScalarKernel`] is bit-for-bit the pre-refactor
//! behavior. The bitwise `Parallel ≡ Sequential` equivalence contract
//! (`rust/tests/scheduler_equivalence.rs`) is stated over this backend.
//!
//! The element-wise functions ([`axpy`], [`scale_add`], [`axpy_sparse`],
//! [`gemv_panel`]) are also shared by the SIMD backend verbatim: with one
//! evaluation order per output element there is nothing to reassociate, so
//! sharing is what *guarantees* those operations stay bitwise
//! backend-invariant (pinned by `rust/tests/kernel_equivalence.rs`).

use super::Kernel;
use crate::linalg::{RowRef, SparseVec};

/// The scalar reference backend (stateless; use [`super::scalar()`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarKernel;

impl Kernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn dot(&self, x: &[f64], y: &[f64]) -> f64 {
        dot(x, y)
    }

    fn dot_row(&self, x: RowRef<'_>, w: &[f64]) -> f64 {
        dot_row(x, w)
    }
    // dot_sparse / axpy / axpy_row / scale_add / axpy_sparse / gemv_panel /
    // hinge_subgrad_accum / score_rows: the trait's provided bodies already
    // are the canonical scalar implementations.
}

/// Dot product `xᵀy` — four-way unrolled accumulation: breaks the serial
/// FP dependence chain so LLVM emits vector FMAs (see EXPERIMENTS.md
/// §Perf). The reduction order — `(s0+s1) + (s2+s3) + tail` — is the
/// reference order every bitwise test pins.
///
/// # Panics
/// Panics if `x.len() != y.len()`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot: length mismatch");
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = 4 * i;
        s0 += x[j] * y[j];
        s1 += x[j + 1] * y[j + 1];
        s2 += x[j + 2] * y[j + 2];
        s3 += x[j + 3] * y[j + 3];
    }
    let mut tail = 0.0;
    for j in 4 * chunks..n {
        tail += x[j] * y[j];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Sparse–dense dot `⟨x, w⟩` over borrowed index/value slices: a single
/// sequential accumulator over the stored entries (the gather pattern
/// auto-vectorizes poorly, and this order is the reference the solvers'
/// trajectories depend on). The canonical loop; [`dot_sparse`] borrows
/// and delegates here.
#[inline]
pub fn dot_row(x: RowRef<'_>, w: &[f64]) -> f64 {
    let mut s = 0.0;
    for (&i, &v) in x.indices.iter().zip(x.values) {
        s += w[i as usize] * v as f64;
    }
    s
}

/// Sparse–dense dot `⟨x, w⟩` for an owned row — delegates to [`dot_row`]
/// (bit-for-bit the same reduction).
#[inline]
pub fn dot_sparse(x: &SparseVec, w: &[f64]) -> f64 {
    dot_row(x.as_row(), w)
}

/// `y ← y + a·x` (element-wise).
///
/// # Panics
/// Panics if `x.len() != y.len()`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
}

/// `y ← a·y + b·x` (element-wise).
///
/// # Panics
/// Panics if `x.len() != y.len()`.
#[inline]
pub fn scale_add(a: f64, y: &mut [f64], b: f64, x: &[f64]) {
    assert_eq!(x.len(), y.len(), "scale_add: length mismatch");
    for i in 0..x.len() {
        y[i] = a * y[i] + b * x[i];
    }
}

/// `w ← w + a·x` for a borrowed sparse row (scatter, element-wise). The
/// canonical loop; [`axpy_sparse`] borrows and delegates here.
#[inline]
pub fn axpy_row(a: f64, x: RowRef<'_>, w: &mut [f64]) {
    for (&i, &v) in x.indices.iter().zip(x.values) {
        w[i as usize] += a * v as f64;
    }
}

/// `w ← w + a·x` for sparse `x` (scatter, element-wise) — delegates to
/// [`axpy_row`].
#[inline]
pub fn axpy_sparse(a: f64, x: &SparseVec, w: &mut [f64]) {
    axpy_row(a, x.as_row(), w)
}

/// Scaled-representation dot `⟨s·v, x⟩ = s·⟨v, x⟩` — the reference
/// reduction for [`Kernel::dot_scaled_row`]: the [`dot_row`] gather
/// followed by one scale multiply.
#[inline]
pub fn dot_scaled_row(x: RowRef<'_>, v: &[f64], scale: f64) -> f64 {
    scale * dot_row(x, v)
}

/// Scaled-representation sparse update `w ← w + c·x` over `w = scale·v`:
/// scatters `v[i] += (c/scale)·x_i` and maintains the caller's `‖v‖²`
/// cache incrementally (`norm_sq_v += new² − old²` per touched slot, in
/// index order — the accumulation order is part of the reference
/// contract, since the cache feeds the O(1) projection). Element-wise:
/// bitwise backend-invariant.
#[inline]
pub fn axpy_scaled_row(c: f64, x: RowRef<'_>, scale: f64, v: &mut [f64], norm_sq_v: &mut f64) {
    let ci = c / scale;
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        let slot = &mut v[i as usize];
        let old = *slot;
        let new = old + ci * xv as f64;
        *slot = new;
        *norm_sq_v += new * new - old * old;
    }
}

/// The O(1) lazy regularization shrink over `w = scale·v`: `scale ← c·scale`.
/// Returns `true` when `|scale|` has drifted below
/// [`crate::linalg::scaled::RESCALE_THRESHOLD`] and the caller must fold
/// the scale into storage ([`crate::linalg::ScaledIterate::rescale`])
/// before the next update divides by it.
#[inline]
pub fn shrink(scale: &mut f64, c: f64) -> bool {
    *scale *= c;
    scale.abs() < crate::linalg::scaled::RESCALE_THRESHOLD
}

/// One destination panel of the blocked `Bᵀ`-apply (see
/// [`Kernel::gemv_panel`] for the contract): ascending-`i` accumulation,
/// zero coefficients skipped, the inner `k` loop a dense axpy over the
/// panel.
#[inline]
pub fn gemv_panel(
    dst: &mut [f64],
    coeffs: &[f64],
    coeff_stride: usize,
    rows: usize,
    src: &[f64],
    src_stride: usize,
    src_off: usize,
) {
    let width = dst.len();
    for i in 0..rows {
        let c = coeffs[i * coeff_stride];
        if c == 0.0 {
            continue;
        }
        let base = i * src_stride + src_off;
        let panel = &src[base..base + width];
        for (o, &s) in dst.iter_mut().zip(panel) {
            *o += c * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernel::Kernel;

    #[test]
    fn trait_methods_match_free_functions_bitwise() {
        let k = ScalarKernel;
        let x: Vec<f64> = (0..19).map(|i| (i as f64 * 0.37).sin()).collect();
        let y: Vec<f64> = (0..19).map(|i| (i as f64 * 0.61).cos()).collect();
        assert_eq!(k.dot(&x, &y).to_bits(), dot(&x, &y).to_bits());
        let sp = SparseVec::new(vec![1, 4, 17], vec![0.5, -2.0, 3.25]);
        assert_eq!(k.dot_sparse(&sp, &x).to_bits(), dot_sparse(&sp, &x).to_bits());
        let mut a = y.clone();
        let mut b = y.clone();
        k.axpy(0.3, &x, &mut a);
        axpy(0.3, &x, &mut b);
        assert_eq!(a, b);
        k.scale_add(0.9, &mut a, -0.2, &x);
        scale_add(0.9, &mut b, -0.2, &x);
        assert_eq!(a, b);
    }

    #[test]
    fn dot_matches_reference_order() {
        // length 7 exercises both the unrolled body and the tail loop
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let y = [1.0; 7];
        assert_eq!(dot(&x, &y), 28.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn scale_add_blends() {
        let mut y = vec![1.0, 2.0];
        scale_add(0.5, &mut y, 2.0, &[3.0, -1.0]);
        assert_eq!(y, vec![6.5, -1.0]);
    }

    #[test]
    fn gemv_panel_accumulates_ascending_rows() {
        // src: 3 rows × stride 4, panel = columns 1..3
        let src = vec![
            1.0, 2.0, 3.0, 4.0, //
            5.0, 6.0, 7.0, 8.0, //
            9.0, 10.0, 11.0, 12.0,
        ];
        // coeffs with stride 2: rows 0/1/2 → 0.5, 0.0 (skipped), 2.0
        let coeffs = vec![0.5, 99.0, 0.0, 99.0, 2.0];
        let mut dst = vec![100.0, 200.0];
        gemv_panel(&mut dst, &coeffs, 2, 3, &src, 4, 1);
        // dst += 0.5·[2,3] + 2·[10,11]
        assert_eq!(dst, vec![100.0 + 1.0 + 20.0, 200.0 + 1.5 + 22.0]);
    }

    #[test]
    fn gemv_panel_matches_naive_double_loop_bitwise() {
        let mut rng = crate::rng::Rng::new(7);
        let (rows, stride, off, width) = (5usize, 11usize, 3usize, 6usize);
        let src: Vec<f64> = (0..rows * stride).map(|_| rng.normal()).collect();
        let coeffs: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let mut dst = vec![0.0f64; width];
        gemv_panel(&mut dst, &coeffs, 1, rows, &src, stride, off);
        let mut expect = vec![0.0f64; width];
        for i in 0..rows {
            for k in 0..width {
                expect[k] += coeffs[i] * src[i * stride + off + k];
            }
        }
        for (a, b) in dst.iter().zip(&expect) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn hinge_provided_method_flags_violators() {
        let k = ScalarKernel;
        let rows = vec![
            SparseVec::new(vec![0], vec![1.0]),  // margin 1·2 = 2 (ok)
            SparseVec::new(vec![1], vec![1.0]),  // margin 1·0.5 (violator)
            SparseVec::new(vec![0], vec![-1.0]), // label −1 ⇒ margin 2 (ok)
        ];
        let labels = vec![1i8, 1, -1];
        let v = vec![4.0, 1.0];
        let mut violators = Vec::new();
        k.hinge_subgrad_accum(
            &v,
            0.5,
            crate::linalg::RowsView::Vecs(&rows),
            &labels,
            &[0, 1, 2, 1],
            &mut violators,
        );
        assert_eq!(violators, vec![1, 1]); // duplicates preserved in draw order
    }

    #[test]
    fn scaled_ops_match_unscaled_reference() {
        let k = ScalarKernel;
        let x = SparseVec::new(vec![0, 2, 4], vec![1.5, -2.0, 0.25]);
        let v = vec![0.3, 9.0, -1.1, 9.0, 4.0];
        // dot: s·⟨v,x⟩, bit-for-bit one multiply after the reference gather
        let want = 0.5 * dot_row(x.as_row(), &v);
        assert_eq!(dot_scaled_row(x.as_row(), &v, 0.5).to_bits(), want.to_bits());
        assert_eq!(k.dot_scaled_row(x.as_row(), &v, 0.5).to_bits(), want.to_bits());
        // axpy: with scale 1 the scatter is exactly axpy_row, and the norm
        // cache follows the documented incremental order
        let mut a = v.clone();
        let mut b = v.clone();
        let mut cache = 0.0;
        axpy_scaled_row(0.7, x.as_row(), 1.0, &mut a, &mut cache);
        axpy_row(0.7, x.as_row(), &mut b);
        assert_eq!(a, b);
        let mut expect_cache = 0.0;
        for &i in x.indices.iter() {
            let (old, new) = (v[i as usize], a[i as usize]);
            expect_cache += new * new - old * old;
        }
        assert_eq!(cache.to_bits(), expect_cache.to_bits());
        // trait provided method shares the loop bitwise
        let mut c = v.clone();
        let mut cache_k = 0.0;
        k.axpy_scaled_row(0.7, x.as_row(), 1.0, &mut c, &mut cache_k);
        assert_eq!(c, a);
        assert_eq!(cache_k.to_bits(), cache.to_bits());
    }

    #[test]
    fn shrink_multiplies_and_flags_underflow() {
        let mut s = 1.0;
        assert!(!shrink(&mut s, 0.5));
        assert_eq!(s, 0.5);
        let k = ScalarKernel;
        assert!(!k.shrink(&mut s, 0.5));
        assert_eq!(s, 0.25);
        let mut tiny = crate::linalg::scaled::RESCALE_THRESHOLD * 1.5;
        assert!(shrink(&mut tiny, 0.5), "crossing the threshold must flag");
        // the flag fires on magnitude, not sign
        let mut neg = -(crate::linalg::scaled::RESCALE_THRESHOLD * 1.5);
        assert!(shrink(&mut neg, 0.5));
    }

    #[test]
    fn score_rows_provided_method() {
        let k = ScalarKernel;
        let rows = vec![
            SparseVec::new(vec![0, 2], vec![1.0, 2.0]),
            SparseVec::default(),
        ];
        let w = vec![1.0, 0.0, -0.5];
        let mut out = vec![0.0; 2];
        k.score_rows(&w, 0.25, &rows, &mut out);
        assert_eq!(out, vec![1.0 - 1.0 + 0.25, 0.25]);
    }
}
