//! The lane-split SIMD backend: explicit-width reductions with a fixed
//! reduction tree.
//!
//! ## Shape
//!
//! The reducing kernels split the accumulation across a fixed number of
//! independent lanes — [`DENSE_LANES`] (8) for dense dots, [`SPARSE_LANES`]
//! (4) for sparse gather dots — and combine the lane partials with a
//! **fixed pairwise reduction tree**:
//!
//! ```text
//! dense:  (((l0+l1) + (l2+l3)) + ((l4+l5) + (l6+l7))) + tail
//! sparse: ((l0+l1) + (l2+l3)) + tail
//! ```
//!
//! where `tail` sequentially accumulates the `n % LANES` trailing
//! elements. The tree depends only on the input *length*, never on
//! alignment or runtime state, so the backend is fully deterministic —
//! just deterministic in a *different* association than the scalar
//! reference.
//!
//! This is portable stable Rust: the lane arrays are shaped so LLVM's
//! auto-vectorizer emits wide vector loads/FMAs on any target with vector
//! units, and the code still compiles (and runs correctly, if more slowly)
//! everywhere else — which is the "portable fallback" that lets toolchains
//! without `std::simd` build the backend. A `std::simd` (or arch
//! intrinsic) specialization can later replace the loop bodies without
//! touching the reduction-tree contract.
//!
//! ## Accuracy contract (the documented ULP bound)
//!
//! Scalar and SIMD compute the *same products* — multiplication order is
//! identical — and differ only in summation association. Two associations
//! of the same `n` products differ by at most `2·γₙ·Σ|xᵢ·yᵢ|` with
//! `γₙ = n·ε/(1−n·ε)` (standard summation error analysis), so this backend
//! guarantees
//!
//! ```text
//! |dot_simd − dot_scalar| ≤ 4·n·ε·Σ|xᵢ·yᵢ|      (ε = f64::EPSILON)
//! ```
//!
//! — i.e. within `4n` ulps *of the absolute-product mass*, not of the
//! (possibly cancelled) result. `rust/tests/kernel_equivalence.rs` pins
//! this bound on adversarial inputs (denormals, `-0.0`, mixed magnitudes,
//! non-multiple-of-lane lengths). Everything element-wise delegates to the
//! canonical loops in [`super::scalar`] and is **bitwise** identical to
//! the scalar backend — see the module docs of [`super`].

use super::Kernel;
use crate::linalg::RowRef;

/// Accumulator lanes for the dense dot (wide enough for two 4-wide FMA
/// pipes on current x86/ARM cores).
pub const DENSE_LANES: usize = 8;
/// Accumulator lanes for the sparse gather dot (gathers bottleneck on the
/// load ports; wider splits only add reduction latency).
pub const SPARSE_LANES: usize = 4;

/// The lane-split backend (stateless; use [`super::simd()`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimdKernel;

impl Kernel for SimdKernel {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn dot(&self, x: &[f64], y: &[f64]) -> f64 {
        assert_eq!(x.len(), y.len(), "dot: length mismatch");
        let n = x.len();
        let chunks = n / DENSE_LANES;
        let mut acc = [0.0f64; DENSE_LANES];
        for c in 0..chunks {
            let j = DENSE_LANES * c;
            // The fixed-stride lane update LLVM turns into vector FMAs.
            for (l, a) in acc.iter_mut().enumerate() {
                *a += x[j + l] * y[j + l];
            }
        }
        let mut tail = 0.0;
        for j in DENSE_LANES * chunks..n {
            tail += x[j] * y[j];
        }
        // Fixed pairwise reduction tree (length-determined, see module docs).
        (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
    }

    fn dot_row(&self, x: RowRef<'_>, w: &[f64]) -> f64 {
        let idx = x.indices;
        let val = x.values;
        let n = idx.len();
        let chunks = n / SPARSE_LANES;
        let mut acc = [0.0f64; SPARSE_LANES];
        for c in 0..chunks {
            let j = SPARSE_LANES * c;
            for (l, a) in acc.iter_mut().enumerate() {
                *a += w[idx[j + l] as usize] * val[j + l] as f64;
            }
        }
        let mut tail = 0.0;
        for j in SPARSE_LANES * chunks..n {
            tail += w[idx[j] as usize] * val[j] as f64;
        }
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail
    }
    // dot_sparse: the provided borrow-and-delegate body routes owned rows
    // through this backend's `dot_row` — same lane split, bit for bit.
    // axpy / axpy_row / scale_add / axpy_sparse / gemv_panel: element-wise
    // — the provided trait bodies (the canonical scalar loops) are already
    // optimal shapes for the auto-vectorizer, and sharing them is what
    // keeps these operations bitwise backend-invariant by construction.
    // hinge_subgrad_accum / score_rows: the provided bodies route through
    // this backend's `dot_row`, inheriting the lane split.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernel::Kernel;
    use crate::linalg::SparseVec;

    fn ramp(n: usize, seed: u64) -> Vec<f64> {
        let mut r = crate::rng::Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    /// The documented bound: |simd − scalar| ≤ 4·n·ε·Σ|xᵢyᵢ|.
    fn assert_within_bound(n: usize, simd: f64, scalar: f64, abs_mass: f64) {
        let tol = 4.0 * n as f64 * f64::EPSILON * abs_mass + f64::MIN_POSITIVE;
        assert!(
            (simd - scalar).abs() <= tol,
            "n={n}: |{simd} − {scalar}| > {tol}"
        );
    }

    #[test]
    fn dot_within_documented_bound_of_scalar_at_all_lane_phases() {
        let k = SimdKernel;
        let s = super::super::ScalarKernel;
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 63, 64, 100, 1024 + 5] {
            let x = ramp(n, 1 + n as u64);
            let y = ramp(n, 1000 + n as u64);
            let mass: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
            assert_within_bound(n, k.dot(&x, &y), s.dot(&x, &y), mass);
        }
    }

    #[test]
    fn dot_exact_on_integer_data() {
        // Integer-valued inputs: every partial sum is exact in f64, so any
        // association gives the same answer — simd must equal scalar
        // exactly here.
        let k = SimdKernel;
        let x: Vec<f64> = (0..37).map(|i| (i % 7) as f64 - 3.0).collect();
        let y: Vec<f64> = (0..37).map(|i| (i % 5) as f64).collect();
        assert_eq!(k.dot(&x, &y), super::super::scalar::dot(&x, &y));
    }

    #[test]
    fn dot_sparse_lane_split_matches_dense_dot_semantics() {
        let k = SimdKernel;
        let w = ramp(40, 9);
        let idx: Vec<u32> = vec![0, 3, 7, 11, 12, 19, 23, 31, 39];
        let val: Vec<f32> = idx.iter().map(|&i| (i as f32 * 0.25) - 2.0).collect();
        let sp = SparseVec::new(idx.clone(), val.clone());
        let scalar = super::super::scalar::dot_sparse(&sp, &w);
        let mass: f64 = idx
            .iter()
            .zip(&val)
            .map(|(&i, &v)| (w[i as usize] * v as f64).abs())
            .sum();
        assert_within_bound(idx.len(), k.dot_sparse(&sp, &w), scalar, mass);
    }

    #[test]
    fn element_wise_ops_are_bitwise_scalar() {
        let k = SimdKernel;
        let s = super::super::ScalarKernel;
        let x = ramp(23, 4);
        let mut a = ramp(23, 5);
        let mut b = a.clone();
        k.axpy(1.5, &x, &mut a);
        s.axpy(1.5, &x, &mut b);
        assert_eq!(a, b);
        k.scale_add(0.75, &mut a, -2.0, &x);
        s.scale_add(0.75, &mut b, -2.0, &x);
        for (p, q) in a.iter().zip(&b) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }

    #[test]
    fn empty_and_single_element_inputs() {
        let k = SimdKernel;
        assert_eq!(k.dot(&[], &[]), 0.0);
        assert_eq!(k.dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(k.dot_sparse(&SparseVec::default(), &[1.0, 2.0]), 0.0);
    }
}
