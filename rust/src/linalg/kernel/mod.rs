//! The unified kernel layer: one [`Kernel`] trait behind every dense/sparse
//! hot loop in the system.
//!
//! Every hot inner loop — the solver sub-gradient dots/axpys (Algorithm 2's
//! local step), the Push-Vector `Bᵀ`-apply panel loop, and the sharded
//! scorer's margin computation — dispatches through this trait instead of a
//! hand-rolled per-call-site loop. Two backends exist:
//!
//! * [`ScalarKernel`] — the original loops, **bit for bit**. This is the
//!   determinism reference: everything the `Parallel ≡ Sequential` bitwise
//!   contract pins runs on it.
//! * [`SimdKernel`] — explicit-width lane splitting with a **fixed
//!   reduction tree** for the reducing operations. Reassociation changes
//!   f64 rounding, so this backend lives *outside* the bitwise contract and
//!   under its own ULP-bounded equivalence suite
//!   (`rust/tests/kernel_equivalence.rs`). Selecting it at runtime
//!   (`[runtime] kernel = "simd"` / `--kernel simd`) requires building with
//!   `--features simd`; the implementation itself is portable stable Rust
//!   (no `std::simd` needed — the lane-split loops are shaped so LLVM emits
//!   vector code on any target), so the type always compiles and the
//!   default build still unit-tests it.
//!
//! ## Which operations diverge between backends
//!
//! Only **reductions** have ordering freedom: [`Kernel::dot`] and
//! [`Kernel::dot_row`] (plus its owned-row delegate [`Kernel::dot_sparse`]
//! and the provided methods built on them —
//! [`Kernel::hinge_subgrad_accum`], [`Kernel::score_rows`]) may reassociate
//! and therefore differ between backends by a documented ULP bound (see
//! [`simd`]). The element-wise operations — [`Kernel::axpy`],
//! [`Kernel::scale_add`], [`Kernel::axpy_row`]/[`Kernel::axpy_sparse`],
//! [`Kernel::gemv_panel`] — have exactly one evaluation order per output
//! element, so they are **bitwise backend-invariant** by construction and
//! share the canonical loops in [`scalar`]. This split is what keeps the
//! Push-Vector mixing round (pure `gemv_panel`) bitwise identical under
//! *every* backend while the margin dots legitimately differ.
//!
//! ## Zero-copy rows
//!
//! Since the out-of-core data plane, the sparse entry points take borrowed
//! [`crate::linalg::RowRef`] slices (and [`crate::linalg::RowsView`] row
//! batches) rather than requiring owned [`SparseVec`]s: a row coming off a
//! memory-mapped CSR pack flows into the same hot loop as a heap row, with
//! no per-row materialization. `dot_sparse`/`axpy_sparse` survive as thin
//! borrowing delegates, so owned-row call sites are unchanged and
//! bit-for-bit equivalent.
//!
//! ## Selection
//!
//! [`KernelKind`] (config `[runtime] kernel = "scalar" | "simd" | "auto"`,
//! CLI `--kernel`) resolves to a `&'static dyn Kernel` via
//! [`KernelKind::build`]: `scalar` always works; `simd` errors unless the
//! crate was built with `--features simd`; `auto` picks `simd` when the
//! feature is compiled in and `scalar` otherwise. The resolved handle
//! threads through `Scheduler` construction (the schedulers carry it to the
//! mixing round), through backend construction (the local step), and
//! through `ShardedScorer` (batch scoring) — see DESIGN.md §Kernel
//! backends.

pub mod scalar;
pub mod simd;

pub use scalar::ScalarKernel;
pub use simd::SimdKernel;

use crate::linalg::{RowRef, RowsView, SparseVec};

/// The object-safe kernel interface behind every hot loop.
///
/// Implementations must be stateless (`Send + Sync`, shared as
/// `&'static dyn Kernel`): a kernel only chooses *how* arithmetic is
/// evaluated, never carries data between calls.
pub trait Kernel: Send + Sync + std::fmt::Debug {
    /// Backend name for reports and logs (`"scalar"` / `"simd"`).
    fn name(&self) -> &'static str;

    /// Dense dot product `xᵀy`. **Reduction** — the summation order is
    /// backend-defined ([`ScalarKernel`] is the reference order).
    ///
    /// # Panics
    /// Panics if `x.len() != y.len()`.
    fn dot(&self, x: &[f64], y: &[f64]) -> f64;

    /// Sparse–dense dot `⟨x, w⟩` over a *borrowed* row — index/value
    /// slices straight out of a heap `SparseVec` or a memory-mapped CSR
    /// pack, with no per-row materialization (gather reduction; order
    /// backend-defined). This is the required zero-copy entry point every
    /// hot loop bottoms out in; [`Kernel::dot_sparse`] is a provided
    /// delegate. Out-of-range indices panic.
    fn dot_row(&self, x: RowRef<'_>, w: &[f64]) -> f64;

    /// Sparse–dense dot `⟨x, w⟩` for an owned row. Provided: borrows and
    /// delegates to [`Kernel::dot_row`], so it is bit-for-bit the same
    /// reduction.
    fn dot_sparse(&self, x: &SparseVec, w: &[f64]) -> f64 {
        self.dot_row(x.as_row(), w)
    }

    /// `y ← y + a·x`. Element-wise: bitwise identical across backends.
    ///
    /// # Panics
    /// Panics if `x.len() != y.len()`.
    fn axpy(&self, a: f64, x: &[f64], y: &mut [f64]) {
        scalar::axpy(a, x, y);
    }

    /// `y ← a·y + b·x` (the unscaled Pegasos/consensus blend form).
    /// Element-wise: bitwise identical across backends.
    ///
    /// No in-tree hot loop needs this today — the solvers carry the blend
    /// inside the O(1)-shrink scaled representation instead
    /// (`linalg::scaled`). It completes the level-1 contract for external
    /// and future consumers (the XLA implementation slot foremost) and is
    /// pinned by the equivalence suite and the hotpath bench like every
    /// other method.
    ///
    /// # Panics
    /// Panics if `x.len() != y.len()`.
    fn scale_add(&self, a: f64, y: &mut [f64], b: f64, x: &[f64]) {
        scalar::scale_add(a, y, b, x);
    }

    /// `w ← w + a·x` for a borrowed sparse row (scatter). Element-wise:
    /// bitwise identical across backends — the zero-copy twin of
    /// [`Kernel::axpy_sparse`].
    fn axpy_row(&self, a: f64, x: RowRef<'_>, w: &mut [f64]) {
        scalar::axpy_row(a, x, w);
    }

    /// `w ← w + a·x` for sparse `x` (scatter). Element-wise: bitwise
    /// identical across backends.
    fn axpy_sparse(&self, a: f64, x: &SparseVec, w: &mut [f64]) {
        scalar::axpy_sparse(a, x, w);
    }

    /// One destination panel of the blocked `Bᵀ`-apply:
    ///
    /// `dst[k] += Σ_i coeffs[i·coeff_stride] · src[i·src_stride + src_off + k]`
    ///
    /// accumulated over **ascending** `i ∈ 0..rows`, skipping zero
    /// coefficients. The accumulation order per output element is part of
    /// the contract (it is what makes the Push-Vector column split bitwise
    /// executor- and backend-invariant), so every backend evaluates it
    /// identically; lane splitting may only run across `k`.
    ///
    /// # Panics
    /// Panics if a source panel `[i·src_stride + src_off, +dst.len())`
    /// falls outside `src`, or `coeffs` is shorter than the strided access
    /// pattern requires.
    fn gemv_panel(
        &self,
        dst: &mut [f64],
        coeffs: &[f64],
        coeff_stride: usize,
        rows: usize,
        src: &[f64],
        src_stride: usize,
        src_off: usize,
    ) {
        scalar::gemv_panel(dst, coeffs, coeff_stride, rows, src, src_stride, src_off);
    }

    /// Scaled-representation dot `⟨s·v, x⟩ = s·⟨v, x⟩` — the margin dot of
    /// the O(nnz) scaled-iterate step (`w = s·v`, see
    /// [`crate::linalg::ScaledIterate`]). **Reduction**: built on
    /// [`Kernel::dot_row`], so backends may differ within the dot's ULP
    /// bound; the trailing scale multiply is a single rounding in every
    /// backend.
    fn dot_scaled_row(&self, x: RowRef<'_>, v: &[f64], scale: f64) -> f64 {
        scale * self.dot_row(x, v)
    }

    /// Scaled-representation sparse update `w ← w + c·x` over `w = scale·v`
    /// (scatter `v[i] += (c/scale)·x_i`, incrementally maintaining the
    /// caller's `‖v‖²` cache). Element-wise: bitwise identical across
    /// backends ([`scalar::axpy_scaled_row`] is the shared loop).
    fn axpy_scaled_row(
        &self,
        c: f64,
        x: RowRef<'_>,
        scale: f64,
        v: &mut [f64],
        norm_sq_v: &mut f64,
    ) {
        scalar::axpy_scaled_row(c, x, scale, v, norm_sq_v);
    }

    /// The O(1) lazy regularization shrink `scale ← c·scale`; returns
    /// `true` when the caller must fold the scale into storage (the
    /// deferred-renormalization rule — see
    /// [`crate::linalg::scaled::RESCALE_THRESHOLD`]). A single f64
    /// multiply: bitwise identical across backends.
    fn shrink(&self, scale: &mut f64, c: f64) -> bool {
        scalar::shrink(scale, c)
    }

    /// The margin half of a mini-batch hinge sub-gradient step over the
    /// scaled weight representation `w = scale·v`: for each sampled row
    /// index `i` in `batch` (in order, duplicates allowed), computes the
    /// margin `labels[i] · scale·⟨v, rows[i]⟩` and appends `i` to
    /// `violators` when it is `< 1`. Takes a [`RowsView`] so the heap and
    /// mmap data planes share one hot loop; built on [`Kernel::dot_row`],
    /// so backends may differ for margins within the dot's ULP bound of 1.
    fn hinge_subgrad_accum(
        &self,
        v: &[f64],
        scale: f64,
        rows: RowsView<'_>,
        labels: &[i8],
        batch: &[usize],
        violators: &mut Vec<usize>,
    ) {
        for &i in batch {
            let margin = labels[i] as f64 * (scale * self.dot_row(rows.row(i), v));
            if margin < 1.0 {
                violators.push(i);
            }
        }
    }

    /// Batched margins `out[r] = ⟨w, rows[r]⟩ + bias` — the scorer's hot
    /// loop. Built on [`Kernel::dot_sparse`].
    ///
    /// # Panics
    /// Panics if `rows.len() != out.len()`.
    fn score_rows(&self, w: &[f64], bias: f64, rows: &[SparseVec], out: &mut [f64]) {
        assert_eq!(rows.len(), out.len(), "score_rows: length mismatch");
        for (o, r) in out.iter_mut().zip(rows) {
            *o = self.dot_sparse(r, w) + bias;
        }
    }
}

/// The shared scalar backend instance.
static SCALAR_KERNEL: ScalarKernel = ScalarKernel;
/// The shared SIMD backend instance (always compiled; runtime-selectable
/// only behind `--features simd` — see [`KernelKind::build`]).
static SIMD_KERNEL: SimdKernel = SimdKernel;

/// The scalar reference backend — the default everywhere.
pub fn scalar() -> &'static dyn Kernel {
    &SCALAR_KERNEL
}

/// The lane-split SIMD backend (tests and benches may use it directly;
/// runtime selection goes through [`KernelKind::build`]).
pub fn simd() -> &'static dyn Kernel {
    &SIMD_KERNEL
}

/// The configured kernel choice (`[runtime] kernel` / `--kernel`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    /// The reference backend — bit-for-bit the original loops; the only
    /// backend under the bitwise `Parallel ≡ Sequential` contract.
    #[default]
    Scalar,
    /// Explicit lane-split backend; requires `--features simd` and its own
    /// ULP-bounded equivalence tolerance.
    Simd,
    /// `simd` when compiled in, `scalar` otherwise.
    Auto,
}

impl std::str::FromStr for KernelKind {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(Self::Scalar),
            "simd" => Ok(Self::Simd),
            "auto" => Ok(Self::Auto),
            other => Err(format!("unknown kernel {other:?} (scalar | simd | auto)")),
        }
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Scalar => "scalar",
            Self::Simd => "simd",
            Self::Auto => "auto",
        })
    }
}

impl KernelKind {
    /// Resolves the configured choice to a backend handle.
    ///
    /// `Simd` without the `simd` cargo feature is an error rather than a
    /// silent fallback — a benchmark log claiming `kernel=simd` must never
    /// have measured the scalar path.
    pub fn build(self) -> crate::Result<&'static dyn Kernel> {
        match self {
            Self::Scalar => Ok(scalar()),
            Self::Simd => {
                if cfg!(feature = "simd") {
                    Ok(simd())
                } else {
                    anyhow::bail!(
                        "kernel = \"simd\" requires a build with `--features simd` \
                         (this binary was built without it; use kernel = \"scalar\" \
                         or \"auto\", or rebuild)"
                    )
                }
            }
            Self::Auto => {
                if cfg!(feature = "simd") {
                    Ok(simd())
                } else {
                    Ok(scalar())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_and_display() {
        assert_eq!("scalar".parse::<KernelKind>().unwrap(), KernelKind::Scalar);
        assert_eq!("simd".parse::<KernelKind>().unwrap(), KernelKind::Simd);
        assert_eq!("auto".parse::<KernelKind>().unwrap(), KernelKind::Auto);
        assert!("avx9".parse::<KernelKind>().is_err());
        assert_eq!(KernelKind::Scalar.to_string(), "scalar");
        assert_eq!(KernelKind::Simd.to_string(), "simd");
        assert_eq!(KernelKind::Auto.to_string(), "auto");
        assert_eq!(KernelKind::default(), KernelKind::Scalar);
    }

    #[test]
    fn scalar_always_builds() {
        assert_eq!(KernelKind::Scalar.build().unwrap().name(), "scalar");
    }

    #[test]
    fn auto_resolves_per_feature() {
        let k = KernelKind::Auto.build().unwrap();
        if cfg!(feature = "simd") {
            assert_eq!(k.name(), "simd");
        } else {
            assert_eq!(k.name(), "scalar");
        }
    }

    #[test]
    fn simd_selection_gated_by_feature() {
        match KernelKind::Simd.build() {
            Ok(k) => {
                assert!(cfg!(feature = "simd"));
                assert_eq!(k.name(), "simd");
            }
            Err(e) => {
                assert!(!cfg!(feature = "simd"));
                assert!(e.to_string().contains("--features simd"), "{e}");
            }
        }
    }

    #[test]
    fn handles_name_their_backend() {
        assert_eq!(scalar().name(), "scalar");
        assert_eq!(simd().name(), "simd");
    }
}
