//! Sparse vectors in LIBSVM style: sorted `(index, value)` pairs.
//!
//! The paper's text corpora (CCAT at 47k features, Reuters at 8.3k) are
//! 99.8%+ sparse; the per-sample work in every solver is `⟨w, x⟩` and
//! `w ← w + a·x`, both of which must cost `O(nnz)` — these two operations
//! are the single hottest code in the native backend (see flamegraph notes
//! in EXPERIMENTS.md §Perf).

/// A sparse feature vector with strictly increasing indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// Strictly increasing feature indices (0-based).
    pub indices: Vec<u32>,
    /// Values aligned with `indices`.
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Builds from parallel slices, validating sortedness.
    ///
    /// # Panics
    /// Panics if lengths differ or indices are not strictly increasing.
    pub fn new(indices: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(indices.len(), values.len(), "SparseVec: length mismatch");
        for w in indices.windows(2) {
            assert!(w[0] < w[1], "SparseVec: indices must strictly increase");
        }
        Self { indices, values }
    }

    /// Builds from a dense slice, dropping exact zeros.
    pub fn from_dense(x: &[f64]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in x.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v as f32);
            }
        }
        Self { indices, values }
    }

    /// Number of stored (non-zero) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Smallest dense dimension that can hold this vector.
    #[inline]
    pub fn min_dim(&self) -> usize {
        self.indices.last().map_or(0, |&i| i as usize + 1)
    }

    /// Sparse–dense dot product `⟨self, w⟩` — the scalar reference
    /// reduction ([`crate::linalg::kernel::scalar::dot_sparse`]).
    /// Out-of-range indices panic.
    #[inline]
    pub fn dot_dense(&self, w: &[f64]) -> f64 {
        crate::linalg::kernel::scalar::dot_sparse(self, w)
    }

    /// `w ← w + a·self` (scatter-add; element-wise, identical in every
    /// kernel backend).
    #[inline]
    pub fn axpy_into(&self, a: f64, w: &mut [f64]) {
        crate::linalg::kernel::scalar::axpy_sparse(a, self, w)
    }

    /// Squared Euclidean norm.
    #[inline]
    pub fn l2_norm_sq(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Materializes into a dense vector of dimension `d`.
    ///
    /// # Panics
    /// Panics if `d < self.min_dim()`.
    pub fn to_dense(&self, d: usize) -> Vec<f64> {
        assert!(d >= self.min_dim(), "to_dense: dimension too small");
        let mut out = vec![0.0; d];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v as f64;
        }
        out
    }

    /// Scales all values in place.
    pub fn scale(&mut self, a: f32) {
        for v in &mut self.values {
            *v *= a;
        }
    }

    /// Borrows this vector as a zero-copy [`RowRef`].
    #[inline]
    pub fn as_row(&self) -> RowRef<'_> {
        RowRef { indices: &self.indices, values: &self.values }
    }
}

/// A borrowed sparse row: index/value slices with no owning allocation.
///
/// This is the zero-copy unit of the out-of-core data plane: a row of a
/// memory-mapped CSR pack *and* a borrowed view of a heap [`SparseVec`]
/// both present as `RowRef`, so the kernel hot loops
/// ([`crate::linalg::kernel::Kernel::dot_row`] and friends) never require
/// per-row materialization. Invariants are those of [`SparseVec`]
/// (strictly increasing indices, parallel slices); producers validate,
/// consumers assume.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowRef<'a> {
    /// Strictly increasing feature indices (0-based).
    pub indices: &'a [u32],
    /// Values aligned with `indices`.
    pub values: &'a [f32],
}

impl<'a> RowRef<'a> {
    /// Number of stored (non-zero) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Smallest dense dimension that can hold this row.
    #[inline]
    pub fn min_dim(&self) -> usize {
        self.indices.last().map_or(0, |&i| i as usize + 1)
    }

    /// Sparse–dense dot product `⟨self, w⟩` — the scalar reference
    /// reduction ([`crate::linalg::kernel::scalar::dot_row`]).
    /// Out-of-range indices panic.
    #[inline]
    pub fn dot_dense(&self, w: &[f64]) -> f64 {
        crate::linalg::kernel::scalar::dot_row(*self, w)
    }

    /// `w ← w + a·self` (scatter-add; element-wise, identical in every
    /// kernel backend).
    #[inline]
    pub fn axpy_into(&self, a: f64, w: &mut [f64]) {
        crate::linalg::kernel::scalar::axpy_row(a, *self, w)
    }

    /// Squared Euclidean norm (same accumulation order as
    /// [`SparseVec::l2_norm_sq`]).
    #[inline]
    pub fn l2_norm_sq(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Copies into an owned [`SparseVec`].
    pub fn to_owned(&self) -> SparseVec {
        SparseVec { indices: self.indices.to_vec(), values: self.values.to_vec() }
    }
}

impl<'a> From<&'a SparseVec> for RowRef<'a> {
    #[inline]
    fn from(x: &'a SparseVec) -> Self {
        x.as_row()
    }
}

/// A borrowed batch of sparse rows — either a slice of heap
/// [`SparseVec`]s (the classic in-memory plane) or a CSR window over
/// columnar index/value arrays (the mmap-backed plane). Both present rows
/// as [`RowRef`], so every consumer downstream of
/// [`crate::data::ShardView`] is layout-agnostic.
#[derive(Clone, Copy, Debug)]
pub enum RowsView<'a> {
    /// Rows as individually-allocated sparse vectors.
    Vecs(&'a [SparseVec]),
    /// Rows as a CSR window: row `i` spans
    /// `indices[indptr[i]..indptr[i+1]]` / `values[..]`. The `indptr`
    /// offsets are **absolute** positions into the full arrays, so a
    /// shard window is just `&indptr[r0..=r1]` plus the untouched
    /// index/value arrays — no per-shard rebasing.
    Csr {
        /// Row-boundary offsets, length `rows + 1`, non-decreasing.
        indptr: &'a [u64],
        /// Column indices for all rows, strictly increasing within a row.
        indices: &'a [u32],
        /// Values aligned with `indices`.
        values: &'a [f32],
    },
}

impl<'a> RowsView<'a> {
    /// Number of rows in the view.
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            Self::Vecs(rows) => rows.len(),
            Self::Csr { indptr, .. } => indptr.len().saturating_sub(1),
        }
    }

    /// True when the view holds no rows.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrows row `i`.
    ///
    /// # Panics
    /// Panics if `i >= self.len()`.
    #[inline]
    pub fn row(&self, i: usize) -> RowRef<'a> {
        match self {
            Self::Vecs(rows) => rows[i].as_row(),
            Self::Csr { indptr, indices, values } => {
                let lo = indptr[i] as usize;
                let hi = indptr[i + 1] as usize;
                RowRef { indices: &indices[lo..hi], values: &values[lo..hi] }
            }
        }
    }

    /// Iterates rows in order.
    pub fn iter(&self) -> impl Iterator<Item = RowRef<'a>> {
        let v = *self;
        (0..v.len()).map(move |i| v.row(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_dense() {
        let d = vec![0.0, 1.5, 0.0, -2.0];
        let s = SparseVec::from_dense(&d);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.min_dim(), 4);
        assert_eq!(s.to_dense(4), d);
        assert_eq!(s.to_dense(6)[4..], [0.0, 0.0]);
    }

    #[test]
    fn dot_and_axpy() {
        let s = SparseVec::new(vec![1, 3], vec![2.0, -1.0]);
        let mut w = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(s.dot_dense(&w), 2.0 * 2.0 - 4.0);
        s.axpy_into(0.5, &mut w);
        assert_eq!(w, vec![1.0, 3.0, 3.0, 3.5]);
    }

    #[test]
    fn norm() {
        let s = SparseVec::new(vec![0, 2], vec![3.0, 4.0]);
        assert_eq!(s.l2_norm_sq(), 25.0);
    }

    #[test]
    #[should_panic(expected = "strictly increase")]
    fn unsorted_panics() {
        SparseVec::new(vec![3, 1], vec![1.0, 1.0]);
    }

    #[test]
    fn empty_vector() {
        let s = SparseVec::default();
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.min_dim(), 0);
        assert_eq!(s.dot_dense(&[1.0, 2.0]), 0.0);
    }

    #[test]
    fn scale_in_place() {
        let mut s = SparseVec::new(vec![0], vec![2.0]);
        s.scale(2.5);
        assert_eq!(s.values, vec![5.0]);
    }

    #[test]
    fn row_ref_matches_owned_vec() {
        let s = SparseVec::new(vec![1, 3], vec![2.0, -1.0]);
        let r = s.as_row();
        let w = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(r.nnz(), s.nnz());
        assert_eq!(r.min_dim(), s.min_dim());
        assert_eq!(r.dot_dense(&w).to_bits(), s.dot_dense(&w).to_bits());
        assert_eq!(r.l2_norm_sq().to_bits(), s.l2_norm_sq().to_bits());
        let mut wa = w.clone();
        let mut wb = w.clone();
        r.axpy_into(0.5, &mut wa);
        s.axpy_into(0.5, &mut wb);
        assert_eq!(wa, wb);
        assert_eq!(r.to_owned(), s);
        let via_from: RowRef<'_> = (&s).into();
        assert_eq!(via_from, r);
    }

    #[test]
    fn rows_view_vecs_and_csr_agree() {
        let rows = vec![
            SparseVec::new(vec![0, 2], vec![1.0, 2.0]),
            SparseVec::default(),
            SparseVec::new(vec![1], vec![-3.0]),
        ];
        // the same rows flattened into CSR arrays (absolute offsets)
        let indptr: Vec<u64> = vec![0, 2, 2, 3];
        let indices: Vec<u32> = vec![0, 2, 1];
        let values: Vec<f32> = vec![1.0, 2.0, -3.0];
        let vecs = RowsView::Vecs(&rows);
        let csr = RowsView::Csr { indptr: &indptr, indices: &indices, values: &values };
        assert_eq!(vecs.len(), 3);
        assert_eq!(csr.len(), 3);
        assert!(!csr.is_empty());
        for i in 0..3 {
            assert_eq!(vecs.row(i), csr.row(i), "row {i}");
        }
        // a window over the middle rows: slice indptr, keep the arrays
        let window = RowsView::Csr { indptr: &indptr[1..=3], indices: &indices, values: &values };
        assert_eq!(window.len(), 2);
        assert_eq!(window.row(0), vecs.row(1));
        assert_eq!(window.row(1), vecs.row(2));
        let collected: Vec<_> = csr.iter().map(|r| r.to_owned()).collect();
        assert_eq!(collected, rows);
        let empty = RowsView::Csr { indptr: &indptr[..1], indices: &indices, values: &values };
        assert_eq!(empty.len(), 0);
        assert!(empty.is_empty());
    }
}
