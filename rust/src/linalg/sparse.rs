//! Sparse vectors in LIBSVM style: sorted `(index, value)` pairs.
//!
//! The paper's text corpora (CCAT at 47k features, Reuters at 8.3k) are
//! 99.8%+ sparse; the per-sample work in every solver is `⟨w, x⟩` and
//! `w ← w + a·x`, both of which must cost `O(nnz)` — these two operations
//! are the single hottest code in the native backend (see flamegraph notes
//! in EXPERIMENTS.md §Perf).

/// A sparse feature vector with strictly increasing indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// Strictly increasing feature indices (0-based).
    pub indices: Vec<u32>,
    /// Values aligned with `indices`.
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Builds from parallel slices, validating sortedness.
    ///
    /// # Panics
    /// Panics if lengths differ or indices are not strictly increasing.
    pub fn new(indices: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(indices.len(), values.len(), "SparseVec: length mismatch");
        for w in indices.windows(2) {
            assert!(w[0] < w[1], "SparseVec: indices must strictly increase");
        }
        Self { indices, values }
    }

    /// Builds from a dense slice, dropping exact zeros.
    pub fn from_dense(x: &[f64]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in x.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v as f32);
            }
        }
        Self { indices, values }
    }

    /// Number of stored (non-zero) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Smallest dense dimension that can hold this vector.
    #[inline]
    pub fn min_dim(&self) -> usize {
        self.indices.last().map_or(0, |&i| i as usize + 1)
    }

    /// Sparse–dense dot product `⟨self, w⟩` — the scalar reference
    /// reduction ([`crate::linalg::kernel::scalar::dot_sparse`]).
    /// Out-of-range indices panic.
    #[inline]
    pub fn dot_dense(&self, w: &[f64]) -> f64 {
        crate::linalg::kernel::scalar::dot_sparse(self, w)
    }

    /// `w ← w + a·self` (scatter-add; element-wise, identical in every
    /// kernel backend).
    #[inline]
    pub fn axpy_into(&self, a: f64, w: &mut [f64]) {
        crate::linalg::kernel::scalar::axpy_sparse(a, self, w)
    }

    /// Squared Euclidean norm.
    #[inline]
    pub fn l2_norm_sq(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Materializes into a dense vector of dimension `d`.
    ///
    /// # Panics
    /// Panics if `d < self.min_dim()`.
    pub fn to_dense(&self, d: usize) -> Vec<f64> {
        assert!(d >= self.min_dim(), "to_dense: dimension too small");
        let mut out = vec![0.0; d];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v as f64;
        }
        out
    }

    /// Scales all values in place.
    pub fn scale(&mut self, a: f32) {
        for v in &mut self.values {
            *v *= a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_dense() {
        let d = vec![0.0, 1.5, 0.0, -2.0];
        let s = SparseVec::from_dense(&d);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.min_dim(), 4);
        assert_eq!(s.to_dense(4), d);
        assert_eq!(s.to_dense(6)[4..], [0.0, 0.0]);
    }

    #[test]
    fn dot_and_axpy() {
        let s = SparseVec::new(vec![1, 3], vec![2.0, -1.0]);
        let mut w = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(s.dot_dense(&w), 2.0 * 2.0 - 4.0);
        s.axpy_into(0.5, &mut w);
        assert_eq!(w, vec![1.0, 3.0, 3.0, 3.5]);
    }

    #[test]
    fn norm() {
        let s = SparseVec::new(vec![0, 2], vec![3.0, 4.0]);
        assert_eq!(s.l2_norm_sq(), 25.0);
    }

    #[test]
    #[should_panic(expected = "strictly increase")]
    fn unsorted_panics() {
        SparseVec::new(vec![3, 1], vec![1.0, 1.0]);
    }

    #[test]
    fn empty_vector() {
        let s = SparseVec::default();
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.min_dim(), 0);
        assert_eq!(s.dot_dense(&[1.0, 2.0]), 0.0);
    }

    #[test]
    fn scale_in_place() {
        let mut s = SparseVec::new(vec![0], vec![2.0]);
        s.scale(2.5);
        assert_eq!(s.values, vec![5.0]);
    }
}
