//! Dense `f64` slice helpers.
//!
//! The hot-loop implementations (dot, axpy, the panel apply) live in
//! [`super::kernel`]; the functions here delegate to the **scalar
//! reference** backend so every non-hot caller keeps the ergonomic
//! free-function API with bit-for-bit the pre-refactor behavior. Code on a
//! runtime-selected hot path should dispatch through a
//! `&'static dyn Kernel` instead (see DESIGN.md §Kernel backends).

/// Dot product `xᵀy` — the scalar reference reduction
/// ([`super::kernel::scalar::dot`]: four-way unrolled, fixed order).
///
/// # Panics
/// Panics if `x.len() != y.len()`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    super::kernel::scalar::dot(x, y)
}

/// `y ← y + a·x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    super::kernel::scalar::axpy(a, x, y);
}

/// `y ← a·y`.
#[inline]
pub fn scale_assign(a: f64, y: &mut [f64]) {
    for v in y.iter_mut() {
        *v *= a;
    }
}

/// Returns `a·x` as a fresh vector (off the hot path).
#[inline]
pub fn scale(a: f64, x: &[f64]) -> Vec<f64> {
    x.iter().map(|v| a * v).collect()
}

/// `y ← y + x`.
#[inline]
pub fn add_assign(x: &[f64], y: &mut [f64]) {
    axpy(1.0, x, y);
}

/// `y ← y − x`.
#[inline]
pub fn sub_assign(x: &[f64], y: &mut [f64]) {
    axpy(-1.0, x, y);
}

/// Squared Euclidean norm `‖x‖₂²`.
#[inline]
pub fn l2_norm_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm `‖x‖₂`.
#[inline]
pub fn l2_norm(x: &[f64]) -> f64 {
    l2_norm_sq(x).sqrt()
}

/// `‖x‖₁`.
#[inline]
pub fn l1_norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// `max_i |x_i − y_i|` — used by the ε-convergence test.
#[inline]
pub fn linf_dist(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "linf_dist: length mismatch");
    x.iter()
        .zip(y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max)
}

/// Projects `w` onto the Euclidean ball of radius `r` (in place); returns
/// the scaling factor applied (1.0 when already inside).
///
/// This is steps (f)/(h) of Algorithm 2: `w ← min{1, r/‖w‖}·w`, which bounds
/// the maximum sub-gradient exactly as in Pegasos
/// (Shalev-Shwartz et al. 2007) with `r = 1/√λ`.
#[inline]
pub fn project_to_ball(w: &mut [f64], r: f64) -> f64 {
    let norm = l2_norm(w);
    if norm > r && norm > 0.0 {
        let f = r / norm;
        scale_assign(f, w);
        f
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn dot_empty() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_unroll_tail() {
        // length 7 exercises both the unrolled body and the tail loop
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let y = [1.0; 7];
        assert_eq!(dot(&x, &y), 28.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_mismatch_panics() {
        dot(&[1.0], &[1.0, 2.0]);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn norms() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(l1_norm(&[-3.0, 4.0]), 7.0);
    }

    #[test]
    fn linf() {
        assert_eq!(linf_dist(&[1.0, 5.0], &[2.0, 2.0]), 3.0);
    }

    #[test]
    fn projection_shrinks_outside() {
        let mut w = vec![3.0, 4.0]; // norm 5
        let f = project_to_ball(&mut w, 1.0);
        assert!((l2_norm(&w) - 1.0).abs() < 1e-12);
        assert!((f - 0.2).abs() < 1e-12);
    }

    #[test]
    fn projection_identity_inside() {
        let mut w = vec![0.3, 0.4];
        let f = project_to_ball(&mut w, 1.0);
        assert_eq!(f, 1.0);
        assert_eq!(w, vec![0.3, 0.4]);
    }

    #[test]
    fn projection_zero_vector() {
        let mut w = vec![0.0, 0.0];
        assert_eq!(project_to_ball(&mut w, 1.0), 1.0);
    }

    #[test]
    fn scale_and_add() {
        let mut y = vec![1.0, 2.0];
        scale_assign(0.5, &mut y);
        assert_eq!(y, vec![0.5, 1.0]);
        add_assign(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![1.5, 2.0]);
        sub_assign(&[0.5, 1.0], &mut y);
        assert_eq!(y, vec![1.0, 1.0]);
        assert_eq!(scale(2.0, &y), vec![2.0, 2.0]);
    }
}
