//! Scaled-iterate representation `w = s·v` — the O(nnz) solver hot path.
//!
//! Pegasos/SVM-SGD multiply the whole weight vector by `(1 − λαₜ)` every
//! step; done naively that is `O(d)` per step and dominates on the CCAT
//! stand-in (d = 47 236, batch nnz ≈ 76). Storing `w` as a scalar `s` times
//! a dense `v` turns the shrink into `s ← s·(1−λαₜ)` — O(1) — while sparse
//! sub-gradient adds become `v[i] += (c/s)·x_i` — O(nnz). This is the
//! classic trick from the SVM-SGD code and Pegasos §4; it is the single
//! biggest native-path optimization (see EXPERIMENTS.md §Perf and
//! DESIGN.md §Scaled-iterate step).
//!
//! ## Representation invariants
//!
//! * `w[k] ≡ scale · v[k]` for all `k`; `scale` is never `0` (a zero scale
//!   would lose the direction — [`ScaledIterate::set_zero`] resets the
//!   representation instead).
//! * `norm_sq_v` caches `‖v‖²`, maintained *incrementally* by the update
//!   loop (`norm_sq_v += new² − old²` per touched slot, in index order), so
//!   `‖w‖² = scale²·norm_sq_v` and the Pegasos ball projection are O(1).
//!   The cache is clamped at zero after each update: cancellation drift
//!   could otherwise push it slightly negative, turning `norm_sq().sqrt()`
//!   into NaN and silently disabling projection.
//! * **Renormalization rule**: whenever `|scale|` drops below
//!   [`RESCALE_THRESHOLD`] (`1e-120` — far above the f64 denormal range at
//!   ~`5e-324`, far below any step factor a sane λ produces) the scale is
//!   folded into the storage (`v ← scale·v`, `scale ← 1`). The trigger
//!   depends only on the sequence of shrink factors, never on the data, so
//!   it fires at the *same step index* on every backend/scheduler — see
//!   `rust/tests/step_equivalence.rs` (renormalization-trigger
//!   determinism).
//! * **Materialization boundary**: gossip consensus (`Mixer::mix`),
//!   convergence tests, and solver exit all consume a plain dense `w`, so
//!   the representation must be materialized
//!   ([`ScaledIterate::materialize_into`]) at those seams — mixing two
//!   `(s, v)` pairs directly would need a common scale and would reorder
//!   the very reductions the bitwise contract pins.
//!
//! The arithmetic lives in the kernel layer
//! ([`crate::linalg::Kernel::dot_scaled_row`],
//! [`crate::linalg::Kernel::axpy_scaled_row`],
//! [`crate::linalg::Kernel::shrink`]) with
//! [`crate::linalg::kernel::ScalarKernel`] as the reference; this type owns
//! the invariants.

use crate::linalg::kernel::scalar;

/// Fold the scale into storage when `|scale|` drifts below this bound.
///
/// The solvers only ever *shrink* the scale (factors in `(0, 1)`), so
/// without folding, thousands of steps would drive `scale` into the
/// denormal range where `c / scale` overflows. `1e-120` leaves ~180 orders
/// of magnitude of headroom for the `new² − old²` norm-cache products.
pub const RESCALE_THRESHOLD: f64 = 1e-120;

/// A dense vector with a multiplicative scale factor.
#[derive(Clone, Debug)]
pub struct ScaledIterate {
    scale: f64,
    v: Vec<f64>,
    /// Cached ‖w‖² = scale²·‖v‖², maintained incrementally so projection
    /// (which Pegasos does every step) is O(1) too.
    norm_sq_v: f64,
}

/// Former name of [`ScaledIterate`] (pre-kernel-layer re-homing); kept so
/// `solver::ScaledVector` call sites keep compiling.
pub type ScaledVector = ScaledIterate;

impl ScaledIterate {
    /// Zero vector of dimension `d`.
    pub fn zeros(d: usize) -> Self {
        Self { scale: 1.0, v: vec![0.0; d], norm_sq_v: 0.0 }
    }

    /// Dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.v.len()
    }

    /// Current scale factor.
    #[inline]
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// `‖w‖²` in O(1).
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        self.scale * self.scale * self.norm_sq_v
    }

    /// `⟨w, x⟩` for sparse `x` — O(nnz), on the scalar reference kernel.
    /// Accepts `&SparseVec` or a zero-copy [`crate::linalg::RowRef`].
    #[inline]
    pub fn dot_sparse<'a>(&self, x: impl Into<crate::linalg::RowRef<'a>>) -> f64 {
        scalar::dot_scaled_row(x.into(), &self.v, self.scale)
    }

    /// `⟨w, x⟩` on an explicit kernel backend — the hot-path variant the
    /// solvers use ([`Self::dot_sparse`] ≡ this on the scalar kernel).
    #[inline]
    pub fn dot_sparse_k<'a>(
        &self,
        x: impl Into<crate::linalg::RowRef<'a>>,
        kernel: &dyn crate::linalg::Kernel,
    ) -> f64 {
        kernel.dot_scaled_row(x.into(), &self.v, self.scale)
    }

    /// The raw (unscaled) dense storage `v` — what kernel-backed batch
    /// operations (e.g. [`crate::linalg::Kernel::hinge_subgrad_accum`])
    /// read together with [`Self::scale`].
    #[inline]
    pub fn storage(&self) -> &[f64] {
        &self.v
    }

    /// `w ← c·w` — O(1). Re-densifies if the scale underflows (the
    /// numerical hazard the SVM-SGD readme warns about) — see
    /// [`RESCALE_THRESHOLD`].
    #[inline]
    pub fn scale_by(&mut self, c: f64) {
        assert!(c != 0.0, "scale_by(0) would lose the direction; use set_zero");
        if scalar::shrink(&mut self.scale, c) {
            self.rescale();
        }
    }

    /// `w ← w + c·x` for sparse `x` — O(nnz), maintaining the norm cache.
    /// Accepts `&SparseVec` or a zero-copy [`crate::linalg::RowRef`].
    pub fn add_sparse<'a>(&mut self, c: f64, x: impl Into<crate::linalg::RowRef<'a>>) {
        scalar::axpy_scaled_row(c, x.into(), self.scale, &mut self.v, &mut self.norm_sq_v);
        // ‖v‖² is a sum of squares, but the incremental `new² − old²`
        // maintenance can cancel it slightly negative over long runs —
        // which would make norm_sq().sqrt() NaN and silently disable
        // project_to_ball (`NaN > r` is false) for the rest of training.
        // This is the only operation that can push the cache below zero.
        if self.norm_sq_v < 0.0 {
            self.norm_sq_v = 0.0;
        }
    }

    /// Projects onto the ball of radius `r`: `w ← min{1, r/‖w‖}·w` — O(1).
    pub fn project_to_ball(&mut self, r: f64) {
        let n = self.norm_sq().sqrt();
        if n > r && n > 0.0 {
            self.scale_by(r / n);
        }
    }

    /// Sets to zero, resetting the scale.
    pub fn set_zero(&mut self) {
        self.scale = 1.0;
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.norm_sq_v = 0.0;
    }

    /// Folds the scale into the storage (`scale = 1` afterwards).
    pub fn rescale(&mut self) {
        if self.scale != 1.0 {
            for x in self.v.iter_mut() {
                *x *= self.scale;
            }
            self.norm_sq_v *= self.scale * self.scale;
            self.scale = 1.0;
        }
    }

    /// Materializes `w` as a plain dense vector.
    pub fn to_dense(&self) -> Vec<f64> {
        self.v.iter().map(|&x| x * self.scale).collect()
    }

    /// Writes `w` into an existing slice — the allocation-free
    /// materialization at solver exit and gossip boundaries (consensus
    /// mixing consumes plain dense vectors; see the module docs).
    pub fn materialize_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.v.len(), "materialize_into: dim mismatch");
        for (o, &x) in out.iter_mut().zip(&self.v) {
            *o = x * self.scale;
        }
    }

    /// Former name of [`Self::materialize_into`].
    #[inline]
    pub fn to_dense_into(&self, out: &mut [f64]) {
        self.materialize_into(out);
    }

    /// Loads from a dense vector.
    pub fn from_dense(w: &[f64]) -> Self {
        Self { scale: 1.0, v: w.to_vec(), norm_sq_v: crate::linalg::l2_norm_sq(w) }
    }

    /// Reloads from a dense slice in place, reusing the storage
    /// (allocation-free counterpart of [`Self::from_dense`]).
    pub fn load_dense(&mut self, w: &[f64]) {
        assert_eq!(w.len(), self.v.len(), "load_dense: dim mismatch");
        self.v.copy_from_slice(w);
        self.scale = 1.0;
        self.norm_sq_v = crate::linalg::l2_norm_sq(w);
    }
}

/// The configured solver step representation (`[runtime] step` / `--step`).
///
/// Mirrors [`crate::linalg::KernelKind`]: `scaled` is the tuned O(nnz)
/// default, `dense` is the plain-`Vec<f64>` O(d) textbook loop kept as the
/// independent cross-check reference (`rust/tests/step_equivalence.rs` pins
/// the two within a documented ULP bound), and `auto` resolves to `scaled`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StepKind {
    /// Plain dense weights: O(d) shrink + O(d)-norm bookkeeping per step.
    /// The independently-written reference the scaled path is pinned
    /// against.
    Dense,
    /// Scaled-iterate `w = s·v`: O(1) shrink, O(nnz) update.
    Scaled,
    /// Resolves to `scaled` — there is no configuration where the dense
    /// path is faster, so auto never picks it.
    #[default]
    Auto,
}

impl std::str::FromStr for StepKind {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "dense" => Ok(Self::Dense),
            "scaled" => Ok(Self::Scaled),
            "auto" => Ok(Self::Auto),
            other => Err(format!("unknown step {other:?} (dense | scaled | auto)")),
        }
    }
}

impl std::fmt::Display for StepKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Dense => "dense",
            Self::Scaled => "scaled",
            Self::Auto => "auto",
        })
    }
}

impl StepKind {
    /// Resolves `auto`; the result is always `Dense` or `Scaled`.
    pub fn resolve(self) -> Self {
        match self {
            Self::Auto => Self::Scaled,
            other => other,
        }
    }

    /// True when the resolved choice is the scaled-iterate fast path.
    pub fn is_scaled(self) -> bool {
        self.resolve() == Self::Scaled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SparseVec;

    #[test]
    fn matches_naive_sequence() {
        // Interleave scales and sparse adds; compare against a plain vector.
        let mut sv = ScaledIterate::zeros(6);
        let mut naive = vec![0.0f64; 6];
        let x1 = SparseVec::new(vec![0, 3], vec![1.0, -2.0]);
        let x2 = SparseVec::new(vec![1, 3, 5], vec![0.5, 0.5, 4.0]);
        let ops: Vec<(f64, Option<&SparseVec>)> =
            vec![(1.0, Some(&x1)), (0.9, None), (-0.5, Some(&x2)), (0.99, None), (2.0, Some(&x1))];
        for (c, x) in ops {
            match x {
                Some(x) => {
                    sv.add_sparse(c, x);
                    x.axpy_into(c, &mut naive);
                }
                None => {
                    sv.scale_by(c);
                    crate::linalg::scale_assign(c, &mut naive);
                }
            }
        }
        let dense = sv.to_dense();
        for i in 0..6 {
            assert!((dense[i] - naive[i]).abs() < 1e-12, "slot {i}");
        }
        assert!((sv.norm_sq() - crate::linalg::l2_norm_sq(&naive)).abs() < 1e-12);
    }

    #[test]
    fn dot_respects_scale() {
        let mut sv = ScaledIterate::from_dense(&[1.0, 2.0, 0.0]);
        sv.scale_by(0.5);
        let x = SparseVec::new(vec![0, 1], vec![2.0, 1.0]);
        assert!((sv.dot_sparse(&x) - (0.5 * (2.0 + 2.0))).abs() < 1e-12);
    }

    #[test]
    fn projection_caps_norm() {
        let mut sv = ScaledIterate::from_dense(&[3.0, 4.0]);
        sv.project_to_ball(2.5);
        assert!((sv.norm_sq().sqrt() - 2.5).abs() < 1e-12);
        // inside the ball: unchanged
        let before = sv.to_dense();
        sv.project_to_ball(10.0);
        assert_eq!(sv.to_dense(), before);
    }

    #[test]
    fn underflow_triggers_rescale() {
        let mut sv = ScaledIterate::from_dense(&[1.0]);
        for _ in 0..5000 {
            sv.scale_by(0.9);
        }
        // value underflows to ~0 but the representation stays finite
        assert!(sv.scale().abs() >= 1e-130);
        assert!(sv.to_dense()[0].is_finite());
    }

    #[test]
    fn set_zero_resets() {
        let mut sv = ScaledIterate::from_dense(&[1.0, -2.0]);
        sv.scale_by(0.5);
        sv.set_zero();
        assert_eq!(sv.to_dense(), vec![0.0, 0.0]);
        assert_eq!(sv.norm_sq(), 0.0);
        assert_eq!(sv.scale(), 1.0);
    }

    #[test]
    fn rescale_is_identity_on_values() {
        let mut sv = ScaledIterate::from_dense(&[2.0, 3.0]);
        sv.scale_by(0.25);
        let before = sv.to_dense();
        sv.rescale();
        assert_eq!(sv.scale(), 1.0);
        for (a, b) in sv.to_dense().iter().zip(&before) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn materialize_into_matches_to_dense() {
        let mut sv = ScaledIterate::from_dense(&[1.0, -2.0, 3.0]);
        sv.scale_by(0.125);
        sv.add_sparse(0.5, &SparseVec::new(vec![1], vec![4.0]));
        let dense = sv.to_dense();
        let mut out = vec![9.0; 3];
        sv.materialize_into(&mut out);
        assert_eq!(out, dense);
        // the legacy name is the same operation
        let mut out2 = vec![7.0; 3];
        sv.to_dense_into(&mut out2);
        assert_eq!(out2, dense);
    }

    #[test]
    fn norm_cache_clamps_negative_drift() {
        // Simulate the cancellation hazard directly: a cache driven
        // slightly negative must not survive the next update — a negative
        // cache makes norm_sq().sqrt() NaN, and `NaN > r` being false
        // would silently disable project_to_ball for the rest of training.
        let mut sv = ScaledIterate::zeros(2);
        sv.norm_sq_v = -1e-300;
        sv.add_sparse(0.0, &SparseVec::new(vec![0], vec![0.0]));
        assert_eq!(sv.norm_sq_v, 0.0);
        assert!(!sv.norm_sq().sqrt().is_nan());
        sv.project_to_ball(1.0);
        assert!(sv.to_dense().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn step_kind_parse_display_resolve() {
        assert_eq!("dense".parse::<StepKind>().unwrap(), StepKind::Dense);
        assert_eq!("scaled".parse::<StepKind>().unwrap(), StepKind::Scaled);
        assert_eq!("auto".parse::<StepKind>().unwrap(), StepKind::Auto);
        assert!("sparse".parse::<StepKind>().is_err());
        assert_eq!(StepKind::Dense.to_string(), "dense");
        assert_eq!(StepKind::Scaled.to_string(), "scaled");
        assert_eq!(StepKind::Auto.to_string(), "auto");
        assert_eq!(StepKind::default(), StepKind::Auto);
        assert_eq!(StepKind::Auto.resolve(), StepKind::Scaled);
        assert_eq!(StepKind::Dense.resolve(), StepKind::Dense);
        assert!(StepKind::Auto.is_scaled());
        assert!(!StepKind::Dense.is_scaled());
    }
}
