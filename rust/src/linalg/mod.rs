//! Dense and sparse linear-algebra primitives for the native hot path.
//!
//! Everything the GADGET coordinator and the baseline solvers need is a
//! handful of level-1 BLAS-style operations over `f64` slices plus
//! sparse-dense products over LIBSVM-style index/value pairs. They are kept
//! here — allocation-free and `#[inline]`-friendly — so the per-cycle hot
//! loop never allocates (see DESIGN.md §Perf).
//!
//! Since the kernel-layer refactor the *implementations* of every hot loop
//! live in [`kernel`] (one object-safe [`kernel::Kernel`] trait, a scalar
//! reference backend and an opt-in lane-split SIMD backend); the free
//! functions in [`dense`] and [`sparse`] are thin delegates onto the
//! scalar reference so non-hot callers keep their ergonomic API and
//! bit-for-bit behavior. Hot paths hold a `&'static dyn Kernel` and
//! dispatch through it — see DESIGN.md §Kernel backends.

pub mod dense;
pub mod kernel;
pub mod scaled;
pub mod sparse;

pub use dense::{
    add_assign, axpy, dot, l1_norm, l2_norm, l2_norm_sq, linf_dist, project_to_ball, scale,
    scale_assign, sub_assign,
};
pub use kernel::{Kernel, KernelKind};
pub use scaled::{ScaledIterate, ScaledVector, StepKind};
pub use sparse::{RowRef, RowsView, SparseVec};
