//! xoshiro256++ core (Blackman & Vigna, 2019) with SplitMix64 seeding —
//! the reference construction recommended by the authors for seeding.

/// One SplitMix64 step: mixes a 64-bit value.
#[inline]
pub fn splitmix64_once(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// xoshiro256++ state.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seeds all 256 bits through a SplitMix64 chain (never all-zero).
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for si in &mut s {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            *si = z ^ (z >> 31);
        }
        if s == [0; 4] {
            s[0] = 1; // cannot happen via splitmix, but keep the invariant explicit
        }
        Self { s }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// A stable fingerprint of the current state (for substream derivation).
    #[inline]
    pub fn state_fingerprint(&self) -> u64 {
        splitmix64_once(self.s[0] ^ self.s[1].rotate_left(16))
            ^ splitmix64_once(self.s[2] ^ self.s[3].rotate_left(32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nonzero_state_from_any_seed() {
        for seed in [0u64, 1, u64::MAX] {
            let mut g = Xoshiro256pp::new(seed);
            // must produce varied output, not get stuck
            let a = g.next_u64();
            let b = g.next_u64();
            assert_ne!(a, b);
        }
    }

    #[test]
    fn reference_vector_xoshiro256pp() {
        // Reference: seeding state directly with s = [1,2,3,4] must produce
        // the published first outputs of xoshiro256++.
        let mut g = Xoshiro256pp { s: [1, 2, 3, 4] };
        let got: Vec<u64> = (0..3).map(|_| g.next_u64()).collect();
        assert_eq!(got, vec![41943041, 58720359, 3588806011781223]);
    }

    #[test]
    fn splitmix_reference() {
        // SplitMix64 of 0 (first output) per Vigna's reference code.
        assert_eq!(splitmix64_once(0), 0xe220a8397b1dcdaf);
    }
}
