//! Deterministic random-number substrate.
//!
//! The build environment is offline (no `rand` crate), and determinism is a
//! first-class requirement anyway: every experiment in EXPERIMENTS.md must
//! regenerate bit-identically from `(config, seed)`. This module provides
//! the xoshiro256++ generator (Blackman & Vigna 2019) seeded through
//! SplitMix64, plus the distributions the system needs: uniform ranges,
//! standard normal (Box–Muller with spare caching), Fisher–Yates shuffling,
//! and Floyd's algorithm for sorted k-subsets.

mod xoshiro;

pub use xoshiro::Xoshiro256pp;

/// The project-wide RNG: xoshiro256++ behind a small distribution API.
///
/// Streams: `Rng::new(seed)` gives the root stream; [`Rng::substream`]
/// derives statistically independent child streams (used to give every
/// network node its own RNG, matching the paper's independent local
/// sampling).
#[derive(Clone, Debug)]
pub struct Rng {
    core: Xoshiro256pp,
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Creates a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        Self { core: Xoshiro256pp::new(seed), spare_normal: None }
    }

    /// Derives an independent child stream. Mixing the label through
    /// SplitMix64 keeps children of the same parent decorrelated.
    pub fn substream(&self, label: u64) -> Self {
        let mixed = xoshiro::splitmix64_once(
            self.core.state_fingerprint() ^ label.wrapping_mul(0x9e3779b97f4a7c15),
        );
        Self::new(mixed)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.core.next_u64()
    }

    /// Uniform `f64` in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection method (unbiased).
    ///
    /// # Panics
    /// Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below: empty range");
        let n = n as u64;
        // Lemire 2019: multiply-shift with rejection on the low word.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let threshold = n.wrapping_neg() % n;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range");
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn flip(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (polar form), caching the spare.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sorted random k-subset of `[0, n)` by Floyd's algorithm — O(k log k),
    /// independent of `n`. Used by the sparse-row generators where
    /// `k ≪ n` (76 of 47236 for the CCAT stand-in).
    pub fn sorted_subset(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "sorted_subset: k > n");
        let mut set = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1) as u32;
            if !set.insert(t) {
                set.insert(j as u32);
            }
        }
        set.into_iter().collect()
    }

    /// Samples one element of a slice uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn substreams_are_decorrelated() {
        let root = Rng::new(7);
        let mut s0 = root.substream(0);
        let mut s1 = root.substream(1);
        let v0: Vec<u64> = (0..4).map(|_| s0.next_u64()).collect();
        let v1: Vec<u64> = (0..4).map(|_| s1.next_u64()).collect();
        assert_ne!(v0, v1);
        // same label ⇒ same stream
        let mut s0b = root.substream(0);
        assert_eq!(v0[0], s0b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sorted_subset_properties() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let s = r.sorted_subset(1000, 20);
            assert_eq!(s.len(), 20);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 1000));
        }
        // edge cases
        assert!(r.sorted_subset(5, 0).is_empty());
        assert_eq!(r.sorted_subset(5, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn range_and_choose() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let v = r.range(10, 13);
            assert!((10..13).contains(&v));
        }
        let xs = [1, 2, 3];
        assert!(xs.contains(r.choose(&xs)));
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn below_zero_panics() {
        Rng::new(0).below(0);
    }
}
