//! Artifact registry: `artifacts/manifest.json` maps logical kernels to
//! shape-specialized HLO files.
//!
//! HLO is shape-monomorphic, so `aot.py` emits one artifact per
//! `(d, batch, steps)` variant. The registry picks, for a requested data
//! dimension, the variant with the smallest `d_pad ≥ d` (the backend
//! zero-pads features — margins and sub-gradients are unaffected because
//! padded coordinates are identically zero in both `X` and `w`).

use crate::util::Json;
use crate::Result;
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};

/// One manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Logical kernel name (`pegasos_steps`, `objective_eval`).
    pub kernel: String,
    /// Padded feature dimension the HLO was lowered for.
    pub d: usize,
    /// Mini-batch size per step.
    pub batch: usize,
    /// Fused scan steps.
    pub steps: usize,
    /// HLO text file, relative to the manifest directory.
    pub path: PathBuf,
}

/// The parsed registry.
#[derive(Clone, Debug, Default)]
pub struct ArtifactRegistry {
    entries: Vec<ArtifactEntry>,
    base: PathBuf,
}

impl ArtifactRegistry {
    /// Loads `manifest.json` from the artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!(
                "read {} — artifacts missing; run `make artifacts` first",
                manifest.display()
            )
        })?;
        Self::from_json(&text, dir)
    }

    /// Parses manifest JSON (exposed for tests).
    pub fn from_json(text: &str, base: impl Into<PathBuf>) -> Result<Self> {
        let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let arr = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest: missing `artifacts` array")?;
        let mut entries = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let field = |k: &str| {
                e.get(k).with_context(|| format!("manifest entry {i}: missing {k:?}"))
            };
            entries.push(ArtifactEntry {
                kernel: field("kernel")?.as_str().context("kernel must be a string")?.to_string(),
                d: field("d")?.as_usize().context("d must be a number")?,
                batch: field("batch")?.as_usize().context("batch must be a number")?,
                steps: field("steps")?.as_usize().context("steps must be a number")?,
                path: PathBuf::from(
                    field("path")?.as_str().context("path must be a string")?,
                ),
            });
        }
        Ok(Self { entries, base: base.into() })
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Selects the best variant: matching kernel/batch/steps with the
    /// smallest `d ≥ data_dim`.
    pub fn select(
        &self,
        kernel: &str,
        data_dim: usize,
        batch: usize,
        steps: usize,
    ) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kernel == kernel && e.batch == batch && e.steps == steps && e.d >= data_dim
            })
            .min_by_key(|e| e.d)
            .with_context(|| {
                let have: Vec<String> = self
                    .entries
                    .iter()
                    .filter(|e| e.kernel == kernel)
                    .map(|e| format!("(d={}, b={}, s={})", e.d, e.batch, e.steps))
                    .collect();
                format!(
                    "no artifact for kernel {kernel:?} with d ≥ {data_dim}, batch {batch}, \
                     steps {steps}; available: [{}] — re-run `make artifacts` with matching \
                     variants (python/compile/aot.py --help)",
                    have.join(", ")
                )
            })
    }

    /// Absolute path of an entry's HLO file.
    pub fn resolve(&self, e: &ArtifactEntry) -> PathBuf {
        self.base.join(&e.path)
    }

    /// Verifies every listed file exists.
    pub fn check_files(&self) -> Result<()> {
        for e in &self.entries {
            let p = self.resolve(e);
            if !p.is_file() {
                bail!("manifest lists missing file {}", p.display());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
        "artifacts": [
            {"kernel": "pegasos_steps", "d": 64, "batch": 1, "steps": 1, "path": "a64.hlo.txt"},
            {"kernel": "pegasos_steps", "d": 256, "batch": 1, "steps": 1, "path": "a256.hlo.txt"},
            {"kernel": "pegasos_steps", "d": 256, "batch": 8, "steps": 4, "path": "b256.hlo.txt"},
            {"kernel": "objective_eval", "d": 256, "batch": 128, "steps": 1, "path": "e256.hlo.txt"}
        ]
    }"#;

    #[test]
    fn parses_manifest() {
        let r = ArtifactRegistry::from_json(MANIFEST, "/tmp/x").unwrap();
        assert_eq!(r.entries().len(), 4);
        assert_eq!(r.entries()[0].kernel, "pegasos_steps");
    }

    #[test]
    fn selects_smallest_adequate_dim() {
        let r = ArtifactRegistry::from_json(MANIFEST, "/tmp/x").unwrap();
        assert_eq!(r.select("pegasos_steps", 60, 1, 1).unwrap().d, 64);
        assert_eq!(r.select("pegasos_steps", 64, 1, 1).unwrap().d, 64);
        assert_eq!(r.select("pegasos_steps", 65, 1, 1).unwrap().d, 256);
    }

    #[test]
    fn missing_variant_is_helpful_error() {
        let r = ArtifactRegistry::from_json(MANIFEST, "/tmp/x").unwrap();
        let err = r.select("pegasos_steps", 1000, 1, 1).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        assert!(err.contains("d ≥ 1000"), "{err}");
    }

    #[test]
    fn batch_steps_must_match_exactly() {
        let r = ArtifactRegistry::from_json(MANIFEST, "/tmp/x").unwrap();
        assert!(r.select("pegasos_steps", 10, 8, 4).is_ok());
        assert!(r.select("pegasos_steps", 10, 8, 2).is_err());
    }

    #[test]
    fn bad_manifest_rejected() {
        assert!(ArtifactRegistry::from_json("{}", "/tmp").is_err());
        assert!(ArtifactRegistry::from_json(r#"{"artifacts": [{"kernel": "x"}]}"#, "/tmp").is_err());
    }

    #[test]
    fn check_files_flags_missing() {
        let r = ArtifactRegistry::from_json(MANIFEST, "/nonexistent-dir").unwrap();
        assert!(r.check_files().is_err());
    }
}
