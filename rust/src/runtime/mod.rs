//! PJRT runtime bridge: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, emitted by `python/compile/aot.py`) and runs
//! them from the rust hot path.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto` —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).
//!
//! * [`artifacts`] — the manifest-driven registry: shape variants keyed by
//!   `(d, batch, steps)`, selected by smallest padding.
//! * [`pjrt`] — the executable wrapper: compile-once, execute with f32
//!   literals, unwrap the 1-tuple convention.
//! * [`xla_backend`] — [`crate::coordinator::LocalBackend`] implemented on
//!   top: samples batches with the node RNG (identically to the native
//!   backend), marshals dense blocks, executes `pegasos_steps`.

pub mod artifacts;
pub mod pjrt;
pub mod xla_backend;

pub use artifacts::{ArtifactEntry, ArtifactRegistry};
pub use pjrt::PjrtExecutable;
pub use xla_backend::XlaBackend;

/// Default artifact directory, overridable with `GADGET_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("GADGET_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
