//! The XLA local-learner backend: Algorithm 2's steps (a)–(f) executed by
//! the AOT-compiled JAX/Pallas `pegasos_steps` artifact on the PJRT CPU
//! client.
//!
//! Contract with the native backend: batches are sampled from the *same*
//! node RNG in the same order, so both backends follow the same
//! optimization trajectory up to f32-vs-f64 rounding — `rust/tests/`
//! asserts this equivalence end-to-end.
//!
//! Artifact calling convention (must match `python/compile/model.py`):
//!
//! ```text
//! pegasos_steps(w: f32[d], xs: f32[S·B·d], ys: f32[S·B],
//!               t0: f32[1], lam: f32[1]) -> (w': f32[d],)
//! ```
//!
//! where `S = local_steps` scan iterations of mini-batch size `B`, learning
//! rate `αₜ = 1/(λ·(t0 + s + 1))`, with the `1/√λ`-ball projection applied
//! every step (the artifact is lowered with projection on — the paper's
//! default; configs with `project_local = false` must use the native
//! backend).

use super::artifacts::ArtifactRegistry;
use super::pjrt::PjrtExecutable;
use crate::coordinator::backend::{LocalBackend, StepContext};
use crate::Result;
use anyhow::Context;

/// PJRT-backed Pegasos stepper.
pub struct XlaBackend {
    exe: PjrtExecutable,
    /// Padded feature dimension of the compiled artifact.
    d_pad: usize,
    batch: usize,
    steps: usize,
    // marshalling buffers reused across calls (no hot-loop allocation)
    w_buf: Vec<f32>,
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
}

impl XlaBackend {
    /// Loads the best-fitting `pegasos_steps` artifact from the default
    /// artifact directory (env `GADGET_ARTIFACTS` or `./artifacts`).
    pub fn from_default_artifacts(
        data_dim: usize,
        batch: usize,
        steps: usize,
        _lambda: f64,
    ) -> Result<Self> {
        Self::from_registry(&ArtifactRegistry::load(super::artifacts_dir())?, data_dim, batch, steps)
    }

    /// Loads from an explicit registry.
    pub fn from_registry(
        reg: &ArtifactRegistry,
        data_dim: usize,
        batch: usize,
        steps: usize,
    ) -> Result<Self> {
        let entry = reg.select("pegasos_steps", data_dim, batch, steps)?;
        let exe = PjrtExecutable::compile_file(reg.resolve(entry))
            .with_context(|| format!("compiling artifact for d={}", entry.d))?;
        Ok(Self {
            exe,
            d_pad: entry.d,
            batch,
            steps,
            w_buf: vec![0.0; entry.d],
            x_buf: vec![0.0; steps * batch * entry.d],
            y_buf: vec![0.0; steps * batch],
        })
    }

    /// The artifact's padded dimension.
    pub fn padded_dim(&self) -> usize {
        self.d_pad
    }
}

impl LocalBackend for XlaBackend {
    fn local_step(&mut self, ctx: &mut StepContext<'_>, w: &mut [f64]) -> Result<()> {
        anyhow::ensure!(
            ctx.project,
            "the pegasos_steps artifact is lowered with projection on; \
             set project_local = true or use backend = \"native\""
        );
        anyhow::ensure!(
            ctx.batch_size == self.batch && ctx.local_steps == self.steps,
            "artifact compiled for (batch={}, steps={}), got ({}, {})",
            self.batch,
            self.steps,
            ctx.batch_size,
            ctx.local_steps
        );
        anyhow::ensure!(
            ctx.shard.dim <= self.d_pad,
            "shard dim {} exceeds artifact dim {}",
            ctx.shard.dim,
            self.d_pad
        );
        let n = ctx.shard.len();
        anyhow::ensure!(n > 0, "xla backend: empty shard");

        // Sample the S×B batch indices in the same order as NativeBackend.
        self.x_buf.iter_mut().for_each(|v| *v = 0.0);
        for s in 0..self.steps {
            for b in 0..self.batch {
                let i = ctx.rng.below(n);
                let (x, y) = ctx.shard.sample(i);
                let base = (s * self.batch + b) * self.d_pad;
                for (&j, &v) in x.indices.iter().zip(x.values) {
                    self.x_buf[base + j as usize] = v;
                }
                self.y_buf[s * self.batch + b] = y as f32;
            }
        }
        // Pad w.
        for (dst, &src) in self.w_buf.iter_mut().zip(w.iter()) {
            *dst = src as f32;
        }
        for dst in self.w_buf[w.len()..].iter_mut() {
            *dst = 0.0;
        }
        let t0 = [(((ctx.t - 1) * self.steps) as f32)];
        let lam = [ctx.lambda as f32];

        let out = self.exe.execute_f32(&[
            (&self.w_buf, &[self.d_pad as i64]),
            (
                &self.x_buf,
                &[self.steps as i64, self.batch as i64, self.d_pad as i64],
            ),
            (&self.y_buf, &[self.steps as i64, self.batch as i64]),
            (&t0, &[1]),
            (&lam, &[1]),
        ])?;
        anyhow::ensure!(out.len() == 1, "pegasos_steps: expected 1 output, got {}", out.len());
        anyhow::ensure!(
            out[0].len() == self.d_pad,
            "pegasos_steps: output dim {} != {}",
            out[0].len(),
            self.d_pad
        );
        for (dst, &src) in w.iter_mut().zip(&out[0]) {
            *dst = src as f64;
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::rng::Rng;

    fn artifacts_available() -> bool {
        ArtifactRegistry::load(crate::runtime::artifacts_dir()).is_ok()
    }

    fn shard(d: usize) -> crate::data::Dataset {
        let spec = DatasetSpec {
            name: "xb".into(),
            train_size: 128,
            test_size: 32,
            features: d,
            nnz_per_row: 8,
            noise: 0.02,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        generate(&spec, 31, 1.0).train
    }

    /// Runs `iters` GADGET-style local iterations with the given backend.
    fn run_backend(
        backend: &mut dyn LocalBackend,
        ds: &crate::data::Dataset,
        iters: usize,
        batch: usize,
        steps: usize,
    ) -> Vec<f64> {
        let mut rng = Rng::new(123);
        let mut w = vec![0.0; ds.dim];
        for t in 1..=iters {
            let mut ctx = StepContext {
                shard: ds.view(),
                t,
                lambda: 1e-2,
                batch_size: batch,
                local_steps: steps,
                project: true,
                rng: &mut rng,
            };
            backend.local_step(&mut ctx, &mut w).unwrap();
        }
        w
    }

    #[test]
    fn xla_matches_native_trajectory() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let ds = shard(48); // pads to the 64-dim artifact
        let reg = ArtifactRegistry::load(crate::runtime::artifacts_dir()).unwrap();
        let mut xla = match XlaBackend::from_registry(&reg, ds.dim, 1, 1) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        let w_xla = run_backend(&mut xla, &ds, 30, 1, 1);
        let w_nat = run_backend(&mut NativeBackend::default(), &ds, 30, 1, 1);
        // f32 artifact vs f64 native: close but not bit-equal
        let mut num = 0.0;
        let mut den = 0.0f64;
        for k in 0..ds.dim {
            num += (w_xla[k] - w_nat[k]).powi(2);
            den += w_nat[k].powi(2);
        }
        let rel = (num / den.max(1e-12)).sqrt();
        assert!(rel < 1e-3, "relative trajectory divergence {rel}");
    }

    #[test]
    fn xla_backend_learns() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let ds = shard(48);
        let reg = ArtifactRegistry::load(crate::runtime::artifacts_dir()).unwrap();
        let mut xla = match XlaBackend::from_registry(&reg, ds.dim, 8, 4) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        let w = run_backend(&mut xla, &ds, 100, 8, 4);
        let acc = crate::metrics::accuracy(&w, &ds);
        assert!(acc > 0.9, "train accuracy {acc}");
    }

    #[test]
    fn mismatched_shape_is_error() {
        if !artifacts_available() {
            return;
        }
        let ds = shard(48);
        let reg = ArtifactRegistry::load(crate::runtime::artifacts_dir()).unwrap();
        if let Ok(mut xla) = XlaBackend::from_registry(&reg, ds.dim, 1, 1) {
            let mut rng = Rng::new(0);
            let mut w = vec![0.0; ds.dim];
            let mut ctx = StepContext {
                shard: ds.view(),
                t: 1,
                lambda: 1e-2,
                batch_size: 2, // != compiled batch
                local_steps: 1,
                project: true,
                rng: &mut rng,
            };
            assert!(xla.local_step(&mut ctx, &mut w).is_err());
        }
    }
}
