//! PJRT executable wrapper: HLO text → compiled executable → f32 execution.
//!
//! Ownership model: each `PjrtExecutable` owns its *own* PJRT CPU client.
//! The xla crate's handles are `Rc`-based (not thread-safe); by keeping the
//! whole client→executable→buffer family inside one struct that is used
//! exclusively through `&mut self`, the non-atomic refcounts are never
//! touched from two threads concurrently, and the struct can be moved
//! across threads safely (hence the manual `Send`). CPU client creation is
//! a few milliseconds — negligible against artifact compilation.
//!
//! The external `xla` bindings (and their xla_extension C library) are not
//! available in the offline build, so the real implementation lives behind
//! the `xla` cargo feature; without it a stub with the identical API
//! reports the runtime as unavailable at construction time. Everything
//! above this layer (artifact registry, `XlaBackend`, config plumbing)
//! compiles and tests either way.

use crate::Result;
use std::path::Path;

#[cfg(feature = "xla")]
use anyhow::Context;

/// A compiled XLA program with an f32 calling convention.
#[cfg(feature = "xla")]
pub struct PjrtExecutable {
    /// Keep the client alive for the executable's lifetime (field order
    /// matters: `exe` drops before `client`).
    exe: xla::PjRtLoadedExecutable,
    _client: xla::PjRtClient,
    /// Human-readable origin (artifact path) for error messages.
    origin: String,
}

// SAFETY: every Rc in the client/executable family is owned by this struct
// and only reachable through `&mut self` / `self` — no concurrent access is
// possible without an exterior `Sync` wrapper, which we do not implement.
#[cfg(feature = "xla")]
unsafe impl Send for PjrtExecutable {}

#[cfg(feature = "xla")]
impl PjrtExecutable {
    /// Loads HLO text from `path` and compiles it on a fresh CPU client.
    pub fn compile_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path is not UTF-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Self { exe, _client: client, origin: path.display().to_string() })
    }

    /// Compiles HLO text directly (tests).
    pub fn compile_text(text: &str) -> Result<Self> {
        let tmp = crate::util::TempDir::new()?;
        let p = tmp.path().join("prog.hlo.txt");
        std::fs::write(&p, text)?;
        Self::compile_file(&p)
    }

    /// Executes with f32 tensor arguments `(data, dims)`; returns the
    /// flattened f32 outputs of the result tuple.
    ///
    /// The AOT convention (`aot.py`, `return_tuple=True`) makes the single
    /// on-device result a tuple literal; each element comes back as one
    /// `Vec<f32>`.
    pub fn execute_f32(&mut self, args: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(args.len());
        for (data, dims) in args {
            let expected: i64 = dims.iter().product();
            anyhow::ensure!(
                expected as usize == data.len(),
                "argument shape {:?} does not match {} elements",
                dims,
                data.len()
            );
            let lit = xla::Literal::vec1(data);
            let lit = if dims.len() == 1 {
                lit
            } else {
                lit.reshape(dims)
                    .with_context(|| format!("reshape arg to {dims:?} ({})", self.origin))?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.origin))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Stub used when the crate is built without the `xla` feature: keeps the
/// API (and everything layered on it) compiling while reporting the PJRT
/// runtime as unavailable. The native backend is the supported path in
/// the offline environment.
#[cfg(not(feature = "xla"))]
pub struct PjrtExecutable {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl PjrtExecutable {
    fn unavailable() -> anyhow::Error {
        anyhow::anyhow!(
            "PJRT runtime unavailable: this binary was built without the `xla` \
             cargo feature (the xla bindings need network + the xla_extension \
             C library); use backend = \"native\""
        )
    }

    /// Stub: always errors — built without the `xla` feature.
    pub fn compile_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Self::unavailable())
    }

    /// Stub: always errors — built without the `xla` feature.
    pub fn compile_text(_text: &str) -> Result<Self> {
        Err(Self::unavailable())
    }

    /// Stub: unreachable in practice (construction always fails).
    pub fn execute_f32(&mut self, _args: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(Self::unavailable())
    }
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    /// A tiny hand-written HLO program: f(x, y) = (x + y,) over f32[4].
    const ADD_HLO: &str = r#"
HloModule add4, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;

    #[test]
    fn compile_and_execute_text() {
        let mut exe = PjrtExecutable::compile_text(ADD_HLO).unwrap();
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [10.0f32, 20.0, 30.0, 40.0];
        let out = exe.execute_f32(&[(&x, &[4]), (&y, &[4])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut exe = PjrtExecutable::compile_text(ADD_HLO).unwrap();
        let x = [1.0f32, 2.0];
        assert!(exe.execute_f32(&[(&x, &[4]), (&x, &[4])]).is_err());
    }

    #[test]
    fn executes_repeatedly() {
        let mut exe = PjrtExecutable::compile_text(ADD_HLO).unwrap();
        for i in 0..10 {
            let x = [i as f32; 4];
            let out = exe.execute_f32(&[(&x, &[4]), (&x, &[4])]).unwrap();
            assert_eq!(out[0][0], 2.0 * i as f32);
        }
    }

    #[test]
    fn stub_behavior_documented() {
        // With the feature on, compile_text of garbage must error, not panic.
        assert!(PjrtExecutable::compile_text("not hlo").is_err());
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjrtExecutable::compile_text("ignored").unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
