//! Asynchronous gossip engine — compatibility facade over the unified
//! runtime's [`crate::coordinator::sched::AsyncScheduler`].
//!
//! The thread-per-node protocol loop used to live here; it is now one of
//! the three execution strategies behind the `Scheduler` abstraction in
//! [`crate::coordinator::sched`], sharing the Algorithm-2 step and the
//! push-sum mass algebra with the cycle-driven engines instead of
//! re-implementing them. This module keeps the original public surface
//! (`AsyncGossipEngine::new(params).run(shards, graph)`) for examples and
//! downstream callers; new code should prefer the scheduler API (or
//! `scheduler = "async"` in the config, which routes `GadgetRunner`
//! through the same path).

pub use super::sched::AsyncParams;
use super::sched::AsyncScheduler;
use crate::data::Dataset;
use crate::topology::Graph;
use crate::Result;

/// The asynchronous engine (facade).
pub struct AsyncGossipEngine {
    inner: AsyncScheduler,
}

impl AsyncGossipEngine {
    /// Creates an engine.
    pub fn new(params: AsyncParams) -> Self {
        Self { inner: AsyncScheduler::new(params) }
    }

    /// Runs the asynchronous protocol over `shards` on `graph`; returns the
    /// per-node weight estimates after all threads finish. See
    /// [`AsyncScheduler::run`] for the full result (mass state, stats).
    pub fn run(&self, shards: Vec<Dataset>, graph: &Graph) -> Result<Vec<Vec<f64>>> {
        Ok(self.inner.run(shards, graph)?.estimates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::horizontal_split;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::topology::Graph;

    fn problem() -> (Vec<Dataset>, Dataset) {
        let spec = DatasetSpec {
            name: "async".into(),
            train_size: 600,
            test_size: 300,
            features: 24,
            nnz_per_row: 6,
            noise: 0.03,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        let s = generate(&spec, 77, 1.0);
        (horizontal_split(&s.train, 4, 1).unwrap(), s.test)
    }

    #[test]
    fn async_engine_learns() {
        let (shards, test) = problem();
        let g = Graph::complete(4);
        let eng = AsyncGossipEngine::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 2,
            cycles: 400,
            cooldown: 0,
            local_steps: 1,
            project: true,
            seed: 5,
            max_lag: 4,
            link_latency: 0,
            link_drop: 0.0,
        });
        let ws = eng.run(shards, &g).unwrap();
        assert_eq!(ws.len(), 4);
        for w in &ws {
            let acc = crate::metrics::accuracy(w, &test);
            assert!(acc > 0.8, "node accuracy {acc}");
        }
    }

    #[test]
    fn nodes_approximately_agree() {
        let (shards, _) = problem();
        let g = Graph::ring(4);
        let eng = AsyncGossipEngine::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 2,
            cycles: 800,
            cooldown: 200,
            local_steps: 1,
            project: true,
            seed: 6,
            max_lag: 4,
            link_latency: 0,
            link_drop: 0.0,
        });
        let ws = eng.run(shards, &g).unwrap();
        // Pairwise distances bounded relative to the norm. The async engine
        // interleaves fresh local drift with single pairwise exchanges, so
        // agreement is approximate (the sync engine's R-round consensus is
        // the tight one) — this asserts rough consensus, not ε-consensus.
        let norm0 = crate::linalg::l2_norm(&ws[0]).max(1e-9);
        for w in &ws[1..] {
            let mut diff = 0.0;
            for k in 0..w.len() {
                let x = w[k] - ws[0][k];
                diff += x * x;
            }
            assert!(diff.sqrt() / norm0 < 1.0, "disagreement {}", diff.sqrt() / norm0);
        }
    }

    #[test]
    fn shard_graph_mismatch_rejected() {
        let (shards, _) = problem();
        let g = Graph::complete(3);
        let eng = AsyncGossipEngine::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 1,
            cycles: 1,
            cooldown: 0,
            local_steps: 1,
            project: true,
            seed: 0,
            max_lag: 4,
            link_latency: 0,
            link_drop: 0.0,
        });
        assert!(eng.run(shards, &g).is_err());
    }
}
