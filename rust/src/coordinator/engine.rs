//! Asynchronous gossip engine: one OS thread per node, channel-based
//! message passing, no global round barrier.
//!
//! The cycle-driven runner in [`super::gadget`] matches Peersim's
//! synchronous accounting (and Theorem 1's analysis); this engine
//! demonstrates the paper's §1 claim that consensus learning is
//! "completely asynchronous": nodes run local steps and ship halves of
//! their `(nᵢ·wᵢ, nᵢ)` mass to random neighbors whenever *they* are ready,
//! ingesting whatever has arrived since. Mass conservation still holds
//! (every message is eventually drained before reporting), so node
//! estimates still converge to the shard-weighted average.

use super::backend::{LocalBackend, NativeBackend, StepContext};
use crate::data::Dataset;
use crate::rng::Rng;
use crate::topology::Graph;
use crate::Result;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

/// A mass message: (vector·weight payload, push-sum weight).
struct MassMsg {
    v: Vec<f64>,
    w: f64,
}

/// Parameters for an asynchronous run.
#[derive(Clone, Debug)]
pub struct AsyncParams {
    /// Regularization λ.
    pub lambda: f64,
    /// Local mini-batch size.
    pub batch_size: usize,
    /// Gossip cycles each node performs.
    pub cycles: usize,
    /// Trailing cycles that gossip *without* fresh local steps — a
    /// consensus cool-down so the final estimates agree tightly (pure
    /// Push-Sum contracts geometrically once the drift stops). 0 disables.
    pub cooldown: usize,
    /// Local Pegasos steps between sends.
    pub local_steps: usize,
    /// Project onto the `1/√λ` ball after local steps.
    pub project: bool,
    /// Root seed.
    pub seed: u64,
    /// Bounded staleness: a node may run at most this many cycles ahead of
    /// the slowest peer. Without a bound, a thread can finish every cycle
    /// before its peers start and no mixing happens — the consensus theory
    /// (and the paper's asynchronous model) assumes bounded communication
    /// delays. 0 = lock-step.
    pub max_lag: usize,
}

/// The asynchronous engine.
pub struct AsyncGossipEngine {
    params: AsyncParams,
}

impl AsyncGossipEngine {
    /// Creates an engine.
    pub fn new(params: AsyncParams) -> Self {
        Self { params }
    }

    /// Runs the asynchronous protocol over `shards` on `graph`; returns the
    /// per-node weight estimates after all threads finish.
    ///
    /// Each node thread, per cycle: (1) local Pegasos step(s); (2) fold its
    /// weight vector into its push-sum mass; (3) keep half, send half to a
    /// random neighbor; (4) drain its inbox. The current estimate `v/w`
    /// becomes the working weight vector for the next local step — the
    /// Algorithm 2 loop, minus the barrier.
    pub fn run(&self, shards: Vec<Dataset>, graph: &Graph) -> Result<Vec<Vec<f64>>> {
        let m = shards.len();
        anyhow::ensure!(m == graph.n, "async engine: shard/graph size mismatch");
        anyhow::ensure!(m > 0, "async engine: no shards");
        let d = shards[0].dim;
        let p = self.params.clone();

        // channels: node i's inbox
        let mut senders: Vec<Sender<MassMsg>> = Vec::with_capacity(m);
        let mut receivers: Vec<Option<Receiver<MassMsg>>> = Vec::with_capacity(m);
        for _ in 0..m {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }

        let root = Rng::new(p.seed);
        // bounded-staleness pacing: per-node completed-cycle counters
        let counters: std::sync::Arc<Vec<std::sync::atomic::AtomicUsize>> =
            std::sync::Arc::new((0..m).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect());
        let mut handles = Vec::with_capacity(m);
        for (i, shard) in shards.into_iter().enumerate() {
            let rx = receivers[i].take().unwrap();
            let txs: Vec<Sender<MassMsg>> = senders.clone();
            let nbrs = graph.adj[i].clone();
            let mut rng = root.substream(i as u64);
            let p = p.clone();
            let counters = counters.clone();
            handles.push(thread::spawn(move || -> Result<(Vec<f64>, f64)> {
                let n_i = shard.len() as f64;
                let mut backend = NativeBackend::default();
                // push-sum state: v = nᵢ·w, weight = nᵢ
                let mut w_est = vec![0.0f64; d];
                let mut v = vec![0.0f64; d];
                let mut mass_w = n_i;
                let active = p.cycles.saturating_sub(p.cooldown);
                for t in 1..=p.cycles {
                    // bounded staleness: wait until the slowest peer is
                    // within `max_lag` cycles (yielding, not spinning hot)
                    loop {
                        let min = counters
                            .iter()
                            .map(|c| c.load(std::sync::atomic::Ordering::Acquire))
                            .min()
                            .unwrap_or(0);
                        if t <= min + p.max_lag + 1 {
                            break;
                        }
                        thread::yield_now();
                    }
                    if t <= active {
                        // (1) local step on the current estimate
                        let mut ctx = StepContext {
                            shard: &shard,
                            t,
                            lambda: p.lambda,
                            batch_size: p.batch_size,
                            local_steps: p.local_steps,
                            project: p.project,
                            rng: &mut rng,
                        };
                        backend.local_step(&mut ctx, &mut w_est)?;
                        // (2) fold the stepped estimate back into the mass
                        for k in 0..d {
                            v[k] = w_est[k] * mass_w;
                        }
                    }
                    // (3) halve and send
                    if !nbrs.is_empty() {
                        let tgt = nbrs[rng.below(nbrs.len())];
                        let half_v: Vec<f64> = v.iter().map(|x| 0.5 * x).collect();
                        let half_w = 0.5 * mass_w;
                        for k in 0..d {
                            v[k] *= 0.5;
                        }
                        mass_w *= 0.5;
                        // A send fails only if the peer already exited; its
                        // inbox is gone, so keep the mass local instead.
                        if let Err(e) = txs[tgt].send(MassMsg { v: half_v, w: half_w }) {
                            let MassMsg { v: hv, w: hw } = e.0;
                            for k in 0..d {
                                v[k] += hv[k];
                            }
                            mass_w += hw;
                        }
                    }
                    // (4) drain inbox (non-blocking)
                    while let Ok(msg) = rx.try_recv() {
                        for k in 0..d {
                            v[k] += msg.v[k];
                        }
                        mass_w += msg.w;
                    }
                    // refresh the estimate
                    for k in 0..d {
                        w_est[k] = v[k] / mass_w;
                    }
                    counters[i].store(t, std::sync::atomic::Ordering::Release);
                }
                // final drain with a short grace period so in-flight mass
                // is ingested (mass conservation at the report boundary)
                let deadline = std::time::Instant::now() + std::time::Duration::from_millis(50);
                while std::time::Instant::now() < deadline {
                    match rx.try_recv() {
                        Ok(msg) => {
                            for k in 0..d {
                                v[k] += msg.v[k];
                            }
                            mass_w += msg.w;
                        }
                        Err(_) => thread::sleep(std::time::Duration::from_millis(1)),
                    }
                }
                for k in 0..d {
                    w_est[k] = v[k] / mass_w;
                }
                Ok((w_est, mass_w))
            }));
        }
        drop(senders);

        let mut out = Vec::with_capacity(m);
        for h in handles {
            let (w, _mass) = h.join().map_err(|_| anyhow::anyhow!("node thread panicked"))??;
            out.push(w);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::horizontal_split;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::topology::Graph;

    fn problem() -> (Vec<Dataset>, Dataset) {
        let spec = DatasetSpec {
            name: "async".into(),
            train_size: 600,
            test_size: 300,
            features: 24,
            nnz_per_row: 6,
            noise: 0.03,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        let s = generate(&spec, 77, 1.0);
        (horizontal_split(&s.train, 4, 1), s.test)
    }

    #[test]
    fn async_engine_learns() {
        let (shards, test) = problem();
        let g = Graph::complete(4);
        let eng = AsyncGossipEngine::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 2,
            cycles: 400,
            cooldown: 0,
            local_steps: 1,
            project: true,
            seed: 5,
            max_lag: 4,
        });
        let ws = eng.run(shards, &g).unwrap();
        assert_eq!(ws.len(), 4);
        for w in &ws {
            let acc = crate::metrics::accuracy(w, &test);
            assert!(acc > 0.8, "node accuracy {acc}");
        }
    }

    #[test]
    fn nodes_approximately_agree() {
        let (shards, _) = problem();
        let g = Graph::ring(4);
        let eng = AsyncGossipEngine::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 2,
            cycles: 800,
            cooldown: 200,
            local_steps: 1,
            project: true,
            seed: 6,
            max_lag: 4,
        });
        let ws = eng.run(shards, &g).unwrap();
        // Pairwise distances bounded relative to the norm. The async engine
        // interleaves fresh local drift with single pairwise exchanges, so
        // agreement is approximate (the sync engine's R-round consensus is
        // the tight one) — this asserts rough consensus, not ε-consensus.
        let norm0 = crate::linalg::l2_norm(&ws[0]).max(1e-9);
        for w in &ws[1..] {
            let mut diff = 0.0;
            for k in 0..w.len() {
                let x = w[k] - ws[0][k];
                diff += x * x;
            }
            assert!(diff.sqrt() / norm0 < 1.0, "disagreement {}", diff.sqrt() / norm0);
        }
    }

    #[test]
    fn shard_graph_mismatch_rejected() {
        let (shards, _) = problem();
        let g = Graph::complete(3);
        let eng = AsyncGossipEngine::new(AsyncParams {
            lambda: 1e-2,
            batch_size: 1,
            cycles: 1,
            cooldown: 0,
            local_steps: 1,
            project: true,
            seed: 0,
            max_lag: 4,
        });
        assert!(eng.run(shards, &g).is_err());
    }
}
