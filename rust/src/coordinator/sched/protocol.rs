//! The shared per-node GADGET protocol step — Algorithm 2 factored out of
//! the execution engines.
//!
//! Every engine (cycle-driven sequential, node-parallel, asynchronous
//! message-passing, churn) runs the *same* per-node work each iteration:
//!
//! * steps (a)–(f): `local_steps` mini-batch Pegasos sub-gradient updates
//!   on the node's shard, with optional `1/√λ`-ball projection;
//! * step (g) consume side: replace the node vector with its consensus
//!   estimate from the configured [`Mixer`] backend;
//! * step (h): optional consensus projection;
//! * the ε-convergence test on `‖ŵ^(t) − ŵ^(t−1)‖`.
//!
//! [`GossipProtocol`] is that per-node logic in one place; the schedulers
//! in [`super`] decide only *where and when* each node's step runs. The
//! asynchronous engine additionally carries push-sum mass explicitly —
//! [`MassState`] holds the `(v = n·w, weight = n)` pair and its
//! conservation-preserving operations (halve/absorb/fold).

use crate::config::ExperimentConfig;
use crate::coordinator::backend::{LocalBackend, StepContext};
use crate::coordinator::node::NodeState;
use crate::data::{ShardStore, ShardView};
use crate::gossip::Mixer;
use crate::Result;

/// The Algorithm-2 parameters shared by every execution engine.
#[derive(Clone, Copy, Debug)]
pub struct ProtocolParams {
    /// Regularization λ.
    pub lambda: f64,
    /// Mini-batch size per local step.
    pub batch_size: usize,
    /// Fused local Pegasos steps per GADGET iteration.
    pub local_steps: usize,
    /// Project after local steps (step (f)).
    pub project_local: bool,
    /// Project the consensus estimate (step (h)).
    pub project_consensus: bool,
    /// ε-convergence threshold.
    pub epsilon: f64,
}

impl ProtocolParams {
    /// Extracts the protocol parameters from an experiment config and the
    /// resolved λ (configs may defer λ to the dataset's Table-2 default).
    pub fn from_config(cfg: &ExperimentConfig, lambda: f64) -> Self {
        Self {
            lambda,
            batch_size: cfg.batch_size,
            local_steps: cfg.local_steps,
            project_local: cfg.project_local,
            project_consensus: cfg.project_consensus,
            epsilon: cfg.epsilon,
        }
    }

    /// The Pegasos ball radius `1/√λ`.
    pub fn radius(&self) -> f64 {
        1.0 / self.lambda.sqrt()
    }
}

/// The per-node GADGET step logic, shared by all schedulers.
#[derive(Clone, Debug)]
pub struct GossipProtocol {
    /// Step parameters.
    pub params: ProtocolParams,
}

impl GossipProtocol {
    /// Creates the protocol from its parameters.
    pub fn new(params: ProtocolParams) -> Self {
        Self { params }
    }

    /// Algorithm 2 steps (a)–(f): advances `node.w` in place by the
    /// backend's local sub-gradient step(s) on `shard` (the node's
    /// current [`ShardView`], borrowed from the run's
    /// [`ShardStore`]), sampling batches from the node's own RNG stream
    /// (which is what makes the result independent of *which* worker
    /// executes the node — see the scheduler equivalence test).
    pub fn local_step(
        &self,
        backend: &mut dyn LocalBackend,
        shard: ShardView<'_>,
        node: &mut NodeState,
        t: usize,
    ) -> Result<()> {
        let p = &self.params;
        let mut ctx = StepContext {
            shard,
            t,
            lambda: p.lambda,
            batch_size: p.batch_size,
            local_steps: p.local_steps,
            project: p.project_local,
            rng: &mut node.rng,
        };
        backend.local_step(&mut ctx, &mut node.w)
    }

    /// The **ingestion boundary** between iterations: lets the shard
    /// store append iteration `t`'s arrivals *before* any node steps, so
    /// every view taken during the iteration sees one consistent shard
    /// size. Fills `added[i]` with per-node arrival counts and returns
    /// the total; `t = 1` is defined as 0 arrivals (the initial shards
    /// *are* iteration 1's data). After a non-empty boundary the caller
    /// must re-read [`ShardStore::sizes_into`] and hand the new `nᵢ` to
    /// the mixer's next [`Mixer::mix`] as weights — the re-weight rule
    /// that keeps the consensus target the Theorem-1 average over the
    /// *current* data (DESIGN.md §Streaming data plane).
    pub fn ingest_boundary(
        &self,
        store: &mut dyn ShardStore,
        t: usize,
        added: &mut [usize],
    ) -> Result<usize> {
        if t <= 1 {
            added.fill(0);
            return Ok(0);
        }
        store.ingest(added)
    }

    /// Drift-aware ε-convergence: runs the standard test (rolling
    /// `w_prev` forward) but refuses to *declare* convergence on a node
    /// that ingested new rows this iteration — `‖ŵ^(t) − ŵ^(t−1)‖ < ε`
    /// on a shard that just changed measures staleness, not consensus.
    /// A run therefore cannot stop while data still arrives; once the
    /// stream dries up the ordinary anytime criterion takes over. With
    /// `drifted = false` this is exactly [`Self::check_convergence`]
    /// (the static path is bit-for-bit unchanged).
    pub fn check_convergence_drift(&self, node: &mut NodeState, drifted: bool) -> bool {
        let converged = node.check_convergence(self.params.epsilon);
        if drifted {
            node.converged = false;
            return false;
        }
        converged
    }

    /// Steps (g)/(h) consume side: writes the mixer's slot-`slot`
    /// consensus estimate into the node and applies the optional consensus
    /// projection. (`slot` is the node's index *within the gossiping set*,
    /// which differs from `node.id` under churn.) This is the consume side
    /// of the [`Mixer`] seam — which consensus mechanism produced the
    /// estimate is invisible here.
    pub fn apply_estimate(&self, mixer: &dyn Mixer, slot: usize, node: &mut NodeState) {
        mixer.estimate_into(slot, &mut node.w);
        if self.params.project_consensus {
            crate::linalg::project_to_ball(&mut node.w, self.params.radius());
        }
    }

    /// The ε-convergence test against the node's previous consensus
    /// vector; rolls the node's `w_prev` forward and records the flag on
    /// the node.
    pub fn check_convergence(&self, node: &mut NodeState) -> bool {
        node.check_convergence(self.params.epsilon)
    }
}

/// Push-sum mass carried by one asynchronous node: `v = weight·w` and the
/// scalar `weight`. All operations preserve the network-wide invariants
/// `Σᵢ vᵢ` and `Σᵢ weightᵢ` (up to f64 rounding on re-association), which
/// is exactly why every node's estimate `v/weight` converges to the
/// shard-weighted average.
#[derive(Clone, Debug)]
pub struct MassState {
    /// Mass vector `v = weight · w`.
    pub v: Vec<f64>,
    /// Push-sum weight (initialized to the shard size `nᵢ`).
    pub w: f64,
}

impl MassState {
    /// Zero mass vector with initial weight `w0` (the shard size).
    pub fn new(d: usize, w0: f64) -> Self {
        Self { v: vec![0.0; d], w: w0 }
    }

    /// Folds a freshly-stepped weight estimate back into the mass:
    /// `v ← w_est · weight`. This is the only operation that *changes* the
    /// network total — it injects the local sub-gradient drift, exactly as
    /// the cycle engine's `reset_weighted` does.
    pub fn fold(&mut self, w_est: &[f64]) {
        for (vk, &ek) in self.v.iter_mut().zip(w_est) {
            *vk = ek * self.w;
        }
    }

    /// Halves the mass in place and returns the shipped half
    /// (`α = ½` push-sum). Conserving: kept + returned = previous total,
    /// exactly (halving an f64 is exact).
    pub fn split_half(&mut self) -> (Vec<f64>, f64) {
        let half_v: Vec<f64> = self.v.iter().map(|x| 0.5 * x).collect();
        let half_w = 0.5 * self.w;
        for x in self.v.iter_mut() {
            *x *= 0.5;
        }
        self.w *= 0.5;
        (half_v, half_w)
    }

    /// Ingests received mass.
    pub fn absorb(&mut self, v: &[f64], w: f64) {
        for (a, &b) in self.v.iter_mut().zip(v) {
            *a += b;
        }
        self.w += w;
    }

    /// Writes the current estimate `v / weight` into `out` and returns
    /// `true`.
    ///
    /// If the push-sum weight has collapsed to zero/denormal — possible
    /// in pathological exchange sequences where a node halves its mass
    /// many times without absorbing (each cycle halves `w`; ~1075 halves
    /// reach exactly 0.0) — or gone non-finite, the division would emit
    /// `inf`/`NaN` that silently poisons `consensus_w` downstream.
    /// Instead `out` is left untouched and `false` is returned; by the
    /// call convention (every engine passes the node's current working
    /// vector) the caller keeps its **last finite estimate**, and the
    /// next absorb restores a healthy weight.
    pub fn estimate_into(&self, out: &mut [f64]) -> bool {
        if !self.w.is_finite() || self.w < f64::MIN_POSITIVE {
            return false;
        }
        let inv = 1.0 / self.w;
        for (o, &x) in out.iter_mut().zip(&self.v) {
            *o = x * inv;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::data::Dataset;
    use crate::rng::Rng;

    fn shard() -> Dataset {
        let spec = DatasetSpec {
            name: "proto".into(),
            train_size: 120,
            test_size: 30,
            features: 12,
            nnz_per_row: 4,
            noise: 0.02,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        generate(&spec, 7, 1.0).train
    }

    fn params() -> ProtocolParams {
        ProtocolParams {
            lambda: 1e-2,
            batch_size: 2,
            local_steps: 1,
            project_local: true,
            project_consensus: true,
            epsilon: 1e-3,
        }
    }

    #[test]
    fn local_step_matches_direct_backend_call() {
        // The protocol wrapper must be a pure refactor of the inline
        // StepContext construction: identical bits either way.
        let ds = shard();
        let proto = GossipProtocol::new(params());
        let mut node = NodeState::new(0, Dataset::default(), ds.dim, Rng::new(3));
        let mut backend = NativeBackend::default();
        for t in 1..=5 {
            proto.local_step(&mut backend, ds.view(), &mut node, t).unwrap();
        }

        let mut rng = Rng::new(3);
        let mut w = vec![0.0; ds.dim];
        let mut backend2 = NativeBackend::default();
        for t in 1..=5 {
            let mut ctx = StepContext {
                shard: ds.view(),
                t,
                lambda: 1e-2,
                batch_size: 2,
                local_steps: 1,
                project: true,
                rng: &mut rng,
            };
            backend2.local_step(&mut ctx, &mut w).unwrap();
        }
        assert_eq!(node.w, w);
    }

    #[test]
    fn apply_estimate_projects_to_ball() {
        use crate::gossip::{Mixer as _, PushSumMixer};
        use crate::pool::SERIAL_EXEC;
        use crate::topology::stochastic::WeightScheme;
        use crate::topology::{Graph, TransitionMatrix};
        let mut p = params();
        p.lambda = 1.0; // radius 1
        let proto = GossipProtocol::new(p);
        // 0 mixing rounds: each slot's estimate is exactly its own input,
        // so only the consume-side projection is under test.
        let b = TransitionMatrix::from_graph(
            &Graph::complete(2),
            WeightScheme::MetropolisHastings,
        );
        let mut mixer = PushSumMixer::new(b, 0, 2, &[1.0, 1.0]);
        let vectors = [vec![3.0, 4.0], vec![3.0, 4.0]];
        mixer.mix(
            &mut vectors.iter().map(|v| v.as_slice()),
            &[1.0, 1.0],
            &SERIAL_EXEC,
            crate::linalg::kernel::scalar(),
        );
        let mut node = NodeState::new(0, Dataset::default(), 2, Rng::new(0));
        proto.apply_estimate(&mixer, 0, &mut node);
        let norm = crate::linalg::l2_norm(&node.w);
        assert!(norm <= 1.0 + 1e-12, "norm {norm}");
    }

    #[test]
    fn drift_gating_suppresses_convergence_only_while_drifting() {
        let proto = GossipProtocol::new(params()); // ε = 1e-3
        let mut node = NodeState::new(0, Dataset::default(), 2, Rng::new(0));
        node.w = vec![1.0, 0.0];
        // first check rolls w_prev forward; big delta ⇒ not converged
        assert!(!proto.check_convergence_drift(&mut node, false));
        // unchanged w would converge — but a drifting shard vetoes it
        assert!(!proto.check_convergence_drift(&mut node, true));
        assert!(!node.converged);
        // the delta bookkeeping still ran (w_prev rolled forward)
        assert_eq!(node.last_delta, 0.0);
        // stream dried up ⇒ the ordinary anytime criterion takes over
        assert!(proto.check_convergence_drift(&mut node, false));
        assert!(node.converged);
    }

    #[test]
    fn ingest_boundary_is_zero_at_iteration_one_and_delegates_after() {
        use crate::data::{StaticStore, StreamingStore};
        let ds = shard();
        let proto = GossipProtocol::new(params());
        let mut st = StaticStore::split(&ds, 2, 3).unwrap();
        let mut added = vec![7usize; 2];
        assert_eq!(proto.ingest_boundary(&mut st, 1, &mut added).unwrap(), 0);
        assert_eq!(added, vec![0, 0]);
        assert_eq!(proto.ingest_boundary(&mut st, 2, &mut added).unwrap(), 0);

        let initial = crate::data::partition::horizontal_split(&ds, 2, 3).unwrap();
        let mut stream =
            StreamingStore::from_pool(initial, shard(), 2.0, 0, false, 5).unwrap();
        let n0 = stream.shard_len(0) + stream.shard_len(1);
        // t = 1: defined as no arrivals (initial shards are iteration 1)
        assert_eq!(proto.ingest_boundary(&mut stream, 1, &mut added).unwrap(), 0);
        assert_eq!(stream.shard_len(0) + stream.shard_len(1), n0);
        // t = 2: the store's schedule takes over
        assert_eq!(proto.ingest_boundary(&mut stream, 2, &mut added).unwrap(), 2);
        assert_eq!(stream.shard_len(0) + stream.shard_len(1), n0 + 2);
    }

    #[test]
    fn mass_operations_conserve_totals() {
        let mut a = MassState::new(3, 10.0);
        let mut b = MassState::new(3, 4.0);
        a.fold(&[1.0, -2.0, 0.5]);
        b.fold(&[0.25, 8.0, -1.0]);
        let total_v: Vec<f64> = (0..3).map(|k| a.v[k] + b.v[k]).collect();
        let total_w = a.w + b.w;
        // a ships half to b, b ships half to a, several times over
        for _ in 0..10 {
            let (hv, hw) = a.split_half();
            b.absorb(&hv, hw);
            let (hv, hw) = b.split_half();
            a.absorb(&hv, hw);
        }
        for k in 0..3 {
            let now = a.v[k] + b.v[k];
            assert!((now - total_v[k]).abs() < 1e-12 * (1.0 + total_v[k].abs()));
        }
        assert!((a.w + b.w - total_w).abs() < 1e-12 * total_w);
        // estimates converge toward the weighted mean under pure exchange
        let mut ea = vec![0.0; 3];
        assert!(a.estimate_into(&mut ea));
        for k in 0..3 {
            assert!((ea[k] - total_v[k] / total_w).abs() < 1e-3, "slot {k}");
        }
    }

    #[test]
    fn estimate_keeps_last_finite_value_on_collapsed_weight() {
        let mut m = MassState::new(2, 8.0);
        m.fold(&[2.0, -4.0]);
        let mut out = vec![0.0; 2];
        assert!(m.estimate_into(&mut out));
        assert_eq!(out, vec![2.0, -4.0]);
        // weight collapsed to exact zero ⇒ out untouched, no inf/NaN
        let last = out.clone();
        m.w = 0.0;
        assert!(!m.estimate_into(&mut out));
        assert_eq!(out, last);
        // denormal weight would overflow the reciprocal — same guard
        m.w = f64::MIN_POSITIVE / 4.0;
        assert!(!m.estimate_into(&mut out));
        assert_eq!(out, last);
        // non-finite weight (absorbed from a poisoned peer) — same guard
        m.w = f64::NAN;
        assert!(!m.estimate_into(&mut out));
        m.w = f64::INFINITY;
        assert!(!m.estimate_into(&mut out));
        assert_eq!(out, last);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn repeated_unanswered_halving_never_emits_non_finite() {
        // A node that ships half its mass every cycle and never receives:
        // after ~1100 cycles the weight underflows to exact 0.0. The
        // estimate must freeze at the last finite value instead of
        // exploding.
        let mut m = MassState::new(3, 50.0);
        m.fold(&[1.0, -0.5, 2.0]);
        let mut est = vec![0.0; 3];
        assert!(m.estimate_into(&mut est));
        for _ in 0..1200 {
            let _ = m.split_half();
            m.estimate_into(&mut est);
            assert!(est.iter().all(|x| x.is_finite()), "w = {}", m.w);
        }
        assert_eq!(m.w, 0.0, "weight should underflow to exactly zero");
        // the frozen estimate is still the (constant) v/w ratio from
        // before the underflow
        assert!((est[0] - 1.0).abs() < 1e-9, "{}", est[0]);
    }
}
