//! The asynchronous scheduler: one OS thread per node, channel-based
//! message passing, no global round barrier.
//!
//! This is the third [`super::Scheduler`] execution strategy — it absorbs
//! the former `coordinator::engine` thread-per-node loop and runs it on
//! the shared protocol atoms: [`super::GossipProtocol::local_step`] for
//! Algorithm 2 (a)–(f) and [`super::MassState`] for the push-sum mass
//! algebra. Nodes run local steps and ship halves of their `(nᵢ·wᵢ, nᵢ)`
//! mass to random neighbors whenever *they* are ready, ingesting whatever
//! has arrived since.
//!
//! Two liveness/correctness mechanisms:
//!
//! * **bounded staleness** — a node may run at most `max_lag` cycles ahead
//!   of the slowest peer; without a bound a thread can finish every cycle
//!   before its peers start and no mixing happens (the consensus theory
//!   assumes bounded communication delays);
//! * **barrier drain** — after the last cycle every thread passes a
//!   barrier and then drains its inbox to empty. All sends happen before
//!   the barrier and in-memory channels deliver immediately, so the final
//!   states ingest *every* in-flight message: total mass `Σ nᵢwᵢ` and
//!   total weight `Σ nᵢ` are conserved at the report boundary (the
//!   mass-conservation property test in `rust/tests/` asserts this).

use super::protocol::{GossipProtocol, MassState, ProtocolParams};
use crate::coordinator::backend::NativeBackend;
use crate::coordinator::node::NodeState;
use crate::data::Dataset;
use crate::gossip::GossipStats;
use crate::rng::Rng;
use crate::topology::Graph;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};
use std::thread;

/// A mass message: (vector·weight payload, push-sum weight).
struct MassMsg {
    v: Vec<f64>,
    w: f64,
}

/// Liveness guard for a node thread: guarantees the thread's exit
/// obligations — unblocking the staleness loop (counter → max) and
/// passing the final-drain [`Barrier`] — are met even if the thread
/// *panics* mid-cycle. Without this, one panicking node would leave the
/// other `m − 1` threads blocked forever (first on the staleness
/// yield-loop, then on the barrier) and `run()` would hang instead of
/// returning the join error.
struct ExitGuard {
    counters: Arc<Vec<AtomicUsize>>,
    barrier: Arc<Barrier>,
    node: usize,
    cycles: usize,
    /// Set by the normal/error exit path right before it performs the
    /// counter-store + barrier-wait itself.
    disarmed: bool,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        if !self.disarmed {
            self.counters[self.node].store(self.cycles, Ordering::Release);
            self.barrier.wait();
        }
    }
}

/// Parameters for an asynchronous run.
#[derive(Clone, Debug)]
pub struct AsyncParams {
    /// Regularization λ.
    pub lambda: f64,
    /// Local mini-batch size.
    pub batch_size: usize,
    /// Gossip cycles each node performs.
    pub cycles: usize,
    /// Trailing cycles that gossip *without* fresh local steps — a
    /// consensus cool-down so the final estimates agree tightly (pure
    /// Push-Sum contracts geometrically once the drift stops). 0 disables.
    pub cooldown: usize,
    /// Local Pegasos steps between sends.
    pub local_steps: usize,
    /// Project onto the `1/√λ` ball after local steps.
    pub project: bool,
    /// Root seed.
    pub seed: u64,
    /// Bounded staleness: a node may run at most this many cycles ahead of
    /// the slowest peer. 0 = lock-step.
    pub max_lag: usize,
    /// Per-link latency schedule: each directed link `(i, j)` gets a
    /// fixed, seeded delay drawn uniformly from `0..=link_latency`
    /// cycles; a message released on cycle `t` is delivered no earlier
    /// than `t + delay`. 0 disables (the bitwise-unchanged fast path).
    /// Delayed messages are held in a sender-side queue and always
    /// flushed before the final-drain barrier, so mass conservation at
    /// the report boundary is exact regardless of the schedule.
    pub link_latency: usize,
    /// Per-message delivery-failure probability in `[0, 1)`, drawn from
    /// a dedicated seeded stream (the node's protocol RNG never sees
    /// it). A failed message counts in `messages`/`bytes` *and*
    /// `dropped` (it was sent), and its mass is reabsorbed by the sender
    /// — delivery fails, conservation does not. 0.0 disables.
    pub link_drop: f64,
}

/// Seed-mixing label for link schedules (latency draws and the drop
/// stream; distinct from the node protocol substreams).
const LINK_SEED: u64 = 0x6c69_6e6b; // "link"

/// Everything an asynchronous run reports: per-node estimates plus the
/// raw push-sum mass (for conservation checks) and communication totals.
#[derive(Clone, Debug)]
pub struct AsyncRunResult {
    /// Per-node final weight estimates `vᵢ / weightᵢ`.
    pub estimates: Vec<Vec<f64>>,
    /// Per-node final mass vectors `vᵢ` (Σᵢ vᵢ is conserved).
    pub mass_v: Vec<Vec<f64>>,
    /// Per-node final push-sum weights (Σᵢ weightᵢ = Σᵢ nᵢ, conserved).
    pub mass_weights: Vec<f64>,
    /// Communication totals across all nodes.
    pub stats: GossipStats,
}

/// The asynchronous execution engine.
pub struct AsyncScheduler {
    params: AsyncParams,
}

impl AsyncScheduler {
    /// Creates the scheduler.
    pub fn new(params: AsyncParams) -> Self {
        Self { params }
    }

    /// The configured parameters.
    pub fn params(&self) -> &AsyncParams {
        &self.params
    }

    /// Runs the asynchronous protocol over `shards` on `graph`.
    ///
    /// Each node thread, per cycle: (1) protocol local step(s); (2) fold
    /// the stepped estimate into its push-sum mass; (3) keep half, send
    /// half to a random neighbor; (4) drain its inbox. The current
    /// estimate `v/w` becomes the working weight vector for the next local
    /// step — the Algorithm 2 loop, minus the barrier.
    pub fn run(&self, shards: Vec<Dataset>, graph: &Graph) -> Result<AsyncRunResult> {
        let m = shards.len();
        anyhow::ensure!(m == graph.n, "async scheduler: shard/graph size mismatch");
        anyhow::ensure!(m > 0, "async scheduler: no shards");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.params.link_drop),
            "async scheduler: link_drop must be in [0, 1)"
        );
        for (i, s) in shards.iter().enumerate() {
            anyhow::ensure!(!s.is_empty(), "async scheduler: shard {i} is empty");
        }
        let d = shards[0].dim;
        let p = self.params.clone();
        let protocol = GossipProtocol::new(ProtocolParams {
            lambda: p.lambda,
            batch_size: p.batch_size,
            local_steps: p.local_steps,
            project_local: p.project,
            // the async path has no consensus projection / ε phase — the
            // estimate itself is the consensus step
            project_consensus: false,
            epsilon: 0.0,
        });

        // channels: node i's inbox
        let mut senders: Vec<Sender<MassMsg>> = Vec::with_capacity(m);
        let mut receivers: Vec<Option<Receiver<MassMsg>>> = Vec::with_capacity(m);
        for _ in 0..m {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }

        let root = Rng::new(p.seed);
        // bounded-staleness pacing: per-node completed-cycle counters
        let counters: Arc<Vec<AtomicUsize>> =
            Arc::new((0..m).map(|_| AtomicUsize::new(0)).collect());
        // the final-drain barrier (see module docs)
        let barrier = Arc::new(Barrier::new(m));
        let mut handles = Vec::with_capacity(m);
        for (i, shard) in shards.into_iter().enumerate() {
            let rx = receivers[i].take().unwrap();
            let txs: Vec<Sender<MassMsg>> = senders.clone();
            let nbrs = graph.adj[i].clone();
            let rng = root.substream(i as u64);
            let p = p.clone();
            let protocol = protocol.clone();
            let counters = counters.clone();
            let barrier = barrier.clone();
            handles.push(thread::spawn(
                move || -> Result<(Vec<f64>, Vec<f64>, f64, usize, usize)> {
                    let mut guard = ExitGuard {
                        counters: counters.clone(),
                        barrier: barrier.clone(),
                        node: i,
                        cycles: p.cycles,
                        disarmed: false,
                    };
                    let n_i = shard.len() as f64;
                    let mut backend = NativeBackend::default();
                    // Link schedules (no-ops when both options are off —
                    // the default path is bitwise-unchanged). Each
                    // outgoing link's latency is a fixed seeded draw; the
                    // drop stream is its own RNG so the node's protocol
                    // substream never moves because of link options.
                    let delays: Vec<usize> = nbrs
                        .iter()
                        .map(|&tgt| {
                            if p.link_latency == 0 {
                                0
                            } else {
                                let mut r = Rng::new(
                                    p.seed
                                        ^ LINK_SEED
                                        ^ ((i as u64) << 32)
                                        ^ tgt as u64,
                                );
                                r.below(p.link_latency + 1)
                            }
                        })
                        .collect();
                    let mut link_rng =
                        Rng::new(p.seed ^ LINK_SEED).substream(i as u64);
                    // (release_cycle, target, payload) — messages in
                    // transit on this node's outgoing links
                    let mut pending: Vec<(usize, usize, MassMsg)> = Vec::new();
                    let mut dropped = 0usize;
                    // The thread owns its shard outright (the async engine
                    // has no ingestion boundary — a fixed snapshot moves in
                    // here); the node state carries the RNG substream and
                    // the working estimate. The test shard is unused
                    // (evaluation happens in the coordinator).
                    let mut node = NodeState::new(i, Dataset::default(), d, rng);
                    let mut mass = MassState::new(d, n_i);
                    let active = p.cycles.saturating_sub(p.cooldown);
                    let mut sent = 0usize;
                    let mut failure: Option<anyhow::Error> = None;
                    for t in 1..=p.cycles {
                        // bounded staleness: wait until the slowest peer is
                        // within `max_lag` cycles (yielding, not spinning hot)
                        loop {
                            let min = counters
                                .iter()
                                .map(|c| c.load(Ordering::Acquire))
                                .min()
                                .unwrap_or(0);
                            if t <= min + p.max_lag + 1 {
                                break;
                            }
                            thread::yield_now();
                        }
                        if t <= active {
                            // (1) protocol local step on the current estimate
                            if let Err(e) =
                                protocol.local_step(&mut backend, shard.view(), &mut node, t)
                            {
                                // Record and unblock peers: the barrier
                                // below must still be reached by everyone.
                                failure = Some(e);
                                counters[i].store(p.cycles, Ordering::Release);
                                break;
                            }
                            // (2) fold the stepped estimate back into the mass
                            mass.fold(&node.w);
                        }
                        // (3a) release in-transit messages whose latency
                        // has elapsed (empty unless link_latency > 0)
                        let mut k = 0;
                        while k < pending.len() {
                            if pending[k].0 <= t {
                                let (_, tgt, msg) = pending.swap_remove(k);
                                match txs[tgt].send(msg) {
                                    Ok(()) => sent += 1,
                                    Err(e) => {
                                        let MassMsg { v: hv, w: hw } = e.0;
                                        mass.absorb(&hv, hw);
                                    }
                                }
                            } else {
                                k += 1;
                            }
                        }
                        // (3b) halve and send
                        if !nbrs.is_empty() {
                            let nk = node.rng.below(nbrs.len());
                            let tgt = nbrs[nk];
                            let (half_v, half_w) = mass.split_half();
                            if p.link_drop > 0.0 && link_rng.flip(p.link_drop) {
                                // lost in transit: it *was* sent (counts in
                                // messages and dropped under the unified
                                // stats definition), but delivery failed —
                                // the sender reabsorbs, conserving mass.
                                sent += 1;
                                dropped += 1;
                                mass.absorb(&half_v, half_w);
                            } else if delays[nk] > 0 {
                                pending.push((
                                    t + delays[nk],
                                    tgt,
                                    MassMsg { v: half_v, w: half_w },
                                ));
                            } else {
                                // A send fails only if the peer already
                                // exited; its inbox is gone, so keep the
                                // mass local.
                                match txs[tgt].send(MassMsg { v: half_v, w: half_w }) {
                                    Ok(()) => sent += 1,
                                    Err(e) => {
                                        let MassMsg { v: hv, w: hw } = e.0;
                                        mass.absorb(&hv, hw);
                                    }
                                }
                            }
                        }
                        // (4) drain inbox (non-blocking)
                        while let Ok(msg) = rx.try_recv() {
                            mass.absorb(&msg.v, msg.w);
                        }
                        // refresh the estimate; on a collapsed push-sum
                        // weight (halved away without absorbing) the node
                        // keeps its last finite estimate rather than
                        // ingesting inf/NaN — see MassState::estimate_into
                        mass.estimate_into(&mut node.w);
                        counters[i].store(t, Ordering::Release);
                    }
                    // Flush every still-pending delayed message *before*
                    // the barrier — in-transit mass must reach an inbox
                    // (or come home on a dead link) for the final drain
                    // to conserve exactly.
                    for (_, tgt, msg) in pending.drain(..) {
                        match txs[tgt].send(msg) {
                            Ok(()) => sent += 1,
                            Err(e) => {
                                let MassMsg { v: hv, w: hw } = e.0;
                                mass.absorb(&hv, hw);
                            }
                        }
                    }
                    // Final drain: every send happens before this barrier,
                    // so draining to empty afterwards ingests all in-flight
                    // mass — exact conservation at the report boundary.
                    // (Normal exit performs the guard's obligations itself;
                    // the guard only fires on a panic path.)
                    guard.disarmed = true;
                    counters[i].store(p.cycles, Ordering::Release);
                    barrier.wait();
                    while let Ok(msg) = rx.try_recv() {
                        mass.absorb(&msg.v, msg.w);
                    }
                    mass.estimate_into(&mut node.w);
                    if let Some(e) = failure {
                        return Err(e);
                    }
                    Ok((node.w, mass.v, mass.w, sent, dropped))
                },
            ));
        }
        drop(senders);

        let mut estimates = Vec::with_capacity(m);
        let mut mass_v = Vec::with_capacity(m);
        let mut mass_weights = Vec::with_capacity(m);
        let mut stats = GossipStats::default();
        for h in handles {
            let (w, v, mw, sent, dropped) =
                h.join().map_err(|_| anyhow::anyhow!("async scheduler: node thread panicked"))??;
            estimates.push(w);
            mass_v.push(v);
            mass_weights.push(mw);
            stats.messages += sent;
            stats.bytes += sent * 8 * (d + 1);
            stats.dropped += dropped;
        }
        stats.rounds = p.cycles;
        Ok(AsyncRunResult { estimates, mass_v, mass_weights, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::horizontal_split;
    use crate::data::synthetic::{generate, DatasetSpec};

    fn problem(m: usize) -> (Vec<Dataset>, Dataset) {
        let spec = DatasetSpec {
            name: "asched".into(),
            train_size: 480,
            test_size: 240,
            features: 20,
            nnz_per_row: 6,
            noise: 0.03,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        let s = generate(&spec, 91, 1.0);
        (horizontal_split(&s.train, m, 2).unwrap(), s.test)
    }

    fn params(cycles: usize, cooldown: usize) -> AsyncParams {
        AsyncParams {
            lambda: 1e-2,
            batch_size: 2,
            cycles,
            cooldown,
            local_steps: 1,
            project: true,
            seed: 5,
            max_lag: 4,
            link_latency: 0,
            link_drop: 0.0,
        }
    }

    #[test]
    fn learns_and_reports_full_mass_state() {
        let (shards, test) = problem(4);
        let total_n: f64 = shards.iter().map(|s| s.len() as f64).sum();
        let g = Graph::complete(4);
        let res = AsyncScheduler::new(params(400, 50)).run(shards, &g).unwrap();
        assert_eq!(res.estimates.len(), 4);
        for w in &res.estimates {
            let acc = crate::metrics::accuracy(w, &test);
            assert!(acc > 0.8, "node accuracy {acc}");
        }
        // total push-sum weight is exactly the sample count (conservation)
        let w_sum: f64 = res.mass_weights.iter().sum();
        assert!((w_sum - total_n).abs() < 1e-9 * total_n, "weight drift {w_sum} vs {total_n}");
        assert!(res.stats.messages > 0);
        assert!(res.stats.bytes > res.stats.messages);
    }

    #[test]
    fn link_latency_conserves_mass_and_still_learns() {
        let (shards, test) = problem(4);
        let total_n: f64 = shards.iter().map(|s| s.len() as f64).sum();
        let g = Graph::complete(4);
        let mut p = params(400, 50);
        p.link_latency = 3;
        let res = AsyncScheduler::new(p).run(shards, &g).unwrap();
        // delayed messages are flushed before the barrier: conservation
        // at the report boundary is exact regardless of the schedule
        let w_sum: f64 = res.mass_weights.iter().sum();
        assert!((w_sum - total_n).abs() < 1e-9 * total_n, "weight drift {w_sum}");
        assert_eq!(res.stats.dropped, 0);
        for w in &res.estimates {
            let acc = crate::metrics::accuracy(w, &test);
            assert!(acc > 0.75, "node accuracy {acc} under latency");
        }
    }

    #[test]
    fn link_drop_counts_losses_and_conserves_mass() {
        let (shards, test) = problem(4);
        let total_n: f64 = shards.iter().map(|s| s.len() as f64).sum();
        let g = Graph::complete(4);
        let mut p = params(400, 50);
        p.link_drop = 0.2;
        let res = AsyncScheduler::new(p).run(shards, &g).unwrap();
        // drops are delivery failures, not mass destruction: the sender
        // reabsorbs, so totals hold exactly
        let w_sum: f64 = res.mass_weights.iter().sum();
        assert!((w_sum - total_n).abs() < 1e-9 * total_n, "weight drift {w_sum}");
        assert!(res.stats.dropped > 0, "a 20% drop rate must lose messages");
        assert!(res.stats.dropped < res.stats.messages);
        for w in &res.estimates {
            let acc = crate::metrics::accuracy(w, &test);
            assert!(acc > 0.75, "node accuracy {acc} under drops");
        }
    }

    #[test]
    fn invalid_link_drop_rejected() {
        let (shards, _) = problem(3);
        let g = Graph::complete(3);
        let mut p = params(10, 0);
        p.link_drop = 1.0;
        assert!(AsyncScheduler::new(p).run(shards, &g).is_err());
    }

    #[test]
    fn empty_shard_is_rejected_upfront() {
        let (mut shards, _) = problem(3);
        shards[1] = Dataset::default();
        let g = Graph::complete(3);
        assert!(AsyncScheduler::new(params(10, 0)).run(shards, &g).is_err());
    }

    #[test]
    fn mismatched_graph_rejected() {
        let (shards, _) = problem(4);
        let g = Graph::complete(3);
        assert!(AsyncScheduler::new(params(1, 0)).run(shards, &g).is_err());
    }
}
