//! The node-parallel execution runtime: one `Scheduler` abstraction behind
//! every GADGET engine.
//!
//! The paper describes GADGET as a *distributed* anytime algorithm — each
//! site runs Algorithm 2 locally. This module separates the protocol (what
//! one node does per iteration — [`protocol::GossipProtocol`]) from the
//! execution strategy (where and when node steps run — [`Scheduler`]):
//!
//! * [`Sequential`] — all nodes stepped in id order on the calling thread.
//!   The determinism reference, and what Peersim's cycle-driven simulation
//!   does.
//! * [`Parallel`] — a **persistent parked worker pool**
//!   ([`crate::pool::WorkerPool`]) fans the per-node work across cores,
//!   one backend instance per worker. Workers spawn once at scheduler
//!   construction and park between dispatches (PR-1 spawned scoped
//!   threads twice per iteration — [`ScopedSpawn`] keeps that
//!   implementation as the benchmark baseline). Because every node
//!   samples from its own RNG substream (`root.substream(i)`) and the
//!   backends carry no result-bearing state across calls, the outcome is
//!   **bitwise identical** to [`Sequential`] — asserted by
//!   `rust/tests/scheduler_equivalence.rs`.
//! * [`AsyncScheduler`] — thread-per-node message passing with bounded
//!   staleness and a consensus cool-down: no global round barrier at all
//!   (the paper's §1 "completely asynchronous" claim).
//!
//! The scheduler choice threads through `[runtime]` in the config
//! (`scheduler = "sequential" | "parallel" | "async"`, `threads = N`) and
//! `--scheduler/--threads` on the CLI.

pub mod async_sched;
pub mod protocol;

pub use async_sched::{AsyncParams, AsyncRunResult, AsyncScheduler};
pub use protocol::{GossipProtocol, MassState, ProtocolParams};

use crate::coordinator::backend::LocalBackend;
use crate::coordinator::node::NodeState;
use crate::linalg::Kernel;
use crate::pool::{ParallelExec, WorkerPool, SERIAL_EXEC};
use crate::Result;

/// A per-node work item: receives the worker's backend, the node's
/// position within the `ids` slice (== the Push-Vector slot under churn;
/// == the node id when `ids` is `0..m`), and exclusive access to the
/// node's state (`node.id` carries the global id).
pub type NodeFn<'a> =
    &'a (dyn Fn(&mut dyn LocalBackend, usize, &mut NodeState) -> Result<()> + Sync);

/// Executes per-node protocol phases over a node set.
///
/// `ids` selects which nodes participate (all of them for the plain
/// runner; the alive set under churn) and must be strictly increasing and
/// in range. A scheduler guarantees each selected node is visited exactly
/// once with exclusive access; it does *not* guarantee any ordering
/// between nodes — per-node work must not depend on other nodes' state,
/// which is exactly the structure of Algorithm 2's local phase.
pub trait Scheduler {
    /// Scheduler name for reports.
    fn name(&self) -> &'static str;

    /// Worker count (1 for sequential).
    fn threads(&self) -> usize;

    /// Applies `f` to every node selected by `ids`.
    fn for_each_node(
        &mut self,
        nodes: &mut [NodeState],
        ids: &[usize],
        f: NodeFn<'_>,
    ) -> Result<()>;

    /// The executor data-parallel *non-node* phases should run on — the
    /// Push-Vector mixing round fans its column panels over this. Inline
    /// by default; the pooled scheduler exposes its worker pool. The
    /// choice may only move work, never change results (the panel apply
    /// is bitwise executor-invariant).
    fn panel_exec(&self) -> &dyn ParallelExec {
        &SERIAL_EXEC
    }

    /// The kernel backend threaded through this scheduler at construction
    /// (`[runtime] kernel` / `--kernel`) — what the mixing round's panel
    /// apply and any other scheduler-driven dense phase computes on.
    /// Scalar (the bitwise reference) unless overridden via the
    /// schedulers' `with_kernel` constructors; the panel apply itself is
    /// element-wise and therefore bitwise identical on every backend (see
    /// `linalg::kernel`), so this choice also only moves work on that
    /// phase.
    fn kernel(&self) -> &'static dyn Kernel {
        crate::linalg::kernel::scalar()
    }
}

/// Checks the [`Scheduler::for_each_node`] id contract — strictly
/// increasing, in range, therefore each node visited exactly once.
/// Shared by every scheduler so they all reject exactly the same inputs:
/// before this helper existed, `Sequential` silently visited a node
/// *twice* on duplicate ids (advancing its RNG stream twice) where
/// `Parallel` errored — a divergence the equivalence contract forbids.
pub fn validate_ids(ids: &[usize], m: usize) -> Result<()> {
    let mut prev: Option<usize> = None;
    for &id in ids {
        if id >= m {
            anyhow::bail!("scheduler: node id {id} out of range (m = {m})");
        }
        if let Some(p) = prev {
            if id <= p {
                anyhow::bail!(
                    "scheduler: node ids must be strictly increasing, each node \
                     visited exactly once (got {p} then {id})"
                );
            }
        }
        prev = Some(id);
    }
    Ok(())
}

/// Resolves a configured thread count: `0` means "use all available
/// cores".
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// The sequential scheduler: today's cycle-driven behavior, one backend,
/// nodes visited in id order on the calling thread.
pub struct Sequential<'b> {
    backend: &'b mut dyn LocalBackend,
    kernel: &'static dyn Kernel,
}

impl<'b> Sequential<'b> {
    /// Wraps a borrowed backend (callers keep ownership — the public
    /// `GadgetRunner::run_with_backend` entry point injects test/bench
    /// backends this way). The scheduler-level kernel is the scalar
    /// reference; see [`Self::with_kernel`].
    pub fn new(backend: &'b mut dyn LocalBackend) -> Self {
        Self { backend, kernel: crate::linalg::kernel::scalar() }
    }

    /// Threads a kernel backend through the scheduler (the runner does
    /// this with the `[runtime] kernel` selection).
    pub fn with_kernel(mut self, kernel: &'static dyn Kernel) -> Self {
        self.kernel = kernel;
        self
    }
}

impl Scheduler for Sequential<'_> {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn threads(&self) -> usize {
        1
    }

    fn kernel(&self) -> &'static dyn Kernel {
        self.kernel
    }

    fn for_each_node(
        &mut self,
        nodes: &mut [NodeState],
        ids: &[usize],
        f: NodeFn<'_>,
    ) -> Result<()> {
        validate_ids(ids, nodes.len())?;
        for (slot, &id) in ids.iter().enumerate() {
            f(&mut *self.backend, slot, &mut nodes[id])?;
        }
        Ok(())
    }
}

/// A raw pointer that may cross threads. Used by the pooled scheduler's
/// indexed dispatch, where each index derives disjoint `&mut` access
/// from a shared base pointer (the disjointness argument lives at the
/// dereference sites).
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: a SendPtr is only dereferenced under the per-index
// disjointness invariants documented where it is used. The `T: Send`
// bound is load-bearing on both impls: sharing the wrapper hands each
// thread exclusive (`&mut`) access to disjoint `T`s, which is a Send
// transfer — an unbounded impl would launder non-Send data (e.g. `Rc`
// internals) across pool threads.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Collects disjoint `&mut` references to the selected nodes, in id
/// order, without unsafe: one forward walk of the slice's `iter_mut`.
/// Requires `validate_ids`-clean ids. (Used by the [`ScopedSpawn`]
/// baseline; the pooled [`Parallel`] computes the same partition by
/// index arithmetic to keep its dispatch allocation-free.)
fn collect_node_refs<'n>(
    nodes: &'n mut [NodeState],
    ids: &[usize],
) -> Vec<(usize, &'n mut NodeState)> {
    let mut refs: Vec<(usize, &mut NodeState)> = Vec::with_capacity(ids.len());
    let mut it = nodes.iter_mut().enumerate();
    for (slot, &want) in ids.iter().enumerate() {
        let node = loop {
            match it.next() {
                Some((i, n)) if i == want => break n,
                Some(_) => continue,
                None => unreachable!("validate_ids guarantees ids are reachable"),
            }
        };
        refs.push((slot, node));
    }
    refs
}

/// The node-parallel scheduler: a **persistent parked worker pool**
/// ([`crate::pool::WorkerPool`]) with one backend per worker. Workers
/// spawn once here, at construction, and park between dispatches; each
/// `for_each_node` call splits the selected id set into contiguous
/// chunks by index arithmetic and ships them through the pool's
/// allocation-free indexed dispatch
/// ([`crate::pool::ParallelExec::run_indexed`]) — at steady state an
/// iteration's two phases allocate nothing. Since node results depend
/// only on the
/// node's own state (shard, RNG substream, weight vector) and the
/// backends re-initialize their scratch from `w` on every call, the
/// results are bitwise identical to [`Sequential`] regardless of worker
/// count or interleaving.
///
/// PR-1's [`ScopedSpawn`] paid ~2·`threads` thread spawns per GADGET
/// iteration (one per worker per phase); the pool pays a condvar wake
/// instead — the difference is measured in `benches/table5_speedup.rs`
/// §dispatch overhead and dominates at small `d`·`batch`.
pub struct Parallel {
    pool: WorkerPool,
    backends: Vec<Box<dyn LocalBackend + Send>>,
    kernel: &'static dyn Kernel,
}

impl Parallel {
    /// Builds a pool of `threads` parked workers (`0` = all cores),
    /// constructing one backend per worker with `factory`. The
    /// scheduler-level kernel is the scalar reference; the runner chains
    /// [`Self::with_kernel`] so the `[runtime] kernel` selection rides
    /// along the worker pool (the backends the factory builds carry their
    /// own handle for the local step).
    pub fn new<F>(threads: usize, factory: F) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn LocalBackend + Send>>,
    {
        let t = resolve_threads(threads);
        let mut backends = Vec::with_capacity(t);
        for _ in 0..t {
            backends.push(factory()?);
        }
        Ok(Self { pool: WorkerPool::new(t), backends, kernel: crate::linalg::kernel::scalar() })
    }

    /// A native-backend pool — the common case (churn, benches).
    pub fn native(threads: usize) -> Self {
        // The factory is infallible for the native backend.
        Self::new(threads, || {
            let b: Box<dyn LocalBackend + Send> =
                Box::new(crate::coordinator::backend::NativeBackend::default());
            Ok(b)
        })
        .expect("native backend construction cannot fail")
    }

    /// Threads a kernel backend through the scheduler.
    pub fn with_kernel(mut self, kernel: &'static dyn Kernel) -> Self {
        self.kernel = kernel;
        self
    }
}

impl Scheduler for Parallel {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn threads(&self) -> usize {
        self.backends.len()
    }

    fn panel_exec(&self) -> &dyn ParallelExec {
        &self.pool
    }

    fn kernel(&self) -> &'static dyn Kernel {
        self.kernel
    }

    fn for_each_node(
        &mut self,
        nodes: &mut [NodeState],
        ids: &[usize],
        f: NodeFn<'_>,
    ) -> Result<()> {
        validate_ids(ids, nodes.len())?;
        if ids.is_empty() {
            return Ok(());
        }
        let Self { pool, backends, .. } = self;
        // Same contiguous partition of the slot range as the boxed-task
        // implementation (and as `ScopedSpawn`), computed by index
        // arithmetic so the dispatch enqueues lightweight index jobs —
        // no per-call `Vec` of node refs, no boxed closures. Trailing
        // indices past the last slot clamp to an empty range.
        let workers = backends.len().min(ids.len()).max(1);
        let chunk = (ids.len() + workers - 1) / workers;
        let n_slots = ids.len();
        let nodes_ptr = SendPtr(nodes.as_mut_ptr());
        let backends_ptr = SendPtr(backends.as_mut_ptr());
        pool.run_indexed(workers, &move |c| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n_slots);
            // SAFETY: index `c` exclusively owns backend `c` (indices are
            // distinct and in range: workers ≤ backends.len()), and the
            // nodes selected by slots [lo, hi): the slot ranges are
            // disjoint and `validate_ids` guarantees ids are strictly
            // increasing — all distinct and in range — so no two indices
            // alias a node. `run_indexed` does not return until every
            // index finished, so no access outlives the borrows the
            // pointers were derived from.
            let backend = unsafe { &mut *backends_ptr.0.add(c) };
            for slot in lo..hi {
                let node = unsafe { &mut *nodes_ptr.0.add(ids[slot]) };
                f(&mut **backend, slot, node)?;
            }
            Ok(())
        })
    }
}

/// PR-1's scoped-spawn scheduler, retained verbatim as the measurement
/// baseline the pooled [`Parallel`] is compared against
/// (`benches/table5_speedup.rs` §dispatch overhead, `benches/hotpath.rs`
/// scheduler sweep). Spawns fresh scoped threads on every
/// `for_each_node` call; produces bit-identical results to both
/// [`Sequential`] and [`Parallel`]. Not reachable from configs — the
/// `parallel` scheduler kind always builds the pooled implementation.
pub struct ScopedSpawn {
    backends: Vec<Box<dyn LocalBackend + Send>>,
}

impl ScopedSpawn {
    /// A native-backend scoped-spawn scheduler with `threads` workers
    /// (`0` = all cores).
    pub fn native(threads: usize) -> Self {
        let t = resolve_threads(threads);
        let backends = (0..t)
            .map(|_| {
                Box::new(crate::coordinator::backend::NativeBackend::default())
                    as Box<dyn LocalBackend + Send>
            })
            .collect();
        Self { backends }
    }
}

impl Scheduler for ScopedSpawn {
    fn name(&self) -> &'static str {
        "parallel-scoped"
    }

    fn threads(&self) -> usize {
        self.backends.len()
    }

    fn for_each_node(
        &mut self,
        nodes: &mut [NodeState],
        ids: &[usize],
        f: NodeFn<'_>,
    ) -> Result<()> {
        validate_ids(ids, nodes.len())?;
        if ids.is_empty() {
            return Ok(());
        }
        let mut refs = collect_node_refs(nodes, ids);
        let workers = self.backends.len().min(refs.len()).max(1);
        let chunk = (refs.len() + workers - 1) / workers;
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(workers);
            for (backend, slab) in self.backends.iter_mut().zip(refs.chunks_mut(chunk)) {
                handles.push(scope.spawn(move || -> Result<()> {
                    for (slot, node) in slab.iter_mut() {
                        f(&mut **backend, *slot, node)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join()
                    .map_err(|_| anyhow::anyhow!("scheduler: worker thread panicked"))??;
            }
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::data::{Dataset, ShardStore, StaticStore};
    use crate::rng::Rng;

    fn nodes(m: usize, seed: u64) -> (StaticStore, Vec<NodeState>) {
        let spec = DatasetSpec {
            name: "sched".into(),
            train_size: 240,
            test_size: 40,
            features: 16,
            nnz_per_row: 5,
            noise: 0.03,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        let ds = generate(&spec, seed, 1.0).train;
        let root = Rng::new(seed);
        let store = StaticStore::split(&ds, m, seed).unwrap();
        let nodes = (0..m)
            .map(|i| NodeState::new(i, Dataset::default(), 16, root.substream(i as u64)))
            .collect();
        (store, nodes)
    }

    fn step_all(
        sched: &mut dyn Scheduler,
        store: &StaticStore,
        nodes: &mut [NodeState],
        iters: usize,
    ) {
        let proto = GossipProtocol::new(ProtocolParams {
            lambda: 1e-2,
            batch_size: 2,
            local_steps: 2,
            project_local: true,
            project_consensus: true,
            epsilon: 1e-3,
        });
        let ids: Vec<usize> = (0..nodes.len()).collect();
        let store_ref: &dyn ShardStore = store;
        for t in 1..=iters {
            sched
                .for_each_node(nodes, &ids, &|backend, _id, node| {
                    proto.local_step(backend, store_ref.shard(node.id), node, t)
                })
                .unwrap();
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        for threads in [1usize, 2, 3, 8] {
            let (seq_store, mut seq_nodes) = nodes(6, 42);
            let mut backend = NativeBackend::default();
            let mut seq = Sequential::new(&mut backend);
            step_all(&mut seq, &seq_store, &mut seq_nodes, 12);

            let (par_store, mut par_nodes) = nodes(6, 42);
            let mut par = Parallel::native(threads);
            step_all(&mut par, &par_store, &mut par_nodes, 12);

            for (a, b) in seq_nodes.iter().zip(&par_nodes) {
                assert_eq!(a.w, b.w, "threads={threads} node {}", a.id);
            }
        }
    }

    #[test]
    fn id_subset_touches_only_selected_nodes() {
        let (_store, mut ns) = nodes(5, 7);
        let before: Vec<Vec<f64>> = ns.iter().map(|n| n.w.clone()).collect();
        let mut par = Parallel::native(2);
        let ids = [1usize, 3];
        par.for_each_node(&mut ns, &ids, &|_b, _id, node| {
            node.w[0] += 1.0;
            Ok(())
        })
        .unwrap();
        for (i, n) in ns.iter().enumerate() {
            if ids.contains(&i) {
                assert_eq!(n.w[0], before[i][0] + 1.0, "node {i} not stepped");
            } else {
                assert_eq!(n.w, before[i], "node {i} touched");
            }
        }
    }

    #[test]
    fn out_of_range_and_unsorted_ids_rejected() {
        let (_store, mut ns) = nodes(3, 1);
        let mut par = Parallel::native(2);
        assert!(par.for_each_node(&mut ns, &[5], &|_b, _i, _n| Ok(())).is_err());
        // descending ids violate the strictly-increasing contract
        assert!(par.for_each_node(&mut ns, &[2, 0], &|_b, _i, _n| Ok(())).is_err());
        let mut backend = NativeBackend::default();
        let mut seq = Sequential::new(&mut backend);
        assert!(seq.for_each_node(&mut ns, &[9], &|_b, _i, _n| Ok(())).is_err());
    }

    #[test]
    fn id_contract_is_shared_by_all_schedulers() {
        // Regression: `Sequential` used to silently accept duplicate and
        // descending ids (visiting a node twice — advancing its RNG
        // stream twice) while `Parallel` rejected them. The shared
        // `validate_ids` helper must make every scheduler enforce the
        // documented "strictly increasing, visited exactly once" contract
        // identically.
        let (_store, mut ns) = nodes(4, 9);
        let w_before: Vec<Vec<f64>> = ns.iter().map(|n| n.w.clone()).collect();
        fn bump(_b: &mut dyn LocalBackend, _i: usize, n: &mut NodeState) -> crate::Result<()> {
            n.w[0] += 1.0;
            Ok(())
        }
        let mut backend = NativeBackend::default();
        let mut seq = Sequential::new(&mut backend);
        let mut par = Parallel::native(2);
        let mut scoped = ScopedSpawn::native(2);
        let scheds: [&mut dyn Scheduler; 3] = [&mut seq, &mut par, &mut scoped];
        for sched in scheds {
            for bad in [&[1usize, 1][..], &[2, 0][..], &[0, 3, 3][..], &[4][..]] {
                let err = sched.for_each_node(&mut ns, bad, &bump).unwrap_err();
                let msg = err.to_string();
                assert!(
                    msg.contains("strictly increasing") || msg.contains("out of range"),
                    "{}: {bad:?}: {msg}",
                    sched.name()
                );
            }
        }
        // rejection happens before any node is touched
        for (n, before) in ns.iter().zip(&w_before) {
            assert_eq!(&n.w, before, "node {} mutated by a rejected call", n.id);
        }
        assert!(validate_ids(&[0, 2, 3], 4).is_ok());
        assert!(validate_ids(&[], 0).is_ok());
    }

    #[test]
    fn scoped_spawn_matches_sequential_bitwise() {
        // The retained PR-1 baseline must stay equivalent too — it is the
        // control arm of the dispatch-overhead bench.
        let (seq_store, mut seq_nodes) = nodes(5, 11);
        let mut backend = NativeBackend::default();
        let mut seq = Sequential::new(&mut backend);
        step_all(&mut seq, &seq_store, &mut seq_nodes, 8);

        let (sc_store, mut sc_nodes) = nodes(5, 11);
        let mut scoped = ScopedSpawn::native(3);
        step_all(&mut scoped, &sc_store, &mut sc_nodes, 8);
        for (a, b) in seq_nodes.iter().zip(&sc_nodes) {
            assert_eq!(a.w, b.w, "node {}", a.id);
        }
    }

    #[test]
    fn pool_larger_than_node_count_matches_sequential() {
        // threads ≫ nodes: surplus workers stay parked and the result is
        // unchanged.
        let (seq_store, mut seq_nodes) = nodes(3, 21);
        let mut backend = NativeBackend::default();
        let mut seq = Sequential::new(&mut backend);
        step_all(&mut seq, &seq_store, &mut seq_nodes, 6);

        let (par_store, mut par_nodes) = nodes(3, 21);
        let mut par = Parallel::native(16);
        assert_eq!(par.threads(), 16);
        step_all(&mut par, &par_store, &mut par_nodes, 6);
        for (a, b) in seq_nodes.iter().zip(&par_nodes) {
            assert_eq!(a.w, b.w, "node {}", a.id);
        }
    }

    #[test]
    fn empty_id_set_is_a_noop_dispatch() {
        // The churn path hands the scheduler an empty alive set when every
        // node is down — must be a clean no-op, not a hang or error.
        let (_store, mut ns) = nodes(3, 2);
        let before: Vec<Vec<f64>> = ns.iter().map(|n| n.w.clone()).collect();
        let mut par = Parallel::native(4);
        par.for_each_node(&mut ns, &[], &|_b, _i, n| {
            n.w[0] += 1.0;
            Ok(())
        })
        .unwrap();
        for (n, b) in ns.iter().zip(&before) {
            assert_eq!(&n.w, b);
        }
    }

    #[test]
    fn panel_exec_defaults_inline_and_pool_for_parallel() {
        let mut backend = NativeBackend::default();
        let seq = Sequential::new(&mut backend);
        assert_eq!(seq.panel_exec().threads(), 1);
        let par = Parallel::native(3);
        assert_eq!(par.panel_exec().threads(), 3);
    }

    #[test]
    fn kernel_threads_through_scheduler_construction() {
        // Default is the scalar reference; `with_kernel` carries the
        // runtime selection alongside the worker pool.
        let mut backend = NativeBackend::default();
        let seq = Sequential::new(&mut backend);
        assert_eq!(seq.kernel().name(), "scalar");
        let mut backend2 = NativeBackend::default();
        let seq_simd =
            Sequential::new(&mut backend2).with_kernel(crate::linalg::kernel::simd());
        assert_eq!(seq_simd.kernel().name(), "simd");
        let par = Parallel::native(2).with_kernel(crate::linalg::kernel::simd());
        assert_eq!(par.kernel().name(), "simd");
        assert_eq!(Parallel::native(2).kernel().name(), "scalar");
        // the bench control arm stays pinned to the reference
        assert_eq!(ScopedSpawn::native(2).kernel().name(), "scalar");
    }

    #[test]
    fn worker_errors_propagate() {
        let (_store, mut ns) = nodes(4, 2);
        let mut par = Parallel::native(4);
        let err = par
            .for_each_node(&mut ns, &[0, 1, 2, 3], &|_b, id, _n| {
                if id == 2 {
                    anyhow::bail!("boom at {id}");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
