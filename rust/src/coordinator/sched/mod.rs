//! The node-parallel execution runtime: one `Scheduler` abstraction behind
//! every GADGET engine.
//!
//! The paper describes GADGET as a *distributed* anytime algorithm — each
//! site runs Algorithm 2 locally. This module separates the protocol (what
//! one node does per iteration — [`protocol::GossipProtocol`]) from the
//! execution strategy (where and when node steps run — [`Scheduler`]):
//!
//! * [`Sequential`] — all nodes stepped in id order on the calling thread.
//!   The determinism reference, and what Peersim's cycle-driven simulation
//!   does.
//! * [`Parallel`] — a scoped pool fans the per-node work across cores,
//!   one backend instance per worker. Because every node samples from its
//!   own RNG substream (`root.substream(i)`) and the backends carry no
//!   result-bearing state across calls, the outcome is **bitwise
//!   identical** to [`Sequential`] — asserted by
//!   `rust/tests/scheduler_equivalence.rs`.
//! * [`AsyncScheduler`] — thread-per-node message passing with bounded
//!   staleness and a consensus cool-down: no global round barrier at all
//!   (the paper's §1 "completely asynchronous" claim).
//!
//! The scheduler choice threads through `[runtime]` in the config
//! (`scheduler = "sequential" | "parallel" | "async"`, `threads = N`) and
//! `--scheduler/--threads` on the CLI.

pub mod async_sched;
pub mod protocol;

pub use async_sched::{AsyncParams, AsyncRunResult, AsyncScheduler};
pub use protocol::{GossipProtocol, MassState, ProtocolParams};

use crate::coordinator::backend::LocalBackend;
use crate::coordinator::node::NodeState;
use crate::Result;

/// A per-node work item: receives the worker's backend, the node's
/// position within the `ids` slice (== the Push-Vector slot under churn;
/// == the node id when `ids` is `0..m`), and exclusive access to the
/// node's state (`node.id` carries the global id).
pub type NodeFn<'a> =
    &'a (dyn Fn(&mut dyn LocalBackend, usize, &mut NodeState) -> Result<()> + Sync);

/// Executes per-node protocol phases over a node set.
///
/// `ids` selects which nodes participate (all of them for the plain
/// runner; the alive set under churn) and must be strictly increasing and
/// in range. A scheduler guarantees each selected node is visited exactly
/// once with exclusive access; it does *not* guarantee any ordering
/// between nodes — per-node work must not depend on other nodes' state,
/// which is exactly the structure of Algorithm 2's local phase.
pub trait Scheduler {
    /// Scheduler name for reports.
    fn name(&self) -> &'static str;

    /// Worker count (1 for sequential).
    fn threads(&self) -> usize;

    /// Applies `f` to every node selected by `ids`.
    fn for_each_node(
        &mut self,
        nodes: &mut [NodeState],
        ids: &[usize],
        f: NodeFn<'_>,
    ) -> Result<()>;
}

/// Resolves a configured thread count: `0` means "use all available
/// cores".
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// The sequential scheduler: today's cycle-driven behavior, one backend,
/// nodes visited in id order on the calling thread.
pub struct Sequential<'b> {
    backend: &'b mut dyn LocalBackend,
}

impl<'b> Sequential<'b> {
    /// Wraps a borrowed backend (callers keep ownership — the public
    /// `GadgetRunner::run_with_backend` entry point injects test/bench
    /// backends this way).
    pub fn new(backend: &'b mut dyn LocalBackend) -> Self {
        Self { backend }
    }
}

impl Scheduler for Sequential<'_> {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn threads(&self) -> usize {
        1
    }

    fn for_each_node(
        &mut self,
        nodes: &mut [NodeState],
        ids: &[usize],
        f: NodeFn<'_>,
    ) -> Result<()> {
        for (slot, &id) in ids.iter().enumerate() {
            let node = nodes
                .get_mut(id)
                .ok_or_else(|| anyhow::anyhow!("scheduler: node id {id} out of range"))?;
            f(&mut *self.backend, slot, node)?;
        }
        Ok(())
    }
}

/// The node-parallel scheduler: scoped worker threads with one backend
/// per worker. Nodes are split into contiguous chunks of the selected id
/// set; each worker steps its chunk in order. Since node results depend
/// only on the node's own state (shard, RNG substream, weight vector) and
/// the backends re-initialize their scratch from `w` on every call, the
/// results are bitwise identical to [`Sequential`] regardless of worker
/// count or interleaving.
///
/// Workers are *spawned per `for_each_node` call* (scoped threads keep
/// the borrow story safe without `unsafe`); only the backends persist.
/// Spawn cost is tens of microseconds per worker per phase, which is
/// noise against the local-step phase but can cap speedups at tiny
/// `d`·`batch` — a persistent parked pool is a ROADMAP open item; the
/// threads sweep in `benches/table5_speedup.rs` tracks the real effect.
pub struct Parallel {
    backends: Vec<Box<dyn LocalBackend + Send>>,
}

impl Parallel {
    /// Builds a pool of `threads` workers (`0` = all cores), constructing
    /// one backend per worker with `factory`.
    pub fn new<F>(threads: usize, factory: F) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn LocalBackend + Send>>,
    {
        let t = resolve_threads(threads);
        let mut backends = Vec::with_capacity(t);
        for _ in 0..t {
            backends.push(factory()?);
        }
        Ok(Self { backends })
    }

    /// A native-backend pool — the common case (churn, benches).
    pub fn native(threads: usize) -> Self {
        // The factory is infallible for the native backend.
        Self::new(threads, || {
            let b: Box<dyn LocalBackend + Send> =
                Box::new(crate::coordinator::backend::NativeBackend::default());
            Ok(b)
        })
        .expect("native backend construction cannot fail")
    }
}

impl Scheduler for Parallel {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn threads(&self) -> usize {
        self.backends.len()
    }

    fn for_each_node(
        &mut self,
        nodes: &mut [NodeState],
        ids: &[usize],
        f: NodeFn<'_>,
    ) -> Result<()> {
        if ids.is_empty() {
            return Ok(());
        }
        // Collect disjoint &mut references to the selected nodes, in id
        // order, without unsafe: walk the slice's iter_mut once.
        let mut refs: Vec<(usize, &mut NodeState)> = Vec::with_capacity(ids.len());
        {
            let mut it = nodes.iter_mut().enumerate();
            for (slot, &want) in ids.iter().enumerate() {
                let node = loop {
                    match it.next() {
                        Some((i, n)) if i == want => break n,
                        Some(_) => continue,
                        None => anyhow::bail!(
                            "scheduler: node ids must be strictly increasing and in \
                             range (id {want} not reachable)"
                        ),
                    }
                };
                refs.push((slot, node));
            }
        }
        let workers = self.backends.len().min(refs.len()).max(1);
        let chunk = (refs.len() + workers - 1) / workers;
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(workers);
            for (backend, slab) in self.backends.iter_mut().zip(refs.chunks_mut(chunk)) {
                handles.push(scope.spawn(move || -> Result<()> {
                    for (slot, node) in slab.iter_mut() {
                        f(&mut **backend, *slot, node)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join()
                    .map_err(|_| anyhow::anyhow!("scheduler: worker thread panicked"))??;
            }
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::data::partition::horizontal_split;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::data::Dataset;
    use crate::rng::Rng;

    fn nodes(m: usize, seed: u64) -> Vec<NodeState> {
        let spec = DatasetSpec {
            name: "sched".into(),
            train_size: 240,
            test_size: 40,
            features: 16,
            nnz_per_row: 5,
            noise: 0.03,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        let ds = generate(&spec, seed, 1.0).train;
        let root = Rng::new(seed);
        horizontal_split(&ds, m, seed)
            .into_iter()
            .enumerate()
            .map(|(i, sh)| NodeState::new(i, sh, Dataset::default(), 16, root.substream(i as u64)))
            .collect()
    }

    fn step_all(sched: &mut dyn Scheduler, nodes: &mut [NodeState], iters: usize) {
        let proto = GossipProtocol::new(ProtocolParams {
            lambda: 1e-2,
            batch_size: 2,
            local_steps: 2,
            project_local: true,
            project_consensus: true,
            epsilon: 1e-3,
        });
        let ids: Vec<usize> = (0..nodes.len()).collect();
        for t in 1..=iters {
            sched
                .for_each_node(nodes, &ids, &|backend, _id, node| {
                    proto.local_step(backend, node, t)
                })
                .unwrap();
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        for threads in [1usize, 2, 3, 8] {
            let mut seq_nodes = nodes(6, 42);
            let mut backend = NativeBackend::default();
            let mut seq = Sequential::new(&mut backend);
            step_all(&mut seq, &mut seq_nodes, 12);

            let mut par_nodes = nodes(6, 42);
            let mut par = Parallel::native(threads);
            step_all(&mut par, &mut par_nodes, 12);

            for (a, b) in seq_nodes.iter().zip(&par_nodes) {
                assert_eq!(a.w, b.w, "threads={threads} node {}", a.id);
            }
        }
    }

    #[test]
    fn id_subset_touches_only_selected_nodes() {
        let mut ns = nodes(5, 7);
        let before: Vec<Vec<f64>> = ns.iter().map(|n| n.w.clone()).collect();
        let mut par = Parallel::native(2);
        let ids = [1usize, 3];
        par.for_each_node(&mut ns, &ids, &|_b, _id, node| {
            node.w[0] += 1.0;
            Ok(())
        })
        .unwrap();
        for (i, n) in ns.iter().enumerate() {
            if ids.contains(&i) {
                assert_eq!(n.w[0], before[i][0] + 1.0, "node {i} not stepped");
            } else {
                assert_eq!(n.w, before[i], "node {i} touched");
            }
        }
    }

    #[test]
    fn out_of_range_and_unsorted_ids_rejected() {
        let mut ns = nodes(3, 1);
        let mut par = Parallel::native(2);
        assert!(par.for_each_node(&mut ns, &[5], &|_b, _i, _n| Ok(())).is_err());
        // descending ids cannot be satisfied by the single forward walk
        assert!(par.for_each_node(&mut ns, &[2, 0], &|_b, _i, _n| Ok(())).is_err());
        let mut backend = NativeBackend::default();
        let mut seq = Sequential::new(&mut backend);
        assert!(seq.for_each_node(&mut ns, &[9], &|_b, _i, _n| Ok(())).is_err());
    }

    #[test]
    fn worker_errors_propagate() {
        let mut ns = nodes(4, 2);
        let mut par = Parallel::native(4);
        let err = par
            .for_each_node(&mut ns, &[0, 1, 2, 3], &|_b, id, _n| {
                if id == 2 {
                    anyhow::bail!("boom at {id}");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
