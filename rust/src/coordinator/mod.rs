//! The GADGET coordinator — the paper's system contribution (Algorithm 2).
//!
//! * [`backend`] — the local-learner abstraction: one trait, two
//!   implementations (native rust sparse path; PJRT-executed JAX/Pallas
//!   artifact in [`crate::runtime`]).
//! * [`node`] — per-site state: shard, weight vector, RNG stream,
//!   convergence bookkeeping.
//! * [`gadget`] — the cycle-driven runner: local sub-gradient step →
//!   Push-Vector consensus → projection → ε-convergence test, with anytime
//!   snapshots for the figures.
//! * [`engine`] — the asynchronous message-passing engine (threads +
//!   channels): the same protocol executed without a global round barrier,
//!   demonstrating the "completely asynchronous" property claimed in §1.

pub mod backend;
pub mod churn;
pub mod engine;
pub mod gadget;
pub mod multiclass;
pub mod node;

pub use backend::{LocalBackend, NativeBackend, StepContext};
pub use churn::{run_with_churn, ChurnEvent, ChurnKind, ChurnReport, ChurnSchedule};
pub use engine::{AsyncGossipEngine, AsyncParams};
pub use gadget::{run_on_datasets, DatasetRunReport, GadgetReport, GadgetRunner, TrialResult};
pub use multiclass::{MulticlassGadget, MulticlassReport};
pub use node::NodeState;
