//! The GADGET coordinator — the paper's system contribution (Algorithm 2).
//!
//! * [`backend`] — the local-learner abstraction: one trait, two
//!   implementations (native rust sparse path; PJRT-executed JAX/Pallas
//!   artifact in [`crate::runtime`]).
//! * [`node`] — per-site state: weight vector, RNG stream, convergence
//!   bookkeeping (training rows live in the [`crate::data::ShardStore`]
//!   and are borrowed per step as [`crate::data::ShardView`]s).
//! * [`sched`] — the unified node-parallel execution runtime: the shared
//!   per-node protocol step (Algorithm 2 (a)–(h) + ε-check) behind one
//!   `Scheduler` abstraction with sequential, parallel (persistent
//!   parked worker pool, see [`crate::pool`]) and asynchronous
//!   (thread-per-node message passing) implementations.
//! * [`gadget`] — the cycle-driven runner: local sub-gradient step →
//!   Push-Vector consensus → projection → ε-convergence test, with anytime
//!   snapshots for the figures, executed through the configured scheduler.
//! * [`engine`] — compatibility facade over the async scheduler (the
//!   "completely asynchronous" property claimed in §1).
//! * [`churn`] — node failures and re-joins during training (§5
//!   resilience), on the same runtime.

pub mod backend;
pub mod churn;
pub mod engine;
pub mod gadget;
pub mod multiclass;
pub mod node;
pub mod sched;

pub use backend::{LocalBackend, NativeBackend, StepContext};
pub use churn::{run_with_churn, ChurnEvent, ChurnKind, ChurnReport, ChurnSchedule};
pub use engine::{AsyncGossipEngine, AsyncParams};
pub use gadget::{
    lambda_for_corpus, run_on_datasets, DatasetRunReport, DriftEvent, GadgetReport, GadgetRunner,
    TrialResult, GRAPH_SEED, MIXER_SEED,
};
pub use multiclass::{MulticlassGadget, MulticlassReport};
pub use node::NodeState;
pub use sched::{
    AsyncRunResult, AsyncScheduler, GossipProtocol, MassState, Parallel, ProtocolParams,
    Scheduler, ScopedSpawn, Sequential,
};
