//! Distributed multi-class GADGET: one-vs-rest over the gossip runtime —
//! the paper's §5 "extension to multi-class variants of SVMs".
//!
//! Each class runs Algorithm 2 on the binary one-vs-rest relabelling of
//! the same horizontal partition; nodes end up with `K` consensus weight
//! vectors and decode by argmax. Classes train sequentially (the gossip
//! network is shared); the per-class runs reuse the standard
//! [`super::GadgetRunner`] machinery so every invariant (ε-convergence,
//! ball projection, shard-weighted Push-Vector) carries over unchanged.

use crate::config::ExperimentConfig;
use crate::solver::multiclass::{MulticlassDataset, MulticlassModel};
use crate::solver::LinearModel;
use crate::Result;

/// Report of a distributed multiclass run.
#[derive(Clone, Debug)]
pub struct MulticlassReport {
    /// The argmax model assembled from per-class consensus vectors.
    pub model: MulticlassModel,
    /// Test accuracy (argmax decoding) on the held-out set.
    pub test_accuracy: f64,
    /// Total training seconds across classes.
    pub train_secs: f64,
    /// Per-class binary reports (accuracy is the one-vs-rest accuracy).
    pub class_accuracy: Vec<f64>,
    /// Feature dimension the per-class scorers were trained at — recorded
    /// so the report can be persisted as a serve artifact
    /// ([`crate::serve::ModelArtifact::from_multiclass`]) without
    /// re-deriving it from the weight rows.
    pub dim: usize,
}

/// One-vs-rest GADGET trainer.
pub struct MulticlassGadget {
    /// Base config; `dataset` is ignored (data passed explicitly).
    pub base: ExperimentConfig,
}

impl MulticlassGadget {
    /// Creates a trainer from a base config (nodes, topology, ε, budget…).
    pub fn new(base: ExperimentConfig) -> Self {
        Self { base }
    }

    /// Trains on `train`, evaluates argmax accuracy on `test`.
    ///
    /// `lambda` applies to every class (the paper tunes one λ per dataset).
    pub fn run(
        &self,
        train: &MulticlassDataset,
        test: &MulticlassDataset,
        lambda: f64,
    ) -> Result<MulticlassReport> {
        anyhow::ensure!(
            train.num_classes == test.num_classes,
            "train/test class count mismatch"
        );
        let sw = crate::util::Stopwatch::new();
        let mut models = Vec::with_capacity(train.num_classes);
        let mut class_accuracy = Vec::with_capacity(train.num_classes);
        for k in 0..train.num_classes as u32 {
            let binary_train = train.binary_view(k);
            let binary_test = test.binary_view(k);
            let report = crate::coordinator::gadget::run_on_datasets(
                &self.base,
                binary_train,
                binary_test,
                lambda,
            )?;
            class_accuracy.push(report.test_accuracy);
            // consensus model = node average of the final vectors (nodes
            // are ε-close; use trial 0's mean objective holder — we take
            // the average of node weight vectors recorded in the report)
            models.push(LinearModel { w: report.consensus_w });
        }
        let model = MulticlassModel { models };
        let test_accuracy = model.accuracy(test);
        Ok(MulticlassReport {
            model,
            test_accuracy,
            train_secs: sw.secs(),
            class_accuracy,
            dim: train.dim,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::multiclass::generate_multiclass;

    #[test]
    fn distributed_multiclass_learns() {
        let full = generate_multiclass(3, 900, 32, 8, 0.03, 21);
        let train = MulticlassDataset::new(
            "tr",
            3,
            32,
            full.rows[..700].to_vec(),
            full.labels[..700].to_vec(),
        );
        let test = MulticlassDataset::new(
            "te",
            3,
            32,
            full.rows[700..].to_vec(),
            full.labels[700..].to_vec(),
        );
        let base = ExperimentConfig::builder()
            .dataset("unused")
            .nodes(4)
            .trials(1)
            .max_iterations(400)
            .seed(9)
            .build()
            .unwrap();
        let report = MulticlassGadget::new(base).run(&train, &test, 1e-3).unwrap();
        assert!(report.test_accuracy > 0.8, "accuracy {}", report.test_accuracy);
        assert_eq!(report.class_accuracy.len(), 3);
        for (k, acc) in report.class_accuracy.iter().enumerate() {
            assert!(*acc > 0.8, "class {k} binary accuracy {acc}");
        }
        assert_eq!(report.dim, 32);

        // the report persists as a serve artifact whose argmax decoding
        // agrees with the in-memory model on every test row
        let artifact = crate::serve::ModelArtifact::from_multiclass(
            &report,
            crate::serve::ScalingMeta { dataset: "tr".into(), scale: 1.0, lambda: 1e-3 },
        )
        .unwrap();
        let tmp = crate::util::TempDir::new().unwrap();
        let path = tmp.path().join("mc.json");
        artifact.save(&path).unwrap();
        let back = crate::serve::ModelArtifact::load(&path).unwrap();
        assert_eq!(back.classes(), 3);
        for x in &test.rows {
            assert_eq!(back.predict(x).label as u32, report.model.predict(x));
        }
    }

    #[test]
    fn class_count_mismatch_rejected() {
        let a = generate_multiclass(3, 60, 8, 4, 0.0, 1);
        let b = generate_multiclass(4, 60, 8, 4, 0.0, 2);
        let base = ExperimentConfig::builder().nodes(2).trials(1).build().unwrap();
        assert!(MulticlassGadget::new(base).run(&a, &b, 1e-3).is_err());
    }
}
