//! Per-site state for the GADGET network.
//!
//! Since the streaming data plane landed, a node no longer *owns* its
//! training shard: the rows live in a [`crate::data::ShardStore`] and the
//! per-node step borrows them through a [`crate::data::ShardView`] at
//! dispatch time. `NodeState` carries only what is genuinely per-node and
//! mutable across iterations — the weight vectors, the RNG substream and
//! the ε-convergence bookkeeping (plus the local test shard, which stays
//! fixed).

use crate::data::Dataset;
use crate::rng::Rng;

/// State owned by one network site `Sᵢ`.
#[derive(Clone, Debug)]
pub struct NodeState {
    /// Node id in `[0, m)`.
    pub id: usize,
    /// Local test shard (the paper splits the test set across nodes too).
    pub test_shard: Dataset,
    /// Current weight vector `ŵᵢ^(t)`.
    pub w: Vec<f64>,
    /// Weight vector after the previous iteration's consensus — the
    /// ε-convergence test compares against this.
    pub w_prev: Vec<f64>,
    /// Node-local RNG stream (independent across nodes).
    pub rng: Rng,
    /// Most recent `‖w − w_prev‖₂` observed at the convergence check.
    pub last_delta: f64,
    /// Whether this node currently satisfies the ε test.
    pub converged: bool,
}

impl NodeState {
    /// Initializes a node with zero weights.
    pub fn new(id: usize, test_shard: Dataset, dim: usize, rng: Rng) -> Self {
        Self {
            id,
            test_shard,
            w: vec![0.0; dim],
            w_prev: vec![0.0; dim],
            rng,
            last_delta: f64::INFINITY,
            converged: false,
        }
    }

    /// Runs the ε-convergence test against the previous consensus vector,
    /// then rolls `w_prev` forward.
    pub fn check_convergence(&mut self, epsilon: f64) -> bool {
        let mut d = 0.0;
        for (a, b) in self.w.iter().zip(&self.w_prev) {
            let x = a - b;
            d += x * x;
        }
        self.last_delta = d.sqrt();
        self.converged = self.last_delta < epsilon;
        self.w_prev.copy_from_slice(&self.w);
        self.converged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SparseVec;

    fn tiny_ds() -> Dataset {
        Dataset::new("t", 2, vec![SparseVec::new(vec![0], vec![1.0])], vec![1])
    }

    #[test]
    fn convergence_threshold_behaviour() {
        let mut n = NodeState::new(0, tiny_ds(), 2, Rng::new(0));
        n.w = vec![0.1, 0.0];
        assert!(!n.check_convergence(0.05)); // delta 0.1 ≥ ε
        assert!((n.last_delta - 0.1).abs() < 1e-12);
        // unchanged since last check ⇒ converged
        assert!(n.check_convergence(0.05));
        assert_eq!(n.last_delta, 0.0);
    }

    #[test]
    fn w_prev_rolls_forward() {
        let mut n = NodeState::new(0, tiny_ds(), 2, Rng::new(0));
        n.w = vec![1.0, 2.0];
        n.check_convergence(1e-3);
        assert_eq!(n.w_prev, vec![1.0, 2.0]);
    }
}
