//! The local-learner backend abstraction.
//!
//! A backend performs the *compute* part of one GADGET iteration at one
//! node: `local_steps` Pegasos sub-gradient steps (Algorithm 2 (a)–(f)) on
//! the node's shard. The coordinator stays agnostic to where that compute
//! runs:
//!
//! * [`NativeBackend`] — in-process rust sparse kernels (this file);
//! * [`crate::runtime::XlaBackend`] — the AOT-compiled JAX/Pallas artifact
//!   executed on the PJRT CPU client (the L1/L2 layers of the stack).
//!
//! Both receive identical pre-sampled batches, so given the same RNG stream
//! the two backends walk the same optimization trajectory (up to f32
//! rounding in the artifact) — the cross-backend equivalence test in
//! `rust/tests/` relies on this.

use crate::data::ShardView;
use crate::linalg::Kernel;
use crate::rng::Rng;
use crate::Result;

/// Everything a backend needs for one node-iteration.
pub struct StepContext<'a> {
    /// Borrowed window onto the node's current training shard. A view
    /// (not an owned `Dataset`) so the same step code runs over static
    /// and streaming shards — the [`crate::data::ShardStore`] owns the
    /// rows and only grows them at the ingestion boundary *between*
    /// iterations, never while a step borrows this.
    pub shard: ShardView<'a>,
    /// Global GADGET iteration `t` (1-based) — sets `αₜ = 1/(λ·t_eff)`.
    pub t: usize,
    /// Regularization λ.
    pub lambda: f64,
    /// Mini-batch size per local step.
    pub batch_size: usize,
    /// Number of fused local steps this iteration.
    pub local_steps: usize,
    /// Project onto the `1/√λ` ball after each step.
    pub project: bool,
    /// Node-local RNG (batch sampling must come from here so backends agree).
    pub rng: &'a mut Rng,
}

/// A local Pegasos learner.
pub trait LocalBackend {
    /// Advances `w` in place by `ctx.local_steps` sub-gradient steps.
    fn local_step(&mut self, ctx: &mut StepContext<'_>, w: &mut [f64]) -> Result<()>;

    /// Backend name for reports.
    fn name(&self) -> &'static str;
}

/// Per-node reusable step scratch: every buffer the local step needs
/// across iterations, allocated lazily on first use and reused forever.
/// This is the solver half of the allocation-free iteration loop — the
/// dispatch half is [`crate::pool::ParallelExec::run_indexed`] — and is
/// what the zero-allocation regression test
/// (`rust/tests/alloc_regression.rs`) pins.
#[derive(Debug, Default)]
pub struct StepScratch {
    /// Scaled-iterate state `w = s·v` (lazily sized to the weight dim).
    sv: Option<crate::linalg::ScaledIterate>,
    /// Pre-sampled batch indices for one local step.
    batch: Vec<usize>,
    /// Violator indices flagged at the current `w`.
    violators: Vec<usize>,
}

/// Pure-rust sparse backend: O(batch·nnz) per step via the scaled-iterate
/// trick, O(d) only at entry/exit (densify). All mutable state lives in a
/// per-node [`StepScratch`] arena that persists across calls, so the
/// per-iteration hot path allocates nothing (EXPERIMENTS.md §Perf).
///
/// The margin dots dispatch through the backend's [`Kernel`] handle
/// ([`Self::with_kernel`]; `Default` is the scalar reference): on the
/// scalar backend every bit of the trajectory matches the pre-kernel-layer
/// loops, on the SIMD backend margins near the hinge threshold may resolve
/// differently within the kernel's documented ULP bound. The step
/// representation ([`Self::with_options`]; `[runtime] step` / `--step`)
/// selects between the scaled fast path and the O(d) dense reference loop,
/// which are pinned against each other in `rust/tests/step_equivalence.rs`.
#[derive(Debug)]
pub struct NativeBackend {
    scratch: StepScratch,
    kernel: &'static dyn Kernel,
    step: crate::linalg::StepKind,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::with_kernel(crate::linalg::kernel::scalar())
    }
}

impl NativeBackend {
    /// A backend whose margin dots run on `kernel`.
    pub fn with_kernel(kernel: &'static dyn Kernel) -> Self {
        Self::with_options(kernel, crate::linalg::StepKind::Auto)
    }

    /// A backend with an explicit kernel *and* step representation.
    pub fn with_options(kernel: &'static dyn Kernel, step: crate::linalg::StepKind) -> Self {
        Self { scratch: StepScratch::default(), kernel, step }
    }

    /// The kernel backend this learner computes on.
    pub fn kernel(&self) -> &'static dyn Kernel {
        self.kernel
    }

    /// The scaled-iterate step loop (O(1) shrink, O(nnz) update).
    fn local_step_scaled(&mut self, ctx: &mut StepContext<'_>, w: &mut [f64]) -> Result<()> {
        let scratch = &mut self.scratch;
        let sv = match &mut scratch.sv {
            Some(sv) if sv.dim() == w.len() => {
                sv.load_dense(w);
                sv
            }
            _ => {
                scratch.sv = Some(crate::linalg::ScaledIterate::from_dense(w));
                scratch.sv.as_mut().unwrap()
            }
        };
        let radius = 1.0 / ctx.lambda.sqrt();
        let n = ctx.shard.len();
        for s in 0..ctx.local_steps {
            // Effective step counter: iterations are global (t), fused local
            // steps advance it fractionally past t to keep αₜ decreasing.
            let t_eff = (ctx.t - 1) * ctx.local_steps + s + 1;
            let alpha = 1.0 / (ctx.lambda * t_eff as f64);
            let shrink = 1.0 - ctx.lambda * alpha; // = 1 − 1/t_eff
            let step = alpha / ctx.batch_size as f64;
            // Sample the batch (all RNG draws up front, same draw order as
            // the pre-kernel per-sample loop), then flag violators at the
            // current w in one kernel call.
            scratch.batch.clear();
            for _ in 0..ctx.batch_size {
                scratch.batch.push(ctx.rng.below(n));
            }
            scratch.violators.clear();
            self.kernel.hinge_subgrad_accum(
                sv.storage(),
                sv.scale(),
                ctx.shard.rows,
                ctx.shard.labels,
                &scratch.batch,
                &mut scratch.violators,
            );
            if shrink > 0.0 {
                sv.scale_by(shrink);
            } else {
                sv.set_zero();
            }
            for &i in &scratch.violators {
                let (x, y) = ctx.shard.sample(i);
                sv.add_sparse(step * y, x);
            }
            if ctx.project {
                sv.project_to_ball(radius);
            }
        }
        sv.materialize_into(w);
        Ok(())
    }

    /// The O(d) dense reference loop: same RNG draw order and step
    /// schedule, plain in-place dense arithmetic on `w` (no scaled state,
    /// no materialization boundary).
    fn local_step_dense(&mut self, ctx: &mut StepContext<'_>, w: &mut [f64]) -> Result<()> {
        let scratch = &mut self.scratch;
        let radius = 1.0 / ctx.lambda.sqrt();
        let n = ctx.shard.len();
        for s in 0..ctx.local_steps {
            let t_eff = (ctx.t - 1) * ctx.local_steps + s + 1;
            let alpha = 1.0 / (ctx.lambda * t_eff as f64);
            let shrink = 1.0 - ctx.lambda * alpha; // = 1 − 1/t_eff
            let step = alpha / ctx.batch_size as f64;
            scratch.batch.clear();
            for _ in 0..ctx.batch_size {
                scratch.batch.push(ctx.rng.below(n));
            }
            scratch.violators.clear();
            self.kernel.hinge_subgrad_accum(
                w,
                1.0,
                ctx.shard.rows,
                ctx.shard.labels,
                &scratch.batch,
                &mut scratch.violators,
            );
            if shrink > 0.0 {
                crate::linalg::scale_assign(shrink, w);
            } else {
                w.fill(0.0);
            }
            for &i in &scratch.violators {
                let (x, y) = ctx.shard.sample(i);
                self.kernel.axpy_row(step * y, x.into(), w);
            }
            if ctx.project {
                crate::linalg::project_to_ball(w, radius);
            }
        }
        Ok(())
    }
}

impl LocalBackend for NativeBackend {
    fn local_step(&mut self, ctx: &mut StepContext<'_>, w: &mut [f64]) -> Result<()> {
        anyhow::ensure!(ctx.shard.len() > 0, "native backend: empty shard");
        if self.step.is_scaled() {
            self.local_step_scaled(ctx, w)
        } else {
            self.local_step_dense(ctx, w)
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::data::Dataset;

    fn shard() -> Dataset {
        let spec = DatasetSpec {
            name: "b".into(),
            train_size: 200,
            test_size: 32,
            features: 16,
            nnz_per_row: 4,
            noise: 0.02,
            positive_rate: 0.5,
            lambda: 1e-2,
        };
        generate(&spec, 5, 1.0).train
    }

    #[test]
    fn single_step_matches_manual_pegasos() {
        // One step, batch 1, t = 1: w₁ = α·y·x·𝟙[violator] then projection.
        let ds = shard();
        let mut rng_backend = Rng::new(9);
        let mut rng_manual = Rng::new(9);
        let lambda = 1e-2;
        let mut w = vec![0.0; ds.dim];
        let mut ctx = StepContext {
            shard: ds.view(),
            t: 1,
            lambda,
            batch_size: 1,
            local_steps: 1,
            project: true,
            rng: &mut rng_backend,
        };
        NativeBackend::default().local_step(&mut ctx, &mut w).unwrap();

        let i = rng_manual.below(ds.len());
        let (x, y) = ds.sample(i);
        // w=0 ⇒ margin 0 < 1 ⇒ violator; shrink (1-1/1)=0 zeroes w
        let alpha = 1.0 / lambda;
        let mut expect = vec![0.0; ds.dim];
        x.axpy_into(alpha * y, &mut expect);
        crate::linalg::project_to_ball(&mut expect, 1.0 / lambda.sqrt());
        for k in 0..ds.dim {
            assert!((w[k] - expect[k]).abs() < 1e-10, "slot {k}: {} vs {}", w[k], expect[k]);
        }
    }

    #[test]
    fn respects_projection_flag() {
        let ds = shard();
        let lambda: f64 = 1e-2;
        let radius = 1.0 / lambda.sqrt();
        for project in [true, false] {
            let mut rng = Rng::new(1);
            let mut w = vec![0.0; ds.dim];
            let mut ctx = StepContext {
                shard: ds.view(),
                t: 1,
                lambda,
                batch_size: 2,
                local_steps: 50,
                project,
                rng: &mut rng,
            };
            NativeBackend::default().local_step(&mut ctx, &mut w).unwrap();
            let norm = crate::linalg::l2_norm(&w);
            if project {
                assert!(norm <= radius * (1.0 + 1e-9));
            }
        }
    }

    #[test]
    fn multiple_fused_steps_advance_learning() {
        let ds = shard();
        let lambda = 1e-2;
        let run = |steps: usize| {
            let mut rng = Rng::new(3);
            let mut w = vec![0.0; ds.dim];
            for t in 1..=40 {
                let mut ctx = StepContext {
                    shard: ds.view(),
                    t,
                    lambda,
                    batch_size: 1,
                    local_steps: steps,
                    project: true,
                    rng: &mut rng,
                };
                NativeBackend::default().local_step(&mut ctx, &mut w).unwrap();
            }
            crate::metrics::objective(&w, &ds, lambda)
        };
        // more fused local steps per iteration ⇒ at least as good objective
        let f1 = run(1);
        let f8 = run(8);
        assert!(f8 <= f1 * 1.2, "fused {f8} vs single {f1}");
    }
}
