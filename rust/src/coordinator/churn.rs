//! Node churn: failures and re-joins during training — the paper's §5
//! "resilience to node failures" and §1's claim that distributed systems
//! "are often subject to abrupt changes in topology due to nodes joining
//! or leaving".
//!
//! Model: a failed node freezes (keeps its shard and weight vector but
//! neither steps nor gossips); the overlay for each iteration is the
//! subgraph induced by the alive set, with the consensus [`Mixer`]
//! rebuilt on membership changes (`[mixing] backend` is honored; the
//! push-sum reference additionally tolerates a disconnected alive set —
//! components mix internally). A recovering node rejoins with its stale
//! vector, which the shard-weighted consensus re-absorbs — no
//! coordinator, no state transfer, exactly the gossip robustness story.
//!
//! Execution goes through the unified runtime: the per-node work is
//! [`super::sched::GossipProtocol`] and the alive set is fanned out by the
//! configured [`super::sched::Scheduler`] (`sequential` or `parallel`;
//! the async scheduler has no global iteration clock to schedule churn
//! events against, so `scheduler = "async"` falls back to sequential
//! here).

use super::backend::NativeBackend;
use super::gadget::{build_mixer, GRAPH_SEED, MIXER_SEED};
use super::node::NodeState;
use super::sched::{GossipProtocol, Parallel, ProtocolParams, Scheduler, Sequential};
use crate::config::{ExperimentConfig, SchedulerKind};
use crate::data::{partition, ShardStore};
use crate::gossip::{Mixer, MixerKind};
use crate::metrics;
use crate::rng::Rng;
use crate::topology::stochastic::WeightScheme;
use crate::topology::{Graph, TransitionMatrix};
use crate::Result;

/// What happens to a node at a given iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// Node stops stepping and gossiping.
    Fail,
    /// Node rejoins with its stale weight vector.
    Recover,
}

/// One scheduled membership change.
#[derive(Clone, Copy, Debug)]
pub struct ChurnEvent {
    /// GADGET iteration at which the event applies (1-based).
    pub at_iter: usize,
    /// Node id.
    pub node: usize,
    /// Fail or recover.
    pub kind: ChurnKind,
}

/// A deterministic churn schedule.
#[derive(Clone, Debug, Default)]
pub struct ChurnSchedule {
    /// Events sorted by iteration (enforced in [`ChurnSchedule::new`]).
    pub events: Vec<ChurnEvent>,
}

impl ChurnSchedule {
    /// Builds a schedule, sorting events by iteration.
    pub fn new(mut events: Vec<ChurnEvent>) -> Self {
        events.sort_by_key(|e| e.at_iter);
        Self { events }
    }

    /// Random transient churn: each alive node fails with `p_fail` per
    /// iteration and each failed node recovers with `p_recover`,
    /// pre-materialized over `iters` iterations for `m` nodes so runs are
    /// reproducible. Node 0 never fails (keeps the alive set non-empty).
    pub fn random(m: usize, iters: usize, p_fail: f64, p_recover: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xc4u64);
        let mut alive = vec![true; m];
        let mut events = Vec::new();
        for t in 1..=iters {
            for node in 1..m {
                if alive[node] {
                    if rng.flip(p_fail) {
                        alive[node] = false;
                        events.push(ChurnEvent { at_iter: t, node, kind: ChurnKind::Fail });
                    }
                } else if rng.flip(p_recover) {
                    alive[node] = true;
                    events.push(ChurnEvent { at_iter: t, node, kind: ChurnKind::Recover });
                }
            }
        }
        Self { events }
    }
}

/// Report of a churn run.
#[derive(Clone, Debug)]
pub struct ChurnReport {
    /// Mean accuracy over *alive* nodes at stop.
    pub test_accuracy: f64,
    /// Iterations executed.
    pub iterations: usize,
    /// Minimum alive-node count observed.
    pub min_alive: usize,
    /// Number of applied membership changes.
    pub events_applied: usize,
    /// Final consensus disagreement: max over alive nodes of
    /// `‖wᵢ − w̄‖/‖w̄‖`.
    pub disagreement: f64,
}

/// Runs GADGET under a churn schedule (cycle engine, native backend),
/// honoring the config's `[runtime]` scheduler choice for the per-node
/// fan-out.
pub fn run_with_churn(cfg: &ExperimentConfig, schedule: &ChurnSchedule) -> Result<ChurnReport> {
    cfg.validate()?;
    let (train, test, spec_lambda) = super::gadget::load_dataset(cfg)?;
    let lambda = cfg
        .lambda
        .or(spec_lambda)
        .ok_or_else(|| anyhow::anyhow!("churn: lambda required"))?;
    let m = cfg.nodes;
    anyhow::ensure!(m <= train.len(), "more nodes than samples");
    let d = train.dim();

    let full_graph = Graph::generate(cfg.topology, m, cfg.seed ^ GRAPH_SEED);
    // Churn rides the same data plane as the plain runner: training rows
    // live in the shard store ([stream] selects static vs streaming), so
    // node failures and ingestion compose — a failed node's buffer keeps
    // accumulating arrivals (data reaches a down site; it processes the
    // backlog on recovery), and the Push-Sum weights below always reflect
    // the *current* shard sizes of the alive set.
    let mut store = super::gadget::build_store(cfg, &train, cfg.seed, None)?;
    let test_shards = partition::horizontal_split(&test, m, cfg.seed ^ 0x7e57)?;
    let root = Rng::new(cfg.seed);
    let mut nodes: Vec<NodeState> = test_shards
        .into_iter()
        .enumerate()
        .map(|(i, te)| NodeState::new(i, te, d, root.substream(i as u64)))
        .collect();

    let protocol = GossipProtocol::new(ProtocolParams::from_config(cfg, lambda));
    // The scheduler behind the per-node fan-out (churn always uses the
    // native backend — the XLA artifact path is a plain-runner concern).
    // `[runtime] kernel` threads through exactly like the plain runner:
    // one handle for the local-step margins and the mixing panels.
    let kernel = cfg.kernel.build()?;
    let mut seq_backend = NativeBackend::with_options(kernel, cfg.step);
    if cfg.scheduler == SchedulerKind::Async {
        // Churn events are keyed to the global iteration clock, which the
        // asynchronous engine does not have — make the fallback visible.
        eprintln!(
            "churn: scheduler = \"async\" has no global iteration clock to \
             schedule events against; falling back to sequential"
        );
    }
    let mut sched: Box<dyn Scheduler + '_> = match cfg.scheduler {
        // Pool capped at m — more workers than nodes can never be used.
        SchedulerKind::Parallel => Box::new(
            Parallel::new(super::sched::resolve_threads(cfg.threads).min(m), || {
                Ok(Box::new(NativeBackend::with_options(kernel, cfg.step))
                    as Box<dyn super::backend::LocalBackend + Send>)
            })?
            .with_kernel(kernel),
        ),
        _ => Box::new(Sequential::new(&mut seq_backend).with_kernel(kernel)),
    };

    let mut alive = vec![true; m];
    let mut next_event = 0usize;
    let mut events_applied = 0usize;
    let mut min_alive = m;
    let mut iterations = 0usize;
    let mut added = vec![0usize; m];
    // rebuilt on membership change
    let mut membership_dirty = true;
    let mut alive_ids: Vec<usize> = Vec::new();
    // Consensus state, rebuilt only when the alive set changes (the
    // per-mix reset keeps the steady-state hot loop allocation-free,
    // same as the plain runner — EXPERIMENTS.md §Perf).
    let mut mixer: Option<Box<dyn Mixer>> = None;

    for t in 1..=cfg.max_iterations {
        iterations = t;
        // ingestion boundary first (both churn events and arrivals mutate
        // the alive/weight state; arrivals land regardless of aliveness)
        protocol.ingest_boundary(&mut *store, t, &mut added)?;
        // while the stream can still deliver, convergence is vetoed
        // network-wide (fractional-rate gap iterations and arrivals that
        // all landed on dead nodes must not end the run early)
        let stream_live = !store.stream_exhausted();
        // apply due events
        while next_event < schedule.events.len() && schedule.events[next_event].at_iter <= t {
            let e = schedule.events[next_event];
            next_event += 1;
            if e.node < m {
                let want = e.kind == ChurnKind::Recover;
                if alive[e.node] != want {
                    alive[e.node] = want;
                    events_applied += 1;
                    membership_dirty = true;
                }
            }
        }
        if membership_dirty {
            alive_ids = (0..m).filter(|&i| alive[i]).collect();
            min_alive = min_alive.min(alive_ids.len());
            if alive_ids.len() >= 2 {
                // induced subgraph on the alive set
                let index_of =
                    |id: usize| alive_ids.iter().position(|&x| x == id).unwrap();
                let mut edges = Vec::new();
                for &i in &alive_ids {
                    for &j in &full_graph.adj[i] {
                        if alive[j] && i < j {
                            edges.push((index_of(i), index_of(j)));
                        }
                    }
                }
                let sub = Graph::from_edges(alive_ids.len(), &edges);
                // Push-sum tolerates a fractured alive set (components mix
                // internally); gradient-flow's edge duals can only enforce
                // agreement along surviving paths — reject loudly instead
                // of silently averaging per component.
                if cfg.mixer != MixerKind::PushSum {
                    anyhow::ensure!(
                        sub.is_connected(),
                        "churn: mixer {} requires the alive overlay to stay \
                         connected (iteration {t}: the {} alive nodes induce a \
                         disconnected subgraph) — use --mixer push-sum for \
                         schedules that can fracture the overlay",
                        cfg.mixer,
                        alive_ids.len()
                    );
                }
                let tm = TransitionMatrix::from_graph(&sub, WeightScheme::MetropolisHastings);
                let rounds = if cfg.gossip_rounds > 0 {
                    cfg.gossip_rounds
                } else {
                    crate::topology::mixing_time(&tm, cfg.gamma).min(10_000)
                };
                let weights: Vec<f64> =
                    alive_ids.iter().map(|&i| store.shard_len(i) as f64).collect();
                mixer = Some(build_mixer(
                    cfg.mixer,
                    &sub,
                    tm,
                    rounds,
                    cfg.seed ^ MIXER_SEED,
                    d,
                    &weights,
                ));
            } else {
                mixer = None;
            }
            membership_dirty = false;
        }

        // (a)–(f): local steps on alive nodes, fanned out by the
        // scheduler; shards are borrowed from the store at dispatch time.
        let store_ref: &dyn ShardStore = &*store;
        sched.for_each_node(&mut nodes, &alive_ids, &|backend, _id, node| {
            protocol.local_step(backend, store_ref.shard(node.id), node, t)
        })?;
        // (g): gossip among alive nodes (disconnected components mix
        // internally). Weights are re-read from the store every iteration
        // — the re-weight rule — so ingestion-grown shards pull the
        // consensus target toward the sites that received data.
        if let Some(mx) = &mut mixer {
            let weights: Vec<f64> =
                alive_ids.iter().map(|&i| store.shard_len(i) as f64).collect();
            // The mixer's inner panels fan over the scheduler's executor
            // (the worker pool when `[runtime] scheduler = "parallel"`)
            // on its kernel; bitwise identical to inline execution on
            // every backend.
            mx.mix(
                &mut alive_ids.iter().map(|&i| nodes[i].w.as_slice()),
                &weights,
                sched.panel_exec(),
                sched.kernel(),
            );
            // (g)-consume/(h)/ε via the shared protocol; the scheduler
            // hands each closure the node's position within `alive_ids`,
            // which is exactly the mixer slot. The convergence test
            // is drift-aware: a node that ingested this iteration cannot
            // declare convergence.
            let mixer_ref: &dyn Mixer = &**mx;
            let added_ref: &[usize] = &added;
            sched.for_each_node(&mut nodes, &alive_ids, &|_backend, slot, node| {
                protocol.apply_estimate(mixer_ref, slot, node);
                protocol
                    .check_convergence_drift(node, stream_live || added_ref[node.id] > 0);
                Ok(())
            })?;
        } else {
            // isolated survivor (or empty alive set): no gossip, still run
            // the ε bookkeeping so convergence can terminate the run
            for &i in &alive_ids {
                let drifted = stream_live || added[i] > 0;
                protocol.check_convergence_drift(&mut nodes[i], drifted);
            }
        }
        let all = alive_ids.iter().all(|&i| nodes[i].converged);
        if all && next_event >= schedule.events.len() {
            break;
        }
    }

    // evaluate alive nodes
    let accs: Vec<f64> = alive_ids
        .iter()
        .map(|&i| {
            let n = &nodes[i];
            metrics::accuracy(&n.w, if n.test_shard.is_empty() { &test } else { &n.test_shard })
        })
        .collect();
    let test_accuracy = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
    // disagreement among alive nodes
    let mut mean_w = vec![0.0; d];
    for &i in &alive_ids {
        crate::linalg::add_assign(&nodes[i].w, &mut mean_w);
    }
    crate::linalg::scale_assign(1.0 / alive_ids.len().max(1) as f64, &mut mean_w);
    let scale = crate::linalg::l2_norm(&mean_w).max(1e-12);
    let disagreement = alive_ids
        .iter()
        .map(|&i| {
            let mut diff = 0.0;
            for k in 0..d {
                let x = nodes[i].w[k] - mean_w[k];
                diff += x * x;
            }
            diff.sqrt() / scale
        })
        .fold(0.0f64, f64::max);

    Ok(ChurnReport {
        test_accuracy,
        iterations,
        min_alive,
        events_applied,
        disagreement,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.05)
            .nodes(6)
            .trials(1)
            .max_iterations(400)
            .seed(3)
            .build()
            .unwrap()
    }

    #[test]
    fn empty_schedule_matches_failure_free_learning() {
        let report = run_with_churn(&cfg(), &ChurnSchedule::default()).unwrap();
        assert_eq!(report.min_alive, 6);
        assert_eq!(report.events_applied, 0);
        assert!(report.test_accuracy > 0.7, "accuracy {}", report.test_accuracy);
    }

    #[test]
    fn survives_transient_random_churn() {
        let schedule = ChurnSchedule::random(6, 400, 0.01, 0.05, 9);
        assert!(!schedule.events.is_empty());
        let report = run_with_churn(&cfg(), &schedule).unwrap();
        assert!(report.events_applied > 0);
        assert!(report.min_alive >= 1);
        assert!(
            report.test_accuracy > 0.65,
            "accuracy under churn {}",
            report.test_accuracy
        );
    }

    #[test]
    fn survives_permanent_loss_of_half_the_nodes() {
        let events = (3..6)
            .map(|node| ChurnEvent { at_iter: 50, node, kind: ChurnKind::Fail })
            .collect();
        let report = run_with_churn(&cfg(), &ChurnSchedule::new(events)).unwrap();
        assert_eq!(report.min_alive, 3);
        assert!(report.test_accuracy > 0.65, "accuracy {}", report.test_accuracy);
    }

    #[test]
    fn recovered_node_rejoins_consensus() {
        let events = vec![
            ChurnEvent { at_iter: 20, node: 2, kind: ChurnKind::Fail },
            ChurnEvent { at_iter: 200, node: 2, kind: ChurnKind::Recover },
        ];
        let report = run_with_churn(&cfg(), &ChurnSchedule::new(events)).unwrap();
        assert_eq!(report.events_applied, 2);
        // after rejoining, the stale node is re-absorbed: final disagreement
        // among alive nodes is small
        assert!(report.disagreement < 0.5, "disagreement {}", report.disagreement);
        assert!(report.test_accuracy > 0.65);
    }

    #[test]
    fn gradient_flow_churn_runs_and_fractured_overlay_rejected() {
        // The mixer seam reaches churn: gradient-flow survives a failure
        // that keeps the alive overlay connected...
        let gf_cfg = ExperimentConfig { mixer: MixerKind::GradientFlow, ..cfg() };
        let events = vec![ChurnEvent { at_iter: 30, node: 2, kind: ChurnKind::Fail }];
        let report = run_with_churn(&gf_cfg, &ChurnSchedule::new(events)).unwrap();
        assert_eq!(report.min_alive, 5);
        assert!(report.test_accuracy > 0.6, "accuracy {}", report.test_accuracy);
        // ...but a fractured ring is a loud error, not a silent
        // per-component average (push-sum is the fracture-tolerant path).
        let ring_cfg = ExperimentConfig {
            topology: crate::topology::TopologyKind::Ring,
            mixer: MixerKind::GradientFlow,
            ..cfg()
        };
        let events = vec![
            ChurnEvent { at_iter: 10, node: 2, kind: ChurnKind::Fail },
            ChurnEvent { at_iter: 10, node: 4, kind: ChurnKind::Fail },
        ];
        let err = run_with_churn(&ring_cfg, &ChurnSchedule::new(events)).unwrap_err();
        assert!(err.to_string().contains("connected"), "{err}");
    }

    #[test]
    fn random_schedule_is_deterministic() {
        let a = ChurnSchedule::random(8, 100, 0.05, 0.1, 7);
        let b = ChurnSchedule::random(8, 100, 0.05, 0.1, 7);
        assert_eq!(a.events.len(), b.events.len());
        let c = ChurnSchedule::random(8, 100, 0.05, 0.1, 8);
        assert!(a.events.len() != c.events.len() || !a
            .events
            .iter()
            .zip(&c.events)
            .all(|(x, y)| x.at_iter == y.at_iter && x.node == y.node));
    }

    #[test]
    fn pooled_scheduler_survives_empty_alive_set() {
        // Every node fails at once: the scheduler receives an *empty* id
        // set each remaining iteration and the gossip phase is skipped.
        // The pooled dispatch must treat that as a clean no-op — no hang
        // on an empty task batch, no error — and the run must terminate.
        let events = (0..6)
            .map(|node| ChurnEvent { at_iter: 5, node, kind: ChurnKind::Fail })
            .collect();
        let par_cfg = ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads: 4,
            ..cfg()
        };
        let report = run_with_churn(&par_cfg, &ChurnSchedule::new(events)).unwrap();
        assert_eq!(report.min_alive, 0);
        assert_eq!(report.events_applied, 6);
    }

    #[test]
    fn streaming_ingestion_composes_with_churn_and_stays_scheduler_invariant() {
        // Both churn events and arrivals mutate the alive/weight state;
        // composed, the run must still learn, terminate, and stay
        // identical across schedulers (ingestion is store-internal and
        // deterministic, so Parallel ≡ Sequential extends to it).
        let base = ExperimentConfig { stream_rate: 2.0, stream_max_rows: 30, ..cfg() };
        let schedule = ChurnSchedule::new(vec![
            ChurnEvent { at_iter: 10, node: 2, kind: ChurnKind::Fail },
            ChurnEvent { at_iter: 40, node: 2, kind: ChurnKind::Recover },
        ]);
        let seq = run_with_churn(&base, &schedule).unwrap();
        assert_eq!(seq.events_applied, 2);
        assert!(seq.test_accuracy > 0.6, "accuracy {}", seq.test_accuracy);
        let par_cfg =
            ExperimentConfig { scheduler: SchedulerKind::Parallel, threads: 3, ..base };
        let par = run_with_churn(&par_cfg, &schedule).unwrap();
        assert_eq!(seq.iterations, par.iterations);
        assert_eq!(seq.test_accuracy, par.test_accuracy);
        assert_eq!(seq.disagreement, par.disagreement);
    }

    #[test]
    fn parallel_scheduler_matches_sequential_under_churn() {
        let schedule = ChurnSchedule::random(6, 200, 0.02, 0.08, 13);
        let seq = run_with_churn(&cfg(), &schedule).unwrap();
        let par_cfg = ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads: 3,
            ..cfg()
        };
        let par = run_with_churn(&par_cfg, &schedule).unwrap();
        assert_eq!(seq.iterations, par.iterations);
        assert_eq!(seq.events_applied, par.events_applied);
        assert_eq!(seq.test_accuracy, par.test_accuracy);
        assert_eq!(seq.disagreement, par.disagreement);
    }
}
