//! The GADGET SVM runner — Algorithm 2 of the paper, executed on the
//! unified node-parallel runtime.
//!
//! Per iteration `t` every node `i`:
//! 1. **local step** (backend): mini-batch Pegasos sub-gradient update on
//!    the local shard, `w̃ᵢ ← (1 − λαₜ)ŵᵢ + αₜ·L̂ᵢ`, optional projection
//!    (steps (a)–(f));
//! 2. **gossip** (Push-Vector over the doubly-stochastic `B`): replaces
//!    `w̃ᵢ` with the shard-weighted network average estimate
//!    `PS(nᵢ·w̃ᵢ, B)` (step (g));
//! 3. optional consensus projection (step (h));
//! 4. **ε-convergence**: stop when every node's weight vector moved less
//!    than ε since the previous iteration (the paper's anytime criterion).
//!
//! The per-node step logic lives in [`super::sched::GossipProtocol`]; this
//! runner only orchestrates trials and drives the phases through the
//! configured [`super::sched::Scheduler`]:
//!
//! * `sequential` — all nodes on the calling thread (determinism
//!   reference);
//! * `parallel` — work fanned across one persistent parked worker pool
//!   ([`crate::pool::WorkerPool`]), bitwise identical to `sequential`
//!   (per-node RNG substreams isolate all randomness). The per-node
//!   phases and the mixing round's column panels dispatch on the pool;
//!   when there are at least as many trials as workers, whole trial
//!   chunks dispatch instead — trials are embarrassingly parallel (one
//!   protocol state and RNG root substream each), so either fan-out
//!   only changes wall-clock;
//! * `async` — the thread-per-node message-passing engine; no global
//!   barrier, so iteration accounting is "cycles" and the ε-criterion is
//!   replaced by a consensus cool-down.
//!
//! The runner executes `trials` independent repetitions and aggregates
//! accuracy/time with the paper's `sqrt(Var(Nodes) + Var(Trials))` rule.

use super::backend::{LocalBackend, NativeBackend};
use super::node::NodeState;
use super::sched::{
    AsyncParams, AsyncScheduler, GossipProtocol, Parallel, ProtocolParams, Scheduler, Sequential,
};
use crate::config::{Backend, ExperimentConfig, SchedulerKind};
use crate::data::synthetic::{generate, spec_by_name};
use crate::linalg::Kernel;
use crate::data::{
    partition, ArrivalQueue, Dataset, MmapStore, PackFile, ShardStore, ShardView, StaticStore,
    StoreKind, StreamSchedule, StreamingStore,
};
use crate::gossip::{GossipStats, GradientFlowMixer, Mixer, MixerKind, PushSumMixer};
use crate::metrics::{self, node_trial_std, Trace, TracePoint};
use crate::pool::{Task, WorkerPool};
use crate::rng::Rng;
use crate::topology::{mixing_time, Graph, TransitionMatrix};
use crate::util::Stopwatch;
use crate::Result;
use anyhow::{bail, Context};
use std::ops::Range;
use std::sync::Arc;

/// Result of one GADGET trial.
#[derive(Clone, Debug)]
pub struct TrialResult {
    /// GADGET iterations executed (≤ `max_iterations`; async: cycles).
    pub iterations: usize,
    /// Model-construction wall time (excludes data loading, as in Table 3).
    pub train_secs: f64,
    /// Per-node test accuracy on the node's local test shard.
    pub node_accuracy: Vec<f64>,
    /// Per-node primal objective (Eq. 1) of the node's model on the full
    /// training set.
    pub node_objective: Vec<f64>,
    /// Max `‖ŵᵢ^(T) − ŵᵢ^(T−1)‖` at stop — the paper's "epsilon at
    /// convergence" (async: max node deviation from the consensus mean).
    pub epsilon_final: f64,
    /// Node-averaged weight vector at stop (the network consensus model).
    pub consensus_w: Vec<f64>,
    /// Gossip communication totals.
    pub gossip: GossipStats,
    /// Convergence trace (non-empty when `snapshot_every > 0`; the async
    /// engine records no trace — there is no global iteration to snapshot).
    pub trace: Trace,
    /// Per-node drift observations at streaming ingestion boundaries
    /// (empty for static runs and the async engine, which has no
    /// boundary).
    pub drift: Vec<DriftEvent>,
}

/// One per-node drift observation at a streaming ingestion boundary:
/// summary statistics of the rows that *arrived* at this node this
/// iteration, so a drifting stream (label skew, feature-scale shift) is
/// visible in the iteration log instead of silently bending the model.
#[derive(Clone, Copy, Debug)]
pub struct DriftEvent {
    /// GADGET iteration at whose boundary the rows arrived.
    pub iteration: usize,
    /// Node that ingested.
    pub node: usize,
    /// Rows ingested this boundary.
    pub added: usize,
    /// Fraction of +1 labels among the arriving rows.
    pub label_balance: f64,
    /// Mean ‖x‖₂ of the arriving rows.
    pub mean_norm: f64,
}

/// Computes the per-node [`DriftEvent`]s for one non-empty ingestion
/// boundary. The store contract is append-only, so the arrivals are
/// exactly the shard suffix of length `added[i]`.
fn drift_events(
    store: &dyn ShardStore,
    added: &[usize],
    t: usize,
    out: &mut Vec<DriftEvent>,
) {
    for (i, &a) in added.iter().enumerate() {
        if a == 0 {
            continue;
        }
        let shard = store.shard(i);
        let n = shard.len();
        let mut pos = 0usize;
        let mut norm_sum = 0.0f64;
        for r in n - a..n {
            let (row, label) = shard.sample(r);
            if label > 0.0 {
                pos += 1;
            }
            norm_sum += row.l2_norm_sq().sqrt();
        }
        out.push(DriftEvent {
            iteration: t,
            node: i,
            added: a,
            label_balance: pos as f64 / a as f64,
            mean_norm: norm_sum / a as f64,
        });
    }
}

/// Aggregated multi-trial report (one Table-3 row).
#[derive(Clone, Debug)]
pub struct GadgetReport {
    /// Dataset name.
    pub dataset: String,
    /// λ used.
    pub lambda: f64,
    /// Seconds spent materializing the dataset (Table 5 accounting).
    pub load_secs: f64,
    /// Mean test accuracy over nodes and trials.
    pub test_accuracy: f64,
    /// `sqrt(Var(Nodes) + Var(Trials))` for accuracy.
    pub test_accuracy_std: f64,
    /// Mean training time across trials.
    pub train_secs: f64,
    /// Std of training time across trials.
    pub train_secs_std: f64,
    /// Mean primal objective over nodes and trials.
    pub objective: f64,
    /// Mean ε at convergence across trials.
    pub epsilon_final: f64,
    /// Mean iterations across trials.
    pub iterations: f64,
    /// Per-trial details.
    pub trials: Vec<TrialResult>,
}

impl GadgetReport {
    /// The trial-0 consensus weight vector as a deployable linear model —
    /// what `train --save` persists
    /// ([`crate::serve::ModelArtifact::from_report`]). Trial 0 is the
    /// canonical artifact: trials differ only in their RNG root
    /// substream, and averaging across trials would produce a model no
    /// single training run ever held.
    pub fn consensus_model(&self) -> crate::solver::LinearModel {
        crate::solver::LinearModel { w: self.trials[0].consensus_w.clone() }
    }
}

/// The GADGET coordinator entry point.
pub struct GadgetRunner {
    cfg: ExperimentConfig,
    lambda: f64,
    train: TrainPlane,
    test: Dataset,
    load_secs: f64,
    /// Live HTTP arrival buffer (`train --http-ingest`): rows staged here
    /// by the HTTP front end enter the shard store only at the ingestion
    /// boundary ([`GossipProtocol::ingest_boundary`]). `None` for every
    /// offline run.
    http_ingest: Option<Arc<ArrivalQueue>>,
}

/// Where a runner's training rows live: on the heap (synthetic
/// generators, `path:` LIBSVM files) or on disk behind a memory-mapped
/// pack window (`pack:` artifacts — rows are served page-by-page and
/// never materialized network-wide).
pub(crate) enum TrainPlane {
    /// Heap-resident training set.
    Heap(Dataset),
    /// Rows `rows` of a mapped pack artifact (the trailing rows past the
    /// window are the held-out test split).
    Pack {
        /// The opened artifact, shared with every trial's shard store.
        pack: Arc<PackFile>,
        /// The training window.
        rows: Range<usize>,
    },
}

impl TrainPlane {
    /// Feature dimension.
    pub(crate) fn dim(&self) -> usize {
        match self {
            Self::Heap(ds) => ds.dim,
            Self::Pack { pack, .. } => pack.dim(),
        }
    }

    /// Number of training rows.
    pub(crate) fn len(&self) -> usize {
        match self {
            Self::Heap(ds) => ds.len(),
            Self::Pack { rows, .. } => rows.end - rows.start,
        }
    }

    /// The whole training plane as a borrowed view — zero-copy for both
    /// variants, so evaluation never materializes a pack.
    pub(crate) fn view(&self) -> ShardView<'_> {
        match self {
            Self::Heap(ds) => ds.view(),
            Self::Pack { pack, rows } => pack.view_range(rows.clone()),
        }
    }

    /// The heap dataset, for consumers that need `&Dataset` semantics
    /// (the async engine's owned shards, legacy accessors). Pack-backed
    /// planes fail loudly instead of silently materializing.
    pub(crate) fn heap(&self) -> Result<&Dataset> {
        match self {
            Self::Heap(ds) => Ok(ds),
            Self::Pack { pack, .. } => bail!(
                "{}: this path needs a heap training set, but the dataset is \
                 a mapped pack artifact (rows stay on disk)",
                pack.name()
            ),
        }
    }
}

/// Result of [`run_on_datasets`]: one GADGET training on explicit data.
#[derive(Clone, Debug)]
pub struct DatasetRunReport {
    /// Mean node accuracy on the test set.
    pub test_accuracy: f64,
    /// The consensus (node-averaged) weight vector of the first trial.
    pub consensus_w: Vec<f64>,
    /// Mean iterations across trials.
    pub iterations: f64,
    /// Mean train seconds.
    pub train_secs: f64,
}

/// Runs GADGET on explicit train/test datasets (bypassing the config's
/// dataset loader) — the entry point the multiclass reduction and the
/// feature-mapped (RFF) paths use. The `[runtime]` scheduler choice of the
/// base config applies here too.
pub fn run_on_datasets(
    base: &ExperimentConfig,
    train: Dataset,
    test: Dataset,
    lambda: f64,
) -> Result<DatasetRunReport> {
    base.validate()?;
    anyhow::ensure!(lambda > 0.0, "run_on_datasets: lambda must be positive");
    anyhow::ensure!(base.nodes <= train.len(), "more nodes than training samples");
    let runner = GadgetRunner {
        cfg: base.clone(),
        lambda,
        train: TrainPlane::Heap(train),
        test,
        load_secs: 0.0,
        http_ingest: None,
    };
    let report = runner.run()?;
    Ok(DatasetRunReport {
        test_accuracy: report.test_accuracy,
        consensus_w: report.trials[0].consensus_w.clone(),
        iterations: report.iterations,
        train_secs: report.train_secs,
    })
}

impl GadgetRunner {
    /// Loads the dataset (timed — Table 5 includes it) and validates config.
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let sw = Stopwatch::new();
        let (train, test, spec_lambda) = load_dataset(&cfg)?;
        let load_secs = sw.secs();
        let lambda = cfg.lambda.or(spec_lambda).context(
            "config: lambda not set and dataset has no Table-2 default (pass lambda = ...)",
        )?;
        if cfg.nodes > train.len() {
            bail!("config: more nodes than training samples");
        }
        Ok(Self { cfg, lambda, train, test, load_secs, http_ingest: None })
    }

    /// Attaches a live HTTP arrival buffer (`train --http-ingest`): the
    /// whole loaded training set becomes iteration 1's split, and rows
    /// staged into `queue` by the HTTP front end join the shards at each
    /// ingestion boundary — paced by `[stream] rate` (0 = drain the whole
    /// buffer every boundary), capped by `[stream] max-rows`. The run
    /// will not declare ε-convergence while the queue is open (the
    /// convergence veto), so a `POST /shutdown` — which closes the
    /// queue — is what lets a converged network actually stop. While the
    /// feed is open but idle the loop *parks* at the ingestion boundary
    /// ([`ArrivalQueue::wait_arrival_or_close`]) instead of spending
    /// iterations: the `max_iterations` budget covers arrivals and the
    /// post-close run to convergence, not wall-clock waiting.
    pub fn with_http_ingest(mut self, queue: Arc<ArrivalQueue>) -> Self {
        self.http_ingest = Some(queue);
        self
    }

    /// Accessor: the loaded training set (heap planes only — a `pack:`
    /// dataset keeps its training rows on disk; use
    /// [`GadgetRunner::train_view`] there).
    ///
    /// # Panics
    /// Panics on a pack-backed runner.
    pub fn train_data(&self) -> &Dataset {
        self.train
            .heap()
            .expect("train_data() on a pack-backed runner — use train_view()")
    }

    /// Accessor: the training rows as a borrowed view — works for every
    /// plane, including mapped `pack:` artifacts.
    pub fn train_view(&self) -> ShardView<'_> {
        self.train.view()
    }

    /// Accessor: number of training rows.
    pub fn train_len(&self) -> usize {
        self.train.len()
    }

    /// Accessor: the training feature dimension.
    pub fn train_dim(&self) -> usize {
        self.train.dim()
    }

    /// Accessor: the loaded test set.
    pub fn test_data(&self) -> &Dataset {
        &self.test
    }

    /// Accessor: the effective λ.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Builds one local-step backend per the config's `backend` choice,
    /// computing on `kernel` (the native path; the XLA artifact's
    /// arithmetic is fixed at compile time — the kernel layer reserves it
    /// a third implementation slot, see DESIGN.md §Kernel backends).
    fn make_backend(&self, kernel: &'static dyn Kernel) -> Result<Box<dyn LocalBackend + Send>> {
        Ok(match self.cfg.backend {
            Backend::Native => Box::new(NativeBackend::with_options(kernel, self.cfg.step)),
            Backend::Xla => {
                // Same loudness for `--step`: the artifact's update loop is
                // whatever was compiled — a log claiming step=dense must
                // never have run the AOT path.
                anyhow::ensure!(
                    self.cfg.step.is_scaled(),
                    "backend = \"xla\" supports only step = \"scaled\"/\"auto\" \
                     (the AOT artifact's update arithmetic is fixed at compile \
                     time; the dense reference loop is a native-path concern)"
                );
                // The artifact's arithmetic is compiled into the HLO —
                // training it while the report claims kernel=simd would be
                // the mislabeled-benchmark case the kernel layer forbids.
                anyhow::ensure!(
                    kernel.name() == "scalar",
                    "backend = \"xla\" supports only kernel = \"scalar\" (the AOT \
                     artifact's arithmetic is fixed at compile time; the kernel \
                     layer reserves the XLA path a future implementation slot)"
                );
                Box::new(crate::runtime::XlaBackend::from_default_artifacts(
                    self.train.dim(),
                    self.cfg.batch_size,
                    self.cfg.local_steps,
                    self.lambda,
                )?)
            }
        })
    }

    /// Runs all configured trials on the configured scheduler and backend.
    pub fn run(&self) -> Result<GadgetReport> {
        // Resolve `[runtime] kernel` once; the handle threads through
        // scheduler construction (mixing-round panels) and backend
        // construction (local-step margin dots) so one selection governs
        // every hot loop of the run.
        let kernel = self.cfg.kernel.build()?;
        // Streaming ingestion happens at the global iteration boundary —
        // which the asynchronous engine deliberately does not have.
        // Silently training on a frozen snapshot while the report claims
        // streaming would be the mislabeled-run case this codebase
        // forbids everywhere else: reject loudly.
        if self.cfg.streaming_enabled() || self.http_ingest.is_some() {
            anyhow::ensure!(
                self.cfg.scheduler != SchedulerKind::Async,
                "scheduler = \"async\" does not support [stream] or --http-ingest \
                 ingestion (the thread-per-node engine has no global iteration \
                 boundary to ingest at); use the sequential or parallel scheduler"
            );
        }
        if self.http_ingest.is_some() {
            // One live arrival buffer cannot feed several independent
            // repetitions — each trial would drain a disjoint, timing-
            // dependent subset and none would see the advertised stream.
            anyhow::ensure!(
                self.cfg.trials == 1,
                "--http-ingest requires trials = 1 (a live arrival stream \
                 cannot be replayed across independent trials)"
            );
        }
        match self.cfg.scheduler {
            SchedulerKind::Sequential => {
                let mut backend = self.make_backend(kernel)?;
                let mut sched = Sequential::new(&mut *backend).with_kernel(kernel);
                self.run_with_scheduler(&mut sched)
            }
            SchedulerKind::Parallel => {
                let threads = super::sched::resolve_threads(self.cfg.threads);
                if threads > 1 && self.cfg.trials >= threads {
                    // Trials are embarrassingly parallel — when there are
                    // enough of them to keep every worker busy, fan trial
                    // chunks across the pool. Each trial's computation is
                    // byte-for-byte the sequential path (own protocol
                    // state and root substream; one backend per worker
                    // chunk), so only wall-clock changes. With fewer
                    // trials than workers this path would idle
                    // `threads − trials` workers (each trial runs
                    // serially inside), so it is taken only at
                    // saturation.
                    self.run_trials_pooled(threads, kernel)
                } else {
                    // Fan the per-node phases inside each trial instead.
                    // Cap the pool at the node count — more workers than
                    // nodes can never be used, and each worker costs a
                    // backend (an entire artifact compilation on the XLA
                    // path).
                    let workers = threads.min(self.cfg.nodes);
                    let mut sched =
                        Parallel::new(workers, || self.make_backend(kernel))?.with_kernel(kernel);
                    self.run_with_scheduler(&mut sched)
                }
            }
            SchedulerKind::Async => {
                // The async engine's node threads run the native backend;
                // silently training native while reporting backend=Xla
                // would poison any backend comparison — reject loudly.
                anyhow::ensure!(
                    self.cfg.backend == Backend::Native,
                    "scheduler = \"async\" supports only backend = \"native\" \
                     (the thread-per-node engine embeds the native local \
                     learner); use the sequential or parallel scheduler for \
                     the XLA backend"
                );
                // Same loudness for the kernel: the embedded learners run
                // the scalar reference; a log claiming kernel=simd must
                // never have trained scalar.
                anyhow::ensure!(
                    kernel.name() == "scalar",
                    "scheduler = \"async\" supports only kernel = \"scalar\" \
                     (the thread-per-node engine embeds scalar-kernel \
                     learners); use the sequential or parallel scheduler \
                     for the simd kernel"
                );
                // The async engine *is* randomized push-sum — its mass
                // exchange has no seam for an alternative mixer. Running
                // it while the report claims mixer=gradient-flow would be
                // the mislabeled-run case this codebase forbids.
                anyhow::ensure!(
                    self.cfg.mixer == MixerKind::PushSum,
                    "scheduler = \"async\" supports only mixer = \"push-sum\" \
                     (the thread-per-node engine is the randomized push-sum \
                     mass exchange itself); use the sequential or parallel \
                     scheduler for alternative mixers"
                );
                // The embedded learners run the scaled-iterate default; a
                // log claiming step=dense must never have run scaled.
                anyhow::ensure!(
                    self.cfg.step.is_scaled(),
                    "scheduler = \"async\" supports only step = \"scaled\"/\"auto\" \
                     (the thread-per-node engine embeds scaled-step learners); \
                     use the sequential or parallel scheduler for the dense \
                     reference loop"
                );
                self.run_async()
            }
        }
    }

    /// Runs all trials sequentially with an explicit backend (tests /
    /// benches inject their own). An injected backend carries its own
    /// kernel handle for the local step; the mixing round runs on the
    /// scalar reference — use [`GadgetRunner::run`] with `[runtime]
    /// kernel` to thread one selection through both.
    pub fn run_with_backend(&self, backend: &mut dyn LocalBackend) -> Result<GadgetReport> {
        let mut sched = Sequential::new(backend);
        self.run_with_scheduler(&mut sched)
    }

    /// Runs all trials on an explicit cycle-driven scheduler.
    pub fn run_with_scheduler(&self, sched: &mut dyn Scheduler) -> Result<GadgetReport> {
        // Defense in depth for callers that bypass `new()` with a struct
        // literal: `aggregate` divides by the trial count and every
        // report consumer indexes `trials[0]` — a zero-trial config must
        // fail here with a clear error, not panic downstream.
        self.cfg.validate()?;
        let mut trials = Vec::with_capacity(self.cfg.trials);
        for trial in 0..self.cfg.trials {
            let seed = self.trial_seed(trial);
            trials.push(self.run_trial(seed, sched)?);
        }
        Ok(self.aggregate(trials))
    }

    /// Fans whole trials across a persistent worker pool: trials are
    /// chunked per worker exactly like `for_each_node` chunks nodes, so
    /// the backend count scales with *workers*, not trials (one backend
    /// per task — an entire artifact compilation each on the XLA path).
    /// Each task steps its trials' nodes sequentially on whichever
    /// worker picks it up; per-trial computation is identical to
    /// [`GadgetRunner::run_with_backend`], so the aggregated report is
    /// bitwise-equal — the scheduler equivalence tests sweep this path
    /// via `trials ≥ threads` configs.
    fn run_trials_pooled(&self, threads: usize, kernel: &'static dyn Kernel) -> Result<GadgetReport> {
        self.cfg.validate()?;
        let workers = threads.min(self.cfg.trials);
        let pool = WorkerPool::new(workers);
        let mut slots: Vec<Option<Result<TrialResult>>> = Vec::new();
        slots.resize_with(self.cfg.trials, || None);
        let chunk = (slots.len() + workers - 1) / workers;
        let tasks: Vec<Task<'_>> = slots
            .chunks_mut(chunk)
            .enumerate()
            .map(|(c, slab)| {
                Box::new(move || -> Result<()> {
                    let mut backend = self.make_backend(kernel)?;
                    let mut sched = Sequential::new(&mut *backend).with_kernel(kernel);
                    for (off, slot) in slab.iter_mut().enumerate() {
                        let trial = c * chunk + off;
                        *slot = Some(self.run_trial(self.trial_seed(trial), &mut sched));
                    }
                    Ok(())
                }) as Task<'_>
            })
            .collect();
        pool.run_tasks(tasks)?;
        let mut trials = Vec::with_capacity(slots.len());
        for slot in slots {
            trials.push(slot.expect("pool ran every trial task")?);
        }
        Ok(self.aggregate(trials))
    }

    /// Per-trial root seed.
    fn trial_seed(&self, trial: usize) -> u64 {
        self.cfg.seed.wrapping_add(trial as u64 * 0x1000_0001)
    }

    /// Paper aggregation over per-trial results.
    fn aggregate(&self, trials: Vec<TrialResult>) -> GadgetReport {
        let acc_matrix: Vec<Vec<f64>> =
            trials.iter().map(|t| t.node_accuracy.clone()).collect();
        let (acc_mean, acc_std) = node_trial_std(&acc_matrix);
        let obj_matrix: Vec<Vec<f64>> =
            trials.iter().map(|t| t.node_objective.clone()).collect();
        let (obj_mean, _) = node_trial_std(&obj_matrix);
        let times: Vec<f64> = trials.iter().map(|t| t.train_secs).collect();
        let (t_mean, t_std) = crate::util::timer::mean_std(&times);
        let eps_mean =
            trials.iter().map(|t| t.epsilon_final).sum::<f64>() / trials.len() as f64;
        let iter_mean =
            trials.iter().map(|t| t.iterations as f64).sum::<f64>() / trials.len() as f64;
        GadgetReport {
            dataset: self.cfg.dataset.clone(),
            lambda: self.lambda,
            load_secs: self.load_secs,
            test_accuracy: acc_mean,
            test_accuracy_std: acc_std,
            train_secs: t_mean,
            train_secs_std: t_std,
            objective: obj_mean,
            epsilon_final: eps_mean,
            iterations: iter_mean,
            trials,
        }
    }

    /// Builds the per-trial node set (test shards, RNG substreams, zero
    /// weights). Training rows live in the trial's [`ShardStore`]
    /// ([`build_store`]), not on the nodes.
    fn build_nodes(&self, seed: u64) -> Result<Vec<NodeState>> {
        let m = self.cfg.nodes;
        let d = self.train.dim();
        let test_shards = partition::horizontal_split(&self.test, m, seed ^ 0x7e57)?;
        let root = Rng::new(seed);
        Ok(test_shards
            .into_iter()
            .enumerate()
            .map(|(i, te)| NodeState::new(i, te, d, root.substream(i as u64)))
            .collect())
    }

    /// Per-node evaluation shared by both execution paths.
    fn evaluate_nodes(&self, nodes: &[NodeState]) -> (Vec<f64>, Vec<f64>) {
        let node_accuracy: Vec<f64> = nodes
            .iter()
            .map(|n| {
                metrics::accuracy(
                    &n.w,
                    if n.test_shard.is_empty() { &self.test } else { &n.test_shard },
                )
            })
            .collect();
        let node_objective: Vec<f64> = nodes
            .iter()
            .map(|n| metrics::objective_view(&n.w, self.train.view(), self.lambda))
            .collect();
        (node_accuracy, node_objective)
    }

    /// One full cycle-driven GADGET trial on the given scheduler.
    fn run_trial(&self, seed: u64, sched: &mut dyn Scheduler) -> Result<TrialResult> {
        let cfg = &self.cfg;
        let m = cfg.nodes;
        let d = self.train.dim();

        // --- network setup -------------------------------------------------
        let graph = Graph::generate(cfg.topology, m, seed ^ GRAPH_SEED);
        let b = TransitionMatrix::from_graph(&graph, cfg.weights);
        let rounds = if cfg.gossip_rounds > 0 {
            cfg.gossip_rounds
        } else {
            mixing_time(&b, cfg.gamma).min(10_000)
        };

        // --- data distribution ---------------------------------------------
        // The shard store owns the per-node training rows: the static
        // store is exactly the old one-shot horizontal split (bitwise
        // reference — pinned by rust/tests/store_equivalence.rs), the
        // streaming store additionally grows its shards at the ingestion
        // boundary below.
        let mut store = build_store(cfg, &self.train, seed, self.http_ingest.as_ref())?;
        let mut nodes = self.build_nodes(seed)?;
        let mut shard_sizes = vec![0.0f64; m];
        store.sizes_into(&mut shard_sizes);
        let mut added = vec![0usize; m];
        let ids: Vec<usize> = (0..m).collect();
        let protocol = GossipProtocol::new(ProtocolParams::from_config(cfg, self.lambda));

        // --- the GADGET loop -----------------------------------------------
        let sw = Stopwatch::new();
        let mut gossip_total = GossipStats::default();
        let mut trace = Trace::new(format!("gadget-{}", cfg.dataset));
        let mut iterations = 0usize;
        let mut drift: Vec<DriftEvent> = Vec::new();
        // One mixer state reused across iterations (its per-mix reset is
        // allocation-free; constructing it fresh allocates the m×d mass
        // buffers per iteration — EXPERIMENTS.md §Perf). On the push-sum
        // backend this holds exactly the old long-lived PushVector.
        let mut mixer = build_mixer(
            cfg.mixer,
            &graph,
            b,
            rounds,
            seed ^ MIXER_SEED,
            d,
            &shard_sizes,
        );

        for t in 1..=cfg.max_iterations {
            iterations = t;
            // Interactive pacing: an HTTP-fed run parks here while the
            // feed is open but idle, so the iteration budget is spent on
            // arrivals (and on the post-close run to convergence) rather
            // than burned at CPU speed in the milliseconds before the
            // first request can land. The `stream_exhausted` guard keeps
            // a `--stream-max-rows`-capped run from parking on a feed it
            // can no longer drain. Pool/tail sources never park — their
            // schedules are store-internal and deterministic.
            if let Some(queue) = &self.http_ingest {
                if !store.stream_exhausted() {
                    queue.wait_arrival_or_close();
                }
            }
            // Ingestion boundary: append this iteration's arrivals before
            // any node steps, then refresh the Push-Sum weights so the
            // consensus target re-weights to the new nᵢ (static stores
            // return 0 and the sizes never move). Arrivals also feed the
            // drift log: per-node label balance and feature scale of the
            // ingested suffix.
            if protocol.ingest_boundary(&mut *store, t, &mut added)? > 0 {
                store.sizes_into(&mut shard_sizes);
                drift_events(&*store, &added, t, &mut drift);
            }
            // While the stream can still deliver (pool rows remain, the
            // cap is unreached, a tailed file is not at EOF) convergence
            // is vetoed network-wide — otherwise a fractional rate's gap
            // iterations (carry < 1 ⇒ zero arrivals) could end the run
            // with rows still undelivered.
            let stream_live = !store.stream_exhausted();
            // (a)–(f): local sub-gradient step at every node, fanned out
            // by the scheduler; each node borrows its current shard window
            // from the store at dispatch time.
            let store_ref: &dyn ShardStore = &*store;
            sched.for_each_node(&mut nodes, &ids, &|backend, _id, node| {
                protocol.local_step(backend, store_ref.shard(node.id), node, t)
            })?;
            // (g): mixer consensus on the shard-weighted vectors. On the
            // push-sum backend this is bit-for-bit the old inline
            // Push-Vector sequence: the Bᵀ-apply fans its column panels
            // over the scheduler's executor (inline for sequential, the
            // worker pool for parallel) on the scheduler's kernel, and
            // the per-mix reset rebuilds (Σnᵢwᵢ, Σnᵢ) from the *current*
            // sizes, so re-weighting after ingestion conserves the mass
            // identity exactly (the re-weight rule). Alternative mixers
            // realize the same weighted-average target through their own
            // mechanism and report through the same GossipStats.
            mixer.mix(
                &mut nodes.iter().map(|n| n.w.as_slice()),
                &shard_sizes,
                sched.panel_exec(),
                sched.kernel(),
            );
            gossip_total.merge(mixer.stats());
            // (g)-consume/(h)/ε: estimate, optional projection and the
            // drift-aware convergence test, per node (slot == id here
            // since ids = 0..m). A node that ingested this iteration may
            // not declare convergence — ε on a changed shard measures
            // staleness, not consensus.
            let added_ref: &[usize] = &added;
            let mixer_ref: &dyn Mixer = &*mixer;
            sched.for_each_node(&mut nodes, &ids, &|_backend, slot, node| {
                protocol.apply_estimate(mixer_ref, slot, node);
                protocol
                    .check_convergence_drift(node, stream_live || added_ref[node.id] > 0);
                Ok(())
            })?;
            let all = nodes.iter().all(|n| n.converged);
            // anytime snapshot for the figures.
            if cfg.snapshot_every > 0 && (t % cfg.snapshot_every == 0 || all) {
                let w_avg = average_w(&nodes);
                trace.push(TracePoint {
                    time_secs: sw.secs(),
                    step: t,
                    objective: metrics::objective_view(&w_avg, self.train.view(), self.lambda),
                    test_error: metrics::zero_one_error(&w_avg, &self.test),
                });
            }
            if all {
                break;
            }
        }
        let train_secs = sw.secs();

        // --- evaluation ------------------------------------------------------
        let (node_accuracy, node_objective) = self.evaluate_nodes(&nodes);
        let epsilon_final =
            nodes.iter().map(|n| n.last_delta).fold(0.0f64, f64::max);

        Ok(TrialResult {
            iterations,
            train_secs,
            node_accuracy,
            node_objective,
            epsilon_final,
            consensus_w: average_w(&nodes),
            gossip: gossip_total,
            trace,
            drift,
        })
    }

    /// Runs all trials through the asynchronous scheduler (`scheduler =
    /// "async"`): thread-per-node, no global barrier. `max_iterations`
    /// becomes the per-node cycle budget, with the trailing eighth of the
    /// budget as a consensus cool-down.
    fn run_async(&self) -> Result<GadgetReport> {
        let mut trials = Vec::with_capacity(self.cfg.trials);
        for trial in 0..self.cfg.trials {
            let seed = self.trial_seed(trial);
            trials.push(self.run_async_trial(seed)?);
        }
        Ok(self.aggregate(trials))
    }

    /// One asynchronous trial. The train shards move straight into the
    /// scheduler's node threads (no NodeState husks, no shard clones);
    /// evaluation works directly on the returned estimates.
    fn run_async_trial(&self, seed: u64) -> Result<TrialResult> {
        let cfg = &self.cfg;
        let m = cfg.nodes;
        // config validation rejects async + pack:, so the heap plane is
        // always present here.
        let train = self.train.heap()?;
        let graph = Graph::generate(cfg.topology, m, seed ^ GRAPH_SEED);
        let train_shards = partition::horizontal_split(train, m, seed)?;
        let test_shards = partition::horizontal_split(&self.test, m, seed ^ 0x7e57)?;
        let params = AsyncParams {
            lambda: self.lambda,
            batch_size: cfg.batch_size,
            cycles: cfg.max_iterations,
            cooldown: (cfg.max_iterations / ASYNC_COOLDOWN_DIV).max(1),
            local_steps: cfg.local_steps,
            project: cfg.project_local,
            seed,
            max_lag: ASYNC_MAX_LAG,
            link_latency: cfg.link_latency,
            link_drop: cfg.link_drop,
        };
        let sw = Stopwatch::new();
        let result = AsyncScheduler::new(params).run(train_shards, &graph)?;
        let train_secs = sw.secs();

        let node_accuracy: Vec<f64> = result
            .estimates
            .iter()
            .zip(&test_shards)
            .map(|(w, te)| {
                metrics::accuracy(w, if te.is_empty() { &self.test } else { te })
            })
            .collect();
        let node_objective: Vec<f64> = result
            .estimates
            .iter()
            .map(|w| metrics::objective(w, train, self.lambda))
            .collect();
        let d = self.train.dim();
        let mut consensus_w = vec![0.0; d];
        for w in &result.estimates {
            crate::linalg::add_assign(w, &mut consensus_w);
        }
        crate::linalg::scale_assign(1.0 / m as f64, &mut consensus_w);
        // ε surrogate: worst node deviation from the consensus mean.
        let epsilon_final = result
            .estimates
            .iter()
            .map(|w| {
                let mut diff = 0.0;
                for (a, b) in w.iter().zip(&consensus_w) {
                    let x = a - b;
                    diff += x * x;
                }
                diff.sqrt()
            })
            .fold(0.0f64, f64::max);

        Ok(TrialResult {
            iterations: cfg.max_iterations,
            train_secs,
            node_accuracy,
            node_objective,
            epsilon_final,
            consensus_w,
            gossip: result.stats,
            trace: Trace::new(format!("gadget-async-{}", cfg.dataset)),
            drift: Vec::new(),
        })
    }
}

/// Async cool-down fraction: the trailing `1/8` of the cycle budget runs
/// pure push-sum so estimates agree tightly before reporting.
const ASYNC_COOLDOWN_DIV: usize = 8;
/// Async bounded-staleness window (cycles a node may run ahead).
const ASYNC_MAX_LAG: usize = 4;

fn average_w(nodes: &[NodeState]) -> Vec<f64> {
    let d = nodes[0].w.len();
    let mut avg = vec![0.0; d];
    for n in nodes {
        crate::linalg::add_assign(&n.w, &mut avg);
    }
    crate::linalg::scale_assign(1.0 / nodes.len() as f64, &mut avg);
    avg
}

/// Builds the per-trial shard store from the config's `[data]` and
/// `[stream]` sections — the one data-plane decision point shared by the
/// plain runner and the churn engine:
///
/// * `pack:` dataset → [`MmapStore`] windows over the mapped artifact
///   (`store = "static"` materializes the same windows into a
///   [`StaticStore`] for bitwise A/B against the heap plane);
/// * streaming off (`rate = 0`) → [`StaticStore`] over the classic
///   seeded horizontal split (the bitwise pre-refactor path);
/// * `schedule = "uniform" | "random"` → hold out `1 − initial` of the
///   training rows as the arrival pool and stream them in at `rate`
///   rows/iteration;
/// * `schedule = "tail:<file>"` → full split up front, arrivals tailed
///   from the line-delimited LIBSVM file.
pub(crate) fn build_store(
    cfg: &ExperimentConfig,
    train: &TrainPlane,
    seed: u64,
    http: Option<&Arc<ArrivalQueue>>,
) -> Result<Box<dyn ShardStore>> {
    let m = cfg.nodes;
    if let Some(queue) = http {
        // Live HTTP ingestion: the whole loaded set is iteration 1's
        // split and arrivals come off the wire — `[stream] initial` has
        // nothing to hold out, and a `tail:` schedule would be a second
        // arrival source fighting over the same boundary.
        anyhow::ensure!(
            !matches!(cfg.stream_schedule, StreamSchedule::Tail(_)),
            "--http-ingest cannot combine with schedule = \"tail:...\" (two \
             arrival sources would race for the ingestion boundary)"
        );
        let train = match train {
            TrainPlane::Heap(ds) => ds,
            TrainPlane::Pack { pack, .. } => bail!(
                "{}: --http-ingest needs a heap training set (a mapped pack \
                 artifact is immutable — its shards cannot grow)",
                pack.name()
            ),
        };
        let initial = partition::horizontal_split(train, m, seed)?;
        return Ok(Box::new(StreamingStore::http(
            initial,
            Arc::clone(queue),
            cfg.stream_rate,
            cfg.stream_max_rows,
            seed,
        )?));
    }
    let train = match train {
        TrainPlane::Pack { pack, rows } => {
            // Pack shards are contiguous row windows, not the seeded
            // shuffle: the whole point of the mapped plane is that rows
            // never leave the artifact, and a shuffle would force a copy.
            // The static/mmap A/B below therefore compares the *same*
            // windows, which is what makes it bitwise.
            return match cfg.store {
                StoreKind::Auto | StoreKind::Mmap => {
                    Ok(Box::new(MmapStore::over_range(pack.clone(), rows.clone(), m)?))
                }
                StoreKind::Static => {
                    let mm = MmapStore::over_range(pack.clone(), rows.clone(), m)?;
                    Ok(Box::new(StaticStore::from_shards(mm.materialize_shards())))
                }
            };
        }
        TrainPlane::Heap(ds) => ds,
    };
    if !cfg.streaming_enabled() {
        return Ok(Box::new(StaticStore::split(train, m, seed)?));
    }
    match &cfg.stream_schedule {
        StreamSchedule::Tail(path) => {
            let initial = partition::horizontal_split(train, m, seed)?;
            Ok(Box::new(StreamingStore::tail(
                initial,
                path,
                cfg.stream_rate,
                cfg.stream_max_rows,
                seed,
            )?))
        }
        schedule => {
            // Seeded holdout: the head is iteration 1's split, the tail
            // streams in. Each trial rebuilds this from its own seed, so
            // trials stay independent and reproducible.
            let (head, pool) =
                partition::train_test_split(train, cfg.stream_initial, seed ^ STREAM_SEED);
            anyhow::ensure!(
                head.len() >= m,
                "stream: initial fraction {} leaves {} rows for {} nodes — raise \
                 [stream] initial or shrink the network",
                cfg.stream_initial,
                head.len(),
                m
            );
            let initial = partition::horizontal_split(&head, m, seed)?;
            Ok(Box::new(StreamingStore::from_pool(
                initial,
                pool,
                cfg.stream_rate,
                cfg.stream_max_rows,
                *schedule == StreamSchedule::Random,
                seed,
            )?))
        }
    }
}

/// Seed-mixing label for the streaming holdout (distinct from the graph,
/// partition and test-split labels).
const STREAM_SEED: u64 = 0x57f2_ea4d;

/// Dataset loading shared by the runner and the experiment harness:
/// `synthetic-*` names hit the Table-2 generators; `path:<file>` reads
/// LIBSVM (splitting 2:1 when no test file is given); `pack:<file>` maps
/// a `gadget pack` artifact and keeps the training rows on disk.
///
/// For file-backed corpora the Table-2 λ resolves from the file stem
/// ([`lambda_for_corpus`]) so `--dataset path:a9a.txt` trains with the
/// paper's `adult` regularizer out of the box; `lambda = ...` in the
/// config still overrides.
pub(crate) fn load_dataset(
    cfg: &ExperimentConfig,
) -> Result<(TrainPlane, Dataset, Option<f64>)> {
    if let Some(path) = cfg.dataset.strip_prefix("path:") {
        let ds = crate::data::libsvm::read_libsvm(path, 0)?;
        let (train, test) = partition::train_test_split(&ds, 2.0 / 3.0, cfg.seed);
        return Ok((TrainPlane::Heap(train), test, lambda_for_corpus(path)));
    }
    if let Some(path) = cfg.dataset.strip_prefix("pack:") {
        let pack = Arc::new(PackFile::open(path)?);
        let n = pack.len();
        // Contiguous 2:1 split — leading two thirds train *in place* (no
        // index indirection, so shard windows stay zero-copy), trailing
        // third materializes as the heap test set. Pack order is the
        // artifact's row order; shuffle at pack time if that matters.
        let n_train = n * 2 / 3;
        anyhow::ensure!(
            n_train >= 1 && n_train < n,
            "pack `{path}`: {n} rows cannot give a non-empty 2:1 train/test split"
        );
        let test = pack.materialize_range(n_train..n);
        let lambda = lambda_for_corpus(path);
        return Ok((TrainPlane::Pack { pack, rows: 0..n_train }, test, lambda));
    }
    let spec = spec_by_name(&cfg.dataset)
        .with_context(|| format!("unknown dataset {:?} (try synthetic-adult, …)", cfg.dataset))?;
    let split = generate(&spec, cfg.seed ^ 0xda7a, cfg.scale);
    Ok((TrainPlane::Heap(split.train), split.test, Some(spec.lambda)))
}

/// Maps a corpus file name to its Table-2 λ by stem: `path:a9a.txt` and
/// `pack:rcv1_ccat.gpack` train with the paper's `adult` / `ccat`
/// regularizers without a `lambda = ...` line. Returns `None` for stems
/// the paper doesn't cover (the config then requires an explicit λ).
pub fn lambda_for_corpus(path: &str) -> Option<f64> {
    // Alias → Table-2 name; longest-useful aliases first so e.g.
    // "rcv1_ccat" resolves before a hypothetical bare "ccat" check matters.
    const ALIASES: &[(&str, &str)] = &[
        ("a9a", "adult"),
        ("adult", "adult"),
        ("rcv1", "ccat"),
        ("ccat", "ccat"),
        ("mnist", "mnist"),
        ("reuters", "reuters"),
        ("usps", "usps"),
        ("webspam", "webspam"),
        ("gisette", "gisette"),
    ];
    let stem = std::path::Path::new(path)
        .file_stem()?
        .to_string_lossy()
        .to_ascii_lowercase();
    let (_, name) = ALIASES.iter().find(|(alias, _)| stem.contains(alias))?;
    spec_by_name(name).map(|s| s.lambda)
}

/// Seed-mixing label for graph construction (avoids colliding with the
/// partition seeds). Public so the CLI startup echo can reconstruct the
/// exact trial-0 graph for its τ_mix estimate.
pub const GRAPH_SEED: u64 = 0x6772_6170_6800; // "graph"

/// Seed-mixing label for mixer-internal randomness (the gradient-flow
/// edge permutation; distinct from the graph and partition labels).
pub const MIXER_SEED: u64 = 0x6d69_7865_7200; // "mixer"

/// Builds the configured consensus backend — the one construction point
/// shared by the plain runner and the churn engine (which rebuilds on
/// membership change from the induced alive-subgraph).
///
/// * [`MixerKind::PushSum`] wraps the doubly-stochastic `B` it is handed
///   in the long-lived Push-Vector state — the bitwise reference path;
/// * [`MixerKind::GradientFlow`] takes the graph itself (its duals live
///   on edges, not on `B`) plus the push-sum round count as its budget
///   hint and `seed` for the edge permutation.
pub(crate) fn build_mixer(
    kind: MixerKind,
    graph: &Graph,
    b: TransitionMatrix,
    rounds: usize,
    seed: u64,
    d: usize,
    weights: &[f64],
) -> Box<dyn Mixer> {
    match kind {
        MixerKind::PushSum => Box::new(PushSumMixer::new(b, rounds, d, weights)),
        MixerKind::GradientFlow => {
            Box::new(GradientFlowMixer::new(graph, rounds, seed, d))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.05)
            .nodes(4)
            .max_iterations(200)
            .epsilon(5e-3)
            .trials(2)
            .seed(3)
            .snapshot_every(25)
            .build()
            .unwrap()
    }

    #[test]
    fn gadget_learns_and_converges() {
        let runner = GadgetRunner::new(small_cfg()).unwrap();
        let report = runner.run().unwrap();
        assert!(report.test_accuracy > 0.80, "accuracy {}", report.test_accuracy);
        assert!(report.iterations > 1.0);
        assert!(report.train_secs > 0.0);
        assert_eq!(report.trials.len(), 2);
    }

    #[test]
    fn nodes_reach_consensus() {
        // After convergence all node vectors must be ε-close to each other.
        let runner = GadgetRunner::new(small_cfg()).unwrap();
        let report = runner.run().unwrap();
        let t = &report.trials[0];
        // node objectives on the shared train set nearly identical
        let min = t.node_objective.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = t.node_objective.iter().cloned().fold(0.0f64, f64::max);
        assert!(max - min < 0.05 * max.max(1e-9), "objectives spread: {min}..{max}");
    }

    #[test]
    fn distributed_tracks_centralized_pegasos() {
        let runner = GadgetRunner::new(small_cfg()).unwrap();
        let report = runner.run().unwrap();
        // centralized Pegasos on the same data, same iteration budget
        let mut peg = crate::solver::Pegasos::new(crate::solver::PegasosParams {
            lambda: runner.lambda(),
            iterations: 10_000,
            batch_size: 1,
            project: true,
            seed: 3,
        });
        let m = crate::solver::Solver::fit(&mut peg, runner.train_data());
        let central = crate::metrics::accuracy(&m.w, runner.test_data());
        assert!(
            (report.test_accuracy - central).abs() < 0.1,
            "gadget {} vs pegasos {central}",
            report.test_accuracy
        );
    }

    #[test]
    fn traces_are_recorded_and_monotone_in_time() {
        let runner = GadgetRunner::new(small_cfg()).unwrap();
        let report = runner.run().unwrap();
        let trace = &report.trials[0].trace;
        assert!(!trace.points.is_empty());
        for w in trace.points.windows(2) {
            assert!(w[1].time_secs >= w[0].time_secs);
            assert!(w[1].step > w[0].step);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = GadgetRunner::new(small_cfg()).unwrap().run().unwrap();
        let b = GadgetRunner::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(a.test_accuracy, b.test_accuracy);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn consensus_model_is_trial_zero() {
        let report = GadgetRunner::new(small_cfg()).unwrap().run().unwrap();
        let model = report.consensus_model();
        assert_eq!(model.w, report.trials[0].consensus_w);
        assert_eq!(model.w.len(), 256); // usps stand-in dim
    }

    #[test]
    fn gossip_stats_accumulate() {
        let runner = GadgetRunner::new(small_cfg()).unwrap();
        let report = runner.run().unwrap();
        let g = report.trials[0].gossip;
        assert!(g.rounds > 0);
        assert!(g.messages > 0);
        assert!(g.bytes > g.messages); // vector payloads
    }

    #[test]
    fn rejects_more_nodes_than_samples() {
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .scale(0.005)
            .nodes(64)
            .build()
            .unwrap();
        // 0.005·7329 ≈ 36 samples ⇒ max(32) ⇒ 36 ≥ 36? borderline; force tiny
        let cfg2 = ExperimentConfig { nodes: 5000, ..cfg };
        assert!(GadgetRunner::new(cfg2).is_err());
    }

    #[test]
    fn zero_trials_rejected_with_clear_error_everywhere() {
        // `GadgetReport` consumers index `trials[0]`; a trials = 0 config
        // must die at validation, not panic in aggregation.
        let cfg = ExperimentConfig { trials: 0, ..small_cfg() };
        // (match, not unwrap_err: GadgetRunner has no Debug impl)
        let err = match GadgetRunner::new(cfg.clone()) {
            Err(e) => e,
            Ok(_) => panic!("trials = 0 must be rejected at construction"),
        };
        assert!(err.to_string().contains("trials"), "{err}");
        // the literal-config bypass is caught by run_with_scheduler too
        let ok_runner = GadgetRunner::new(small_cfg()).unwrap();
        let bypass = GadgetRunner { cfg, ..ok_runner };
        let mut backend = NativeBackend::default();
        let err2 = bypass.run_with_backend(&mut backend).unwrap_err();
        assert!(err2.to_string().contains("trials"), "{err2}");
        // and by the explicit-dataset entry point
        let good = GadgetRunner::new(small_cfg()).unwrap();
        let err3 = run_on_datasets(
            &ExperimentConfig { trials: 0, ..small_cfg() },
            good.train_data().clone(),
            good.test_data().clone(),
            good.lambda(),
        )
        .unwrap_err();
        assert!(err3.to_string().contains("trials"), "{err3}");
    }

    #[test]
    fn pooled_trial_fanout_is_bitwise_identical_to_sequential() {
        // trials (2) ≥ threads (2) on the parallel scheduler takes the
        // trial fan-out path; every aggregate must match the sequential
        // reference exactly.
        let seq = GadgetRunner::new(small_cfg()).unwrap().run().unwrap();
        let cfg = ExperimentConfig {
            scheduler: SchedulerKind::Parallel,
            threads: 2,
            ..small_cfg()
        };
        let par = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert_eq!(seq.trials.len(), par.trials.len());
        assert_eq!(seq.test_accuracy.to_bits(), par.test_accuracy.to_bits());
        assert_eq!(seq.iterations, par.iterations);
        for (a, b) in seq.trials.iter().zip(&par.trials) {
            assert_eq!(a.consensus_w, b.consensus_w);
            assert_eq!(a.iterations, b.iterations);
        }
    }

    #[test]
    fn streaming_run_learns_and_stops_only_after_arrivals_end() {
        // rate 4, cap 40 ⇒ arrivals at iterations 2..=11. The drift-aware
        // ε test vetoes convergence on any ingesting node, so the run
        // cannot stop before the stream dries up at t = 11.
        let cfg = ExperimentConfig {
            stream_rate: 4.0,
            stream_max_rows: 40,
            trials: 1,
            ..small_cfg()
        };
        let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
        for t in &report.trials {
            assert!(
                t.iterations > 11,
                "stopped at {} while rows were still arriving",
                t.iterations
            );
        }
        assert!(report.test_accuracy > 0.7, "accuracy {}", report.test_accuracy);
        assert!(report.epsilon_final.is_finite());
    }

    #[test]
    fn fractional_rate_gap_iterations_cannot_end_the_run() {
        // rate ½ delivers on every other boundary (gap iterations have
        // zero arrivals, so the per-node "ingested this iteration" veto
        // alone would not fire); with a very generous ε the static
        // problem converges almost immediately, so only the network-wide
        // stream-live veto can hold the run open until the cap is
        // reached at iteration 9 (arrivals at t = 3, 5, 7, 9).
        let cfg = ExperimentConfig {
            epsilon: 5e-2,
            stream_rate: 0.5,
            stream_max_rows: 4,
            trials: 1,
            ..small_cfg()
        };
        let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert!(
            report.trials[0].iterations >= 9,
            "stopped at {} with stream rows still undelivered",
            report.trials[0].iterations
        );
    }

    #[test]
    fn streaming_is_deterministic_across_runs() {
        let cfg = || ExperimentConfig {
            stream_rate: 3.0,
            stream_max_rows: 24,
            trials: 1,
            ..small_cfg()
        };
        let a = GadgetRunner::new(cfg()).unwrap().run().unwrap();
        let b = GadgetRunner::new(cfg()).unwrap().run().unwrap();
        assert_eq!(a.trials[0].consensus_w, b.trials[0].consensus_w);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn async_rejects_streaming_config_loudly() {
        let cfg = ExperimentConfig {
            scheduler: SchedulerKind::Async,
            stream_rate: 1.0,
            ..small_cfg()
        };
        let err = GadgetRunner::new(cfg).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("stream"), "{err}");
    }

    #[test]
    fn lambda_for_corpus_maps_table2_stems() {
        let spec = |name: &str| spec_by_name(name).map(|s| s.lambda);
        assert_eq!(lambda_for_corpus("data/a9a.txt"), spec("adult"));
        assert_eq!(lambda_for_corpus("/tmp/rcv1_ccat.gpack"), spec("ccat"));
        assert_eq!(lambda_for_corpus("corpus/WEBSPAM-trigram.pack"), spec("webspam"));
        assert_eq!(lambda_for_corpus("usps.gpack"), spec("usps"));
        assert_eq!(lambda_for_corpus("mystery.bin"), None);
    }

    #[test]
    fn pack_dataset_trains_end_to_end_and_static_ab_is_bitwise() {
        // Pack the synthetic usps training rows, then train straight off
        // the artifact (`pack:`): λ resolves from the "usps" stem, the
        // mapped plane converges, and `store = "static"` (materialized
        // copies of the same windows) is bitwise identical.
        let spec = spec_by_name("synthetic-usps").unwrap();
        let split = generate(&spec, 3 ^ 0xda7a, 0.05);
        let td = crate::util::TempDir::new().unwrap();
        let path = td.path().join("usps.gpack");
        crate::data::pack::pack_dataset(&split.train, &path).unwrap();

        let cfg = |store: StoreKind| ExperimentConfig {
            dataset: format!("pack:{}", path.display()),
            store,
            trials: 1,
            ..small_cfg()
        };
        let mmap = GadgetRunner::new(cfg(StoreKind::Mmap)).unwrap().run().unwrap();
        assert_eq!(mmap.lambda, spec.lambda, "λ must resolve from the file stem");
        assert!(mmap.test_accuracy > 0.75, "pack accuracy {}", mmap.test_accuracy);
        assert!(!mmap.trials[0].trace.points.is_empty());

        let stat = GadgetRunner::new(cfg(StoreKind::Static)).unwrap().run().unwrap();
        assert_eq!(mmap.trials[0].consensus_w, stat.trials[0].consensus_w);
        assert_eq!(mmap.iterations, stat.iterations);
        assert_eq!(mmap.test_accuracy.to_bits(), stat.test_accuracy.to_bits());
    }

    #[test]
    fn gradient_flow_mixer_trains_on_ring_and_grid() {
        // The non-push-sum backend must realize the same consensus target
        // well enough to train: comparable accuracy on the slow-mixing
        // ring and the torus ("grid").
        use crate::topology::TopologyKind;
        for topo in [TopologyKind::Ring, TopologyKind::Torus] {
            let cfg = ExperimentConfig {
                mixer: crate::gossip::MixerKind::GradientFlow,
                topology: topo,
                trials: 1,
                ..small_cfg()
            };
            let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
            assert!(
                report.test_accuracy > 0.75,
                "{topo}: gradient-flow accuracy {}",
                report.test_accuracy
            );
            let g = report.trials[0].gossip;
            assert!(g.rounds > 0 && g.messages > 0 && g.bytes > 0);
        }
    }

    #[test]
    fn gradient_flow_mixer_is_deterministic_across_runs() {
        let cfg = || ExperimentConfig {
            mixer: crate::gossip::MixerKind::GradientFlow,
            trials: 1,
            ..small_cfg()
        };
        let a = GadgetRunner::new(cfg()).unwrap().run().unwrap();
        let b = GadgetRunner::new(cfg()).unwrap().run().unwrap();
        assert_eq!(a.trials[0].consensus_w, b.trials[0].consensus_w);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn async_rejects_non_push_sum_mixer_loudly() {
        let cfg = ExperimentConfig {
            scheduler: SchedulerKind::Async,
            mixer: crate::gossip::MixerKind::GradientFlow,
            ..small_cfg()
        };
        let err = GadgetRunner::new(cfg).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("push-sum"), "{err}");
    }

    #[test]
    fn streaming_runs_record_drift_events_static_runs_do_not() {
        let stream_cfg = ExperimentConfig {
            stream_rate: 4.0,
            stream_max_rows: 40,
            trials: 1,
            ..small_cfg()
        };
        let report = GadgetRunner::new(stream_cfg).unwrap().run().unwrap();
        let drift = &report.trials[0].drift;
        assert!(!drift.is_empty(), "streaming run must log drift events");
        let total: usize = drift.iter().map(|e| e.added).sum();
        assert_eq!(total, 40, "every arriving row is drift-accounted");
        for e in drift {
            assert!(e.iteration >= 2, "t=1 is defined as no arrivals");
            assert!((0.0..=1.0).contains(&e.label_balance));
            assert!(e.mean_norm.is_finite() && e.mean_norm > 0.0);
        }
        let static_report =
            GadgetRunner::new(small_cfg()).unwrap().run().unwrap();
        assert!(static_report.trials.iter().all(|t| t.drift.is_empty()));
    }

    #[test]
    fn async_scheduler_trains_end_to_end() {
        let cfg = ExperimentConfig {
            scheduler: SchedulerKind::Async,
            max_iterations: 400,
            trials: 1,
            ..small_cfg()
        };
        let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
        assert!(report.test_accuracy > 0.75, "async accuracy {}", report.test_accuracy);
        assert_eq!(report.iterations, 400.0);
        assert!(report.trials[0].gossip.messages > 0);
    }
}
