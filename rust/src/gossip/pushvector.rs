//! Push-Vector: the vector-valued Push-Sum extension (Kempe et al. §3) that
//! GADGET uses at step (g) of Algorithm 2 to average weight vectors.
//!
//! Node `i` holds `(v_i ∈ ℝᵈ, w_i)`. Rounds move both by `Bᵀ`; the estimate
//! `v_i / w_i` converges to the network average of the initial vectors.
//! To realize the shard-weighted average `Σ nᵢ·w̃ᵢ / Σ nᵢ` of Theorem 1,
//! initialize with `v_i = nᵢ·w̃ᵢ` and `w_i = nᵢ` (see
//! [`PushVector::new_weighted`]).
//!
//! The state is stored as one contiguous `m×d` row-major buffer; the mixing
//! round is the d-wide generalization of [`super::pushsum`]'s `Bᵀ`-apply and
//! is the dominant L3 cost at large d — see EXPERIMENTS.md §Perf for the
//! blocking notes.

use super::pushsum::count_offdiag;
use super::GossipStats;
use crate::linalg::Kernel;
use crate::pool::{ParallelExec, SERIAL_EXEC};
use crate::topology::TransitionMatrix;

/// Column-panel width (f64 entries) for the tiled `Bᵀ`-apply: 1024
/// columns = 8 KB per destination row, so a 10-node destination panel
/// (~80 KB) sits comfortably in L2 while the source rows stream.
const COL_BLOCK: usize = 1024;

/// Minimum columns a parallel panel task must own: below this the
/// dispatch latency (condvar wake, ~µs) exceeds the panel's arithmetic,
/// and [`PushVector::round_with`] stays on the inline path.
const PAR_COL_MIN: usize = 256;

/// The tiled `Bᵀ`-accumulation restricted to columns `[k0, k1)`: for
/// every `(i, j)` with `b_ij ≠ 0`,
/// `v_next[j, k0..k1] += b_ij · v[i, k0..k1]`, destination rows
/// addressed through the raw base pointer `v_next` (row-major `m×d`).
///
/// Each destination row's panel is one [`Kernel::gemv_panel`] call —
/// coefficients are column `j` of `B` (stride-`m` view of the row-major
/// entries), sources the stride-`d` column panel of `v`. The kernel
/// contract fixes the per-element accumulation to ascending `i` (exactly
/// the original blocked loop), and a column's value never depends on any
/// other column — so **any** column split (serial full-width, or panels
/// fanned across threads) and **any** kernel backend (`gemv_panel` is
/// element-wise) reproduces the same bits.
///
/// # Safety
/// `v_next` must point to a live `m×d` f64 buffer disjoint from `v`, and
/// no other thread may access columns `[k0, k1)` of it for the duration
/// of the call. Callers pass pairwise-disjoint column ranges.
unsafe fn bt_apply_columns(
    b: &TransitionMatrix,
    v: &[f64],
    v_next: *mut f64,
    m: usize,
    d: usize,
    k0: usize,
    k1: usize,
    kernel: &'static dyn Kernel,
) {
    let mut c0 = k0;
    while c0 < k1 {
        let c1 = (c0 + COL_BLOCK).min(k1);
        for j in 0..m {
            // SAFETY: columns [c0, c1) ⊆ [k0, k1) of row j — inside the
            // m×d buffer and exclusive to this call per the function
            // contract.
            let dst = std::slice::from_raw_parts_mut(v_next.add(j * d + c0), c1 - c0);
            // Column j of row-major B starts at flat index j with stride m.
            kernel.gemv_panel(dst, &b.b[j..], m, m, v, d, c0);
        }
        c0 = c1;
    }
}

/// `Send`/`Sync` wrapper for shipping the `v_next` base pointer into
/// panel tasks. The wrapper itself proves nothing — soundness comes from
/// the tasks' pairwise-disjoint column ranges (see [`bt_apply_columns`]).
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Synchronous deterministic Push-Vector state.
#[derive(Clone, Debug)]
pub struct PushVector {
    m: usize,
    d: usize,
    /// Row-major `m×d`: node i's mass vector at `v[i*d..(i+1)*d]`.
    v: Vec<f64>,
    w: Vec<f64>,
    v_next: Vec<f64>,
    w_next: Vec<f64>,
    stats: GossipStats,
}

impl PushVector {
    /// Uniform initialization: node `i` starts with `vectors[i]`, weight 1.
    /// The consensus limit is the plain average of the vectors.
    pub fn new(vectors: &[Vec<f64>]) -> Self {
        Self::new_weighted(vectors, &vec![1.0; vectors.len()])
    }

    /// Weighted initialization: node `i` starts with `weights[i] · vectors[i]`
    /// and Push-Sum weight `weights[i]`; the consensus limit is the
    /// weights-weighted average `Σ aᵢvᵢ / Σ aᵢ` (Theorem 1's `Σnᵢŵᵢ/N`).
    pub fn new_weighted(vectors: &[Vec<f64>], weights: &[f64]) -> Self {
        let m = vectors.len();
        assert!(m > 0, "PushVector: need at least one node");
        assert_eq!(weights.len(), m, "PushVector: weights length mismatch");
        let d = vectors[0].len();
        let mut v = Vec::with_capacity(m * d);
        for (vec_i, &a) in vectors.iter().zip(weights) {
            assert_eq!(vec_i.len(), d, "PushVector: ragged vectors");
            assert!(a > 0.0, "PushVector: weights must be positive");
            v.extend(vec_i.iter().map(|&x| a * x));
        }
        Self {
            m,
            d,
            v,
            w: weights.to_vec(),
            v_next: vec![0.0; m * d],
            w_next: vec![0.0; m],
            stats: GossipStats::default(),
        }
    }

    /// Re-initializes the state in place from node weight slices — the
    /// allocation-free path the GADGET runner uses every iteration (a fresh
    /// `new_weighted` allocates 4 `m×d` buffers per call; at CCAT scale
    /// that is ~15 MB of allocation per iteration — see EXPERIMENTS.md
    /// §Perf).
    ///
    /// # Panics
    /// Panics on shape mismatch with the constructed state.
    pub fn reset_weighted<'a>(
        &mut self,
        vectors: impl ExactSizeIterator<Item = &'a [f64]>,
        weights: &[f64],
    ) {
        assert_eq!(vectors.len(), self.m, "reset: node count mismatch");
        assert_eq!(weights.len(), self.m, "reset: weights length mismatch");
        for (i, vec_i) in vectors.enumerate() {
            assert_eq!(vec_i.len(), self.d, "reset: vector dim mismatch");
            let a = weights[i];
            assert!(a > 0.0, "reset: weights must be positive");
            let dst = &mut self.v[i * self.d..(i + 1) * self.d];
            for (o, &x) in dst.iter_mut().zip(vec_i) {
                *o = a * x;
            }
            self.w[i] = a;
        }
        self.stats = GossipStats::default();
    }

    /// Node count.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Vector dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// One synchronous round: `V ← Bᵀ V`, `w ← Bᵀ w`, on the calling
    /// thread with the scalar reference kernel. Equivalent to
    /// [`PushVector::round_with`] on the inline executor — and, because
    /// the panel apply is element-wise, bitwise equivalent on **every**
    /// kernel backend.
    pub fn round(&mut self, b: &TransitionMatrix) {
        self.round_with(b, &SERIAL_EXEC, crate::linalg::kernel::scalar());
    }

    /// One synchronous round with the `Bᵀ`-apply fanned over column
    /// panels on `exec` and computed on `kernel`: `V ← Bᵀ V`, `w ← Bᵀ w`.
    ///
    /// **Cache blocking**: at large `d` the two `m×d` buffers exceed L2/L3
    /// and the naive (i, j, k) loop streams the whole `v_next` matrix once
    /// per source row — `m` full passes of `m·d·8` bytes. The apply is
    /// therefore tiled over column panels of [`COL_BLOCK`] entries: within
    /// a panel the `m` source panels and the destination panel all stay
    /// cache-resident, cutting main-memory traffic by ~`m×`. Each
    /// destination row's panel is one [`Kernel::gemv_panel`] call whose
    /// contract fixes the per-element accumulation to ascending `i`, so
    /// the result is **bitwise identical** to the unblocked loop and to
    /// every kernel backend — `gemv_panel` is element-wise
    /// (EXPERIMENTS.md §Perf has the before/after numbers).
    ///
    /// **Panel parallelism**: when `exec` offers more than one thread and
    /// `d` spans at least two [`PAR_COL_MIN`] panels, the column range is
    /// split into contiguous chunks by index arithmetic and fanned over
    /// `exec`'s allocation-free indexed dispatch
    /// ([`ParallelExec::run_indexed`] — the scheduler's worker pool in
    /// the parallel runtime), so a steady-state mixing round allocates
    /// nothing. Column values are mutually independent and each keeps its
    /// ascending-`i` accumulation, so the result is bitwise identical to
    /// the inline path for every thread count — the equivalence tests pin
    /// this.
    pub fn round_with(
        &mut self,
        b: &TransitionMatrix,
        exec: &dyn ParallelExec,
        kernel: &'static dyn Kernel,
    ) {
        assert_eq!(b.m, self.m, "PushVector: matrix size mismatch");
        // Rank-1 fast path: uniform B (complete graph + MH) averages in one
        // mean + broadcast — O(2m·d) instead of O(m²·d).
        if let Some(u) = b.uniform_value() {
            let (head, tail) = self.v_next.split_at_mut(self.d);
            head.fill(0.0);
            for i in 0..self.m {
                let src = &self.v[i * self.d..(i + 1) * self.d];
                kernel.axpy(u, src, head);
            }
            for chunk in tail.chunks_mut(self.d) {
                chunk.copy_from_slice(head);
            }
            let w_mean: f64 = self.w.iter().sum::<f64>() * u;
            self.w_next.iter_mut().for_each(|x| *x = w_mean);
            std::mem::swap(&mut self.v, &mut self.v_next);
            std::mem::swap(&mut self.w, &mut self.w_next);
            self.stats.rounds += 1;
            let msgs = self.m * (self.m - 1);
            self.stats.messages += msgs;
            self.stats.bytes += msgs * 8 * (self.d + 1);
            return;
        }
        self.v_next.fill(0.0);
        self.w_next.fill(0.0);
        let (m, d) = (self.m, self.d);
        let v = &self.v;
        let base = self.v_next.as_mut_ptr();
        // How many panel tasks are worth dispatching: one per PAR_COL_MIN
        // columns, capped by the executor's parallelism. 1 ⇒ run inline.
        let tasks_n = exec.threads().min(d / PAR_COL_MIN).max(1);
        if tasks_n <= 1 {
            // SAFETY: `&mut self` gives this call exclusive access to the
            // whole `v_next` buffer.
            unsafe { bt_apply_columns(b, v, base, m, d, 0, d, kernel) };
        } else {
            let chunk = (d + tasks_n - 1) / tasks_n;
            let dst = SendPtr(base);
            exec.run_indexed(tasks_n, &move |t| {
                let k0 = t * chunk;
                let k1 = ((t + 1) * chunk).min(d);
                if k0 < k1 {
                    // SAFETY: the indices' `[k0, k1)` ranges partition
                    // `[0, d)` — pairwise disjoint columns of `v_next` —
                    // and `run_indexed` returns only after every index
                    // finished, so the buffer outlives all writes.
                    unsafe { bt_apply_columns(b, v, dst.0, m, d, k0, k1, kernel) };
                }
                Ok(())
            })
            .expect("panel apply is infallible");
        }
        for i in 0..m {
            let row = b.row(i);
            for j in 0..m {
                let bij = row[j];
                if bij != 0.0 {
                    self.w_next[j] += bij * self.w[i];
                }
            }
        }
        std::mem::swap(&mut self.v, &mut self.v_next);
        std::mem::swap(&mut self.w, &mut self.w_next);
        self.stats.rounds += 1;
        let msgs = count_offdiag(b);
        self.stats.messages += msgs;
        self.stats.bytes += msgs * 8 * (self.d + 1);
    }

    /// Node `i`'s current Push-Sum weight.
    pub fn weight(&self, i: usize) -> f64 {
        self.w[i]
    }

    /// Total Push-Sum weight `Σᵢ wᵢ` (ascending-`i` summation). Rounds
    /// conserve this up to f64 re-association; `reset_weighted` re-seeds
    /// it to exactly `Σ nᵢ` of the weights passed in — the streaming
    /// re-weight invariant the property suite pins.
    pub fn total_weight(&self) -> f64 {
        self.w.iter().sum()
    }

    /// Writes node `i`'s current estimate `v_i / w_i` into `out`.
    pub fn estimate_into(&self, i: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        let inv = 1.0 / self.w[i];
        let src = &self.v[i * self.d..(i + 1) * self.d];
        for (o, &s) in out.iter_mut().zip(src) {
            *o = s * inv;
        }
    }

    /// Node `i`'s estimate as a fresh vector.
    pub fn estimate(&self, i: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        self.estimate_into(i, &mut out);
        out
    }

    /// The exact consensus target `Σ v₀ / Σ w₀` (conserved mass ratio).
    pub fn target(&self) -> Vec<f64> {
        let total_w: f64 = self.w.iter().sum();
        let mut t = vec![0.0; self.d];
        for i in 0..self.m {
            let src = &self.v[i * self.d..(i + 1) * self.d];
            for (tk, &sk) in t.iter_mut().zip(src) {
                *tk += sk;
            }
        }
        for tk in t.iter_mut() {
            *tk /= total_w;
        }
        t
    }

    /// Max over nodes of `‖est_i − target‖₂ / max(‖target‖₂, 1e-12)`.
    pub fn max_rel_error(&self) -> f64 {
        let t = self.target();
        let scale = crate::linalg::l2_norm(&t).max(1e-12);
        let mut worst = 0.0f64;
        let mut est = vec![0.0; self.d];
        for i in 0..self.m {
            self.estimate_into(i, &mut est);
            let mut diff = 0.0;
            for k in 0..self.d {
                let e = est[k] - t[k];
                diff += e * e;
            }
            worst = worst.max(diff.sqrt() / scale);
        }
        worst
    }

    /// Runs rounds until max relative error ≤ `gamma` (or `max_rounds`);
    /// returns rounds executed in this call.
    pub fn run_to_gamma(&mut self, b: &TransitionMatrix, gamma: f64, max_rounds: usize) -> usize {
        let start = self.stats.rounds;
        while self.max_rel_error() > gamma && self.stats.rounds - start < max_rounds {
            self.round(b);
        }
        self.stats.rounds - start
    }

    /// Runs exactly `rounds` rounds.
    pub fn run_rounds(&mut self, b: &TransitionMatrix, rounds: usize) {
        self.run_rounds_with(b, rounds, &SERIAL_EXEC, crate::linalg::kernel::scalar());
    }

    /// Runs exactly `rounds` rounds with the `Bᵀ`-apply fanned over
    /// `exec` on `kernel` (see [`PushVector::round_with`]); bitwise
    /// identical to [`PushVector::run_rounds`] for every executor and
    /// kernel backend.
    pub fn run_rounds_with(
        &mut self,
        b: &TransitionMatrix,
        rounds: usize,
        exec: &dyn ParallelExec,
        kernel: &'static dyn Kernel,
    ) {
        for _ in 0..rounds {
            self.round_with(b, exec, kernel);
        }
    }

    /// Communication stats so far.
    pub fn stats(&self) -> GossipStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::stochastic::WeightScheme;
    use crate::topology::Graph;

    fn mh(g: &Graph) -> TransitionMatrix {
        TransitionMatrix::from_graph(g, WeightScheme::MetropolisHastings)
    }

    #[test]
    fn converges_to_uniform_average() {
        let vectors = vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![2.0, 2.0], vec![1.0, 1.0]];
        let b = mh(&Graph::ring(4));
        let mut pv = PushVector::new(&vectors);
        pv.run_to_gamma(&b, 1e-10, 10_000);
        for i in 0..4 {
            let e = pv.estimate(i);
            assert!((e[0] - 1.0).abs() < 1e-8);
            assert!((e[1] - 1.0).abs() < 1e-8);
        }
    }

    #[test]
    fn weighted_average_matches_shard_sizes() {
        // Theorem 1 target: Σ nᵢ ŵᵢ / N.
        let vectors = vec![vec![1.0], vec![4.0]];
        let weights = vec![3.0, 1.0]; // n₁=3, n₂=1 ⇒ target (3·1+1·4)/4 = 1.75
        let b = mh(&Graph::complete(2));
        let mut pv = PushVector::new_weighted(&vectors, &weights);
        pv.run_to_gamma(&b, 1e-12, 1000);
        assert!((pv.estimate(0)[0] - 1.75).abs() < 1e-9);
        assert!((pv.estimate(1)[0] - 1.75).abs() < 1e-9);
    }

    #[test]
    fn mass_conservation_target_is_invariant() {
        let vectors = vec![vec![1.0, -2.0], vec![3.0, 5.0], vec![-1.0, 0.5]];
        let b = mh(&Graph::ring(3));
        let mut pv = PushVector::new(&vectors);
        let t0 = pv.target();
        for _ in 0..25 {
            pv.round(&b);
            let t = pv.target();
            for k in 0..2 {
                assert!((t[k] - t0[k]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn error_is_monotone_decreasing_on_average() {
        let vectors: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64, (8 - i) as f64]).collect();
        let b = mh(&Graph::torus(8));
        let mut pv = PushVector::new(&vectors);
        let e0 = pv.max_rel_error();
        pv.run_rounds(&b, 10);
        let e10 = pv.max_rel_error();
        pv.run_rounds(&b, 10);
        let e20 = pv.max_rel_error();
        assert!(e10 < e0 && e20 < e10, "{e0} {e10} {e20}");
    }

    #[test]
    fn stats_count_vector_bytes() {
        let b = mh(&Graph::ring(3));
        let mut pv = PushVector::new(&[vec![0.0; 5], vec![0.0; 5], vec![0.0; 5]]);
        pv.round(&b);
        let s = pv.stats();
        assert_eq!(s.rounds, 1);
        assert_eq!(s.messages, 6); // C3: 6 directed edges
        assert_eq!(s.bytes, 6 * 8 * 6); // (d+1)=6 f64s per message
    }

    #[test]
    fn blocked_round_is_bitwise_equal_to_naive_apply() {
        // d straddles the panel boundary so the tiled loop takes both the
        // full-panel and the tail path.
        let d = super::COL_BLOCK + 37;
        let m = 5;
        let mut rng = crate::rng::Rng::new(404);
        let vectors: Vec<Vec<f64>> =
            (0..m).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let b = mh(&Graph::ring(m));
        let mut pv = PushVector::new(&vectors);
        // naive untiled Bᵀ-apply with the same ascending-i accumulation
        let mut expect = vec![vec![0.0f64; d]; m];
        let mut expect_w = vec![0.0f64; m];
        for i in 0..m {
            for j in 0..m {
                let bij = b.get(i, j);
                if bij == 0.0 {
                    continue;
                }
                for k in 0..d {
                    expect[j][k] += bij * vectors[i][k];
                }
                expect_w[j] += bij; // initial weights are all 1
            }
        }
        pv.round(&b);
        for j in 0..m {
            // estimate = v/w; both sides divide by the identically-computed
            // weight, so the comparison is exact.
            let est = pv.estimate(j);
            let inv = 1.0 / expect_w[j]; // mirror estimate_into exactly
            for k in 0..d {
                let want = expect[j][k] * inv;
                assert_eq!(
                    est[k].to_bits(),
                    want.to_bits(),
                    "node {j} slot {k}: {} vs {want}",
                    est[k]
                );
            }
        }
    }

    #[test]
    fn panel_parallel_round_is_bitwise_equal_to_inline() {
        // d spans several PAR_COL_MIN panels with a ragged tail, on a
        // non-uniform B (ring ⇒ no rank-1 fast path): the pooled apply
        // must reproduce the inline apply bit for bit at every pool size,
        // including sizes above the panel count.
        let d = 3 * super::PAR_COL_MIN + 41;
        let m = 5;
        let mut rng = crate::rng::Rng::new(909);
        let vectors: Vec<Vec<f64>> =
            (0..m).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let b = mh(&Graph::ring(m));
        for threads in [2usize, 3, 8] {
            let pool = crate::pool::WorkerPool::new(threads);
            let mut inline = PushVector::new(&vectors);
            let mut pooled = PushVector::new(&vectors);
            for _ in 0..7 {
                inline.round(&b);
                pooled.round_with(&b, &pool, crate::linalg::kernel::scalar());
            }
            for i in 0..m {
                let (a, c) = (inline.estimate(i), pooled.estimate(i));
                for k in 0..d {
                    assert_eq!(
                        a[k].to_bits(),
                        c[k].to_bits(),
                        "threads={threads} node {i} col {k}"
                    );
                }
            }
        }
    }

    #[test]
    fn small_d_stays_on_inline_path_and_matches() {
        // Below 2·PAR_COL_MIN columns the dispatch is skipped entirely;
        // results are identical either way.
        let vectors = vec![vec![1.0, -2.0, 0.5], vec![3.0, 5.0, -0.25], vec![0.0, 1.0, 2.0]];
        let b = mh(&Graph::ring(3));
        let pool = crate::pool::WorkerPool::new(4);
        let mut inline = PushVector::new(&vectors);
        let mut pooled = PushVector::new(&vectors);
        for _ in 0..5 {
            inline.round(&b);
            pooled.round_with(&b, &pool, crate::linalg::kernel::scalar());
        }
        for i in 0..3 {
            assert_eq!(inline.estimate(i), pooled.estimate(i));
        }
    }

    #[test]
    fn mixing_round_is_bitwise_kernel_invariant() {
        // The Bᵀ-apply is pure gemv_panel + axpy — element-wise kernel
        // operations — so even the reassociating SIMD backend must
        // reproduce the scalar round bit for bit, on both the general
        // path (ring) and the rank-1 uniform fast path (complete).
        let d = super::COL_BLOCK + 13;
        let m = 4;
        let mut rng = crate::rng::Rng::new(606);
        let vectors: Vec<Vec<f64>> =
            (0..m).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        for b in [mh(&Graph::ring(m)), mh(&Graph::complete(m))] {
            let mut scalar_pv = PushVector::new(&vectors);
            let mut simd_pv = PushVector::new(&vectors);
            for _ in 0..6 {
                scalar_pv.round_with(&b, &SERIAL_EXEC, crate::linalg::kernel::scalar());
                simd_pv.round_with(&b, &SERIAL_EXEC, crate::linalg::kernel::simd());
            }
            for i in 0..m {
                let (a, c) = (scalar_pv.estimate(i), simd_pv.estimate(i));
                for k in 0..d {
                    assert_eq!(a[k].to_bits(), c[k].to_bits(), "node {i} col {k}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "ragged vectors")]
    fn ragged_input_panics() {
        PushVector::new(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    #[should_panic(expected = "weights must be positive")]
    fn zero_weight_panics() {
        PushVector::new_weighted(&[vec![1.0]], &[0.0]);
    }
}
