//! Randomized uniform-gossip engine: the classical Push-Sum execution where
//! each node, once per round, picks a single neighbor at random and ships
//! half of its (vector, weight) mass, keeping the other half
//! (`α_{t,i,i} = α_{t,i,j} = ½` in Algorithm 1's share notation).
//!
//! This matches the paper's description "each node contacts a neighbor at
//! random and exchanges information" and the Peersim cycle-driven protocol.
//! The deterministic `Bᵀ` engine in [`super::pushvector`] is the expectation
//! of this process; the mixing benches (`benches/pushsum_mixing.rs`) verify
//! both hit the `O(τ_mix log 1/γ)` rate.

use super::GossipStats;
use crate::rng::Rng;
use crate::topology::Graph;

/// Randomized Push-Vector gossip over a graph.
#[derive(Clone, Debug)]
pub struct RandomizedGossip {
    m: usize,
    d: usize,
    v: Vec<f64>,
    w: Vec<f64>,
    inbox_v: Vec<f64>,
    inbox_w: Vec<f64>,
    rng: Rng,
    stats: GossipStats,
    /// Per-message loss probability (lossy links, paper §1). Lost messages
    /// destroy mass, so estimates acquire bias ∝ drop rate — measured by
    /// `tests::message_loss_biases_estimates` and the mixing bench.
    drop_prob: f64,
    /// Messages lost so far.
    pub dropped: usize,
}

impl RandomizedGossip {
    /// Initializes node `i` with `vectors[i]` and weight 1.
    pub fn new(vectors: &[Vec<f64>], seed: u64) -> Self {
        let m = vectors.len();
        assert!(m > 0, "RandomizedGossip: need at least one node");
        let d = vectors[0].len();
        let mut v = Vec::with_capacity(m * d);
        for vec_i in vectors {
            assert_eq!(vec_i.len(), d, "RandomizedGossip: ragged vectors");
            v.extend_from_slice(vec_i);
        }
        Self {
            m,
            d,
            v,
            w: vec![1.0; m],
            inbox_v: vec![0.0; m * d],
            inbox_w: vec![0.0; m],
            rng: Rng::new(seed),
            stats: GossipStats::default(),
            drop_prob: 0.0,
            dropped: 0,
        }
    }

    /// Enables lossy links: each message is dropped with probability `p`.
    pub fn with_drop_prob(mut self, p: f64) -> Self {
        assert!((0.0..1.0).contains(&p), "drop_prob must be in [0,1)");
        self.drop_prob = p;
        self
    }

    /// One round: every node halves its mass, sends one half to a uniformly
    /// random neighbor, keeps the other half, then everyone ingests.
    pub fn round(&mut self, g: &Graph) {
        assert_eq!(g.n, self.m, "RandomizedGossip: graph size mismatch");
        self.inbox_v.fill(0.0);
        self.inbox_w.fill(0.0);
        for i in 0..self.m {
            let nbrs = &g.adj[i];
            let (keep, send_to) = if nbrs.is_empty() {
                (1.0, i)
            } else {
                (0.5, nbrs[self.rng.below(nbrs.len())])
            };
            let share = 1.0 - keep;
            let src = i * self.d;
            // keep-half into own inbox
            for k in 0..self.d {
                self.inbox_v[src + k] += keep * self.v[src + k];
            }
            self.inbox_w[i] += keep * self.w[i];
            // send-half to the chosen neighbor (may be lost on the link)
            if share > 0.0 {
                self.stats.messages += 1;
                self.stats.bytes += 8 * (self.d + 1);
                if self.drop_prob > 0.0 && self.rng.flip(self.drop_prob) {
                    // mass destroyed: the bias source
                    self.dropped += 1;
                    self.stats.dropped += 1;
                } else {
                    let dst = send_to * self.d;
                    for k in 0..self.d {
                        self.inbox_v[dst + k] += share * self.v[src + k];
                    }
                    self.inbox_w[send_to] += share * self.w[i];
                }
            }
        }
        std::mem::swap(&mut self.v, &mut self.inbox_v);
        std::mem::swap(&mut self.w, &mut self.inbox_w);
        self.stats.rounds += 1;
    }

    /// Node `i`'s current estimate `v_i / w_i`.
    pub fn estimate(&self, i: usize) -> Vec<f64> {
        let inv = 1.0 / self.w[i];
        self.v[i * self.d..(i + 1) * self.d].iter().map(|&x| x * inv).collect()
    }

    /// True average (conserved).
    pub fn target(&self) -> Vec<f64> {
        let total_w: f64 = self.w.iter().sum();
        let mut t = vec![0.0; self.d];
        for i in 0..self.m {
            for k in 0..self.d {
                t[k] += self.v[i * self.d + k];
            }
        }
        for tk in t.iter_mut() {
            *tk /= total_w;
        }
        t
    }

    /// Max relative estimate error across nodes (see `PushVector`).
    pub fn max_rel_error(&self) -> f64 {
        let t = self.target();
        let scale = crate::linalg::l2_norm(&t).max(1e-12);
        (0..self.m)
            .map(|i| {
                let e = self.estimate(i);
                let mut diff = 0.0;
                for k in 0..self.d {
                    let x = e[k] - t[k];
                    diff += x * x;
                }
                diff.sqrt() / scale
            })
            .fold(0.0, f64::max)
    }

    /// Runs until error ≤ gamma or max_rounds; returns rounds executed.
    pub fn run_to_gamma(&mut self, g: &Graph, gamma: f64, max_rounds: usize) -> usize {
        let start = self.stats.rounds;
        while self.max_rel_error() > gamma && self.stats.rounds - start < max_rounds {
            self.round(g);
        }
        self.stats.rounds - start
    }

    /// Communication stats.
    pub fn stats(&self) -> GossipStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_complete_graph() {
        let vectors: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64]).collect();
        let g = Graph::complete(8);
        let mut rg = RandomizedGossip::new(&vectors, 42);
        let rounds = rg.run_to_gamma(&g, 1e-6, 10_000);
        assert!(rounds < 10_000, "did not converge");
        for i in 0..8 {
            assert!((rg.estimate(i)[0] - 3.5).abs() < 1e-5);
        }
    }

    #[test]
    fn mass_conserved_under_randomized_rounds() {
        let vectors = vec![vec![2.0, 1.0], vec![0.0, -1.0], vec![4.0, 3.0]];
        let g = Graph::ring(3);
        let mut rg = RandomizedGossip::new(&vectors, 7);
        let t0 = rg.target();
        for _ in 0..40 {
            rg.round(&g);
            let t = rg.target();
            assert!((t[0] - t0[0]).abs() < 1e-12);
            assert!((t[1] - t0[1]).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        let vectors = vec![vec![1.0], vec![5.0], vec![9.0], vec![2.0]];
        let g = Graph::ring(4);
        let mut a = RandomizedGossip::new(&vectors, 3);
        let mut b = RandomizedGossip::new(&vectors, 3);
        for _ in 0..20 {
            a.round(&g);
            b.round(&g);
        }
        assert_eq!(a.estimate(0), b.estimate(0));
    }

    #[test]
    fn message_count_is_one_per_node_per_round() {
        let g = Graph::ring(5);
        let mut rg = RandomizedGossip::new(&vec![vec![0.0]; 5], 1);
        rg.round(&g);
        rg.round(&g);
        assert_eq!(rg.stats().messages, 10);
    }

    #[test]
    fn message_loss_biases_estimates() {
        // With lossy links mass is destroyed; estimates still converge to a
        // common value but it is no longer the exact average. Both facts
        // are the claim here: consensus survives, unbiasedness does not.
        let vectors: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 + 1.0]).collect();
        let g = Graph::complete(8);
        let true_avg = 4.5;

        let mut lossless = RandomizedGossip::new(&vectors, 3);
        for _ in 0..400 {
            lossless.round(&g);
        }
        assert_eq!(lossless.dropped, 0);
        let err_lossless = (lossless.estimate(0)[0] - true_avg).abs();
        assert!(err_lossless < 1e-6, "lossless error {err_lossless}");

        let mut lossy = RandomizedGossip::new(&vectors, 3).with_drop_prob(0.2);
        for _ in 0..400 {
            lossy.round(&g);
        }
        assert!(lossy.dropped > 0);
        // losses surface through the unified stats definition too
        assert_eq!(lossy.stats().dropped, lossy.dropped);
        assert_eq!(lossless.stats().dropped, 0);
        // nodes still agree with each other…
        let e0 = lossy.estimate(0)[0];
        for i in 1..8 {
            assert!((lossy.estimate(i)[0] - e0).abs() < 0.2 * e0.abs().max(1.0));
        }
        // …but mass is gone: the (v, w) totals no longer describe the true
        // average; the target stays finite and inside the value range.
        let t = lossy.target();
        assert!(t[0].is_finite() && t[0] > 0.0 && t[0] < 9.0);
    }

    #[test]
    fn single_isolated_node_is_stable() {
        let g = Graph::from_edges(1, &[]);
        let mut rg = RandomizedGossip::new(&[vec![3.0]], 0);
        rg.round(&g);
        assert_eq!(rg.estimate(0), vec![3.0]);
        assert_eq!(rg.stats().messages, 0);
    }
}
