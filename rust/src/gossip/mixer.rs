//! The consensus seam: an object-safe [`Mixer`] trait behind step (g) of
//! Algorithm 2, abstracting the mixing scheme the way
//! [`crate::linalg::Kernel`] abstracted arithmetic.
//!
//! Every GADGET iteration hands the mixer the current per-node weight
//! vectors plus their shard sizes and asks for the shard-weighted network
//! average `Σ nᵢwᵢ / Σ nᵢ` (Theorem 1's consensus target); how the mixer
//! gets there — push-sum mass exchange, primal-dual gradient flow, or
//! anything else — is its own business, as long as it reports its
//! communication through the one [`GossipStats`] definition (one message
//! = one directed node-to-node payload transfer; see [`super`]).
//!
//! Two backends:
//!
//! * [`PushSumMixer`] — wraps the existing deterministic Push-Vector
//!   round sequence **unchanged**: `reset_weighted` → `run_rounds_with`
//!   over the doubly-stochastic `B`. This is the **bitwise reference** —
//!   `rust/tests/mixer_equivalence.rs` pins the runner on this mixer
//!   bit-for-bit against the pre-refactor inline Push-Vector loop, across
//!   schedulers and pool sizes.
//! * [`GradientFlowMixer`] — a structurally different backend after the
//!   primal-dual gradient-flow DSVM (arXiv 1807.08684): per-edge dual
//!   variables on a fixed graph enforce pairwise agreement, and
//!   Arrow–Hurwicz descent/ascent on the constrained quadratic
//!   `min Σᵢ (aᵢ/2)‖zᵢ − xᵢ‖²  s.t.  zᵢ = zⱼ ∀(i,j) ∈ E`
//!   drives every `zᵢ` to the weighted average (the unique saddle point
//!   on a connected graph). Rounds are deterministic and seeded: the
//!   seed fixes the edge permutation, which fixes the floating-point
//!   accumulation order of the dual contributions.

use super::{GossipStats, PushVector};
use crate::linalg::Kernel;
use crate::pool::ParallelExec;
use crate::rng::Rng;
use crate::topology::{Graph, TransitionMatrix};

/// Which consensus backend step (g) runs on (`[mixing] backend` /
/// `--mixer`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MixerKind {
    /// Deterministic Push-Vector over the doubly-stochastic `B` — the
    /// paper's Algorithm 1 and the bitwise reference path.
    #[default]
    PushSum,
    /// Primal-dual gradient flow with per-edge duals (arXiv 1807.08684).
    GradientFlow,
}

impl std::str::FromStr for MixerKind {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "push-sum" | "pushsum" => Ok(Self::PushSum),
            "gradient-flow" | "gradientflow" | "flow" => Ok(Self::GradientFlow),
            other => Err(format!(
                "unknown mixer {other:?} (push-sum | gradient-flow)"
            )),
        }
    }
}

impl std::fmt::Display for MixerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::PushSum => "push-sum",
            Self::GradientFlow => "gradient-flow",
        })
    }
}

/// The consensus step behind Algorithm 2 step (g), object-safe so the
/// runner holds a `Box<dyn Mixer>` chosen by config.
///
/// Contract (what `mixer_equivalence.rs` and the runner rely on):
///
/// * `mix` consumes the *current* per-node vectors and weights — the
///   mixer must not carry vector state across calls (weights may change
///   between iterations under streaming ingestion and churn);
/// * after `mix`, `estimate_into(slot, …)` yields node `slot`'s estimate
///   of the weighted average `Σ aᵢvᵢ / Σ aᵢ`;
/// * `stats` reports the communication of the **last `mix` call only**
///   (the runner accumulates across iterations itself), under the
///   unified [`GossipStats`] definition;
/// * `conservation_error` is the relative drift of the conserved
///   quantity (`Σ aᵢ·estᵢ` vs `Σ aᵢ·vᵢ`) after the last `mix` — 0 for
///   exactly-conserving engines.
pub trait Mixer: Send + Sync {
    /// Backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// One consensus phase: mixes `vectors` (one slice per node, in slot
    /// order) weighted by `weights`, fanning any parallelizable inner
    /// work over `exec` on `kernel`.
    fn mix<'a>(
        &mut self,
        vectors: &mut dyn ExactSizeIterator<Item = &'a [f64]>,
        weights: &[f64],
        exec: &dyn ParallelExec,
        kernel: &'static dyn Kernel,
    );

    /// Writes node `slot`'s estimate after the last [`Mixer::mix`] into
    /// `out`.
    fn estimate_into(&self, slot: usize, out: &mut [f64]);

    /// Communication stats of the last [`Mixer::mix`] call.
    fn stats(&self) -> GossipStats;

    /// Relative conservation error of the last [`Mixer::mix`]:
    /// `‖Σ aᵢ·estᵢ − Σ aᵢ·vᵢ‖ / max(‖Σ aᵢ·vᵢ‖, tiny)`. Exactly-tracked
    /// mass engines report 0.
    fn conservation_error(&self) -> f64 {
        0.0
    }
}

// ---------------------------------------------------------------------------
// Push-Sum (the bitwise reference)
// ---------------------------------------------------------------------------

/// The Push-Vector consensus phase as a [`Mixer`]: exactly the sequence
/// the runner inlined before the seam existed —
/// `pv.reset_weighted(vectors, weights)` then
/// `pv.run_rounds_with(&b, rounds, exec, kernel)` — so the refactor is
/// bitwise invisible (`reset_weighted` also zeroes the stats block, which
/// is what makes [`Mixer::stats`] per-mix here, matching the old
/// per-iteration `merge(pv.stats())`).
pub struct PushSumMixer {
    b: TransitionMatrix,
    rounds: usize,
    pv: PushVector,
}

impl PushSumMixer {
    /// Builds the mixer over transition matrix `b`, running `rounds`
    /// Push-Vector rounds per mix, for `weights.len()` nodes of dimension
    /// `d`. `weights` seed the initial Push-Sum weights (they are
    /// re-seeded on every mix; only the count matters at construction).
    pub fn new(b: TransitionMatrix, rounds: usize, d: usize, weights: &[f64]) -> Self {
        let m = weights.len();
        assert_eq!(b.m, m, "PushSumMixer: matrix size mismatch");
        let pv = PushVector::new_weighted(&vec![vec![0.0; d]; m], weights);
        Self { b, rounds, pv }
    }

    /// Push-Vector rounds per mix.
    pub fn rounds(&self) -> usize {
        self.rounds
    }
}

impl Mixer for PushSumMixer {
    fn name(&self) -> &'static str {
        "push-sum"
    }

    fn mix<'a>(
        &mut self,
        vectors: &mut dyn ExactSizeIterator<Item = &'a [f64]>,
        weights: &[f64],
        exec: &dyn ParallelExec,
        kernel: &'static dyn Kernel,
    ) {
        self.pv.reset_weighted(vectors, weights);
        self.pv.run_rounds_with(&self.b, self.rounds, exec, kernel);
    }

    fn estimate_into(&self, slot: usize, out: &mut [f64]) {
        self.pv.estimate_into(slot, out);
    }

    fn stats(&self) -> GossipStats {
        self.pv.stats()
    }
}

// ---------------------------------------------------------------------------
// Primal-dual gradient flow (arXiv 1807.08684 style)
// ---------------------------------------------------------------------------

/// Floor on the internal gradient-flow rounds per mix: the saddle-point
/// dynamics need more sweeps than push-sum's spectral rounds to reach a
/// comparable consensus residual (each round is O((m + |E|)·d)).
const FLOW_MIN_ROUNDS: usize = 200;
/// Cap on the internal rounds (mirrors the runner's mixing-time cap).
const FLOW_MAX_ROUNDS: usize = 10_000;
/// Internal rounds per requested reference round: the dual ascent
/// converges at the graph's consensus rate, not the push-sum rate, so it
/// gets a constant-factor larger budget.
const FLOW_ROUNDS_FACTOR: usize = 4;
/// Step-size safety factor against the Arrow–Hurwicz stability bound.
const FLOW_STEP: f64 = 0.5;

/// Primal-dual consensus on a fixed graph: each undirected edge `(i, j)`
/// carries a dual vector `u_e ∈ ℝᵈ` for the constraint `zᵢ = zⱼ`, and one
/// round is a gradient descent step on the primal followed by an ascent
/// step on the duals:
///
/// ```text
/// gᵢ  = aᵢ(zᵢ − xᵢ) + Σ_{e=(i,·)} u_e − Σ_{e=(·,i)} u_e
/// zᵢ ← zᵢ − α·gᵢ
/// u_e ← u_e + β·(zᵢ − zⱼ)          (on the updated z)
/// ```
///
/// with `aᵢ` the shard weights normalized to mean 1. On a connected graph
/// the unique saddle point has every `zᵢ` equal to the weighted average
/// `Σ aᵢxᵢ / Σ aᵢ` (sum the stationarity conditions: the incidence terms
/// telescope away), so this realizes the same Theorem-1 target as
/// push-sum through an entirely different mechanism — no mass is moved,
/// agreement is *enforced* by the duals, and conservation holds only
/// approximately ([`Mixer::conservation_error`] reports the residual).
///
/// Determinism: rounds are synchronous and the seeded edge permutation
/// (drawn once at construction) fixes the floating-point accumulation
/// order of the dual contributions, so a seed pins the run bit-for-bit.
pub struct GradientFlowMixer {
    m: usize,
    d: usize,
    /// Undirected edges `(i, j)` with `i < j`, in seeded permuted order.
    edges: Vec<(usize, usize)>,
    /// Internal rounds per mix.
    rounds: usize,
    /// Arrow–Hurwicz stability denominator: `a_max + 2·max_degree` is a
    /// bound on the coupled system's curvature; the per-mix steps are
    /// `FLOW_STEP / (a_max + 2·max_degree)` with `a_max` from the
    /// *current* normalized weights.
    max_degree: usize,
    /// Normalized weights of the last mix (mean 1).
    wts: Vec<f64>,
    /// Input snapshot `x` (row-major m×d).
    x0: Vec<f64>,
    /// Primal iterates `z` (row-major m×d).
    z: Vec<f64>,
    /// Gradient scratch (row-major m×d).
    grad: Vec<f64>,
    /// Per-edge duals (row-major |E|×d), zeroed per mix.
    u: Vec<f64>,
    stats: GossipStats,
    conservation: f64,
}

impl GradientFlowMixer {
    /// Builds the mixer on `graph` for vectors of dimension `d`.
    /// `rounds_hint` is the reference (push-sum) round count — the
    /// internal budget is `FLOW_ROUNDS_FACTOR`× that, clamped to
    /// `[FLOW_MIN_ROUNDS, FLOW_MAX_ROUNDS]`. `seed` fixes the edge
    /// permutation (and with it the accumulation order).
    pub fn new(graph: &Graph, rounds_hint: usize, seed: u64, d: usize) -> Self {
        let m = graph.n;
        assert!(m > 0, "GradientFlowMixer: need at least one node");
        assert!(
            m == 1 || graph.is_connected(),
            "GradientFlowMixer: the constraint graph must be connected \
             (disconnected components would converge to per-component \
             averages, silently breaking the Theorem-1 target)"
        );
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(graph.edge_count());
        for i in 0..m {
            for &j in &graph.adj[i] {
                if i < j {
                    edges.push((i, j));
                }
            }
        }
        Rng::new(seed).shuffle(&mut edges);
        let rounds = (rounds_hint.saturating_mul(FLOW_ROUNDS_FACTOR))
            .clamp(FLOW_MIN_ROUNDS, FLOW_MAX_ROUNDS);
        let ne = edges.len();
        Self {
            m,
            d,
            edges,
            rounds,
            max_degree: graph.max_degree(),
            wts: vec![1.0; m],
            x0: vec![0.0; m * d],
            z: vec![0.0; m * d],
            grad: vec![0.0; m * d],
            u: vec![0.0; ne * d],
            stats: GossipStats::default(),
            conservation: 0.0,
        }
    }

    /// Internal rounds per mix.
    pub fn rounds(&self) -> usize {
        self.rounds
    }
}

impl Mixer for GradientFlowMixer {
    fn name(&self) -> &'static str {
        "gradient-flow"
    }

    fn mix<'a>(
        &mut self,
        vectors: &mut dyn ExactSizeIterator<Item = &'a [f64]>,
        weights: &[f64],
        _exec: &dyn ParallelExec,
        _kernel: &'static dyn Kernel,
    ) {
        let (m, d) = (self.m, self.d);
        assert_eq!(vectors.len(), m, "mix: node count mismatch");
        assert_eq!(weights.len(), m, "mix: weights length mismatch");
        for (i, v) in vectors.enumerate() {
            assert_eq!(v.len(), d, "mix: vector dim mismatch");
            self.x0[i * d..(i + 1) * d].copy_from_slice(v);
        }
        // Normalize the shard weights to mean 1 so the step size keeps a
        // shard-count-free scale (the target Σaᵢxᵢ/Σaᵢ is normalization
        // invariant).
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "mix: weights must have positive total");
        let scale = m as f64 / total;
        let mut a_max = 0.0f64;
        for (o, &w) in self.wts.iter_mut().zip(weights) {
            assert!(w > 0.0, "mix: weights must be positive");
            *o = w * scale;
            a_max = a_max.max(*o);
        }
        let step = FLOW_STEP / (a_max + 2.0 * self.max_degree as f64);
        let (alpha, beta) = (step, step);

        self.z.copy_from_slice(&self.x0);
        self.u.fill(0.0);
        self.stats = GossipStats::default();
        // One round = every node exchanges its current zᵢ with each
        // neighbor (the dual update needs both endpoints' iterates):
        // 2 directed d-vector transfers per undirected edge per round.
        let round_msgs = 2 * self.edges.len();
        let round_bytes = round_msgs * 8 * d;

        for _ in 0..self.rounds {
            // primal gradient: aᵢ(zᵢ − xᵢ) plus the incidence-transposed
            // duals, accumulated in the seeded permuted edge order.
            for i in 0..m {
                let ai = self.wts[i];
                let row = i * d;
                for k in 0..d {
                    self.grad[row + k] = ai * (self.z[row + k] - self.x0[row + k]);
                }
            }
            for (e, &(i, j)) in self.edges.iter().enumerate() {
                let ue = e * d;
                let (ri, rj) = (i * d, j * d);
                for k in 0..d {
                    let u = self.u[ue + k];
                    self.grad[ri + k] += u;
                    self.grad[rj + k] -= u;
                }
            }
            for (zk, gk) in self.z.iter_mut().zip(&self.grad) {
                *zk -= alpha * gk;
            }
            // dual ascent on the updated primal iterates.
            for (e, &(i, j)) in self.edges.iter().enumerate() {
                let ue = e * d;
                let (ri, rj) = (i * d, j * d);
                for k in 0..d {
                    self.u[ue + k] += beta * (self.z[ri + k] - self.z[rj + k]);
                }
            }
            self.stats.rounds += 1;
            self.stats.messages += round_msgs;
            self.stats.bytes += round_bytes;
        }

        // Conservation residual: ‖Σaᵢzᵢ − Σaᵢxᵢ‖ / max(‖Σaᵢxᵢ‖, tiny).
        // Unlike push-sum this engine conserves only at the fixed point.
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for k in 0..d {
            let mut sz = 0.0;
            let mut sx = 0.0;
            for i in 0..m {
                sz += self.wts[i] * self.z[i * d + k];
                sx += self.wts[i] * self.x0[i * d + k];
            }
            let e = sz - sx;
            num += e * e;
            den += sx * sx;
        }
        self.conservation = num.sqrt() / den.sqrt().max(1e-12);
    }

    fn estimate_into(&self, slot: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        out.copy_from_slice(&self.z[slot * self.d..(slot + 1) * self.d]);
    }

    fn stats(&self) -> GossipStats {
        self.stats
    }

    fn conservation_error(&self) -> f64 {
        self.conservation
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::SERIAL_EXEC;
    use crate::topology::stochastic::WeightScheme;

    fn scalar() -> &'static dyn Kernel {
        crate::linalg::kernel::scalar()
    }

    fn random_vectors(m: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..m).map(|_| (0..d).map(|_| rng.normal()).collect()).collect()
    }

    #[test]
    fn mixer_kind_parses_and_displays() {
        assert_eq!("push-sum".parse::<MixerKind>().unwrap(), MixerKind::PushSum);
        assert_eq!("pushsum".parse::<MixerKind>().unwrap(), MixerKind::PushSum);
        assert_eq!(
            "gradient-flow".parse::<MixerKind>().unwrap(),
            MixerKind::GradientFlow
        );
        assert_eq!("flow".parse::<MixerKind>().unwrap(), MixerKind::GradientFlow);
        assert!("belief-prop".parse::<MixerKind>().is_err());
        assert_eq!(MixerKind::PushSum.to_string(), "push-sum");
        assert_eq!(MixerKind::GradientFlow.to_string(), "gradient-flow");
        assert_eq!(MixerKind::default(), MixerKind::PushSum);
    }

    #[test]
    fn push_sum_mixer_is_bitwise_the_inline_push_vector_sequence() {
        // The seam contract: PushSumMixer::mix must be *exactly* the old
        // inline sequence (reset_weighted → run_rounds_with), estimates
        // and stats included, across repeated mixes with changing
        // weights (the streaming re-weight pattern).
        let m = 5;
        let d = 17;
        let g = Graph::ring(m);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let rounds = 6;
        let weights0 = vec![3.0, 1.0, 2.0, 5.0, 4.0];
        let mut mixer = PushSumMixer::new(b.clone(), rounds, d, &weights0);
        let mut pv = PushVector::new_weighted(&vec![vec![0.0; d]; m], &weights0);

        for iter in 0..3u64 {
            let vectors = random_vectors(m, d, 100 + iter);
            // weights drift between mixes, as under ingestion
            let weights: Vec<f64> =
                weights0.iter().map(|w| w + iter as f64).collect();
            pv.reset_weighted(vectors.iter().map(|v| v.as_slice()), &weights);
            pv.run_rounds_with(&b, rounds, &SERIAL_EXEC, scalar());
            mixer.mix(
                &mut vectors.iter().map(|v| v.as_slice()),
                &weights,
                &SERIAL_EXEC,
                scalar(),
            );
            let mut want = vec![0.0; d];
            let mut got = vec![0.0; d];
            for i in 0..m {
                pv.estimate_into(i, &mut want);
                mixer.estimate_into(i, &mut got);
                for k in 0..d {
                    assert_eq!(
                        got[k].to_bits(),
                        want[k].to_bits(),
                        "iter {iter} node {i} col {k}"
                    );
                }
            }
            assert_eq!(mixer.stats(), pv.stats(), "iter {iter} stats");
            assert_eq!(mixer.conservation_error(), 0.0);
        }
    }

    #[test]
    fn gradient_flow_converges_to_weighted_average() {
        let m = 6;
        let d = 8;
        let g = Graph::ring(m);
        let vectors = random_vectors(m, d, 7);
        let weights = vec![3.0, 1.0, 2.0, 1.0, 1.0, 4.0];
        let mut mixer = GradientFlowMixer::new(&g, 600, 42, d);
        mixer.mix(
            &mut vectors.iter().map(|v| v.as_slice()),
            &weights,
            &SERIAL_EXEC,
            scalar(),
        );
        // target = Σ wᵢvᵢ / Σ wᵢ
        let total: f64 = weights.iter().sum();
        let mut target = vec![0.0; d];
        for (v, &w) in vectors.iter().zip(&weights) {
            for k in 0..d {
                target[k] += w * v[k];
            }
        }
        for t in target.iter_mut() {
            *t /= total;
        }
        let scale = crate::linalg::l2_norm(&target).max(1e-12);
        let mut est = vec![0.0; d];
        for i in 0..m {
            mixer.estimate_into(i, &mut est);
            let mut diff = 0.0;
            for k in 0..d {
                let e = est[k] - target[k];
                diff += e * e;
            }
            assert!(
                diff.sqrt() / scale < 0.05,
                "node {i} rel error {}",
                diff.sqrt() / scale
            );
        }
        assert!(mixer.conservation_error() < 0.05, "{}", mixer.conservation_error());
        let s = mixer.stats();
        assert_eq!(s.rounds, mixer.rounds());
        // ring: |E| = m ⇒ 2m directed transfers of d f64s per round
        assert_eq!(s.messages, s.rounds * 2 * m);
        assert_eq!(s.bytes, s.messages * 8 * d);
    }

    #[test]
    fn gradient_flow_is_seed_deterministic() {
        let m = 5;
        let d = 6;
        let g = Graph::complete(m);
        let vectors = random_vectors(m, d, 3);
        let weights = vec![1.0, 2.0, 1.0, 3.0, 1.0];
        let run = |seed: u64| {
            let mut mixer = GradientFlowMixer::new(&g, 100, seed, d);
            mixer.mix(
                &mut vectors.iter().map(|v| v.as_slice()),
                &weights,
                &SERIAL_EXEC,
                scalar(),
            );
            let mut out = Vec::new();
            let mut est = vec![0.0; d];
            for i in 0..m {
                mixer.estimate_into(i, &mut est);
                out.extend(est.iter().map(|x| x.to_bits()));
            }
            out
        };
        assert_eq!(run(9), run(9), "same seed must be bit-for-bit identical");
        // a different seed permutes the dual accumulation order — still a
        // valid mix (close to the same target), generally different bits
        let a = run(9);
        let b = run(10);
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn gradient_flow_single_node_is_identity() {
        let g = Graph::generate(crate::topology::TopologyKind::Ring, 1, 0);
        let vectors = vec![vec![2.5, -1.0, 0.25]];
        let mut mixer = GradientFlowMixer::new(&g, 10, 0, 3);
        mixer.mix(
            &mut vectors.iter().map(|v| v.as_slice()),
            &[4.0],
            &SERIAL_EXEC,
            scalar(),
        );
        let mut est = vec![0.0; 3];
        mixer.estimate_into(0, &mut est);
        // no edges ⇒ z stays at x exactly (gradient is aᵢ(z−x) = 0 at z=x)
        assert_eq!(est, vectors[0]);
        assert_eq!(mixer.stats().messages, 0);
    }

    #[test]
    #[should_panic(expected = "connected")]
    fn gradient_flow_rejects_disconnected_graphs() {
        // two isolated edges: components would average separately
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        GradientFlowMixer::new(&g, 10, 0, 2);
    }
}
