//! Scalar Push-Sum over a doubly-stochastic `B` (Algorithm 1 of the paper).
//!
//! Node `i` holds `(s_i, w_i)`, initialized to `(x_i, 1)`. Each round every
//! node ships shares `(b_{ij}·s_i, b_{ij}·w_i)` to each neighbor `j`
//! (including the self share `b_{ii}`), then sums what it received. The
//! estimate at node `i` after round `t` is `s_i/w_i → (Σx)/m`.

use super::GossipStats;
use crate::topology::TransitionMatrix;

/// Synchronous deterministic Push-Sum state.
#[derive(Clone, Debug)]
pub struct PushSum {
    sums: Vec<f64>,
    weights: Vec<f64>,
    // double-buffering scratch, reused across rounds (no hot-loop alloc)
    sums_next: Vec<f64>,
    weights_next: Vec<f64>,
    stats: GossipStats,
}

impl PushSum {
    /// Initializes with node values `x` (weight 1 per node).
    pub fn new(x: &[f64]) -> Self {
        Self {
            sums: x.to_vec(),
            weights: vec![1.0; x.len()],
            sums_next: vec![0.0; x.len()],
            weights_next: vec![0.0; x.len()],
            stats: GossipStats::default(),
        }
    }

    /// Number of nodes.
    pub fn m(&self) -> usize {
        self.sums.len()
    }

    /// One synchronous round: `s ← Bᵀ s`, `w ← Bᵀ w`.
    pub fn round(&mut self, b: &TransitionMatrix) {
        assert_eq!(b.m, self.m(), "PushSum: matrix size mismatch");
        b.transpose_apply(&self.sums, &mut self.sums_next);
        b.transpose_apply(&self.weights, &mut self.weights_next);
        std::mem::swap(&mut self.sums, &mut self.sums_next);
        std::mem::swap(&mut self.weights, &mut self.weights_next);
        self.stats.rounds += 1;
        // Every nonzero b_ij with i≠j is one message.
        let msgs = count_offdiag(b);
        self.stats.messages += msgs;
        self.stats.bytes += msgs * 16; // (s, w) pair
    }

    /// Current estimate `s_i / w_i` at node `i`.
    pub fn estimate(&self, i: usize) -> f64 {
        self.sums[i] / self.weights[i]
    }

    /// All per-node estimates.
    pub fn estimates(&self) -> Vec<f64> {
        (0..self.m()).map(|i| self.estimate(i)).collect()
    }

    /// Total mass `Σ s_i` (conserved across rounds).
    pub fn total_sum(&self) -> f64 {
        self.sums.iter().sum()
    }

    /// Total weight `Σ w_i` (conserved; equals `m`).
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }

    /// Maximum relative error of the node estimates vs the true average,
    /// with the paper's `‖M‖`-relative convention: `|est_i − μ| / scale`
    /// where `scale = max(|μ|, 1e-12)`.
    pub fn max_rel_error(&self) -> f64 {
        let mu = self.total_sum() / self.total_weight();
        let scale = mu.abs().max(1e-12);
        (0..self.m())
            .map(|i| (self.estimate(i) - mu).abs() / scale)
            .fold(0.0, f64::max)
    }

    /// Runs until the max relative error drops below `gamma` or `max_rounds`
    /// is hit; returns the rounds executed in this call.
    pub fn run_to_gamma(&mut self, b: &TransitionMatrix, gamma: f64, max_rounds: usize) -> usize {
        let start = self.stats.rounds;
        while self.max_rel_error() > gamma && self.stats.rounds - start < max_rounds {
            self.round(b);
        }
        self.stats.rounds - start
    }

    /// Communication stats so far.
    pub fn stats(&self) -> GossipStats {
        self.stats
    }
}

pub(crate) fn count_offdiag(b: &TransitionMatrix) -> usize {
    let mut c = 0;
    for i in 0..b.m {
        for j in 0..b.m {
            if i != j && b.get(i, j) != 0.0 {
                c += 1;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::stochastic::WeightScheme;
    use crate::topology::Graph;

    fn mh(g: &Graph) -> TransitionMatrix {
        TransitionMatrix::from_graph(g, WeightScheme::MetropolisHastings)
    }

    #[test]
    fn converges_to_average_on_ring() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = mh(&Graph::ring(6));
        let mut ps = PushSum::new(&x);
        let rounds = ps.run_to_gamma(&b, 1e-9, 10_000);
        assert!(rounds > 0);
        for i in 0..6 {
            assert!((ps.estimate(i) - 3.5).abs() < 1e-8);
        }
    }

    #[test]
    fn mass_is_conserved_every_round() {
        let x = vec![10.0, -4.0, 7.0, 0.5];
        let b = mh(&Graph::torus(4));
        let mut ps = PushSum::new(&x);
        for _ in 0..50 {
            ps.round(&b);
            assert!((ps.total_sum() - 13.5).abs() < 1e-10);
            assert!((ps.total_weight() - 4.0).abs() < 1e-10);
        }
    }

    #[test]
    fn complete_graph_converges_in_one_round() {
        let x = vec![0.0, 8.0, 0.0, 0.0];
        let b = mh(&Graph::complete(4));
        let mut ps = PushSum::new(&x);
        ps.round(&b);
        for i in 0..4 {
            assert!((ps.estimate(i) - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn rounds_scale_with_log_inv_gamma() {
        // On a fixed topology, rounds-to-γ must grow ≈ linearly in log(1/γ).
        let x: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let b = mh(&Graph::ring(12));
        let mut r = Vec::new();
        for gamma in [1e-2, 1e-4, 1e-6] {
            let mut ps = PushSum::new(&x);
            r.push(ps.run_to_gamma(&b, gamma, 100_000) as f64);
        }
        let d1 = r[1] - r[0];
        let d2 = r[2] - r[1];
        assert!(d1 > 0.0 && d2 > 0.0);
        assert!((d2 / d1 - 1.0).abs() < 0.5, "not linear in log(1/γ): {r:?}");
    }

    #[test]
    fn stats_accumulate() {
        let b = mh(&Graph::ring(4));
        let mut ps = PushSum::new(&[1.0, 2.0, 3.0, 4.0]);
        ps.round(&b);
        ps.round(&b);
        let s = ps.stats();
        assert_eq!(s.rounds, 2);
        assert_eq!(s.messages, 2 * 8); // ring of 4: 8 directed edges
        assert_eq!(s.bytes, 2 * 8 * 16);
    }

    #[test]
    fn negative_and_zero_values() {
        let x = vec![-5.0, 5.0, 0.0];
        let b = mh(&Graph::complete(3));
        let mut ps = PushSum::new(&x);
        ps.run_to_gamma(&b, 1e-10, 1000);
        for i in 0..3 {
            assert!(ps.estimate(i).abs() < 1e-8);
        }
    }
}
