//! Gossip consensus protocols: Push-Sum (Algorithm 1 of the paper) and its
//! vector extension Push-Vector (Kempe et al., FOCS 2003).
//!
//! Two execution engines are provided:
//!
//! * [`pushsum`] / [`pushvector`] — *deterministic* synchronous engines that
//!   move mass by `Bᵀ` each round ("Push-Sum deterministically simulates a
//!   random walk across G", paper §3). These are what the GADGET runner
//!   uses: exact, reproducible, and the object Theorem 1's ε₁/ε₂ bounds are
//!   stated about.
//! * [`randomized`] — the classical randomized engine where each node picks
//!   a single random neighbor per round and ships half its mass
//!   (`α_{t,i,j} = ½`). Used by the mixing benches to show both engines
//!   converge at the `O(τ_mix log 1/γ)` rate.
//!
//! Invariant maintained by every engine: **mass conservation** — the total
//! sum `Σᵢ sᵢ` and total weight `Σᵢ wᵢ` never change, which is exactly why
//! `sᵢ/wᵢ → (Σ s₀)/(Σ w₀) =` the true average at every node.
//!
//! The [`mixer`] module puts an object-safe seam ([`Mixer`]) in front of
//! the engines so the GADGET runner can swap the consensus mechanism
//! (Push-Vector, primal-dual gradient flow, …) by config while every
//! backend reports through the same [`GossipStats`] definition.

pub mod mixer;
pub mod pushsum;
pub mod pushvector;
pub mod randomized;

pub use mixer::{GradientFlowMixer, Mixer, MixerKind, PushSumMixer};
pub use pushsum::PushSum;
pub use pushvector::PushVector;
pub use randomized::RandomizedGossip;

/// Communication accounting shared by every engine and mixer, under one
/// definition so topology experiments compare backends apples to apples:
///
/// * one **message** = one *directed* node-to-node payload transfer over
///   one edge in one round (a deterministic `Bᵀ` round on an `m`-node
///   graph sends one message per off-diagonal entry; the randomized
///   engine sends one per push; gradient flow sends two per undirected
///   edge per round — one each way);
/// * **bytes** = `messages × 8 × (payload f64 count)` — the payload is
///   everything a transfer ships, e.g. `d + 1` for a Push-Vector
///   (vector + weight), `2` for scalar Push-Sum (sum + weight), `d` for
///   a gradient-flow iterate exchange;
/// * **dropped** = messages lost in transit (async link-drop schedules,
///   randomized-engine drops). Dropped messages are *also* counted in
///   `messages`/`bytes` — they were sent; the field reports delivery
///   failures, not a discount.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GossipStats {
    /// Rounds executed.
    pub rounds: usize,
    /// Messages sent (directed edge traversals).
    pub messages: usize,
    /// Payload bytes (8 bytes per f64 shipped, including the weight).
    pub bytes: usize,
    /// Messages lost in transit (drop schedules; 0 for lossless engines).
    pub dropped: usize,
}

impl GossipStats {
    /// Accumulates another stats block.
    pub fn merge(&mut self, other: GossipStats) {
        self.rounds += other.rounds;
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.dropped += other.dropped;
    }
}
