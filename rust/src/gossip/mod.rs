//! Gossip consensus protocols: Push-Sum (Algorithm 1 of the paper) and its
//! vector extension Push-Vector (Kempe et al., FOCS 2003).
//!
//! Two execution engines are provided:
//!
//! * [`pushsum`] / [`pushvector`] — *deterministic* synchronous engines that
//!   move mass by `Bᵀ` each round ("Push-Sum deterministically simulates a
//!   random walk across G", paper §3). These are what the GADGET runner
//!   uses: exact, reproducible, and the object Theorem 1's ε₁/ε₂ bounds are
//!   stated about.
//! * [`randomized`] — the classical randomized engine where each node picks
//!   a single random neighbor per round and ships half its mass
//!   (`α_{t,i,j} = ½`). Used by the mixing benches to show both engines
//!   converge at the `O(τ_mix log 1/γ)` rate.
//!
//! Invariant maintained by every engine: **mass conservation** — the total
//! sum `Σᵢ sᵢ` and total weight `Σᵢ wᵢ` never change, which is exactly why
//! `sᵢ/wᵢ → (Σ s₀)/(Σ w₀) =` the true average at every node.

pub mod pushsum;
pub mod pushvector;
pub mod randomized;

pub use pushsum::PushSum;
pub use pushvector::PushVector;
pub use randomized::RandomizedGossip;

/// Communication accounting shared by the engines: one "message" is one
/// (sum, weight) or (vector, weight) payload sent over one edge.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GossipStats {
    /// Rounds executed.
    pub rounds: usize,
    /// Messages sent (edge traversals).
    pub messages: usize,
    /// Payload bytes (8 bytes per f64 shipped, including the weight).
    pub bytes: usize,
}

impl GossipStats {
    /// Accumulates another stats block.
    pub fn merge(&mut self, other: GossipStats) {
        self.rounds += other.rounds;
        self.messages += other.messages;
        self.bytes += other.bytes;
    }
}
