//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `benches/*.rs` binaries (declared with
//! `harness = false`); each uses the [`bench()`](fn@bench) helper for
//! warmup + repeated timing with mean/std/median reporting, and prints
//! paper-table rows via [`crate::util::TextTable`].

use crate::util::timer::{mean_std, median};
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_secs: f64,
    /// Sample std of per-iteration seconds.
    pub std_secs: f64,
    /// Median seconds per iteration.
    pub median_secs: f64,
    /// Fastest iteration.
    pub min_secs: f64,
}

impl BenchResult {
    /// `ops = items/iteration` → throughput in items/second (by median).
    pub fn throughput(&self, ops_per_iter: f64) -> f64 {
        ops_per_iter / self.median_secs.max(1e-12)
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            format_secs(self.median_secs),
            format_secs(self.mean_secs),
            format!("±{}", format_secs(self.std_secs)),
            self.iters
        )
    }
}

/// Formats seconds with an adaptive unit.
pub fn format_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Runs `f` for `warmup` untimed and `iters` timed repetitions.
///
/// The closure should return a value whose drop is trivial; use
/// [`std::hint::black_box`] inside to defeat DCE.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0, "bench: need at least one iteration");
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let (mean_secs, std_secs) = mean_std(&samples);
    let median_secs = median(&samples);
    let min_secs = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult { name: name.to_string(), iters, mean_secs, std_secs, median_secs, min_secs }
}

/// Times a single long-running case (end-to-end benches where one run is
/// already seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Prints the standard bench header matching [`BenchResult::summary`].
pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<40} {:>12} {:>12} {:>12}",
        "case", "median", "mean", "std"
    );
    println!("{}", "-".repeat(80));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut count = 0usize;
        let r = bench("noop", 2, 10, || {
            count += 1;
            std::hint::black_box(count);
        });
        assert_eq!(count, 12); // warmup + timed
        assert_eq!(r.iters, 10);
        assert!(r.mean_secs >= 0.0);
        assert!(r.min_secs <= r.median_secs);
    }

    #[test]
    fn format_units() {
        assert!(format_secs(2.5).ends_with('s'));
        assert!(format_secs(2.5e-3).ends_with("ms"));
        assert!(format_secs(2.5e-6).ends_with("µs"));
        assert!(format_secs(2.5e-10).ends_with("ns"));
    }

    #[test]
    fn throughput_uses_median() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_secs: 1.0,
            std_secs: 0.0,
            median_secs: 0.5,
            min_secs: 0.4,
        };
        assert!((r.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
