//! Wall-clock timing helpers used by the experiment harness and benches.

use std::time::{Duration, Instant};

/// A resettable stopwatch accumulating named laps.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Starts timing now.
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds since start (fractional).
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Restarts and returns the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Mean and sample standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

/// Median of a slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        let lap = sw.lap();
        assert!(lap >= 0.004, "lap {lap}");
        assert!(sw.secs() < lap); // restarted
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn mean_std_degenerate() {
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(mean_std(&[3.0]), (3.0, 0.0));
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }
}
