//! Small self-contained utilities: JSON emission, scoped temp dirs, timers,
//! aligned text tables, and CSV writing. The offline build has no serde /
//! tempfile / prettytable, so these substrates live in-tree.

pub mod json;
pub mod table;
pub mod tempdir;
pub mod timer;

pub use json::Json;
pub use table::TextTable;
pub use tempdir::TempDir;
pub use timer::Stopwatch;
