//! Small self-contained utilities: JSON emission, scoped temp dirs, timers,
//! aligned text tables, CSV writing, and read-only file memory mapping.
//! The offline build has no serde / tempfile / prettytable / memmap2, so
//! these substrates live in-tree.

pub mod json;
pub mod mmap;
pub mod table;
pub mod tempdir;
pub mod timer;

pub use json::Json;
pub use mmap::Mmap;
pub use table::TextTable;
pub use tempdir::TempDir;
pub use timer::Stopwatch;
