//! Read-only file memory mapping for the out-of-core data plane.
//!
//! The offline build has no `memmap2`/`libc` crates, so the unix path
//! declares the three syscalls it needs (`mmap`/`munmap`/`madvise`)
//! directly and wraps them in an RAII [`Mmap`]. Non-unix targets fall
//! back to reading the file into an 8-byte-aligned heap buffer — slower
//! and RAM-bound, but semantically identical, so the pack reader
//! ([`crate::data::pack`]) is portable while the paging win stays on the
//! platforms that can deliver it.

use crate::Result;
use anyhow::Context;
use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_long, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    /// `MADV_SEQUENTIAL` — shared by Linux and the BSDs.
    pub const MADV_SEQUENTIAL: c_int = 2;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: c_long,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// A read-only memory-mapped file (unix) or an aligned heap copy
/// (elsewhere). Dereferences to `&[u8]`; the mapping lives as long as
/// the value, and the bytes never change (the map is `MAP_PRIVATE` over
/// a file opened read-only), which is what makes sharing it across
/// worker threads sound.
pub struct Mmap {
    state: State,
}

enum State {
    #[cfg(unix)]
    Mapped { ptr: *mut std::os::raw::c_void, len: usize },
    /// Heap fallback: a `Vec<u64>` backing guarantees 8-byte alignment
    /// for the pack's widest section type.
    Heap { buf: Vec<u64>, len: usize },
}

// SAFETY: the mapping is read-only for its entire lifetime (PROT_READ,
// private, file opened read-only; the heap fallback is never written
// after construction), so shared references from any thread are fine.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Maps the whole file at `path` read-only.
    ///
    /// Zero-length files are represented without a syscall (mmap rejects
    /// length 0), so callers can rely on uniform error reporting from
    /// their own header validation instead.
    pub fn open(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        let len = usize::try_from(len)
            .map_err(|_| anyhow::anyhow!("{}: file too large to map", path.display()))?;
        if len == 0 {
            return Ok(Self { state: State::Heap { buf: Vec::new(), len: 0 } });
        }
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            // SAFETY: fd is a valid open file descriptor, len > 0, and we
            // request a fresh private read-only mapping at a kernel-chosen
            // address.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::MAP_FAILED {
                anyhow::bail!(
                    "mmap {} ({} bytes) failed: {}",
                    path.display(),
                    len,
                    std::io::Error::last_os_error()
                );
            }
            // Training scans shards front to back; tell the kernel so
            // readahead works for us. Purely advisory — ignore failures.
            // SAFETY: ptr/len describe the mapping we just created.
            unsafe {
                let _ = sys::madvise(ptr, len, sys::MADV_SEQUENTIAL);
            }
            Ok(Self { state: State::Mapped { ptr, len } })
        }
        #[cfg(not(unix))]
        {
            use std::io::Read;
            let mut buf = vec![0u64; (len + 7) / 8];
            // SAFETY: u64 → u8 reinterpretation of an owned, fully
            // initialized buffer; lengths match the allocation.
            let bytes = unsafe {
                std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len)
            };
            let mut file = file;
            file.read_exact(bytes)
                .with_context(|| format!("read {}", path.display()))?;
            Ok(Self { state: State::Heap { buf, len } })
        }
    }

    /// The mapped bytes.
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        match &self.state {
            #[cfg(unix)]
            // SAFETY: ptr/len describe a live read-only mapping owned by
            // self; the borrow ties the slice to the mapping's lifetime.
            State::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr as *const u8, *len)
            },
            State::Heap { buf, len } => {
                // SAFETY: reinterpreting the owned u64 buffer's first
                // `len` bytes; the allocation is at least that large.
                unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len) }
            }
        }
    }

    /// Mapped length in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        match &self.state {
            #[cfg(unix)]
            State::Mapped { len, .. } => *len,
            State::Heap { len, .. } => *len,
        }
    }

    /// True for a zero-length mapping.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let State::Mapped { ptr, len } = self.state {
            // SAFETY: unmapping the exact region mmap returned; the value
            // is being dropped so no borrow of the bytes can outlive this.
            unsafe {
                let _ = sys::munmap(ptr, len);
            }
        }
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.bytes()
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mmap({} bytes)", self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_file_contents() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("blob.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&p, &payload).unwrap();
        let m = Mmap::open(&p).unwrap();
        assert_eq!(m.len(), payload.len());
        assert_eq!(&m[..], &payload[..]);
        assert!(!m.is_empty());
    }

    #[test]
    fn empty_file_maps_empty() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("empty.bin");
        std::fs::write(&p, b"").unwrap();
        let m = Mmap::open(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), b"");
    }

    #[test]
    fn missing_file_errors() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("no-such-file");
        assert!(Mmap::open(&p).is_err());
    }

    #[test]
    fn shared_across_threads() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("shared.bin");
        std::fs::write(&p, vec![7u8; 4096]).unwrap();
        let m = std::sync::Arc::new(Mmap::open(&p).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || m.iter().map(|&b| b as u64).sum::<u64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * 4096);
        }
    }
}
