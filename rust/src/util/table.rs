//! Aligned plain-text tables — how the experiment harness prints the
//! paper's table rows, and a small CSV writer for the figure series.

/// A simple column-aligned text table.
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    /// Creates a table with the given header.
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Appends a row (must match the header length).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "TextTable: column count mismatch");
        self.rows.push(cells);
        self
    }

    /// Renders with column alignment and a separator under the header.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let emit = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(c);
                if i + 1 < ncol {
                    for _ in c.chars().count()..widths[i] + 2 {
                        out.push(' ');
                    }
                }
            }
            out.push('\n');
        };
        emit(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            emit(&mut out, row);
        }
        out
    }

    /// Renders as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Formats `mean (±std)` like the paper's tables.
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{mean:.decimals$} (±{std:.decimals$})")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(&["Dataset", "Acc"]);
        t.row(vec!["adult".into(), "77.04".into()]);
        t.row(vec!["ccat-long-name".into(), "84.99".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Dataset"));
        assert!(lines[3].starts_with("ccat-long-name"));
        // "Acc" column aligned: both data rows have the value at same offset
        let off2 = lines[2].find("77.04").unwrap();
        let off3 = lines[3].find("84.99").unwrap();
        assert_eq!(off2, off3);
    }

    #[test]
    fn csv_escaping() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(77.041, 0.034, 2), "77.04 (±0.03)");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn bad_row_panics() {
        TextTable::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }
}
