//! Minimal JSON document builder (emission only).
//!
//! Experiment reports and the artifact manifest reader need structured
//! output/input; with serde unavailable offline, this module provides an
//! explicit value tree with a compact serializer and a small recursive
//! parser (objects, arrays, strings, numbers, bools, null) sufficient for
//! `artifacts/manifest.json` and `results/*.json`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// Boolean.
    Bool(bool),
    /// All numbers are f64 (adequate for our manifests/reports).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order for reproducible files.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Convenience object constructor.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience array-of-numbers constructor.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Object field accessor.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// usize accessor.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serializes compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serializes with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parses a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    // Integer-valued floats render without a fraction — except -0.0,
    // whose sign the i64 cast would drop: "-0" parses back to -0.0, so
    // the text round trip stays bitwise exact for every finite f64
    // (Display is shortest-round-trip) — the serve model artifacts rely
    // on this.
    if x.fract() == 0.0 && x.abs() < 1e15 && !(x == 0.0 && x.is_sign_negative()) {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut arr = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    arr.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(arr));
                        }
                        _ => return Err(format!("bad array at byte {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    map.insert(k, self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        _ => return Err(format!("bad object at byte {}", self.pos)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let doc = Json::obj(vec![
            ("name", Json::Str("gadget".into())),
            ("dims", Json::nums(&[1.0, 2.5, -3.0])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true)), ("none", Json::Null)])),
        ]);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn parses_pretty_output() {
        let doc = Json::obj(vec![("a", Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())]))]);
        let back = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn string_escapes() {
        let doc = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&doc.to_string()).unwrap(), doc);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn finite_floats_roundtrip_bitwise() {
        for x in [
            -0.0,
            0.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            1e300,
            -2.5e-17,
            0.1 + 0.2,
            1e15,
            -(2f64.powi(53)),
        ] {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {text} -> {back}");
        }
    }

    #[test]
    fn accessors() {
        let doc = Json::parse(r#"{"n": 3, "s": "hi", "a": [1, 2]}"#).unwrap();
        assert_eq!(doc.get("n").and_then(Json::as_usize), Some(3));
        assert_eq!(doc.get("s").and_then(Json::as_str), Some("hi"));
        assert_eq!(doc.get("a").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert_eq!(doc.get("missing"), None);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
