//! Scoped temporary directories (in-tree replacement for the `tempfile`
//! crate): unique path under `std::env::temp_dir()`, removed on drop.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory deleted when the guard drops.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Creates a fresh directory `gadget-<pid>-<n>` under the system tmp.
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("gadget-{}-{}", std::process::id(), n));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let kept_path;
        {
            let t = TempDir::new().unwrap();
            kept_path = t.path().to_path_buf();
            std::fs::write(t.path().join("f.txt"), "x").unwrap();
            assert!(kept_path.exists());
        }
        assert!(!kept_path.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
