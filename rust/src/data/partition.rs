//! Horizontal partitioning: splitting a dataset over the `m` network sites.
//!
//! The paper's setting (§3): `M = M₁ ∪ M₂ ∪ … ∪ M_m`, each site holding the
//! same feature space ("horizontal" = row-wise split) with approximately
//! equal shard sizes. The partitioner shuffles with a seeded RNG and deals
//! rows round-robin so shard sizes differ by at most one.

use super::Dataset;
use crate::rng::Rng;

/// Splits `ds` into `m` shards of near-equal size after a seeded shuffle.
///
/// # Panics
/// Panics if `m == 0` or `m > ds.len()`.
pub fn horizontal_split(ds: &Dataset, m: usize, seed: u64) -> Vec<Dataset> {
    assert!(m > 0, "horizontal_split: m must be positive");
    assert!(m <= ds.len(), "horizontal_split: more shards than samples");
    let mut order: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut order);

    let mut shards: Vec<(Vec<_>, Vec<_>)> = (0..m).map(|_| (Vec::new(), Vec::new())).collect();
    for (pos, &i) in order.iter().enumerate() {
        let s = pos % m;
        shards[s].0.push(ds.rows[i].clone());
        shards[s].1.push(ds.labels[i]);
    }
    shards
        .into_iter()
        .enumerate()
        .map(|(s, (rows, labels))| {
            Dataset::new(format!("{}-shard{}", ds.name, s), ds.dim, rows, labels)
        })
        .collect()
}

/// Splits into train/test with the given train fraction (seeded shuffle).
pub fn train_test_split(ds: &Dataset, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..=1.0).contains(&train_frac), "train_frac out of range");
    let mut order: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Rng::new(seed ^ 0xdead_beef);
    rng.shuffle(&mut order);
    let n_train = (ds.len() as f64 * train_frac).round() as usize;
    let take = |idx: &[usize], tag: &str| {
        Dataset::new(
            format!("{}-{}", ds.name, tag),
            ds.dim,
            idx.iter().map(|&i| ds.rows[i].clone()).collect(),
            idx.iter().map(|&i| ds.labels[i]).collect(),
        )
    };
    (take(&order[..n_train], "train"), take(&order[n_train..], "test"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SparseVec;

    fn ds(n: usize) -> Dataset {
        Dataset::new(
            "t",
            2,
            (0..n).map(|i| SparseVec::new(vec![0], vec![i as f32])).collect(),
            (0..n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect(),
        )
    }

    #[test]
    fn shard_sizes_balanced() {
        let shards = horizontal_split(&ds(10), 3, 0);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn shards_preserve_all_samples() {
        let base = ds(17);
        let shards = horizontal_split(&base, 4, 42);
        let mut seen: Vec<f32> =
            shards.iter().flat_map(|s| s.rows.iter().map(|r| r.values[0])).collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f32> = (0..17).map(|i| i as f32).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn split_is_seeded() {
        let base = ds(20);
        let a = horizontal_split(&base, 4, 1);
        let b = horizontal_split(&base, 4, 1);
        let c = horizontal_split(&base, 4, 2);
        assert_eq!(a[0].rows, b[0].rows);
        assert_ne!(a[0].rows, c[0].rows);
    }

    #[test]
    fn train_test_sizes() {
        let (tr, te) = train_test_split(&ds(10), 0.7, 0);
        assert_eq!(tr.len(), 7);
        assert_eq!(te.len(), 3);
    }

    #[test]
    #[should_panic(expected = "more shards than samples")]
    fn too_many_shards_panics() {
        horizontal_split(&ds(2), 3, 0);
    }
}
