//! Horizontal partitioning: splitting a dataset over the `m` network sites.
//!
//! The paper's setting (§3): `M = M₁ ∪ M₂ ∪ … ∪ M_m`, each site holding the
//! same feature space ("horizontal" = row-wise split) with approximately
//! equal shard sizes. The partitioner shuffles with a seeded RNG and deals
//! rows round-robin so shard sizes differ by at most one.

use super::Dataset;
use crate::rng::Rng;
use crate::Result;

/// Shared shard-count validation: `m` must be in `[1, rows]` so every
/// shard receives at least one sample. One rule for every split site
/// (the runner, churn, the Table-4 per-node baselines, the shard
/// stores) — callers used to enforce this individually, and a missed
/// check turned into a panic deep inside the round-robin deal.
pub fn validate_split(m: usize, rows: usize) -> Result<()> {
    anyhow::ensure!(m > 0, "partition: shard count m must be ≥ 1");
    anyhow::ensure!(
        m <= rows,
        "partition: more shards than samples (m = {m}, rows = {rows})"
    );
    Ok(())
}

/// Splits `ds` into `m` shards of near-equal size after a seeded shuffle.
///
/// Errors when `m == 0` or `m > ds.len()` (see [`validate_split`]).
pub fn horizontal_split(ds: &Dataset, m: usize, seed: u64) -> Result<Vec<Dataset>> {
    validate_split(m, ds.len())?;
    let mut order: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut order);

    let mut shards: Vec<(Vec<_>, Vec<_>)> = (0..m).map(|_| (Vec::new(), Vec::new())).collect();
    for (pos, &i) in order.iter().enumerate() {
        let s = pos % m;
        shards[s].0.push(ds.rows[i].clone());
        shards[s].1.push(ds.labels[i]);
    }
    Ok(shards
        .into_iter()
        .enumerate()
        .map(|(s, (rows, labels))| {
            Dataset::new(format!("{}-shard{}", ds.name, s), ds.dim, rows, labels)
        })
        .collect())
}

/// Splits into train/test with the given train fraction (seeded shuffle).
pub fn train_test_split(ds: &Dataset, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..=1.0).contains(&train_frac), "train_frac out of range");
    let mut order: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Rng::new(seed ^ 0xdead_beef);
    rng.shuffle(&mut order);
    let n_train = (ds.len() as f64 * train_frac).round() as usize;
    let take = |idx: &[usize], tag: &str| {
        Dataset::new(
            format!("{}-{}", ds.name, tag),
            ds.dim,
            idx.iter().map(|&i| ds.rows[i].clone()).collect(),
            idx.iter().map(|&i| ds.labels[i]).collect(),
        )
    };
    (take(&order[..n_train], "train"), take(&order[n_train..], "test"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SparseVec;

    fn ds(n: usize) -> Dataset {
        Dataset::new(
            "t",
            2,
            (0..n).map(|i| SparseVec::new(vec![0], vec![i as f32])).collect(),
            (0..n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect(),
        )
    }

    #[test]
    fn shard_sizes_balanced() {
        let shards = horizontal_split(&ds(10), 3, 0).unwrap();
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn shards_preserve_all_samples() {
        let base = ds(17);
        let shards = horizontal_split(&base, 4, 42).unwrap();
        let mut seen: Vec<f32> =
            shards.iter().flat_map(|s| s.rows.iter().map(|r| r.values[0])).collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f32> = (0..17).map(|i| i as f32).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn split_is_seeded() {
        let base = ds(20);
        let a = horizontal_split(&base, 4, 1).unwrap();
        let b = horizontal_split(&base, 4, 1).unwrap();
        let c = horizontal_split(&base, 4, 2).unwrap();
        assert_eq!(a[0].rows, b[0].rows);
        assert_ne!(a[0].rows, c[0].rows);
    }

    #[test]
    fn train_test_sizes() {
        let (tr, te) = train_test_split(&ds(10), 0.7, 0);
        assert_eq!(tr.len(), 7);
        assert_eq!(te.len(), 3);
    }

    #[test]
    fn degenerate_shard_counts_are_clean_errors() {
        // The shared validation turns the old caller-discipline panics
        // into uniform, descriptive errors at every split site.
        let err = horizontal_split(&ds(2), 3, 0).unwrap_err();
        assert!(err.to_string().contains("more shards than samples"), "{err}");
        let err0 = horizontal_split(&ds(2), 0, 0).unwrap_err();
        assert!(err0.to_string().contains("must be ≥ 1"), "{err0}");
        assert!(validate_split(1, 1).is_ok());
        assert!(validate_split(4, 4).is_ok());
        assert!(validate_split(5, 4).is_err());
        assert!(validate_split(0, 10).is_err());
        // m == rows: every shard gets exactly one sample
        let singles = horizontal_split(&ds(3), 3, 0).unwrap();
        assert!(singles.iter().all(|s| s.len() == 1));
    }
}
