//! The streaming data plane: shard storage behind one object-safe
//! [`ShardStore`] abstraction.
//!
//! The paper's motivating deployment is peer-to-peer — each site
//! "processes its local homogeneously partitioned data" that in a real
//! network *keeps arriving* while the anytime algorithm gossips. The
//! pre-refactor pipeline (`load_dataset` → `partition::horizontal_split`
//! before iteration 0) could not express that: every consumer assumed an
//! immutable, fixed-size shard. This module makes shard size a
//! first-class dynamic quantity:
//!
//! * [`ShardView`] — the borrowed, read-only row window every backend and
//!   solver iterates. Borrowing (instead of owning a `Dataset`) is what
//!   lets the same hot loop run over static and growing shards.
//! * [`StaticStore`] — wraps today's `horizontal_split` output. This is
//!   the **bitwise determinism reference**: training through it
//!   reproduces the pre-refactor trajectory exactly (same rows, same
//!   order, same RNG draw sequence), pinned by
//!   `rust/tests/store_equivalence.rs`.
//! * [`StreamingStore`] — per-node append buffers fed by a seeded
//!   arrival schedule over a held-out pool, or by tailing a
//!   line-delimited LIBSVM file. New rows are swapped in at the
//!   **ingestion boundary** between GADGET iterations
//!   ([`crate::coordinator::sched::GossipProtocol::ingest_boundary`]),
//!   so the per-step hot loop stays allocation-free and borrow-only;
//!   all append-side allocation happens at the boundary.
//!
//! Growing shards change the Push-Sum weights `nᵢ`: the runner re-reads
//! [`ShardStore::sizes_into`] after a non-empty ingest and passes the new
//! sizes to `PushVector::reset_weighted`, which rebuilds the mass state
//! as `(Σ nᵢwᵢ, Σ nᵢ)` from scratch each iteration — so the Theorem-1
//! weighted-average target tracks the *current* shard sizes exactly
//! (DESIGN.md §Streaming data plane has the re-weight rule).

use super::{partition, Dataset};
use crate::linalg::{RowRef, RowsView, SparseVec};
use crate::rng::Rng;
use crate::Result;
use anyhow::{bail, Context};
use std::collections::VecDeque;
use std::io::BufRead;
use std::sync::{Arc, Condvar, Mutex};

/// A borrowed, read-only window onto one node's current shard.
///
/// Everything a local learner needs — rows, labels, the feature
/// dimension — without ownership, so the same `StepContext` drives
/// static shards, streaming shards, memory-mapped pack windows
/// ([`super::pack::MmapStore`]) and plain `Dataset`s ([`Dataset::view`]).
/// Rows are a layout-agnostic [`RowsView`]: heap `SparseVec` slices and
/// zero-copy CSR windows present identically, so every consumer
/// downstream is out-of-core-ready.
#[derive(Clone, Copy, Debug)]
pub struct ShardView<'a> {
    /// Feature dimension (shared by every row).
    pub dim: usize,
    /// Feature vectors.
    pub rows: RowsView<'a>,
    /// Labels in {-1, +1}, aligned with `rows`.
    pub labels: &'a [i8],
}

impl<'a> ShardView<'a> {
    /// Number of samples currently visible through the view.
    #[inline]
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the view holds no samples.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Borrowing view of one sample (same convention as
    /// [`Dataset::sample`], but the row comes back as a zero-copy
    /// [`RowRef`]).
    #[inline]
    pub fn sample(&self, i: usize) -> (RowRef<'a>, f64) {
        (self.rows.row(i), self.labels[i] as f64)
    }
}

/// Object-safe shard storage: who holds the per-node data, and how it
/// grows.
///
/// The contract every implementation upholds:
///
/// * **append-only** — rows already visible through [`Self::shard`]
///   never change or reorder; ingestion may only extend the suffix.
///   This is what keeps the node-local RNG trajectory meaningful: a
///   sampled index refers to the same row forever.
/// * **boundary-only mutation** — [`Self::ingest`] is the only mutating
///   call, and callers invoke it strictly *between* iterations (never
///   while a scheduler dispatch borrows views). Views taken after the
///   boundary see the grown shard; the local-step hot path never
///   observes a mid-step size change.
/// * **determinism** — arrivals are a pure function of the construction
///   inputs (seed, schedule, source), never of wall clock or execution
///   interleaving, so `Parallel ≡ Sequential` extends to streaming runs
///   (`rust/tests/scheduler_equivalence.rs`).
pub trait ShardStore: Send + Sync {
    /// Number of node shards `m`.
    fn nodes(&self) -> usize;

    /// Feature dimension shared by every shard.
    fn dim(&self) -> usize;

    /// The node's current shard window.
    fn shard(&self, node: usize) -> ShardView<'_>;

    /// Current shard size `nᵢ`.
    fn shard_len(&self, node: usize) -> usize {
        self.shard(node).len()
    }

    /// Writes the current shard sizes as Push-Sum weights (`nᵢ` as f64)
    /// into `out` — what `reset_weighted` re-weights the mass with after
    /// a non-empty ingest.
    fn sizes_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.nodes(), "sizes_into: node count mismatch");
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.shard_len(i) as f64;
        }
    }

    /// The ingestion boundary: appends the next boundary's arrivals to
    /// the per-node buffers. Fills `added[i]` with the number of rows
    /// appended to node `i` (zeroing stale entries) and returns the
    /// total. Static stores return 0 unconditionally. Arrival pacing is
    /// store-internal (carry/cursor state advanced per call) — the
    /// caller's iteration counter is deliberately *not* an input; the
    /// "iteration 1 has no arrivals" rule lives in
    /// `GossipProtocol::ingest_boundary`, which simply skips the call.
    fn ingest(&mut self, added: &mut [usize]) -> Result<usize>;

    /// True when the stream can deliver no further rows — static stores
    /// always, streaming stores once the cap is reached, the pool is
    /// drained, or the tailed file sits at EOF. While this is `false`
    /// the drift-aware ε test vetoes convergence *network-wide*, so a
    /// fractional-rate run cannot terminate on a gap iteration (carry
    /// < 1 ⇒ zero arrivals that iteration) with rows still undelivered.
    fn stream_exhausted(&self) -> bool {
        true
    }
}

/// The static store: today's one-shot horizontal partition, wrapped.
/// Ingestion is a no-op; training through this store is bit-for-bit the
/// pre-refactor pipeline.
#[derive(Clone, Debug)]
pub struct StaticStore {
    shards: Vec<Dataset>,
    dim: usize,
}

impl StaticStore {
    /// Wraps pre-partitioned shards (they must agree on a feature
    /// dimension; [`Dataset`] construction already validated rows).
    pub fn from_shards(shards: Vec<Dataset>) -> Self {
        assert!(!shards.is_empty(), "StaticStore: need at least one shard");
        let dim = shards[0].dim;
        for s in &shards {
            assert_eq!(s.dim, dim, "StaticStore: shard dim mismatch");
        }
        Self { shards, dim }
    }

    /// Partitions `ds` into `m` shards with the seeded round-robin deal —
    /// exactly [`partition::horizontal_split`], wrapped.
    pub fn split(ds: &Dataset, m: usize, seed: u64) -> Result<Self> {
        Ok(Self::from_shards(partition::horizontal_split(ds, m, seed)?))
    }

    /// The node's shard as an owned-`Dataset` reference — for callers
    /// that need a `&Dataset` (e.g. `metrics::accuracy`) rather than the
    /// borrowed [`ShardView`] the training path uses.
    pub fn shard_data(&self, node: usize) -> &Dataset {
        &self.shards[node]
    }
}

impl ShardStore for StaticStore {
    fn nodes(&self) -> usize {
        self.shards.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn shard(&self, node: usize) -> ShardView<'_> {
        self.shards[node].view()
    }

    fn shard_len(&self, node: usize) -> usize {
        self.shards[node].len()
    }

    fn ingest(&mut self, added: &mut [usize]) -> Result<usize> {
        added.fill(0);
        Ok(0)
    }
}

/// How arriving rows are scheduled onto nodes (`[stream] schedule`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamSchedule {
    /// Round-robin assignment from a held-out arrival pool: exactly
    /// `rate` rows per iteration (fractional rates accumulate), dealt to
    /// nodes `0, 1, …, m−1, 0, …` — the homogeneous-arrival reference.
    Uniform,
    /// Seeded-random node assignment from the pool — arrival *counts*
    /// per node fluctuate, modelling uneven site traffic, but the
    /// sequence is a pure function of the seed.
    Random,
    /// Tail a line-delimited LIBSVM file: up to `rate` lines are
    /// consumed per iteration and dealt round-robin; EOF pauses
    /// ingestion until the file grows (real feed semantics).
    Tail(String),
}

impl std::str::FromStr for StreamSchedule {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        if let Some(path) = s.strip_prefix("tail:") {
            if path.is_empty() {
                return Err("stream schedule: tail: needs a file path".into());
            }
            return Ok(Self::Tail(path.to_string()));
        }
        match s {
            "uniform" => Ok(Self::Uniform),
            "random" => Ok(Self::Random),
            other => Err(format!(
                "unknown stream schedule {other:?} (uniform | random | tail:<file>)"
            )),
        }
    }
}

impl std::fmt::Display for StreamSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Uniform => f.write_str("uniform"),
            Self::Random => f.write_str("random"),
            Self::Tail(p) => write!(f, "tail:{p}"),
        }
    }
}

/// Why an [`ArrivalQueue`] push was refused (the rows come back so the
/// transport can answer on the still-open connection — never a silent
/// drop).
#[derive(Debug)]
pub enum ArrivalPushError {
    /// The buffer is at capacity — the sender should retry after the
    /// next ingestion boundary drains it (HTTP: `503` + `Retry-After`).
    Full(Vec<(SparseVec, i8)>),
    /// The queue is closed — the training run is draining/terminating.
    Closed(Vec<(SparseVec, i8)>),
}

struct ArrivalInner {
    rows: VecDeque<(SparseVec, i8)>,
    closed: bool,
    /// Rows ever admitted (monotonic; survives draining).
    accepted: usize,
}

/// The network-side arrival buffer behind `train --http-ingest`: a
/// bounded, thread-safe staging area between the HTTP front end (any
/// thread, any time) and the training loop (which drains it **only** at
/// [`crate::coordinator::sched::GossipProtocol::ingest_boundary`], via
/// [`StreamingStore`]'s source hookup). The bound is the backpressure
/// seam: a full buffer refuses the batch and returns it, so the
/// transport answers `503` + `Retry-After` instead of buffering without
/// limit or dropping rows on the floor.
///
/// Admission is all-or-nothing per batch — a request's rows either all
/// enter the stream or none do, so a `503` can honestly mean "resend
/// everything".
pub struct ArrivalQueue {
    inner: Mutex<ArrivalInner>,
    /// Signalled on admission and on close — the training loop parks on
    /// this between boundaries while the feed is open but idle.
    arrivals: Condvar,
    cap: usize,
    dim: usize,
}

impl ArrivalQueue {
    /// A queue staging at most `cap` rows (≥ 1) for a stream training at
    /// feature dimension `dim`.
    pub fn bounded(cap: usize, dim: usize) -> Arc<Self> {
        assert!(cap >= 1, "ArrivalQueue: capacity must be ≥ 1");
        Arc::new(Self {
            inner: Mutex::new(ArrivalInner {
                rows: VecDeque::new(),
                closed: false,
                accepted: 0,
            }),
            arrivals: Condvar::new(),
            cap,
            dim,
        })
    }

    /// The training feature dimension rows must fit (transports validate
    /// per row *before* pushing so errors can name the input line).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Admits `rows` atomically, or returns them all when capacity or
    /// admission is gone. Never blocks, never partially admits.
    pub fn push_batch(
        &self,
        rows: Vec<(SparseVec, i8)>,
    ) -> std::result::Result<(), ArrivalPushError> {
        let mut inner = self.inner.lock().expect("ArrivalQueue poisoned");
        if inner.closed {
            return Err(ArrivalPushError::Closed(rows));
        }
        if inner.rows.len() + rows.len() > self.cap {
            return Err(ArrivalPushError::Full(rows));
        }
        inner.accepted += rows.len();
        inner.rows.extend(rows);
        self.arrivals.notify_all();
        Ok(())
    }

    /// Blocks until at least one row is staged or the feed closes;
    /// returns immediately when either already holds. This is the
    /// interactive run's boundary pacing: an HTTP-fed training loop
    /// parks here between boundaries, so iterations are spent on
    /// arrivals (and on the post-close run to convergence) instead of
    /// burning the whole `max_iterations` budget in the milliseconds
    /// before the first request can land.
    pub fn wait_arrival_or_close(&self) {
        let mut inner = self.inner.lock().expect("ArrivalQueue poisoned");
        while inner.rows.is_empty() && !inner.closed {
            inner = self.arrivals.wait(inner).expect("ArrivalQueue poisoned");
        }
    }

    /// Takes the oldest staged row, if any. Non-blocking — the ingestion
    /// boundary drains what is there and moves on; rows landing a moment
    /// later wait for the next boundary (boundary-only mutation).
    fn pop(&self) -> Option<(SparseVec, i8)> {
        self.inner.lock().expect("ArrivalQueue poisoned").rows.pop_front()
    }

    /// Stops admissions (staged rows still drain). This is the stream's
    /// end-of-feed signal: once closed *and* drained the store reports
    /// [`ShardStore::stream_exhausted`], lifting the network-wide
    /// convergence veto so the run can terminate. Idempotent.
    pub fn close(&self) {
        self.inner.lock().expect("ArrivalQueue poisoned").closed = true;
        self.arrivals.notify_all();
    }

    /// True once [`Self::close`] has run.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("ArrivalQueue poisoned").closed
    }

    /// Currently staged (admitted, not yet drained) rows.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("ArrivalQueue poisoned").rows.len()
    }

    /// True when nothing is staged.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rows ever admitted (monotonic — unaffected by draining).
    pub fn accepted(&self) -> usize {
        self.inner.lock().expect("ArrivalQueue poisoned").accepted
    }

    /// Closed *and* drained — nothing more can ever arrive.
    fn exhausted(&self) -> bool {
        let inner = self.inner.lock().expect("ArrivalQueue poisoned");
        inner.closed && inner.rows.is_empty()
    }
}

/// Where arriving rows come from.
enum StreamSource {
    /// A held-out pool, pre-ordered at construction; rows are stored
    /// reversed so consumption is an O(1) `pop` with no clones.
    Pool { rows: Vec<SparseVec>, labels: Vec<i8> },
    /// A line-delimited LIBSVM file consumed incrementally. `at_eof`
    /// remembers whether the most recent read attempt hit EOF — the
    /// "currently dried up" signal for [`ShardStore::stream_exhausted`]
    /// (cleared again the moment a grown file delivers a row).
    Tail {
        reader: std::io::BufReader<std::fs::File>,
        path: String,
        line: usize,
        at_eof: bool,
    },
    /// A live network arrival buffer (`train --http-ingest`): rows staged
    /// by the HTTP front end, drained here at the ingestion boundary.
    /// Arrival *timing* is inherently wall-clock (like a concurrently
    /// written tail file), so HTTP-fed runs sit outside the bitwise
    /// determinism contracts; everything after admission — assignment,
    /// re-weighting, the training trajectory given the arrivals — stays
    /// deterministic.
    Http(Arc<ArrivalQueue>),
}

impl StreamSource {
    /// Produces the next arriving row, or `None` when the source is
    /// (currently) exhausted. `dim` bounds the admissible feature
    /// indices of tailed rows.
    fn next_row(&mut self, dim: usize) -> Result<Option<(SparseVec, i8)>> {
        match self {
            Self::Pool { rows, labels } => match (rows.pop(), labels.pop()) {
                (Some(r), Some(y)) => Ok(Some((r, y))),
                _ => Ok(None),
            },
            // Dimension was validated at admission (the transport knows
            // the input line); an over-dim row here is a programming
            // error, caught by the shard append's own invariants.
            Self::Http(queue) => Ok(queue.pop()),
            Self::Tail { reader, path, line, at_eof } => {
                let mut buf = String::new();
                loop {
                    buf.clear();
                    let n = reader
                        .read_line(&mut buf)
                        .with_context(|| format!("tail {path}"))?;
                    if n == 0 {
                        // EOF: pause — a later ingest re-reads, picking up
                        // appended lines (the buffered reader issues a
                        // fresh read once its buffer is drained).
                        *at_eof = true;
                        return Ok(None);
                    }
                    if !buf.ends_with('\n') {
                        // Partial final line: a concurrent writer is mid-
                        // append (the feed's normal case). Parsing the
                        // prefix would train on a silently truncated
                        // value and choke on the remainder next read —
                        // rewind and pause until the newline lands.
                        reader
                            .seek_relative(-(n as i64))
                            .with_context(|| format!("tail rewind {path}"))?;
                        *at_eof = true;
                        return Ok(None);
                    }
                    *at_eof = false;
                    *line += 1;
                    let trimmed = buf.trim();
                    if trimmed.is_empty() || trimmed.starts_with('#') {
                        continue;
                    }
                    let (y, row) = super::libsvm::parse_line(trimmed)
                        .with_context(|| format!("{path}:{line}"))?;
                    if row.min_dim() > dim {
                        // min_dim is max index + 1 — report it as the
                        // dimension the row *requires*, not as an index.
                        bail!(
                            "{path}:{line}: row requires feature dimension {} \
                             but the stream trains at dimension {dim}",
                            row.min_dim()
                        );
                    }
                    return Ok(Some((row, y)));
                }
            }
        }
    }
}

/// The streaming store: per-node append buffers plus a seeded arrival
/// process. Construction pre-reserves the append buffers for the
/// expected arrival volume; `ingest` only ever extends the row suffix.
pub struct StreamingStore {
    shards: Vec<Dataset>,
    dim: usize,
    source: StreamSource,
    /// Seeded node-assignment stream (used by [`StreamSchedule::Random`]).
    rng: Rng,
    random_assign: bool,
    /// Round-robin cursor for uniform/tail assignment.
    next_node: usize,
    /// Network-wide expected arrivals per iteration.
    rate: f64,
    /// Fractional-arrival accumulator (`rate = 0.5` ⇒ one row every
    /// other iteration).
    carry: f64,
    /// Total-ingest cap (`0` = unlimited).
    max_rows: usize,
    ingested: usize,
}

impl StreamingStore {
    fn base(
        initial: Vec<Dataset>,
        source: StreamSource,
        rate: f64,
        max_rows: usize,
        random_assign: bool,
        seed: u64,
        expected_total: usize,
    ) -> Result<Self> {
        anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "streaming store: rate must be positive and finite (got {rate})"
        );
        anyhow::ensure!(!initial.is_empty(), "streaming store: need at least one shard");
        let dim = initial[0].dim;
        for (i, s) in initial.iter().enumerate() {
            anyhow::ensure!(s.dim == dim, "streaming store: shard {i} dim mismatch");
            anyhow::ensure!(!s.is_empty(), "streaming store: initial shard {i} is empty");
        }
        let mut shards = initial;
        // Reserve the append buffers up front: round-robin assignment
        // needs exactly ⌈total/m⌉ extra slots per node; random
        // assignment may exceed that on some nodes, where Vec's
        // amortized doubling takes over (still boundary-time, never
        // hot-loop allocation).
        let m = shards.len();
        let budget = if max_rows > 0 { expected_total.min(max_rows) } else { expected_total };
        let per_node = (budget + m - 1) / m;
        for s in shards.iter_mut() {
            s.rows.reserve(per_node);
            s.labels.reserve(per_node);
        }
        Ok(Self {
            shards,
            dim,
            source,
            rng: Rng::new(seed ^ 0x57f3_a11f),
            random_assign,
            next_node: 0,
            rate,
            carry: 0.0,
            max_rows,
            ingested: 0,
        })
    }

    /// A store fed from a held-out `pool` of future arrivals (rows are
    /// consumed in `pool` order). `random_assign` selects the
    /// [`StreamSchedule::Random`] node assignment; otherwise round-robin.
    pub fn from_pool(
        initial: Vec<Dataset>,
        pool: Dataset,
        rate: f64,
        max_rows: usize,
        random_assign: bool,
        seed: u64,
    ) -> Result<Self> {
        anyhow::ensure!(
            !pool.is_empty(),
            "streaming store: empty arrival pool — a streaming run that can \
             never ingest a row (lower [stream] initial or use a tail: schedule)"
        );
        if !initial.is_empty() {
            anyhow::ensure!(
                pool.dim == initial[0].dim,
                "streaming store: pool dim {} != shard dim {}",
                pool.dim,
                initial[0].dim
            );
        }
        let expected = pool.len();
        let mut rows = pool.rows;
        let mut labels = pool.labels;
        // Reverse so `pop()` yields the original pool order clone-free.
        rows.reverse();
        labels.reverse();
        Self::base(
            initial,
            StreamSource::Pool { rows, labels },
            rate,
            max_rows,
            random_assign,
            seed,
            expected,
        )
    }

    /// A store fed by tailing the line-delimited LIBSVM file at `path`;
    /// assignment is round-robin. Lines must fit the training dimension.
    pub fn tail(
        initial: Vec<Dataset>,
        path: &str,
        rate: f64,
        max_rows: usize,
        seed: u64,
    ) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("open stream tail {path}"))?;
        let reader = std::io::BufReader::new(file);
        // Reservation estimate: one iteration's worth per node; the tail
        // length is unknowable up front.
        let est = rate.ceil() as usize;
        Self::base(
            initial,
            StreamSource::Tail { reader, path: path.to_string(), line: 0, at_eof: false },
            rate,
            max_rows,
            false,
            seed,
            est,
        )
    }

    /// A store fed by a live [`ArrivalQueue`] (`train --http-ingest`);
    /// assignment is round-robin. `rate = 0` means "drain everything
    /// staged at each boundary": the effective per-iteration quota is
    /// [`Self::DRAIN_ALL_RATE`] — a finite value exact in the f64 carry
    /// arithmetic (`carry += r; carry -= ⌊carry⌋` stays exactly 0), kept
    /// far above any plausible arrival burst, rather than an infinity
    /// that would poison the accumulator. A positive `rate` paces
    /// draining exactly like the pool schedules.
    pub fn http(
        initial: Vec<Dataset>,
        queue: Arc<ArrivalQueue>,
        rate: f64,
        max_rows: usize,
        seed: u64,
    ) -> Result<Self> {
        let dim = initial.first().map(|s| s.dim).unwrap_or(0);
        anyhow::ensure!(
            queue.dim() == dim,
            "streaming store: arrival queue dim {} != shard dim {dim}",
            queue.dim()
        );
        let rate = if rate == 0.0 { Self::DRAIN_ALL_RATE } else { rate };
        // Reservation estimate: one boundary's worth of the queue bound.
        let est = queue.cap;
        Self::base(initial, StreamSource::Http(queue), rate, max_rows, false, seed, est)
    }

    /// The effective rate standing in for "unpaced — drain the whole
    /// arrival buffer every boundary" (exactly representable in f64, so
    /// the fractional-rate carry stays identically zero).
    pub const DRAIN_ALL_RATE: f64 = 1e9;

    /// Rows ingested so far (across all nodes).
    pub fn ingested(&self) -> usize {
        self.ingested
    }
}

impl ShardStore for StreamingStore {
    fn nodes(&self) -> usize {
        self.shards.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn shard(&self, node: usize) -> ShardView<'_> {
        self.shards[node].view()
    }

    fn shard_len(&self, node: usize) -> usize {
        self.shards[node].len()
    }

    fn ingest(&mut self, added: &mut [usize]) -> Result<usize> {
        assert_eq!(added.len(), self.shards.len(), "ingest: node count mismatch");
        added.fill(0);
        self.carry += self.rate;
        let mut quota = self.carry as usize;
        self.carry -= quota as f64;
        if self.max_rows > 0 {
            quota = quota.min(self.max_rows.saturating_sub(self.ingested));
        }
        let m = self.shards.len();
        let mut total = 0usize;
        while total < quota {
            let (row, label) = match self.source.next_row(self.dim)? {
                Some(next) => next,
                None => break, // source exhausted (pool empty / tail at EOF)
            };
            let node = if self.random_assign {
                self.rng.below(m)
            } else {
                let n = self.next_node;
                self.next_node = (n + 1) % m;
                n
            };
            self.shards[node].rows.push(row);
            self.shards[node].labels.push(label);
            added[node] += 1;
            total += 1;
        }
        self.ingested += total;
        Ok(total)
    }

    fn stream_exhausted(&self) -> bool {
        if self.max_rows > 0 && self.ingested >= self.max_rows {
            return true;
        }
        match &self.source {
            StreamSource::Pool { rows, .. } => rows.is_empty(),
            // A tail is "dried up" while its last read sat at EOF; a
            // grown file flips this back at the next delivering ingest.
            StreamSource::Tail { at_eof, .. } => *at_eof,
            // A live queue can deliver until it is closed AND drained —
            // so an open HTTP feed vetoes convergence network-wide, and
            // `POST /shutdown` (which closes the queue) is what lets a
            // serving-while-training run terminate.
            StreamSource::Http(queue) => queue.exhausted(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(n: usize, dim: usize) -> Dataset {
        Dataset::new(
            "s",
            dim,
            (0..n).map(|i| SparseVec::new(vec![0], vec![i as f32])).collect(),
            (0..n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect(),
        )
    }

    fn split2(n: usize) -> Vec<Dataset> {
        partition::horizontal_split(&ds(n, 3), 2, 7).unwrap()
    }

    #[test]
    fn static_store_matches_horizontal_split_exactly() {
        let base = ds(11, 3);
        let shards = partition::horizontal_split(&base, 3, 42).unwrap();
        let store = StaticStore::split(&base, 3, 42).unwrap();
        assert_eq!(store.nodes(), 3);
        assert_eq!(store.dim(), 3);
        for i in 0..3 {
            let v = store.shard(i);
            let rows: Vec<SparseVec> = v.rows.iter().map(|r| r.to_owned()).collect();
            assert_eq!(rows, shards[i].rows, "node {i} rows");
            assert_eq!(v.labels, &shards[i].labels[..], "node {i} labels");
            assert_eq!(store.shard_len(i), shards[i].len());
            assert_eq!(store.shard_data(i).rows, shards[i].rows);
        }
        let mut sizes = vec![0.0; 3];
        store.sizes_into(&mut sizes);
        let want: Vec<f64> = shards.iter().map(|s| s.len() as f64).collect();
        assert_eq!(sizes, want);
    }

    #[test]
    fn static_ingest_is_a_noop() {
        let mut store = StaticStore::split(&ds(6, 3), 2, 1).unwrap();
        let before: Vec<usize> = (0..2).map(|i| store.shard_len(i)).collect();
        let mut added = vec![9usize; 2]; // stale values must be zeroed
        for _ in 1..5 {
            assert_eq!(store.ingest(&mut added).unwrap(), 0);
            assert_eq!(added, vec![0, 0]);
        }
        for (i, &b) in before.iter().enumerate() {
            assert_eq!(store.shard_len(i), b);
        }
    }

    #[test]
    fn uniform_schedule_deals_round_robin_at_rate() {
        let mut store =
            StreamingStore::from_pool(split2(4), ds(6, 3), 3.0, 0, false, 9).unwrap();
        let init: Vec<usize> = (0..2).map(|i| store.shard_len(i)).collect();
        let mut added = vec![0usize; 2];
        // iteration 1: 3 arrivals, round-robin 0,1,0
        assert_eq!(store.ingest(&mut added).unwrap(), 3);
        assert_eq!(added, vec![2, 1]);
        // iteration 2: 3 more, cursor continues at node 1: 1,0,1
        assert_eq!(store.ingest(&mut added).unwrap(), 3);
        assert_eq!(added, vec![1, 2]);
        // pool exhausted
        assert_eq!(store.ingest(&mut added).unwrap(), 0);
        assert_eq!(store.ingested(), 6);
        assert_eq!(store.shard_len(0), init[0] + 3);
        assert_eq!(store.shard_len(1), init[1] + 3);
    }

    #[test]
    fn arrivals_preserve_the_existing_prefix() {
        // Append-only contract: rows visible before an ingest are
        // bitwise unchanged after it.
        let mut store =
            StreamingStore::from_pool(split2(4), ds(5, 3), 2.0, 0, false, 3).unwrap();
        let before: Vec<Vec<SparseVec>> = (0..2)
            .map(|i| store.shard(i).rows.iter().map(|r| r.to_owned()).collect())
            .collect();
        let mut added = vec![0usize; 2];
        store.ingest(&mut added).unwrap();
        for i in 0..2 {
            let now = store.shard(i);
            let prefix: Vec<SparseVec> =
                now.rows.iter().take(before[i].len()).map(|r| r.to_owned()).collect();
            assert_eq!(prefix, before[i], "node {i} prefix");
        }
    }

    #[test]
    fn pool_rows_arrive_in_pool_order() {
        let pool = ds(4, 3); // values 0,1,2,3 at index 0
        let mut store =
            StreamingStore::from_pool(split2(4), pool, 4.0, 0, false, 1).unwrap();
        let mut added = vec![0usize; 2];
        store.ingest(&mut added).unwrap();
        // round-robin: node0 gets pool rows 0,2; node1 gets 1,3 — appended
        // after the two initial rows each node holds.
        let tail0: Vec<f32> =
            store.shard(0).rows.iter().skip(2).map(|r| r.values[0]).collect();
        let tail1: Vec<f32> =
            store.shard(1).rows.iter().skip(2).map(|r| r.values[0]).collect();
        assert_eq!(tail0, vec![0.0, 2.0]);
        assert_eq!(tail1, vec![1.0, 3.0]);
    }

    #[test]
    fn fractional_rate_accumulates() {
        let mut store =
            StreamingStore::from_pool(split2(4), ds(3, 3), 0.5, 0, false, 2).unwrap();
        let mut added = vec![0usize; 2];
        assert_eq!(store.ingest(&mut added).unwrap(), 0); // carry 0.5
        assert_eq!(store.ingest(&mut added).unwrap(), 1); // carry 1.0 → 1 row
        assert_eq!(store.ingest(&mut added).unwrap(), 0);
        assert_eq!(store.ingest(&mut added).unwrap(), 1);
    }

    #[test]
    fn max_rows_caps_total_ingestion() {
        let mut store =
            StreamingStore::from_pool(split2(4), ds(10, 3), 4.0, 5, false, 2).unwrap();
        let mut added = vec![0usize; 2];
        assert_eq!(store.ingest(&mut added).unwrap(), 4);
        assert_eq!(store.ingest(&mut added).unwrap(), 1); // cap reached
        assert_eq!(store.ingest(&mut added).unwrap(), 0);
        assert_eq!(store.ingested(), 5);
    }

    #[test]
    fn random_assignment_is_seeded_and_deterministic() {
        let run = |seed: u64| -> Vec<usize> {
            let mut store =
                StreamingStore::from_pool(split2(4), ds(12, 3), 4.0, 0, true, seed)
                    .unwrap();
            let mut added = vec![0usize; 2];
            for _ in 2..6 {
                store.ingest(&mut added).unwrap();
            }
            (0..2).map(|i| store.shard_len(i)).collect()
        };
        assert_eq!(run(5), run(5));
        // total is schedule-invariant even when the split is not
        assert_eq!(run(5).iter().sum::<usize>(), run(6).iter().sum::<usize>());
    }

    #[test]
    fn tail_source_consumes_lines_and_resumes_after_eof() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("feed.libsvm");
        std::fs::write(&p, "+1 1:1.0\n-1 2:2.0\n").unwrap();
        let mut store =
            StreamingStore::tail(split2(4), p.to_str().unwrap(), 4.0, 0, 3).unwrap();
        let mut added = vec![0usize; 2];
        // only 2 lines available although the rate allows 4
        assert_eq!(store.ingest(&mut added).unwrap(), 2);
        assert_eq!(store.ingest(&mut added).unwrap(), 0); // EOF pauses
        // the feed grows; the next boundary picks the new line up
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        writeln!(f, "+1 3:0.5").unwrap();
        drop(f);
        assert_eq!(store.ingest(&mut added).unwrap(), 1);
        assert_eq!(store.ingested(), 3);
    }

    #[test]
    fn tail_defers_partial_final_line_until_terminated() {
        // A concurrent feed writer may be mid-append: an unterminated
        // final line must be left in place (rewind + pause), not parsed
        // as a truncated row.
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("feed.libsvm");
        std::fs::write(&p, "+1 1:1\n-1 2:0.2").unwrap(); // 2nd line unterminated
        let mut store =
            StreamingStore::tail(split2(4), p.to_str().unwrap(), 4.0, 0, 3).unwrap();
        let mut added = vec![0usize; 2];
        assert_eq!(store.ingest(&mut added).unwrap(), 1); // only the complete line
        assert!(store.stream_exhausted());
        // the writer finishes the line (value becomes 0.25, plus 3:1)
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        write!(f, "5 3:1\n").unwrap();
        drop(f);
        assert_eq!(store.ingest(&mut added).unwrap(), 1);
        let v = store.shard(1); // round-robin: node 0 got line 1, node 1 line 2
        let last = v.rows.row(v.len() - 1);
        assert_eq!(last.indices, &[1u32, 2][..]);
        assert_eq!(last.values, &[0.25f32, 1.0][..]);
        assert_eq!(v.labels[v.len() - 1], -1);
    }

    #[test]
    fn tail_rejects_rows_beyond_training_dim() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("bad.libsvm");
        std::fs::write(&p, "+1 9:1.0\n").unwrap(); // dim 9 > shard dim 3
        let mut store =
            StreamingStore::tail(split2(4), p.to_str().unwrap(), 1.0, 0, 3).unwrap();
        let mut added = vec![0usize; 2];
        let err = store.ingest(&mut added).unwrap_err();
        assert!(err.to_string().contains("requires feature dimension 9"), "{err}");
    }

    #[test]
    fn stream_exhaustion_tracks_pool_cap_and_tail_eof() {
        let mut added = vec![0usize; 2];
        // pool: live until drained
        let mut store =
            StreamingStore::from_pool(split2(4), ds(3, 3), 2.0, 0, false, 1).unwrap();
        assert!(!store.stream_exhausted());
        store.ingest(&mut added).unwrap(); // 2 of 3 rows
        assert!(!store.stream_exhausted());
        store.ingest(&mut added).unwrap(); // last row
        assert!(store.stream_exhausted());
        // cap: exhausted the moment max_rows is reached, even with pool
        // rows remaining
        let mut capped =
            StreamingStore::from_pool(split2(4), ds(9, 3), 2.0, 2, false, 1).unwrap();
        assert!(!capped.stream_exhausted());
        capped.ingest(&mut added).unwrap();
        assert!(capped.stream_exhausted());
        // static: always exhausted (there is no stream)
        let st = StaticStore::split(&ds(6, 3), 2, 1).unwrap();
        assert!(st.stream_exhausted());
        // tail: dries up at EOF, revives when the file grows
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("t.libsvm");
        std::fs::write(&p, "+1 1:1\n").unwrap();
        let mut tail =
            StreamingStore::tail(split2(4), p.to_str().unwrap(), 2.0, 0, 1).unwrap();
        assert!(!tail.stream_exhausted()); // not yet probed
        tail.ingest(&mut added).unwrap(); // 1 row, then EOF
        assert!(tail.stream_exhausted());
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        writeln!(f, "-1 2:1").unwrap();
        drop(f);
        // the next boundary delivers the new row (and probes EOF again
        // inside the same quota, so the flag ends up dry once more)
        assert_eq!(tail.ingest(&mut added).unwrap(), 1);
        assert!(tail.stream_exhausted());
    }

    fn labeled(v: f32, y: i8) -> (SparseVec, i8) {
        (SparseVec::new(vec![0], vec![v]), y)
    }

    #[test]
    fn arrival_queue_admits_all_or_nothing_and_reports_overflow() {
        let q = ArrivalQueue::bounded(3, 3);
        assert_eq!(q.dim(), 3);
        q.push_batch(vec![labeled(1.0, 1), labeled(2.0, -1)]).unwrap();
        assert_eq!((q.len(), q.accepted()), (2, 2));
        // a 2-row batch against 1 free slot is refused whole — a 503 can
        // honestly mean "resend everything"
        match q.push_batch(vec![labeled(3.0, 1), labeled(4.0, 1)]) {
            Err(ArrivalPushError::Full(rows)) => assert_eq!(rows.len(), 2),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!((q.len(), q.accepted()), (2, 2));
        q.push_batch(vec![labeled(3.0, 1)]).unwrap();
        q.close();
        match q.push_batch(vec![labeled(9.0, 1)]) {
            Err(ArrivalPushError::Closed(rows)) => assert_eq!(rows.len(), 1),
            other => panic!("expected Closed, got {other:?}"),
        }
        // staged rows survive close and drain in admission order
        assert_eq!(q.pop().unwrap().0.values[0], 1.0);
        assert_eq!(q.accepted(), 3);
    }

    #[test]
    fn arrival_wait_parks_until_admission_or_close() {
        // staged rows: returns immediately
        let q = ArrivalQueue::bounded(4, 3);
        q.push_batch(vec![labeled(1.0, 1)]).unwrap();
        q.wait_arrival_or_close();
        // open + empty: parks until a concurrent push wakes it
        let q = ArrivalQueue::bounded(4, 3);
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                q.wait_arrival_or_close();
                q.len()
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push_batch(vec![labeled(2.0, -1)]).unwrap();
        assert_eq!(waiter.join().unwrap(), 1);
        // closed (even empty): returns immediately — the post-close
        // free-run must never park
        let q = ArrivalQueue::bounded(4, 3);
        q.close();
        q.wait_arrival_or_close();
    }

    #[test]
    fn http_rows_enter_shards_only_at_the_ingestion_boundary() {
        let queue = ArrivalQueue::bounded(16, 3);
        let mut store =
            StreamingStore::http(split2(4), Arc::clone(&queue), 0.0, 0, 7).unwrap();
        let before: Vec<usize> = (0..2).map(|i| store.shard_len(i)).collect();
        queue.push_batch(vec![labeled(1.0, 1), labeled(2.0, -1), labeled(3.0, 1)]).unwrap();
        // staged rows are invisible to every shard view until ingest runs
        for i in 0..2 {
            assert_eq!(store.shard_len(i), before[i]);
        }
        let mut added = vec![0usize; 2];
        // drain-all: the whole staged buffer lands in one boundary,
        // round-robin, and Σ added == Σ(nᵢ − nᵢ_before) exactly
        assert_eq!(store.ingest(&mut added).unwrap(), 3);
        assert_eq!(added, vec![2, 1]);
        let mut sizes = vec![0.0f64; 2];
        store.sizes_into(&mut sizes);
        assert_eq!(sizes[0], (before[0] + 2) as f64);
        assert_eq!(sizes[1], (before[1] + 1) as f64);
        assert!(queue.is_empty());
        // nothing staged ⇒ the next boundary is a no-op
        assert_eq!(store.ingest(&mut added).unwrap(), 0);
        assert_eq!(store.ingested(), 3);
    }

    #[test]
    fn http_paced_rate_drains_incrementally() {
        let queue = ArrivalQueue::bounded(16, 3);
        let mut store =
            StreamingStore::http(split2(4), Arc::clone(&queue), 2.0, 0, 7).unwrap();
        queue
            .push_batch(vec![labeled(1.0, 1), labeled(2.0, -1), labeled(3.0, 1)])
            .unwrap();
        let mut added = vec![0usize; 2];
        assert_eq!(store.ingest(&mut added).unwrap(), 2);
        assert_eq!(queue.len(), 1); // the rest waits for the next boundary
        assert_eq!(store.ingest(&mut added).unwrap(), 1);
    }

    #[test]
    fn http_stream_exhausts_only_when_closed_and_drained() {
        let queue = ArrivalQueue::bounded(8, 3);
        let mut store =
            StreamingStore::http(split2(4), Arc::clone(&queue), 0.0, 0, 7).unwrap();
        // open + empty: more rows may still arrive — convergence vetoed
        assert!(!store.stream_exhausted());
        queue.push_batch(vec![labeled(1.0, 1)]).unwrap();
        queue.close();
        // closed but staged: still not exhausted (a row is undelivered)
        assert!(!store.stream_exhausted());
        let mut added = vec![0usize; 2];
        assert_eq!(store.ingest(&mut added).unwrap(), 1);
        assert!(store.stream_exhausted());
    }

    #[test]
    fn http_store_rejects_queue_dim_mismatch() {
        let queue = ArrivalQueue::bounded(8, 5); // shards are dim 3
        assert!(StreamingStore::http(split2(4), queue, 0.0, 0, 7).is_err());
    }

    #[test]
    fn schedule_parses_and_displays() {
        assert_eq!("uniform".parse::<StreamSchedule>().unwrap(), StreamSchedule::Uniform);
        assert_eq!("random".parse::<StreamSchedule>().unwrap(), StreamSchedule::Random);
        assert_eq!(
            "tail:/tmp/x.libsvm".parse::<StreamSchedule>().unwrap(),
            StreamSchedule::Tail("/tmp/x.libsvm".into())
        );
        assert!("poisson".parse::<StreamSchedule>().is_err());
        assert!("tail:".parse::<StreamSchedule>().is_err());
        assert_eq!(StreamSchedule::Uniform.to_string(), "uniform");
        assert_eq!(
            StreamSchedule::Tail("a.txt".into()).to_string(),
            "tail:a.txt"
        );
    }

    #[test]
    fn invalid_rates_and_empty_shards_rejected() {
        assert!(StreamingStore::from_pool(split2(4), ds(2, 3), 0.0, 0, false, 1).is_err());
        assert!(
            StreamingStore::from_pool(split2(4), ds(2, 3), f64::NAN, 0, false, 1).is_err()
        );
        let mut bad = split2(4);
        bad[1] = Dataset { name: "e".into(), dim: 3, rows: vec![], labels: vec![] };
        assert!(StreamingStore::from_pool(bad, ds(2, 3), 1.0, 0, false, 1).is_err());
        // pool dim mismatch
        assert!(StreamingStore::from_pool(split2(4), ds(2, 5), 1.0, 0, false, 1).is_err());
    }

    #[test]
    fn view_of_dataset_matches_fields() {
        let d = ds(3, 3);
        let v = d.view();
        assert_eq!(v.len(), 3);
        assert!(!v.is_empty());
        assert_eq!(v.dim, 3);
        let (x, y) = v.sample(1);
        assert_eq!(x.values[0], 1.0);
        assert_eq!(y, -1.0);
    }
}
