//! Seeded synthetic stand-ins for the paper's evaluation corpora.
//!
//! We cannot redistribute RCV1/Reuters/UCI/MNIST inside this environment, so
//! each corpus is replaced by a generator matched on the *shape statistics*
//! the paper reports in Table 2 — training/test size, feature count,
//! sparsity and class balance — with a planted linear separator `w⋆` and a
//! calibrated label-flip rate, so that a well-tuned linear SVM reaches
//! roughly the paper's centralized accuracy and, crucially, the *relative*
//! comparisons (GADGET vs Pegasos vs SVM-SGD vs SVM-Perf) exercise the same
//! code paths on data of the same shape. See DESIGN.md §Substitutions.
//!
//! Generators are fully deterministic given `(spec, seed)` (xoshiro
//! substreams) and scale-invariant: `scale` shrinks N (never d), so tests
//! can run the same distributions in milliseconds.

use super::Dataset;
use crate::linalg::SparseVec;
use crate::rng::Rng;

/// Shape + difficulty description of a synthetic corpus.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Name, e.g. `"synthetic-ccat"`.
    pub name: String,
    /// Training-set size at scale 1.0.
    pub train_size: usize,
    /// Test-set size at scale 1.0.
    pub test_size: usize,
    /// Feature dimension.
    pub features: usize,
    /// Expected non-zeros per row (`density·features`); `0` ⇒ dense rows.
    pub nnz_per_row: usize,
    /// Label-noise rate: fraction of labels flipped after planting.
    pub noise: f64,
    /// Fraction of positive labels before noise.
    pub positive_rate: f64,
    /// Paper's λ for the dataset (Table 2).
    pub lambda: f64,
}

/// Paper Table 2 stand-ins. `nnz_per_row` for the sparse text corpora is set
/// from the published RCV1 statistics (~76 nnz/doc ⇒ 0.16% of 47k) and
/// comparable ratios for Reuters; dense corpora use `0`.
pub fn paper_specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "synthetic-adult".into(),
            train_size: 32561,
            test_size: 16281,
            features: 123,
            nnz_per_row: 14, // one-hot encoding of 14 census attributes
            noise: 0.20,     // adult is noisy: best linear ≈ 85%, pegasos-1step ≈ 70-80%
            positive_rate: 0.24,
            lambda: 3.07e-5,
        },
        DatasetSpec {
            name: "synthetic-ccat".into(),
            train_size: 781265,
            test_size: 23149,
            features: 47236,
            nnz_per_row: 76, // 0.16% sparsity from Table 2
            noise: 0.12,
            positive_rate: 0.47,
            lambda: 1e-4,
        },
        DatasetSpec {
            name: "synthetic-mnist".into(),
            train_size: 60000,
            test_size: 10000,
            features: 784,
            nnz_per_row: 150, // MNIST pixels are ~19% non-zero
            noise: 0.10,
            positive_rate: 0.099, // digit 0 vs rest
            lambda: 1.67e-5,
        },
        DatasetSpec {
            name: "synthetic-reuters".into(),
            train_size: 7770,
            test_size: 3299,
            features: 8315,
            nnz_per_row: 60,
            noise: 0.05,
            positive_rate: 0.09, // money-fx vs rest
            lambda: 1.29e-4,
        },
        DatasetSpec {
            name: "synthetic-usps".into(),
            train_size: 7329,
            test_size: 1969,
            features: 256,
            nnz_per_row: 0, // dense scans
            noise: 0.08,
            positive_rate: 0.17, // "0" vs rest
            lambda: 1.36e-4,
        },
        DatasetSpec {
            name: "synthetic-webspam".into(),
            train_size: 234500,
            test_size: 115500,
            features: 254,
            nnz_per_row: 90,
            noise: 0.18,
            positive_rate: 0.39,
            lambda: 1e-5,
        },
        DatasetSpec {
            name: "synthetic-gisette".into(),
            train_size: 6000,
            test_size: 1000,
            features: 5000,
            nnz_per_row: 0, // dense, the Table 5 "dense large-feature" case
            noise: 0.45,    // paper reports ≈55/50% accuracy — near-random
            positive_rate: 0.5,
            lambda: 1e-4,
        },
    ]
}

/// Looks a spec up by name (with or without the `synthetic-` prefix).
pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    let want = name.strip_prefix("synthetic-").unwrap_or(name);
    paper_specs()
        .into_iter()
        .find(|s| s.name.strip_prefix("synthetic-").unwrap_or(&s.name) == want)
}

/// A generated train/test pair plus the planted ground truth.
#[derive(Clone, Debug)]
pub struct SyntheticSplit {
    /// Training partition.
    pub train: Dataset,
    /// Test partition.
    pub test: Dataset,
    /// The planted separator (unit norm): `sign(⟨w⋆, x⟩)` recovers the
    /// pre-flip label with probability ≈ Φ(SNR).
    pub w_star: Vec<f64>,
}

/// Class-separation strength in noise-σ units (SNR of the planted margin).
/// 3σ puts the mixture Bayes error ≪ the label-flip floor, so `noise`
/// alone controls each dataset's accuracy ceiling.
const SIGNAL_SNR: f64 = 3.0;

/// Generates a train/test split from a spec.
///
/// `scale ∈ (0, 1]` shrinks the number of samples (minimum 32/16) while
/// keeping the feature space and difficulty fixed.
///
/// Mechanics — a two-component Gaussian mixture separable *through the
/// origin* (the paper's model has no intercept):
/// 1. draw a unit separator `w⋆`;
/// 2. per sample: plant `y = ±1` with `P(+1) = positive_rate`, pick
///    `nnz` active features, set
///    `x_j = (z_j + y·SNR·√(d/nnz)·w⋆_j)/√nnz`, `z_j ~ N(0,1)`,
///    so `⟨w⋆, x⟩ ≈ N(y·SNR/√d, 1/d)` — signal-to-noise `SNR` regardless
///    of dimension or sparsity, and `‖x‖₂ ≈ 1` like the paper's
///    normalized corpora;
/// 3. flip the label with probability `noise` — the accuracy ceiling is
///    `1 − noise` (tuned per dataset to land near the paper's Table 3/4
///    numbers).
pub fn generate(spec: &DatasetSpec, seed: u64, scale: f64) -> SyntheticSplit {
    assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
    let mut rng = Rng::new(seed ^ 0x9e3779b97f4a7c15);
    let d = spec.features;

    // Planted separator: dense gaussian, unit norm.
    let mut w_star: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let norm = crate::linalg::l2_norm(&w_star);
    for v in &mut w_star {
        *v /= norm;
    }
    let n_train = ((spec.train_size as f64 * scale) as usize).max(32);
    let n_test = ((spec.test_size as f64 * scale) as usize).max(16);

    let gen_part = |n: usize, rng: &mut Rng, tag: &str| {
        let mut rows = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let y_plant: i8 = if rng.flip(spec.positive_rate) { 1 } else { -1 };
            let row = sample_row(spec, d, y_plant, &w_star, rng);
            let mut y = y_plant;
            if rng.flip(spec.noise) {
                y = -y;
            }
            rows.push(row);
            labels.push(y);
        }
        Dataset::new(format!("{}-{}", spec.name, tag), d, rows, labels)
    };

    let train = gen_part(n_train, &mut rng, "train");
    let test = gen_part(n_test, &mut rng, "test");
    SyntheticSplit { train, test, w_star }
}

/// Draws one feature row: noise plus the class-mean shift along `w⋆`,
/// scaled so `‖x‖₂ ≈ 1` (keeps the Pegasos sub-gradient bound `c ≈ 1`).
fn sample_row(spec: &DatasetSpec, d: usize, y: i8, w_star: &[f64], rng: &mut Rng) -> SparseVec {
    let nnz = if spec.nnz_per_row == 0 { d } else { spec.nnz_per_row.min(d) };
    let idx: Vec<u32> =
        if nnz == d { (0..d as u32).collect() } else { rng.sorted_subset(d, nnz) };
    let inv = 1.0 / (nnz as f64).sqrt();
    let shift = y as f64 * SIGNAL_SNR * (d as f64 / nnz as f64).sqrt();
    let vals: Vec<f32> = idx
        .iter()
        .map(|&j| ((rng.normal() + shift * w_star[j as usize]) * inv) as f32)
        .collect();
    SparseVec::new(idx, vals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_cover_paper_table2() {
        let names: Vec<String> = paper_specs().iter().map(|s| s.name.clone()).collect();
        for want in ["adult", "ccat", "mnist", "reuters", "usps", "webspam", "gisette"] {
            assert!(names.iter().any(|n| n.contains(want)), "missing {want}");
        }
    }

    #[test]
    fn lookup_with_or_without_prefix() {
        assert!(spec_by_name("usps").is_some());
        assert!(spec_by_name("synthetic-usps").is_some());
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = spec_by_name("usps").unwrap();
        let a = generate(&spec, 7, 0.02);
        let b = generate(&spec, 7, 0.02);
        assert_eq!(a.train.rows, b.train.rows);
        assert_eq!(a.train.labels, b.train.labels);
        let c = generate(&spec, 8, 0.02);
        assert_ne!(a.train.labels, c.train.labels);
    }

    #[test]
    fn shape_statistics_match_spec() {
        let spec = spec_by_name("reuters").unwrap();
        let s = generate(&spec, 1, 0.05);
        assert_eq!(s.train.dim, 8315);
        assert_eq!(s.train.len(), (7770.0 * 0.05) as usize);
        assert_eq!(s.test.len(), (3299.0 * 0.05) as usize);
        // sparse rows: ~60 nnz each
        let mean_nnz = s.train.total_nnz() as f64 / s.train.len() as f64;
        assert!((mean_nnz - 60.0).abs() < 1.0, "mean nnz {mean_nnz}");
    }

    #[test]
    fn dense_spec_generates_dense_rows() {
        let spec = spec_by_name("usps").unwrap();
        let s = generate(&spec, 1, 0.01);
        assert!(s.train.rows.iter().all(|r| r.nnz() == 256));
    }

    #[test]
    fn rows_are_unit_scaled() {
        let spec = spec_by_name("reuters").unwrap();
        let s = generate(&spec, 3, 0.02);
        for r in s.train.rows.iter().take(20) {
            let n = r.l2_norm_sq().sqrt();
            assert!(n > 0.3 && n < 3.0, "row norm {n} not ≈1");
        }
    }

    #[test]
    fn positive_rate_roughly_respected() {
        let spec = spec_by_name("webspam").unwrap();
        let s = generate(&spec, 5, 0.01);
        let p = s.train.positive_rate();
        assert!((p - 0.39).abs() < 0.12, "positive rate {p}");
    }

    #[test]
    fn planted_separator_is_learnable() {
        // The planted w* itself must classify well above the noise floor.
        let spec = DatasetSpec {
            name: "t".into(),
            train_size: 2000,
            test_size: 500,
            features: 64,
            nnz_per_row: 16,
            noise: 0.05,
            positive_rate: 0.5,
            lambda: 1e-4,
        };
        let s = generate(&spec, 11, 1.0);
        let mut correct = 0;
        for i in 0..s.test.len() {
            let (x, y) = s.test.sample(i);
            let m = x.dot_dense(&s.w_star);
            if m * y > 0.0 {
                correct += 1;
            }
        }
        let acc = correct as f64 / s.test.len() as f64;
        assert!(acc > 0.90, "planted separator accuracy {acc}");
    }

    #[test]
    #[should_panic(expected = "scale must be in (0,1]")]
    fn bad_scale_panics() {
        let spec = spec_by_name("usps").unwrap();
        generate(&spec, 0, 0.0);
    }
}
