//! Data substrate: sample storage, LIBSVM I/O, synthetic stand-ins for the
//! paper's corpora, and horizontal partitioning.
//!
//! The paper evaluates on Adult, CCAT (RCV1), MNIST-binary, Reuters-21578,
//! USPS, Webspam and Gisette. Those corpora are not redistributable inside
//! this environment, so [`synthetic`] provides seeded generators matched on
//! the public shape statistics (N, d, sparsity, class balance) with a
//! planted linear separator — see DESIGN.md §Substitutions. Real copies in
//! LIBSVM format drop in through [`libsvm::read_libsvm`].

pub mod libsvm;
pub mod pack;
pub mod partition;
pub mod rff;
pub mod store;
pub mod synthetic;

pub use pack::{MmapStore, PackFile, StoreKind};
pub use store::{
    ArrivalPushError, ArrivalQueue, ShardStore, ShardView, StaticStore, StreamSchedule,
    StreamingStore,
};

use crate::linalg::{RowsView, SparseVec};

/// A labelled binary-classification dataset with sparse rows.
///
/// Labels are `±1`. Rows share a fixed feature dimension `dim`; every row's
/// indices are `< dim`.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    /// Human-readable name (used in reports).
    pub name: String,
    /// Feature dimension.
    pub dim: usize,
    /// Feature vectors.
    pub rows: Vec<SparseVec>,
    /// Labels in {-1, +1}, aligned with `rows`.
    pub labels: Vec<i8>,
}

impl Dataset {
    /// Creates a dataset, validating row dimensions and labels.
    pub fn new(name: impl Into<String>, dim: usize, rows: Vec<SparseVec>, labels: Vec<i8>) -> Self {
        assert_eq!(rows.len(), labels.len(), "Dataset: rows/labels mismatch");
        for r in &rows {
            assert!(r.min_dim() <= dim, "Dataset: row exceeds dim");
        }
        for &y in &labels {
            assert!(y == 1 || y == -1, "Dataset: labels must be ±1");
        }
        Self { name: name.into(), dim, rows, labels }
    }

    /// Number of samples.
    #[inline]
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the dataset holds no samples.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Total stored non-zeros across all rows.
    pub fn total_nnz(&self) -> usize {
        self.rows.iter().map(|r| r.nnz()).sum()
    }

    /// Fraction of non-zero entries, `nnz / (N·d)`.
    pub fn density(&self) -> f64 {
        if self.rows.is_empty() || self.dim == 0 {
            return 0.0;
        }
        self.total_nnz() as f64 / (self.len() as f64 * self.dim as f64)
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        if self.labels.is_empty() {
            return 0.0;
        }
        self.labels.iter().filter(|&&y| y > 0).count() as f64 / self.labels.len() as f64
    }

    /// Materializes rows `idx` into a dense row-major `(idx.len() × d)` f32
    /// buffer plus the matching label vector — the marshalling format of the
    /// XLA backend (`runtime::literals`). `d ≥ self.dim` zero-pads columns.
    pub fn dense_batch(&self, idx: &[usize], d: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(d >= self.dim, "dense_batch: pad dim smaller than data dim");
        let mut x = vec![0.0f32; idx.len() * d];
        let mut y = Vec::with_capacity(idx.len());
        for (r, &i) in idx.iter().enumerate() {
            let row = &self.rows[i];
            let base = r * d;
            for (&j, &v) in row.indices.iter().zip(&row.values) {
                x[base + j as usize] = v;
            }
            y.push(self.labels[i] as f32);
        }
        (x, y)
    }

    /// Borrowing view of one sample.
    #[inline]
    pub fn sample(&self, i: usize) -> (&SparseVec, f64) {
        (&self.rows[i], self.labels[i] as f64)
    }

    /// The whole dataset as a borrowed [`ShardView`] — what the solvers
    /// and local-step backends iterate (see [`store`]).
    #[inline]
    pub fn view(&self) -> ShardView<'_> {
        ShardView { dim: self.dim, rows: RowsView::Vecs(&self.rows), labels: &self.labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            "toy",
            3,
            vec![
                SparseVec::new(vec![0, 2], vec![1.0, -1.0]),
                SparseVec::new(vec![1], vec![2.0]),
            ],
            vec![1, -1],
        )
    }

    #[test]
    fn stats() {
        let ds = toy();
        assert_eq!(ds.len(), 2);
        assert!(!ds.is_empty());
        assert_eq!(ds.total_nnz(), 3);
        assert!((ds.density() - 0.5).abs() < 1e-12);
        assert!((ds.positive_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dense_batch_pads() {
        let ds = toy();
        let (x, y) = ds.dense_batch(&[1, 0], 4);
        assert_eq!(x.len(), 8);
        assert_eq!(&x[0..4], &[0.0, 2.0, 0.0, 0.0]);
        assert_eq!(&x[4..8], &[1.0, 0.0, -1.0, 0.0]);
        assert_eq!(y, vec![-1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn bad_label_panics() {
        Dataset::new("bad", 1, vec![SparseVec::default()], vec![0]);
    }

    #[test]
    #[should_panic(expected = "row exceeds dim")]
    fn row_dim_checked() {
        Dataset::new("bad", 1, vec![SparseVec::new(vec![5], vec![1.0])], vec![1]);
    }
}
