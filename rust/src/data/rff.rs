//! Random Fourier Features (Rahimi & Recht 2007): the substrate for the
//! paper's §5 "development of distributed gossip-based algorithms for
//! non-linear SVMs".
//!
//! An RBF kernel `k(x, x') = exp(−‖x−x'‖²/2σ²)` is approximated by the
//! explicit map `φ(x)_j = √(2/D)·cos(⟨ω_j, x⟩ + b_j)`,
//! `ω_j ~ N(0, σ⁻²I)`, `b_j ~ U[0, 2π)`. Mapping every shard locally and
//! running the unchanged *linear* GADGET on `φ(x)` gives a decentralized
//! non-linear SVM with zero protocol changes — each node only needs the
//! shared `(seed, σ, D)` triple, not the data of any other node.

use super::Dataset;
use crate::linalg::SparseVec;
use crate::rng::Rng;

/// A sampled feature map `x ↦ φ(x) ∈ ℝ^D`.
#[derive(Clone, Debug)]
pub struct RandomFourierFeatures {
    /// Input dimension.
    pub dim_in: usize,
    /// Output dimension `D`.
    pub dim_out: usize,
    /// Row-major `D × dim_in` frequency matrix ω.
    omega: Vec<f64>,
    /// Phase offsets `b_j`.
    phase: Vec<f64>,
    scale: f64,
}

impl RandomFourierFeatures {
    /// Samples a map for bandwidth `sigma` — deterministic in `seed`, so
    /// every network node independently materializes the *same* map.
    pub fn new(dim_in: usize, dim_out: usize, sigma: f64, seed: u64) -> Self {
        assert!(dim_in > 0 && dim_out > 0, "RFF: dims must be positive");
        assert!(sigma > 0.0, "RFF: sigma must be positive");
        let mut rng = Rng::new(seed ^ 0x52ff);
        let inv_sigma = 1.0 / sigma;
        let omega: Vec<f64> =
            (0..dim_in * dim_out).map(|_| rng.normal() * inv_sigma).collect();
        let phase: Vec<f64> =
            (0..dim_out).map(|_| rng.uniform() * std::f64::consts::TAU).collect();
        Self { dim_in, dim_out, omega, phase, scale: (2.0 / dim_out as f64).sqrt() }
    }

    /// Maps one sparse input row to its dense feature vector.
    pub fn transform(&self, x: &SparseVec) -> Vec<f64> {
        assert!(x.min_dim() <= self.dim_in, "RFF: input exceeds dim_in");
        let mut out = Vec::with_capacity(self.dim_out);
        for j in 0..self.dim_out {
            let row = &self.omega[j * self.dim_in..(j + 1) * self.dim_in];
            let mut dot = self.phase[j];
            for (&i, &v) in x.indices.iter().zip(&x.values) {
                dot += row[i as usize] * v as f64;
            }
            out.push(self.scale * dot.cos());
        }
        out
    }

    /// Maps a whole dataset (rows become dense `D`-vectors).
    pub fn map_dataset(&self, ds: &Dataset) -> Dataset {
        assert!(ds.dim <= self.dim_in, "RFF: dataset dim exceeds map dim_in");
        let rows: Vec<SparseVec> = ds
            .rows
            .iter()
            .map(|x| SparseVec::from_dense(&self.transform(x)))
            .collect();
        Dataset::new(format!("{}-rff{}", ds.name, self.dim_out), self.dim_out, rows, ds.labels.clone())
    }

    /// The kernel estimate `⟨φ(x), φ(x')⟩ ≈ exp(−‖x−x'‖²/2σ²)`.
    pub fn kernel_estimate(&self, a: &SparseVec, b: &SparseVec) -> f64 {
        let fa = self.transform(a);
        let fb = self.transform(b);
        crate::linalg::dot(&fa, &fb)
    }
}

/// A planted *non-linear* binary problem: concentric spheres — labels by
/// `‖x‖ ≶ r` with flip noise. No linear separator through the origin (or
/// anywhere) does better than chance, so it cleanly demonstrates the RFF
/// path.
pub fn generate_spheres(n: usize, dim: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5fe3);
    // radius threshold = median of the chi distribution ≈ sqrt(dim)
    let r2_threshold = dim as f64;
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        // inner class: sigma 0.7; outer class: sigma 1.3 — radii separate
        let inner = rng.flip(0.5);
        let s = if inner { 0.7 } else { 1.3 };
        let x: Vec<f64> = (0..dim).map(|_| rng.normal() * s).collect();
        let r2: f64 = crate::linalg::l2_norm_sq(&x);
        let mut y: i8 = if r2 < r2_threshold { 1 } else { -1 };
        if rng.flip(noise) {
            y = -y;
        }
        rows.push(SparseVec::from_dense(&x));
        labels.push(y);
    }
    Dataset::new("spheres", dim, rows, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{Pegasos, PegasosParams, Solver};

    #[test]
    fn kernel_estimate_tracks_rbf() {
        let dim = 8;
        let sigma = 1.5;
        let rff = RandomFourierFeatures::new(dim, 2048, sigma, 3);
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let a: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.5).collect();
            let b: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.5).collect();
            let sa = SparseVec::from_dense(&a);
            let sb = SparseVec::from_dense(&b);
            let mut d2 = 0.0;
            for k in 0..dim {
                d2 += (a[k] - b[k]).powi(2);
            }
            let want = (-d2 / (2.0 * sigma * sigma)).exp();
            let got = rff.kernel_estimate(&sa, &sb);
            assert!((got - want).abs() < 0.08, "kernel {got} vs {want}");
        }
    }

    #[test]
    fn same_seed_same_map() {
        let a = RandomFourierFeatures::new(4, 16, 1.0, 9);
        let b = RandomFourierFeatures::new(4, 16, 1.0, 9);
        let x = SparseVec::new(vec![1, 3], vec![0.5, -1.0]);
        assert_eq!(a.transform(&x), b.transform(&x));
        let c = RandomFourierFeatures::new(4, 16, 1.0, 10);
        assert_ne!(a.transform(&x), c.transform(&x));
    }

    #[test]
    fn spheres_defeat_linear_but_not_rff() {
        let dim = 6;
        let train = generate_spheres(1500, dim, 0.02, 1);
        let test = generate_spheres(500, dim, 0.02, 2);

        // linear SVM: chance-level
        let mut linear = Pegasos::new(PegasosParams {
            lambda: 1e-3,
            iterations: 15_000,
            batch_size: 1,
            project: true,
            seed: 4,
        });
        let lm = linear.fit(&train);
        let linear_acc = crate::metrics::accuracy(&lm.w, &test);
        assert!(linear_acc < 0.65, "linear should fail on spheres: {linear_acc}");

        // RFF + the same linear solver: strong
        let rff = RandomFourierFeatures::new(dim, 256, 1.8, 7);
        let train_f = rff.map_dataset(&train);
        let test_f = rff.map_dataset(&test);
        let mut nonlinear = Pegasos::new(PegasosParams {
            lambda: 1e-4,
            iterations: 20_000,
            batch_size: 1,
            project: true,
            seed: 4,
        });
        let nm = nonlinear.fit(&train_f);
        let rff_acc = crate::metrics::accuracy(&nm.w, &test_f);
        assert!(rff_acc > 0.85, "rff accuracy {rff_acc}");
    }

    #[test]
    fn map_dataset_preserves_labels_and_sets_dim() {
        let ds = generate_spheres(50, 4, 0.0, 3);
        let rff = RandomFourierFeatures::new(4, 32, 1.0, 1);
        let mapped = rff.map_dataset(&ds);
        assert_eq!(mapped.dim, 32);
        assert_eq!(mapped.labels, ds.labels);
        assert_eq!(mapped.len(), 50);
    }
}
