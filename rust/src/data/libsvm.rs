//! LIBSVM / SVMlight text format I/O.
//!
//! Format: one sample per line, `label idx:val idx:val ...` with 1-based
//! indices (the convention of the files on the paper's dataset page).
//! Reading shifts to 0-based internal indices; writing shifts back.
//!
//! This is the escape hatch that lets the *real* paper corpora (Adult,
//! rcv1/CCAT, MNIST, ...) replace the synthetic stand-ins: download the
//! LIBSVM copies and point the config's `dataset.path` at them.

use super::Dataset;
use crate::linalg::SparseVec;
use crate::Result;
use anyhow::{bail, Context};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Parses one LIBSVM line into `(label, sparse row)`.
///
/// Accepts labels `+1/1/-1` (or `0`, mapped to `-1` for 0/1-labelled files)
/// and `#`-prefixed trailing comments.
pub fn parse_line(line: &str) -> Result<(i8, SparseVec)> {
    let mut row = SparseVec::default();
    let label = parse_line_into(line, &mut row)?;
    Ok((label, row))
}

/// Parses one LIBSVM line into a caller-owned row, clearing it first.
///
/// Same grammar and error messages as [`parse_line`], but reuses the row's
/// index/value vectors so a warm parse loop performs no heap allocations.
pub fn parse_line_into(line: &str, row: &mut SparseVec) -> Result<i8> {
    let line = line.split('#').next().unwrap_or("").trim();
    let mut it = line.split_ascii_whitespace();
    let label_tok = it.next().context("empty LIBSVM line")?;
    let label_val: f64 = label_tok.parse().with_context(|| format!("bad label {label_tok:?}"))?;
    let label: i8 = if label_val > 0.0 { 1 } else { -1 };
    parse_features_into(it, row)?;
    Ok(label)
}

/// Parses `idx:val` feature tokens into a caller-owned row, clearing it first.
///
/// Shared by the labelled [`parse_line_into`] path and the serve-layer path
/// for unlabelled rows (which has no label token to strip).
pub fn parse_features_into<'a>(
    tokens: impl Iterator<Item = &'a str>,
    row: &mut SparseVec,
) -> Result<()> {
    row.indices.clear();
    row.values.clear();
    for tok in tokens {
        let (i, v) = tok.split_once(':').with_context(|| format!("bad feature {tok:?}"))?;
        let i: u32 = i.parse().with_context(|| format!("bad index {i:?}"))?;
        if i == 0 {
            bail!("LIBSVM indices are 1-based; got 0");
        }
        let v: f32 = v.parse().with_context(|| format!("bad value {v:?}"))?;
        if let Some(&last) = row.indices.last() {
            if i - 1 <= last {
                bail!("indices must strictly increase (got {i} after {})", last + 1);
            }
        }
        row.indices.push(i - 1);
        row.values.push(v);
    }
    Ok(())
}

/// Reads a LIBSVM file. `dim` forces the feature dimension (pass 0 to infer
/// the max index seen — note that inferring can differ between train/test
/// splits, so prefer passing the known dimension).
pub fn read_libsvm(path: impl AsRef<Path>, dim: usize) -> Result<Dataset> {
    let path = path.as_ref();
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    let mut max_dim = 0usize;
    for (ln, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() || line.trim_start().starts_with('#') {
            continue;
        }
        let (y, row) =
            parse_line(&line).with_context(|| format!("{}:{}", path.display(), ln + 1))?;
        max_dim = max_dim.max(row.min_dim());
        rows.push(row);
        labels.push(y);
    }
    let dim = if dim == 0 { max_dim } else { dim };
    if max_dim > dim {
        bail!("file has feature index {max_dim} > declared dim {dim}");
    }
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("libsvm").to_string();
    Ok(Dataset::new(name, dim, rows, labels))
}

/// Writes a dataset in LIBSVM format (1-based indices).
pub fn write_libsvm(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let file = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(file);
    for (row, &y) in ds.rows.iter().zip(&ds.labels) {
        write!(w, "{}", if y > 0 { "+1" } else { "-1" })?;
        for (&i, &v) in row.indices.iter().zip(&row.values) {
            write!(w, " {}:{}", i + 1, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let (y, row) = parse_line("+1 1:0.5 3:2 # comment").unwrap();
        assert_eq!(y, 1);
        assert_eq!(row.indices, vec![0, 2]);
        assert_eq!(row.values, vec![0.5, 2.0]);
    }

    #[test]
    fn parse_zero_label_maps_negative() {
        let (y, _) = parse_line("0 1:1").unwrap();
        assert_eq!(y, -1);
    }

    #[test]
    fn parse_rejects_zero_index() {
        assert!(parse_line("+1 0:1").is_err());
    }

    #[test]
    fn parse_rejects_unsorted() {
        assert!(parse_line("+1 3:1 2:1").is_err());
    }

    #[test]
    fn parse_line_into_reuses_and_clears_row() {
        let mut row = SparseVec::default();
        assert_eq!(parse_line_into("+1 1:0.5 3:2", &mut row).unwrap(), 1);
        assert_eq!(row.indices, vec![0, 2]);
        assert_eq!(row.values, vec![0.5, 2.0]);
        // A shorter row must fully replace the previous contents.
        assert_eq!(parse_line_into("-1 2:4", &mut row).unwrap(), -1);
        assert_eq!(row.indices, vec![1]);
        assert_eq!(row.values, vec![4.0]);
        // A failed parse may leave partial contents but must not corrupt reuse.
        assert!(parse_line_into("+1 2:1 1:1", &mut row).is_err());
        assert_eq!(parse_line_into("0 5:1", &mut row).unwrap(), -1);
        assert_eq!(row.indices, vec![4]);
    }

    #[test]
    fn parse_features_into_accepts_unlabelled_tokens() {
        let mut row = SparseVec::default();
        parse_features_into("1:0.5 3:2".split_ascii_whitespace(), &mut row).unwrap();
        assert_eq!(row.indices, vec![0, 2]);
        assert_eq!(row.values, vec![0.5, 2.0]);
    }

    #[test]
    fn roundtrip_file() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("toy.libsvm");
        let ds = Dataset::new(
            "toy",
            4,
            vec![
                SparseVec::new(vec![0, 3], vec![1.0, -0.5]),
                SparseVec::new(vec![1], vec![2.0]),
            ],
            vec![1, -1],
        );
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, 4).unwrap();
        assert_eq!(back.dim, 4);
        assert_eq!(back.rows, ds.rows);
        assert_eq!(back.labels, ds.labels);
    }

    #[test]
    fn infer_dim_and_overflow_check() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("t.libsvm");
        std::fs::write(&p, "+1 5:1.0\n-1 2:3\n").unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.dim, 5);
        assert!(read_libsvm(&p, 3).is_err());
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("t.libsvm");
        std::fs::write(&p, "\n# header\n+1 1:1\n\n").unwrap();
        assert_eq!(read_libsvm(&p, 0).unwrap().len(), 1);
    }
}
