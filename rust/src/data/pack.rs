//! The out-of-core data plane: pre-parsed columnar CSR pack files and the
//! mmap-backed [`MmapStore`].
//!
//! `gadget pack` converts a LIBSVM text corpus **once** into a binary
//! artifact holding four columnar arrays — `indptr` (u64 row boundaries),
//! `indices` (u32), `values` (f32), `labels` (i8) — behind a versioned,
//! checksummed 64-byte header. Training then memory-maps the artifact
//! ([`PackFile`]) and serves borrowed [`ShardView`] windows straight out
//! of the page cache: row `i` of a shard is two subslices of the mapped
//! arrays ([`crate::linalg::RowsView::Csr`]), so node count × shard size
//! can exceed RAM (the kernel pages windows in and out) and a cold start
//! pays a checksum scan instead of a text parse.
//!
//! ## File layout (version 1, native-endian)
//!
//! ```text
//! offset  size  field
//! 0       8     magic "GDGTPACK"
//! 8       4     version (u32, = 1)
//! 12      4     endianness marker (u32, = 0x01020304 in writer byte order)
//! 16      8     feature dimension d (u64)
//! 24      8     row count n (u64)
//! 32      8     total non-zeros nnz (u64)
//! 40      8     FNV-1a-64 checksum of the payload (u64)
//! 48      8     payload length in bytes (u64)
//! 56      8     flags (u64; bit 0 = rows were shuffled at pack time —
//!               `gadget pack --shuffle SEED`; other bits must be zero)
//! 64      …     payload: indptr (n+1)×u64 | indices nnz×u32 |
//!               values nnz×f32 | labels n×i8 | zero pad to 8-byte multiple
//! ```
//!
//! Section order is by descending alignment, and the payload starts at the
//! 8-aligned offset 64, so every section is naturally aligned inside the
//! mapping — the reader casts with `align_to` and *asserts* the empty
//! prefix rather than copying. The file is native-endian; the marker field
//! makes a foreign-endian pack fail loudly at open instead of decoding
//! garbage. [`PackFile::open`] validates everything up front — magic,
//! version, endianness, exact file size, checksum, `indptr` monotonicity,
//! per-row strictly-increasing indices `< d`, labels `±1` — so a
//! truncated or corrupt pack can never silently train on partial data.

use super::{libsvm, Dataset, ShardStore, ShardView};
use crate::linalg::RowsView;
use crate::util::Mmap;
use crate::Result;
use anyhow::{ensure, Context};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::ops::Range;
use std::path::Path;
use std::sync::Arc;

/// File magic.
pub const PACK_MAGIC: [u8; 8] = *b"GDGTPACK";
/// Current format version.
pub const PACK_VERSION: u32 = 1;
/// Endianness marker value (in writer byte order).
pub const PACK_ENDIAN_MARK: u32 = 0x0102_0304;
/// Header size in bytes.
pub const PACK_HEADER_LEN: usize = 64;
/// Header flag bit 0: the row order is a seeded permutation of the source
/// order (`gadget pack --shuffle SEED`). Because contiguous pack shards
/// are *windows*, an unshuffled pack of a sorted corpus would hand every
/// node a label-skewed shard — the flag records that the skew was broken
/// at conversion, as part of the experiment record.
pub const PACK_FLAG_SHUFFLED: u64 = 1;
/// All flag bits this build understands; anything else fails open.
const PACK_FLAGS_KNOWN: u64 = PACK_FLAG_SHUFFLED;
/// Seed label for the pack shuffle stream ("pack"), domain-separating it
/// from the trainer's seed streams.
const SHUFFLE_SEED: u64 = 0x7061_636b;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// What `gadget pack` reports after writing an artifact.
#[derive(Clone, Debug)]
pub struct PackSummary {
    /// Rows written.
    pub rows: usize,
    /// Feature dimension recorded in the header.
    pub dim: usize,
    /// Total stored non-zeros.
    pub nnz: usize,
    /// Artifact size in bytes (header + payload).
    pub bytes: u64,
}

fn payload_sizes(n: u64, nnz: u64) -> Result<(u64, u64, u64, u64, u64)> {
    // Section byte sizes with overflow checks (a hostile header must not
    // wrap the arithmetic into a plausible-looking layout).
    let indptr = (n + 1).checked_mul(8).context("pack: indptr size overflow")?;
    let indices = nnz.checked_mul(4).context("pack: indices size overflow")?;
    let values = nnz.checked_mul(4).context("pack: values size overflow")?;
    let labels = n;
    let raw = indptr
        .checked_add(indices)
        .and_then(|s| s.checked_add(values))
        .and_then(|s| s.checked_add(labels))
        .context("pack: payload size overflow")?;
    let padded = raw.checked_add(7).context("pack: payload size overflow")? & !7;
    Ok((indptr, indices, values, labels, padded))
}

fn write_pack(
    path: &Path,
    dim: usize,
    flags: u64,
    indptr: &[u64],
    indices: &[u32],
    values: &[f32],
    labels: &[i8],
) -> Result<PackSummary> {
    let n = labels.len();
    let nnz = indices.len();
    assert_eq!(indptr.len(), n + 1, "write_pack: indptr length");
    assert_eq!(values.len(), nnz, "write_pack: values length");
    ensure!(n > 0, "pack: refusing to write an empty corpus");
    let (_, _, _, _, payload_len) = payload_sizes(n as u64, nnz as u64)?;
    let raw_len =
        8 * (n as u64 + 1) + 4 * nnz as u64 + 4 * nnz as u64 + n as u64;
    let pad = (payload_len - raw_len) as usize;

    // Pass 1: checksum over the exact payload byte stream (pad included).
    let mut sum = FNV_OFFSET;
    for v in indptr {
        fnv1a(&mut sum, &v.to_ne_bytes());
    }
    for v in indices {
        fnv1a(&mut sum, &v.to_ne_bytes());
    }
    for v in values {
        fnv1a(&mut sum, &v.to_ne_bytes());
    }
    for &v in labels {
        fnv1a(&mut sum, &[v as u8]);
    }
    fnv1a(&mut sum, &[0u8; 7][..pad]);

    let mut header = [0u8; PACK_HEADER_LEN];
    header[0..8].copy_from_slice(&PACK_MAGIC);
    header[8..12].copy_from_slice(&PACK_VERSION.to_ne_bytes());
    header[12..16].copy_from_slice(&PACK_ENDIAN_MARK.to_ne_bytes());
    header[16..24].copy_from_slice(&(dim as u64).to_ne_bytes());
    header[24..32].copy_from_slice(&(n as u64).to_ne_bytes());
    header[32..40].copy_from_slice(&(nnz as u64).to_ne_bytes());
    header[40..48].copy_from_slice(&sum.to_ne_bytes());
    header[48..56].copy_from_slice(&payload_len.to_ne_bytes());
    header[56..64].copy_from_slice(&flags.to_ne_bytes());

    // Pass 2: write.
    let file = std::fs::File::create(path)
        .with_context(|| format!("create pack {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(&header)?;
    for v in indptr {
        w.write_all(&v.to_ne_bytes())?;
    }
    for v in indices {
        w.write_all(&v.to_ne_bytes())?;
    }
    for v in values {
        w.write_all(&v.to_ne_bytes())?;
    }
    for &v in labels {
        w.write_all(&[v as u8])?;
    }
    w.write_all(&[0u8; 7][..pad])?;
    w.flush().with_context(|| format!("write pack {}", path.display()))?;
    Ok(PackSummary {
        rows: n,
        dim,
        nnz,
        bytes: PACK_HEADER_LEN as u64 + payload_len,
    })
}

/// Gathers the columnar arrays in `perm` order (one pass, row slices
/// copied via the row boundaries).
fn permute_columnar(
    perm: &[usize],
    indptr: &[u64],
    indices: &[u32],
    values: &[f32],
    labels: &[i8],
) -> (Vec<u64>, Vec<u32>, Vec<f32>, Vec<i8>) {
    let n = labels.len();
    let mut p_indptr = Vec::with_capacity(n + 1);
    p_indptr.push(0u64);
    let mut p_indices = Vec::with_capacity(indices.len());
    let mut p_values = Vec::with_capacity(values.len());
    let mut p_labels = Vec::with_capacity(n);
    for &r in perm {
        let (a, b) = (indptr[r] as usize, indptr[r + 1] as usize);
        p_indices.extend_from_slice(&indices[a..b]);
        p_values.extend_from_slice(&values[a..b]);
        p_indptr.push(p_indices.len() as u64);
        p_labels.push(labels[r]);
    }
    (p_indptr, p_indices, p_values, p_labels)
}

/// The seeded row permutation `--shuffle SEED` applies at pack time.
fn shuffle_permutation(n: usize, seed: u64) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..n).collect();
    crate::rng::Rng::new(seed ^ SHUFFLE_SEED).shuffle(&mut perm);
    perm
}

/// Converts a LIBSVM text file into a pack artifact — the one-time
/// `gadget pack` step. `dim` forces the feature dimension (0 infers the
/// max index seen, like [`libsvm::read_libsvm`]). Rows accumulate
/// straight into the columnar arrays; per-row `SparseVec`s exist only
/// transiently during parsing.
pub fn pack_libsvm(input: &Path, output: &Path, dim: usize) -> Result<PackSummary> {
    pack_libsvm_opts(input, output, dim, None)
}

/// [`pack_libsvm`] with options: `shuffle = Some(seed)` writes the rows
/// in a seeded random permutation of the source order and sets
/// [`PACK_FLAG_SHUFFLED`] in the header (contiguous shard windows then
/// sample the corpus instead of inheriting its sort order).
/// `shuffle = None` is byte-identical to [`pack_libsvm`].
pub fn pack_libsvm_opts(
    input: &Path,
    output: &Path,
    dim: usize,
    shuffle: Option<u64>,
) -> Result<PackSummary> {
    let file = std::fs::File::open(input)
        .with_context(|| format!("open {}", input.display()))?;
    let mut indptr: Vec<u64> = vec![0];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut labels: Vec<i8> = Vec::new();
    let mut max_dim = 0usize;
    for (ln, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let (y, row) = libsvm::parse_line(trimmed)
            .with_context(|| format!("{}:{}", input.display(), ln + 1))?;
        max_dim = max_dim.max(row.min_dim());
        indices.extend_from_slice(&row.indices);
        values.extend_from_slice(&row.values);
        indptr.push(indices.len() as u64);
        labels.push(y);
    }
    ensure!(!labels.is_empty(), "pack: {} holds no samples", input.display());
    let dim = if dim == 0 { max_dim } else { dim };
    ensure!(
        max_dim <= dim,
        "pack: {} has feature index {max_dim} > declared dim {dim}",
        input.display()
    );
    match shuffle {
        None => write_pack(output, dim, 0, &indptr, &indices, &values, &labels),
        Some(seed) => {
            let perm = shuffle_permutation(labels.len(), seed);
            let (pi, px, pv, pl) = permute_columnar(&perm, &indptr, &indices, &values, &labels);
            write_pack(output, dim, PACK_FLAG_SHUFFLED, &pi, &px, &pv, &pl)
        }
    }
}

/// Packs an in-memory dataset — the test/CI convenience twin of
/// [`pack_libsvm`] (byte-identical output for the same rows).
pub fn pack_dataset(ds: &Dataset, output: &Path) -> Result<PackSummary> {
    pack_dataset_opts(ds, output, None)
}

/// [`pack_dataset`] with the same `shuffle` option as
/// [`pack_libsvm_opts`] (same seed ⇒ same permutation ⇒ byte-identical
/// artifact for the same rows).
pub fn pack_dataset_opts(
    ds: &Dataset,
    output: &Path,
    shuffle: Option<u64>,
) -> Result<PackSummary> {
    let mut indptr: Vec<u64> = Vec::with_capacity(ds.len() + 1);
    indptr.push(0);
    let nnz = ds.total_nnz();
    let mut indices: Vec<u32> = Vec::with_capacity(nnz);
    let mut values: Vec<f32> = Vec::with_capacity(nnz);
    for r in &ds.rows {
        indices.extend_from_slice(&r.indices);
        values.extend_from_slice(&r.values);
        indptr.push(indices.len() as u64);
    }
    match shuffle {
        None => write_pack(output, ds.dim, 0, &indptr, &indices, &values, &ds.labels),
        Some(seed) => {
            let perm = shuffle_permutation(ds.len(), seed);
            let (pi, px, pv, pl) =
                permute_columnar(&perm, &indptr, &indices, &values, &ds.labels);
            write_pack(output, ds.dim, PACK_FLAG_SHUFFLED, &pi, &px, &pv, &pl)
        }
    }
}

/// A validated, memory-mapped pack artifact.
///
/// All accessors are zero-copy borrows into the mapping; a [`ShardView`]
/// window over a row range is two slice borrows ([`Self::view_range`]),
/// never an allocation. The full file is validated at open (checksum and
/// structure), so every later access may assume well-formed data.
#[derive(Debug)]
pub struct PackFile {
    map: Mmap,
    name: String,
    dim: usize,
    n_rows: usize,
    nnz: usize,
    flags: u64,
    indices_off: usize,
    values_off: usize,
    labels_off: usize,
}

impl PackFile {
    /// Opens and fully validates a pack artifact. Every malformation —
    /// truncation, version or endianness mismatch, checksum failure,
    /// non-monotone row boundaries, out-of-range or unsorted indices,
    /// bad labels — is a loud error here; there is no partial-read mode.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let map = Mmap::open(path)?;
        let b = map.bytes();
        ensure!(
            b.len() >= PACK_HEADER_LEN,
            "{}: truncated pack (only {} bytes, header needs {PACK_HEADER_LEN})",
            path.display(),
            b.len()
        );
        ensure!(
            b[0..8] == PACK_MAGIC,
            "{}: not a gadget pack (bad magic {:?}; expected {:?})",
            path.display(),
            &b[0..8],
            &PACK_MAGIC[..]
        );
        let u32_at = |off: usize| u32::from_ne_bytes(b[off..off + 4].try_into().unwrap());
        let u64_at = |off: usize| u64::from_ne_bytes(b[off..off + 8].try_into().unwrap());
        let version = u32_at(8);
        ensure!(
            version == PACK_VERSION,
            "{}: unsupported pack version {version} (this build reads version \
             {PACK_VERSION}; re-run `gadget pack`)",
            path.display()
        );
        ensure!(
            u32_at(12) == PACK_ENDIAN_MARK,
            "{}: pack was written on a machine with different endianness — \
             re-run `gadget pack` on this machine",
            path.display()
        );
        let dim64 = u64_at(16);
        let n64 = u64_at(24);
        let nnz64 = u64_at(32);
        let checksum = u64_at(40);
        let payload_len = u64_at(48);
        let flags = u64_at(56);
        ensure!(
            flags & !PACK_FLAGS_KNOWN == 0,
            "{}: pack header carries unknown flag bits {:#x} (this build \
             understands {:#x}) — written by a newer tool; refusing to \
             guess what they mean",
            path.display(),
            flags & !PACK_FLAGS_KNOWN,
            PACK_FLAGS_KNOWN
        );
        ensure!(n64 > 0, "{}: pack holds zero rows", path.display());
        let (indptr_b, indices_b, values_b, _labels_b, expect_payload) =
            payload_sizes(n64, nnz64)?;
        ensure!(
            payload_len == expect_payload,
            "{}: header payload length {payload_len} does not match the \
             declared shape (n = {n64}, nnz = {nnz64} ⇒ {expect_payload} \
             bytes) — corrupt header",
            path.display()
        );
        let expect_file = PACK_HEADER_LEN as u64 + payload_len;
        ensure!(
            b.len() as u64 == expect_file,
            "{}: file is {} bytes but the header declares {expect_file} — \
             truncated or trailing garbage",
            path.display(),
            b.len()
        );
        let mut sum = FNV_OFFSET;
        fnv1a(&mut sum, &b[PACK_HEADER_LEN..]);
        ensure!(
            sum == checksum,
            "{}: payload checksum mismatch (stored {checksum:#018x}, \
             computed {sum:#018x}) — the pack is corrupt",
            path.display()
        );
        let dim = usize::try_from(dim64).context("pack: dim overflows usize")?;
        let n_rows = usize::try_from(n64).context("pack: row count overflows usize")?;
        let nnz = usize::try_from(nnz64).context("pack: nnz overflows usize")?;
        let indices_off = PACK_HEADER_LEN + indptr_b as usize;
        let values_off = indices_off + indices_b as usize;
        let labels_off = values_off + values_b as usize;
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("pack")
            .to_string();
        let pf =
            Self { map, name, dim, n_rows, nnz, flags, indices_off, values_off, labels_off };

        // Structural validation: row boundaries and per-row indices. This
        // (like the checksum) is one sequential scan — still far cheaper
        // than a text parse, and it is what lets every later access skip
        // bounds reasoning.
        let indptr = pf.indptr();
        ensure!(
            indptr[0] == 0 && indptr[n_rows] == nnz as u64,
            "{}: indptr endpoints [{}, {}] do not match [0, nnz = {nnz}]",
            path.display(),
            indptr[0],
            indptr[n_rows]
        );
        for (i, w) in indptr.windows(2).enumerate() {
            ensure!(
                w[0] <= w[1],
                "{}: indptr decreases at row {i} ({} → {})",
                path.display(),
                w[0],
                w[1]
            );
        }
        let idx = pf.indices();
        for i in 0..n_rows {
            let row = &idx[indptr[i] as usize..indptr[i + 1] as usize];
            for (k, &j) in row.iter().enumerate() {
                ensure!(
                    (j as usize) < dim,
                    "{}: row {i} has feature index {j} ≥ dim {dim}",
                    path.display()
                );
                ensure!(
                    k == 0 || row[k - 1] < j,
                    "{}: row {i} indices are not strictly increasing",
                    path.display()
                );
            }
        }
        for (i, &y) in pf.labels().iter().enumerate() {
            ensure!(
                y == 1 || y == -1,
                "{}: row {i} label {y} is not ±1",
                path.display()
            );
        }
        Ok(pf)
    }

    fn section<T: Copy>(&self, off: usize, len: usize) -> &[T] {
        let bytes = &self.map.bytes()[off..off + len * std::mem::size_of::<T>()];
        // SAFETY: T is a plain number type (u64/u32/f32/i8 — every bit
        // pattern valid) and the layout guarantees natural alignment
        // (asserted, not assumed).
        let (pre, mid, post) = unsafe { bytes.align_to::<T>() };
        assert!(pre.is_empty() && post.is_empty() && mid.len() == len, "pack section misaligned");
        mid
    }

    /// Absolute row boundaries, length `n + 1`.
    #[inline]
    pub fn indptr(&self) -> &[u64] {
        self.section::<u64>(PACK_HEADER_LEN, self.n_rows + 1)
    }

    /// All column indices.
    #[inline]
    pub fn indices(&self) -> &[u32] {
        self.section::<u32>(self.indices_off, self.nnz)
    }

    /// All values.
    #[inline]
    pub fn values(&self) -> &[f32] {
        self.section::<f32>(self.values_off, self.nnz)
    }

    /// All labels.
    #[inline]
    pub fn labels(&self) -> &[i8] {
        self.section::<i8>(self.labels_off, self.n_rows)
    }

    /// Corpus name (the artifact's file stem).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Feature dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row count.
    #[inline]
    pub fn len(&self) -> usize {
        self.n_rows
    }

    /// True when the pack holds no rows (never after a successful open).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// Total stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Header flags (see [`PACK_FLAG_SHUFFLED`]).
    #[inline]
    pub fn flags(&self) -> u64 {
        self.flags
    }

    /// True when the rows were written in a seeded shuffle of the source
    /// order (`gadget pack --shuffle SEED`).
    #[inline]
    pub fn is_shuffled(&self) -> bool {
        self.flags & PACK_FLAG_SHUFFLED != 0
    }

    /// A zero-copy window over rows `r` — the page-serving primitive:
    /// the `indptr` subslice plus the *untouched* index/value arrays
    /// (offsets are absolute, so no rebasing, no allocation).
    ///
    /// # Panics
    /// Panics if `r` is out of range.
    pub fn view_range(&self, r: Range<usize>) -> ShardView<'_> {
        assert!(r.start <= r.end && r.end <= self.n_rows, "view_range: out of range");
        ShardView {
            dim: self.dim,
            rows: RowsView::Csr {
                indptr: &self.indptr()[r.start..=r.end],
                indices: self.indices(),
                values: self.values(),
            },
            labels: &self.labels()[r],
        }
    }

    /// The whole pack as one view.
    pub fn view(&self) -> ShardView<'_> {
        self.view_range(0..self.n_rows)
    }

    /// Copies rows `r` into a heap [`Dataset`] — for consumers that need
    /// ownership (the held-out test split, the `--store static` on-pack
    /// path). Row order is preserved, so training on the materialized
    /// copy is bitwise identical to training on the window.
    pub fn materialize_range(&self, r: Range<usize>) -> Dataset {
        let view = self.view_range(r.clone());
        let rows = view.rows.iter().map(|row| row.to_owned()).collect();
        Dataset::new(self.name.clone(), self.dim, rows, view.labels.to_vec())
    }
}

/// Splits `rows` into `m` contiguous blocks: the first `len % m` blocks
/// get one extra row. This — not `horizontal_split`'s seeded shuffle —
/// is the mmap partition: contiguity is what makes a shard a *window*
/// (one `indptr` subslice) instead of a gather. The `--store static`
/// on-pack path materializes these same ranges, so the two stores train
/// on identical shards and the bitwise equivalence tier can pin them
/// against each other.
pub fn contiguous_ranges(rows: Range<usize>, m: usize) -> Vec<Range<usize>> {
    assert!(m > 0, "contiguous_ranges: need at least one shard");
    let total = rows.end - rows.start;
    let base = total / m;
    let extra = total % m;
    let mut out = Vec::with_capacity(m);
    let mut at = rows.start;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        out.push(at..at + len);
        at += len;
    }
    out
}

/// The mmap-backed shard store: `m` contiguous row windows over one
/// [`PackFile`]. Serving a shard is two slice borrows into the mapping —
/// the OS pages the windows in on demand, so the working set is bounded
/// by what training touches, not by corpus size. Static (no ingestion);
/// the streaming plane stays heap-backed.
#[derive(Debug)]
pub struct MmapStore {
    pack: Arc<PackFile>,
    ranges: Vec<Range<usize>>,
}

impl MmapStore {
    /// Shards rows `rows` of `pack` into `m` contiguous windows.
    pub fn over_range(pack: Arc<PackFile>, rows: Range<usize>, m: usize) -> Result<Self> {
        ensure!(m > 0, "mmap store: need at least one node");
        ensure!(
            rows.start <= rows.end && rows.end <= pack.len(),
            "mmap store: row range {rows:?} exceeds pack rows {}",
            pack.len()
        );
        ensure!(
            rows.end - rows.start >= m,
            "mmap store: {} rows cannot fill {m} shards (every node needs \
             at least one row)",
            rows.end - rows.start
        );
        let ranges = contiguous_ranges(rows, m);
        Ok(Self { pack, ranges })
    }

    /// Shards the whole pack.
    pub fn new(pack: Arc<PackFile>, m: usize) -> Result<Self> {
        let n = pack.len();
        Self::over_range(pack, 0..n, m)
    }

    /// The per-node row windows.
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }

    /// The underlying pack.
    pub fn pack(&self) -> &Arc<PackFile> {
        &self.pack
    }

    /// Materializes every shard window as a heap [`Dataset`] — the
    /// `--store static` on-pack path (identical rows and order, so the
    /// resulting [`super::StaticStore`] trains bitwise-identically).
    pub fn materialize_shards(&self) -> Vec<Dataset> {
        self.ranges.iter().map(|r| self.pack.materialize_range(r.clone())).collect()
    }
}

impl ShardStore for MmapStore {
    fn nodes(&self) -> usize {
        self.ranges.len()
    }

    fn dim(&self) -> usize {
        self.pack.dim()
    }

    fn shard(&self, node: usize) -> ShardView<'_> {
        self.pack.view_range(self.ranges[node].clone())
    }

    fn shard_len(&self, node: usize) -> usize {
        let r = &self.ranges[node];
        r.end - r.start
    }

    fn ingest(&mut self, added: &mut [usize]) -> Result<usize> {
        added.fill(0);
        Ok(0)
    }
}

/// Which [`ShardStore`] backend the runner builds (`[data] store` /
/// `--store`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StoreKind {
    /// `mmap` for `pack:` datasets, `static` otherwise (streaming config
    /// still selects the streaming store — see the runner).
    #[default]
    Auto,
    /// Heap shards. On a `pack:` dataset this *materializes the same
    /// contiguous windows* the mmap store would serve — the A/B side of
    /// the bitwise equivalence pin.
    Static,
    /// Memory-mapped pack windows; requires a `pack:` dataset.
    Mmap,
}

impl std::str::FromStr for StoreKind {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "auto" => Ok(Self::Auto),
            "static" => Ok(Self::Static),
            "mmap" => Ok(Self::Mmap),
            other => Err(format!("unknown store {other:?} (auto | static | mmap)")),
        }
    }
}

impl std::fmt::Display for StoreKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Auto => "auto",
            Self::Static => "static",
            Self::Mmap => "mmap",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SparseVec;

    fn toy(n: usize, dim: usize) -> Dataset {
        let mut rows = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let j = (i % dim) as u32;
            let last = (dim - 1) as u32;
            if j < last {
                rows.push(SparseVec::new(vec![j, last], vec![i as f32 + 0.5, -1.0]));
            } else {
                rows.push(SparseVec::new(vec![last], vec![1.5]));
            }
            labels.push(if i % 3 == 0 { 1 } else { -1 });
        }
        Dataset::new("toy", dim, rows, labels)
    }

    #[test]
    fn pack_roundtrips_bitwise() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("toy.gpack");
        let ds = toy(13, 5);
        let summary = pack_dataset(&ds, &p).unwrap();
        assert_eq!(summary.rows, 13);
        assert_eq!(summary.dim, 5);
        assert_eq!(summary.nnz, ds.total_nnz());
        assert_eq!(summary.bytes, std::fs::metadata(&p).unwrap().len());
        let pf = PackFile::open(&p).unwrap();
        assert_eq!((pf.len(), pf.dim(), pf.nnz()), (13, 5, ds.total_nnz()));
        assert_eq!(pf.name(), "toy");
        let v = pf.view();
        assert_eq!(v.len(), 13);
        for i in 0..13 {
            let (x, y) = v.sample(i);
            assert_eq!(x.to_owned(), ds.rows[i], "row {i}");
            assert_eq!(y, ds.labels[i] as f64, "label {i}");
        }
    }

    #[test]
    fn pack_of_libsvm_matches_pack_of_parsed_dataset() {
        let dir = crate::util::TempDir::new().unwrap();
        let text = dir.path().join("c.libsvm");
        std::fs::write(&text, "# hdr\n+1 1:0.5 3:2\n\n-1 2:1\n+1 1:1 2:1 3:1\n").unwrap();
        let via_text = dir.path().join("a.gpack");
        let via_ds = dir.path().join("b.gpack");
        pack_libsvm(&text, &via_text, 0).unwrap();
        let ds = libsvm::read_libsvm(&text, 0).unwrap();
        pack_dataset(&ds, &via_ds).unwrap();
        assert_eq!(
            std::fs::read(&via_text).unwrap(),
            std::fs::read(&via_ds).unwrap(),
            "text and dataset packing must be byte-identical"
        );
    }

    #[test]
    fn shuffled_pack_permutes_rows_deterministically() {
        let dir = crate::util::TempDir::new().unwrap();
        let ds = toy(20, 5);
        let (a, b, c) = (
            dir.path().join("a.gpack"),
            dir.path().join("b.gpack"),
            dir.path().join("c.gpack"),
        );
        pack_dataset_opts(&ds, &a, Some(9)).unwrap();
        pack_dataset_opts(&ds, &b, Some(9)).unwrap();
        pack_dataset_opts(&ds, &c, Some(10)).unwrap();
        // same seed ⇒ byte-identical artifact; different seed ⇒ different
        // permutation
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
        assert_ne!(std::fs::read(&a).unwrap(), std::fs::read(&c).unwrap());
        let pf = PackFile::open(&a).unwrap();
        assert!(pf.is_shuffled());
        assert_eq!(pf.flags(), PACK_FLAG_SHUFFLED);
        // the shuffle is a permutation: every source row appears exactly
        // once (toy rows are pairwise distinct), in a changed order
        let v = pf.view();
        let packed: Vec<_> = (0..ds.len()).map(|i| v.sample(i).0.to_owned()).collect();
        for (i, r) in ds.rows.iter().enumerate() {
            assert_eq!(
                packed.iter().filter(|p| *p == r).count(),
                1,
                "source row {i} lost or duplicated"
            );
        }
        assert!(
            (0..ds.len()).any(|i| packed[i] != ds.rows[i]),
            "seed 9 left 20 rows in source order"
        );
        // labels moved with their rows
        for i in 0..ds.len() {
            let (row, y) = v.sample(i);
            let src = ds.rows.iter().position(|r| *r == row.to_owned()).unwrap();
            assert_eq!(y, ds.labels[src] as f64, "label detached from row {i}");
        }
        // the unshuffled writer stays flagless (and so byte-compatible
        // with packs from before the flag existed)
        let plain = dir.path().join("p.gpack");
        pack_dataset(&ds, &plain).unwrap();
        let pp = PackFile::open(&plain).unwrap();
        assert!(!pp.is_shuffled());
        assert_eq!(pp.flags(), 0);
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("f.gpack");
        pack_dataset(&toy(8, 3), &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // flags live at 56..64 (native-endian); bit 1 is not assigned
        bytes[56] |= 0x02;
        std::fs::write(&p, &bytes).unwrap();
        let e = PackFile::open(&p).unwrap_err();
        assert!(e.to_string().contains("flag"), "{e}");
    }

    #[test]
    fn view_range_windows_are_absolute() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("w.gpack");
        let ds = toy(10, 4);
        pack_dataset(&ds, &p).unwrap();
        let pf = PackFile::open(&p).unwrap();
        let v = pf.view_range(4..9);
        assert_eq!(v.len(), 5);
        for (k, i) in (4..9).enumerate() {
            assert_eq!(v.sample(k).0.to_owned(), ds.rows[i]);
            assert_eq!(v.labels[k], ds.labels[i]);
        }
        let m = pf.materialize_range(4..9);
        assert_eq!(m.rows, ds.rows[4..9]);
        assert_eq!(m.labels, ds.labels[4..9]);
    }

    #[test]
    fn truncated_pack_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("t.gpack");
        pack_dataset(&toy(8, 3), &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        // header-level truncation
        std::fs::write(&p, &full[..32]).unwrap();
        let e = PackFile::open(&p).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
        // payload-level truncation
        std::fs::write(&p, &full[..full.len() - 8]).unwrap();
        let e = PackFile::open(&p).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
    }

    #[test]
    fn corrupt_payload_rejected_by_checksum() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("c.gpack");
        pack_dataset(&toy(8, 3), &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = PACK_HEADER_LEN + (bytes.len() - PACK_HEADER_LEN) / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let e = PackFile::open(&p).unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");
    }

    #[test]
    fn wrong_version_and_magic_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("v.gpack");
        pack_dataset(&toy(8, 3), &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        let mut bad = good.clone();
        bad[8] = 99; // version field
        std::fs::write(&p, &bad).unwrap();
        let e = PackFile::open(&p).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
        let mut bad = good;
        bad[0] = b'X'; // magic
        std::fs::write(&p, &bad).unwrap();
        let e = PackFile::open(&p).unwrap_err();
        assert!(e.to_string().contains("magic"), "{e}");
    }

    #[test]
    fn mmap_store_windows_partition_the_pack() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("s.gpack");
        let ds = toy(11, 4);
        pack_dataset(&ds, &p).unwrap();
        let pack = Arc::new(PackFile::open(&p).unwrap());
        let store = MmapStore::new(pack, 3).unwrap();
        assert_eq!(store.nodes(), 3);
        assert_eq!(store.dim(), 4);
        // 11 rows over 3 nodes: 4, 4, 3 — contiguous and exhaustive
        assert_eq!(store.ranges(), &[0..4, 4..8, 8..11]);
        let mut seen = 0usize;
        for node in 0..3 {
            let v = store.shard(node);
            assert_eq!(v.len(), store.shard_len(node));
            for k in 0..v.len() {
                assert_eq!(v.sample(k).0.to_owned(), ds.rows[seen]);
                seen += 1;
            }
        }
        assert_eq!(seen, 11);
        // static ingestion contract
        let mut store = store;
        let mut added = vec![7usize; 3];
        assert_eq!(store.ingest(&mut added).unwrap(), 0);
        assert_eq!(added, vec![0, 0, 0]);
        assert!(store.stream_exhausted());
        // materialized shards are the same rows in the same order
        let shards = store.materialize_shards();
        let flat: Vec<_> = shards.iter().flat_map(|s| s.rows.iter().cloned()).collect();
        assert_eq!(flat, ds.rows);
    }

    #[test]
    fn too_few_rows_for_nodes_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("few.gpack");
        pack_dataset(&toy(2, 3), &p).unwrap();
        let pack = Arc::new(PackFile::open(&p).unwrap());
        let e = MmapStore::new(pack, 5).unwrap_err();
        assert!(e.to_string().contains("cannot fill"), "{e}");
    }

    #[test]
    fn store_kind_parses_and_displays() {
        assert_eq!("auto".parse::<StoreKind>().unwrap(), StoreKind::Auto);
        assert_eq!("static".parse::<StoreKind>().unwrap(), StoreKind::Static);
        assert_eq!("mmap".parse::<StoreKind>().unwrap(), StoreKind::Mmap);
        assert!("disk".parse::<StoreKind>().is_err());
        assert_eq!(StoreKind::Auto.to_string(), "auto");
        assert_eq!(StoreKind::Static.to_string(), "static");
        assert_eq!(StoreKind::Mmap.to_string(), "mmap");
        assert_eq!(StoreKind::default(), StoreKind::Auto);
    }
}
