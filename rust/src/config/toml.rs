//! Minimal TOML-subset parser for config files.
//!
//! Supported: `key = value` lines with string / integer / float / bool
//! values, `#` comments, blank lines, and flat `[section]` headers (keys in
//! a section are exposed as `section.key`). This covers every config file
//! the project ships; anything fancier is rejected loudly.

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Any numeric literal (integers included).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl Value {
    /// String accessor with a field-name-bearing error.
    pub fn as_str_or(&self, key: &str) -> anyhow::Result<String> {
        match self {
            Value::Str(s) => Ok(s.clone()),
            other => anyhow::bail!("config key {key:?}: expected string, got {other:?}"),
        }
    }

    /// Float accessor.
    pub fn as_f64_or(&self, key: &str) -> anyhow::Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            other => anyhow::bail!("config key {key:?}: expected number, got {other:?}"),
        }
    }

    /// Unsigned-integer accessor (rejects negatives and fractions).
    pub fn as_usize_or(&self, key: &str) -> anyhow::Result<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Ok(*x as usize),
            other => anyhow::bail!("config key {key:?}: expected non-negative integer, got {other:?}"),
        }
    }

    /// Bool accessor.
    pub fn as_bool_or(&self, key: &str) -> anyhow::Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => anyhow::bail!("config key {key:?}: expected bool, got {other:?}"),
        }
    }
}

/// Parses TOML-subset text into ordered `(key, value)` pairs.
/// Keys inside `[section]` blocks come out as `"section.key"`.
pub fn parse(text: &str) -> Result<Vec<(String, Value)>, String> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(format!("line {}: bad section", ln + 1))?;
            section = name.trim().to_string();
            if section.is_empty() {
                return Err(format!("line {}: empty section name", ln + 1));
            }
            continue;
        }
        let (key, value) =
            line.split_once('=').ok_or(format!("line {}: expected key = value", ln + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", ln + 1));
        }
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        let value = parse_value(value.trim()).map_err(|e| format!("line {}: {e}", ln + 1))?;
        out.push((full_key, value));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = 1e-4\nf = 1_000").unwrap();
        assert_eq!(doc[0], ("a".into(), Value::Num(1.0)));
        assert_eq!(doc[1], ("b".into(), Value::Num(2.5)));
        assert_eq!(doc[2], ("c".into(), Value::Str("hi".into())));
        assert_eq!(doc[3], ("d".into(), Value::Bool(true)));
        assert_eq!(doc[4], ("e".into(), Value::Num(1e-4)));
        assert_eq!(doc[5], ("f".into(), Value::Num(1000.0)));
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = parse("# top\n\na = 1  # trailing\ns = \"a # not comment\"\n").unwrap();
        assert_eq!(doc.len(), 2);
        assert_eq!(doc[1].1, Value::Str("a # not comment".into()));
    }

    #[test]
    fn sections_prefix_keys() {
        let doc = parse("[net]\nnodes = 10\n[data]\nname = \"x\"").unwrap();
        assert_eq!(doc[0].0, "net.nodes");
        assert_eq!(doc[1].0, "data.name");
    }

    #[test]
    fn errors_are_line_numbered() {
        assert!(parse("a").unwrap_err().contains("line 1"));
        assert!(parse("a = 1\nb = @").unwrap_err().contains("line 2"));
        assert!(parse("[x\n").unwrap_err().contains("bad section"));
        assert!(parse("= 3").unwrap_err().contains("empty key"));
    }

    #[test]
    fn accessor_type_errors() {
        let v = Value::Num(1.5);
        assert!(v.as_usize_or("k").is_err());
        assert!(v.as_str_or("k").is_err());
        assert!(v.as_bool_or("k").is_err());
        assert!(Value::Num(3.0).as_usize_or("k").is_ok());
        assert!(Value::Num(-1.0).as_usize_or("k").is_err());
    }
}
