//! Configuration system: experiment configs as TOML files or builder calls.
//!
//! The offline environment has no `serde`/`toml`, so [`toml`] implements the
//! subset the configs need (tables, string/number/bool scalars, comments)
//! and [`ExperimentConfig`] maps the parsed tree onto typed fields with
//! defaults and validation. Every CLI subcommand and example goes through
//! this type, so a config file fully determines a run (together with the
//! seed it is the reproducibility unit recorded in EXPERIMENTS.md).

pub mod toml;

use crate::gossip::MixerKind;
use crate::topology::stochastic::WeightScheme;
use crate::topology::TopologyKind;
use crate::Result;
use anyhow::{bail, Context};

pub use crate::data::{StoreKind, StreamSchedule};
pub use crate::linalg::{KernelKind, StepKind};

/// Compute backend for the local Pegasos step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust sparse path (default; fastest for the paper's sparse data).
    Native,
    /// AOT-compiled JAX/Pallas artifact executed via PJRT
    /// (`artifacts/*.hlo.txt`) — the three-layer stack's L1/L2.
    Xla,
}

impl std::str::FromStr for Backend {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "native" => Ok(Self::Native),
            "xla" | "pjrt" => Ok(Self::Xla),
            other => Err(format!("unknown backend {other:?}")),
        }
    }
}

/// Execution strategy for the node-parallel runtime
/// ([`crate::coordinator::sched`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedulerKind {
    /// All nodes stepped in id order on the calling thread — the
    /// determinism reference (Peersim-equivalent cycle simulation).
    #[default]
    Sequential,
    /// Work fanned across a persistent parked worker pool (per-node
    /// phases, mixing panels, whole trials); bitwise identical results to
    /// `Sequential` (per-node RNG substreams isolate all randomness).
    Parallel,
    /// Thread-per-node message passing without a global round barrier —
    /// the paper's "completely asynchronous" execution.
    Async,
}

impl std::str::FromStr for SchedulerKind {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "sequential" | "seq" => Ok(Self::Sequential),
            "parallel" | "par" => Ok(Self::Parallel),
            "async" => Ok(Self::Async),
            other => Err(format!(
                "unknown scheduler {other:?} (sequential | parallel | async)"
            )),
        }
    }
}

impl std::fmt::Display for SchedulerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Sequential => "sequential",
            Self::Parallel => "parallel",
            Self::Async => "async",
        })
    }
}

/// Full description of a GADGET run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset name (`synthetic-*` from `data::synthetic::paper_specs`) or a
    /// LIBSVM `path:` prefixed file path.
    pub dataset: String,
    /// Sample-count scale factor for synthetic corpora, in (0, 1].
    pub scale: f64,
    /// Number of network nodes `m` (paper: k = 10).
    pub nodes: usize,
    /// Overlay topology (paper's Peersim setup gossips over the complete
    /// overlay).
    pub topology: TopologyKind,
    /// Doubly-stochastic weight scheme for `B`.
    pub weights: WeightScheme,
    /// Regularization λ. `None` ⇒ take the dataset spec's Table-2 value.
    pub lambda: Option<f64>,
    /// ε-convergence threshold on `‖ŵ^(t+1) − ŵ^(t)‖` (paper: 0.001).
    pub epsilon: f64,
    /// Hard cap on GADGET iterations.
    pub max_iterations: usize,
    /// Local mini-batch size per node per iteration.
    pub batch_size: usize,
    /// Local Pegasos steps fused per GADGET iteration (the L2 scan depth
    /// when the XLA backend runs; 1 = the paper's exact algorithm).
    pub local_steps: usize,
    /// Push-Sum rounds per GADGET iteration. `0` ⇒ derive from the spectral
    /// mixing-time estimate `τ(γ)`.
    pub gossip_rounds: usize,
    /// Relative-error target γ used when deriving rounds.
    pub gamma: f64,
    /// Project local update onto the `1/√λ` ball (Algorithm 2 step (f)).
    pub project_local: bool,
    /// Project the consensus vector too (step (h)).
    pub project_consensus: bool,
    /// Number of independent trials (paper: 5).
    pub trials: usize,
    /// Root RNG seed.
    pub seed: u64,
    /// Compute backend for the local step.
    pub backend: Backend,
    /// Snapshot cadence in GADGET iterations for the figure traces
    /// (0 = no traces).
    pub snapshot_every: usize,
    /// Execution strategy for the node-parallel runtime (`[runtime]`
    /// section: `scheduler = "sequential" | "parallel" | "async"`).
    pub scheduler: SchedulerKind,
    /// Worker threads for the parallel scheduler (`[runtime]` section:
    /// `threads = N`; 0 = all available cores). Ignored by the other
    /// schedulers.
    pub threads: usize,
    /// Kernel backend behind every dense/sparse hot loop (`[runtime]`
    /// section: `kernel = "scalar" | "simd" | "auto"`). `scalar` is the
    /// bitwise determinism reference; `simd` requires a `--features simd`
    /// build and has its own ULP-bounded equivalence contract (see
    /// `linalg::kernel`).
    pub kernel: KernelKind,
    /// Solver step representation (`[runtime]` section:
    /// `step = "dense" | "scaled" | "auto"`). `scaled` is the O(nnz)
    /// scaled-iterate fast path (`auto` resolves to it); `dense` is the
    /// O(d) reference loop the fast path is pinned against in
    /// `rust/tests/step_equivalence.rs` (see `linalg::scaled`).
    pub step: StepKind,
    /// Shard replica count for the batch-inference service (`[serve]`
    /// section: `shards = N`; 0 = one per available core). Predictions
    /// are bitwise shard-count-invariant — this only moves work.
    pub serve_shards: usize,
    /// Rows per scoring batch for the inference service (`[serve]`
    /// section: `batch = N`).
    pub serve_batch: usize,
    /// Listen address for the HTTP front end (`[serve]` section:
    /// `http = "127.0.0.1:8080"`; port 0 binds an ephemeral port). `None`
    /// — the default — keeps `serve` on stdin/stdout; the `--http` /
    /// `--http-ingest` CLI flags override.
    pub serve_http: Option<String>,
    /// Bound on HTTP requests admitted but not yet processed (`[serve]`
    /// section: `queue-depth = N`, ≥ 1). Overflow answers `503` +
    /// `Retry-After` — explicit backpressure, never a silent drop. Also
    /// sizes the `--http-ingest` arrival buffer.
    pub serve_queue_depth: usize,
    /// Per-HTTP-request deadline budget in milliseconds (`[serve]`
    /// section: `deadline-ms = N`, ≥ 1), counted from admission — time
    /// spent queued counts against it.
    pub serve_deadline_ms: u64,
    /// HTTP worker threads serving admitted connections concurrently
    /// (`[serve]` section: `workers = N`; 0 = auto: the scorer's shard
    /// count, or 1 on an ingest-only server). Responses are bitwise
    /// worker-count-invariant — this only moves work.
    pub serve_workers: usize,
    /// Streaming ingestion rate in rows per GADGET iteration, network
    /// wide (`[stream]` section: `rate = F`). `0` (the default) disables
    /// streaming — the classic load-once/partition-once static path.
    /// Fractional rates accumulate (0.5 ⇒ one row every other iteration).
    pub stream_rate: f64,
    /// Arrival schedule (`[stream] schedule = "uniform" | "random" |
    /// "tail:<file>"`): round-robin or seeded-random assignment from a
    /// held-out pool, or tailing a line-delimited LIBSVM file.
    pub stream_schedule: StreamSchedule,
    /// Cap on total ingested rows (`[stream] max-rows = N`; 0 =
    /// unlimited — the pool or file bounds the stream naturally).
    pub stream_max_rows: usize,
    /// Fraction of the training set dealt to the nodes before iteration
    /// 1 (`[stream] initial = F`, in (0, 1) for the pool schedules); the
    /// remainder is the arrival pool. The `tail:` schedule deals the
    /// full set up front and rejects a non-default value (it would be
    /// silently ignored otherwise).
    pub stream_initial: f64,
    /// Shard-store backend (`[data]` section: `store = "auto" | "static"
    /// | "mmap"`). `auto` picks `mmap` for `pack:` datasets and `static`
    /// otherwise; `mmap` requires a `pack:` dataset; `static` on a
    /// `pack:` dataset materializes the same contiguous windows onto the
    /// heap (the bitwise A/B of the out-of-core plane).
    pub store: StoreKind,
    /// Consensus mixing backend (`[mixing]` section: `backend =
    /// "push-sum" | "gradient-flow"`). `push-sum` is the paper's
    /// Push-Vector protocol and the bitwise determinism reference;
    /// `gradient-flow` is the primal-dual edge-flow alternative (see
    /// `gossip::mixer`). The async scheduler supports `push-sum` only.
    pub mixer: MixerKind,
    /// Fixed per-link message latency in async cycles (`[mixing]`
    /// section: `link-latency = N`; 0 = deliver immediately). Each
    /// directed link draws its delay once from the seed, so a schedule
    /// is reproducible. Async scheduler only.
    pub link_latency: usize,
    /// Per-message drop probability in `[0, 1)` (`[mixing]` section:
    /// `link-drop = F`). Drops are counted in [`crate::gossip::
    /// GossipStats::dropped`] and the sender reabsorbs the mass, so
    /// conservation holds exactly. Async scheduler only.
    pub link_drop: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            dataset: "synthetic-reuters".into(),
            scale: 1.0,
            nodes: 10,
            topology: TopologyKind::Complete,
            weights: WeightScheme::MetropolisHastings,
            lambda: None,
            epsilon: 1e-3,
            max_iterations: 2_000,
            batch_size: 1,
            local_steps: 1,
            gossip_rounds: 0,
            gamma: 0.01,
            project_local: true,
            project_consensus: true,
            trials: 5,
            seed: 1,
            backend: Backend::Native,
            snapshot_every: 0,
            scheduler: SchedulerKind::Sequential,
            threads: 0,
            kernel: KernelKind::Scalar,
            step: StepKind::Auto,
            serve_shards: 0,
            serve_batch: 256,
            serve_http: None,
            serve_queue_depth: 64,
            serve_deadline_ms: 5_000,
            serve_workers: 0,
            stream_rate: 0.0,
            stream_schedule: StreamSchedule::Uniform,
            stream_max_rows: 0,
            stream_initial: 0.5,
            store: StoreKind::Auto,
            mixer: MixerKind::PushSum,
            link_latency: 0,
            link_drop: 0.0,
        }
    }
}

impl ExperimentConfig {
    /// Starts a builder.
    pub fn builder() -> ConfigBuilder {
        ConfigBuilder { cfg: Self::default() }
    }

    /// Validates invariants shared by every consumer.
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            bail!("config: nodes must be ≥ 1");
        }
        if !(self.scale > 0.0 && self.scale <= 1.0) {
            bail!("config: scale must be in (0, 1]");
        }
        if self.epsilon <= 0.0 {
            bail!("config: epsilon must be positive");
        }
        if let Some(l) = self.lambda {
            if l <= 0.0 {
                bail!("config: lambda must be positive");
            }
        }
        if self.batch_size == 0 || self.local_steps == 0 {
            bail!("config: batch_size and local_steps must be ≥ 1");
        }
        if !(self.gamma > 0.0 && self.gamma < 1.0) {
            bail!("config: gamma must be in (0, 1)");
        }
        if self.trials == 0 {
            bail!(
                "config: trials must be ≥ 1 (reports aggregate over trials and \
                 index trial 0; use trials = 1 for a single run)"
            );
        }
        if self.max_iterations == 0 {
            bail!("config: max_iterations must be ≥ 1");
        }
        if self.serve_batch == 0 {
            bail!("config: serve batch must be ≥ 1");
        }
        if self.serve_queue_depth == 0 {
            bail!(
                "config: [serve] queue-depth must be ≥ 1 (0 would refuse every \
                 request; to disable HTTP, drop [serve] http instead)"
            );
        }
        if self.serve_deadline_ms == 0 {
            bail!("config: [serve] deadline-ms must be ≥ 1");
        }
        if let Some(addr) = &self.serve_http {
            if addr.trim().is_empty() {
                bail!("config: [serve] http must be a bind address like \"127.0.0.1:8080\"");
            }
        }
        if !(self.stream_rate.is_finite() && self.stream_rate >= 0.0) {
            bail!("config: stream rate must be ≥ 0 and finite (0 = static)");
        }
        if !(self.stream_initial > 0.0 && self.stream_initial <= 1.0) {
            bail!("config: stream initial fraction must be in (0, 1]");
        }
        if self.stream_rate == 0.0
            && (self.stream_schedule != StreamSchedule::Uniform || self.stream_max_rows != 0)
        {
            bail!(
                "config: [stream] schedule/max-rows are set but rate = 0, so \
                 streaming is off and they would be silently ignored — set \
                 [stream] rate > 0 (or pass --stream / --stream-rate)"
            );
        }
        if self.stream_rate > 0.0 {
            match self.stream_schedule {
                // Pool schedules hold out (1 − initial) of the data as
                // the arrival stream: initial = 1 would leave an empty
                // pool — a run labeled "streaming" that never ingests.
                StreamSchedule::Uniform | StreamSchedule::Random => {
                    if self.stream_initial >= 1.0 {
                        bail!(
                            "config: [stream] initial must be < 1 for the pool \
                             schedules (1.0 leaves an empty arrival pool — a \
                             streaming run that never ingests a row)"
                        );
                    }
                }
                // The tail schedule deals the full training set up front
                // and streams from the file; a non-default initial would
                // be silently ignored — reject instead.
                StreamSchedule::Tail(_) => {
                    if self.stream_initial != 0.5 {
                        bail!(
                            "config: [stream] initial is ignored by the tail: \
                             schedule (the full training set is dealt before \
                             iteration 1) — remove it"
                        );
                    }
                }
            }
        }
        let packed = self.dataset.starts_with("pack:");
        if self.store == StoreKind::Mmap && !packed {
            bail!(
                "config: store = \"mmap\" requires a pack: dataset (the mmap \
                 store serves windows of a pre-parsed artifact — run `gadget \
                 pack` first and point dataset at pack:<file>)"
            );
        }
        if packed && self.streaming_enabled() {
            bail!(
                "config: pack: datasets are the static out-of-core plane and \
                 cannot stream — drop the [stream] section, or stream from \
                 the original text file instead"
            );
        }
        if packed && self.scheduler == SchedulerKind::Async {
            bail!(
                "config: the async scheduler does not support pack: datasets \
                 yet (its nodes own their shards) — use the sequential or \
                 parallel scheduler"
            );
        }
        if !(self.link_drop.is_finite() && (0.0..1.0).contains(&self.link_drop)) {
            bail!("config: [mixing] link-drop must be in [0, 1)");
        }
        if (self.link_latency > 0 || self.link_drop > 0.0)
            && self.scheduler != SchedulerKind::Async
        {
            bail!(
                "config: [mixing] link-latency/link-drop model the async \
                 engine's network and would be silently ignored by the \
                 cycle-driven schedulers — set [runtime] scheduler = \"async\""
            );
        }
        Ok(())
    }

    /// True when the `[stream]` section turned the streaming data plane
    /// on (`rate > 0`): the runner then builds a
    /// [`crate::data::StreamingStore`] per trial instead of the static
    /// split.
    pub fn streaming_enabled(&self) -> bool {
        self.stream_rate > 0.0
    }

    /// Loads from a TOML file (see `configs/*.toml` for examples).
    pub fn from_toml_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read config {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parses from TOML text. Unknown keys are rejected — configs are part
    /// of the experiment record and typos must not silently no-op.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
        let mut cfg = Self::default();
        for (key, value) in doc.iter() {
            let k = key.as_str();
            match k {
                "dataset" => cfg.dataset = value.as_str_or(k)?,
                "scale" => cfg.scale = value.as_f64_or(k)?,
                "nodes" => cfg.nodes = value.as_usize_or(k)?,
                "topology" => {
                    cfg.topology = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                "weights" => {
                    cfg.weights = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                "lambda" => cfg.lambda = Some(value.as_f64_or(k)?),
                "epsilon" => cfg.epsilon = value.as_f64_or(k)?,
                "max_iterations" => cfg.max_iterations = value.as_usize_or(k)?,
                "batch_size" => cfg.batch_size = value.as_usize_or(k)?,
                "local_steps" => cfg.local_steps = value.as_usize_or(k)?,
                "gossip_rounds" => cfg.gossip_rounds = value.as_usize_or(k)?,
                "gamma" => cfg.gamma = value.as_f64_or(k)?,
                "project_local" => cfg.project_local = value.as_bool_or(k)?,
                "project_consensus" => cfg.project_consensus = value.as_bool_or(k)?,
                "trials" => cfg.trials = value.as_usize_or(k)?,
                "seed" => cfg.seed = value.as_usize_or(k)? as u64,
                "backend" => {
                    cfg.backend = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                "snapshot_every" => cfg.snapshot_every = value.as_usize_or(k)?,
                // `[runtime]` section (flat spellings accepted too).
                "runtime.scheduler" | "scheduler" => {
                    cfg.scheduler = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                "runtime.threads" | "threads" => cfg.threads = value.as_usize_or(k)?,
                "runtime.kernel" | "kernel" => {
                    cfg.kernel = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                "runtime.step" | "step" => {
                    cfg.step = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                // `[serve]` section (flat spellings accepted too).
                "serve.shards" | "shards" => cfg.serve_shards = value.as_usize_or(k)?,
                "serve.batch" | "batch" => cfg.serve_batch = value.as_usize_or(k)?,
                "serve.http" | "http" => cfg.serve_http = Some(value.as_str_or(k)?),
                "serve.queue-depth" | "serve.queue_depth" | "queue-depth" | "queue_depth" => {
                    cfg.serve_queue_depth = value.as_usize_or(k)?
                }
                "serve.deadline-ms" | "serve.deadline_ms" | "deadline-ms" | "deadline_ms" => {
                    cfg.serve_deadline_ms = value.as_usize_or(k)? as u64
                }
                "serve.workers" | "workers" => cfg.serve_workers = value.as_usize_or(k)?,
                // `[stream]` section (flat spellings accepted too).
                "stream.rate" | "rate" => cfg.stream_rate = value.as_f64_or(k)?,
                "stream.schedule" | "schedule" => {
                    cfg.stream_schedule = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                "stream.max-rows" | "stream.max_rows" | "max-rows" | "max_rows" => {
                    cfg.stream_max_rows = value.as_usize_or(k)?
                }
                "stream.initial" | "initial" => cfg.stream_initial = value.as_f64_or(k)?,
                // `[data]` section (flat spelling accepted too).
                "data.store" | "store" => {
                    cfg.store = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                // `[mixing]` section. The flat spelling for the backend is
                // `mixer` — bare `backend` is the compute backend above.
                "mixing.backend" | "mixer" => {
                    cfg.mixer = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                // `[mixing] topology` aliases the top-level key so the
                // consensus scenario can live in one section.
                "mixing.topology" => {
                    cfg.topology = value
                        .as_str_or(k)?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?
                }
                "mixing.link-latency" | "mixing.link_latency" | "link-latency"
                | "link_latency" => cfg.link_latency = value.as_usize_or(k)?,
                "mixing.link-drop" | "mixing.link_drop" | "link-drop" | "link_drop" => {
                    cfg.link_drop = value.as_f64_or(k)?
                }
                other => bail!("config: unknown key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Fluent builder over [`ExperimentConfig`].
#[derive(Clone, Debug)]
pub struct ConfigBuilder {
    cfg: ExperimentConfig,
}

impl ConfigBuilder {
    /// Sets the dataset name / path.
    pub fn dataset(mut self, name: impl Into<String>) -> Self {
        self.cfg.dataset = name.into();
        self
    }

    /// Sets the synthetic scale factor.
    pub fn scale(mut self, s: f64) -> Self {
        self.cfg.scale = s;
        self
    }

    /// Sets the node count.
    pub fn nodes(mut self, m: usize) -> Self {
        self.cfg.nodes = m;
        self
    }

    /// Sets the overlay topology.
    pub fn topology(mut self, t: TopologyKind) -> Self {
        self.cfg.topology = t;
        self
    }

    /// Sets λ explicitly.
    pub fn lambda(mut self, l: f64) -> Self {
        self.cfg.lambda = Some(l);
        self
    }

    /// Sets the ε-convergence threshold.
    pub fn epsilon(mut self, e: f64) -> Self {
        self.cfg.epsilon = e;
        self
    }

    /// Sets the iteration cap.
    pub fn max_iterations(mut self, t: usize) -> Self {
        self.cfg.max_iterations = t;
        self
    }

    /// Sets the local batch size.
    pub fn batch_size(mut self, b: usize) -> Self {
        self.cfg.batch_size = b;
        self
    }

    /// Sets fused local steps per iteration.
    pub fn local_steps(mut self, s: usize) -> Self {
        self.cfg.local_steps = s;
        self
    }

    /// Sets fixed gossip rounds per iteration (0 = derive from τ_mix).
    pub fn gossip_rounds(mut self, r: usize) -> Self {
        self.cfg.gossip_rounds = r;
        self
    }

    /// Sets the number of trials.
    pub fn trials(mut self, t: usize) -> Self {
        self.cfg.trials = t;
        self
    }

    /// Sets the root seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    /// Sets the compute backend.
    pub fn backend(mut self, b: Backend) -> Self {
        self.cfg.backend = b;
        self
    }

    /// Sets snapshot cadence for traces.
    pub fn snapshot_every(mut self, n: usize) -> Self {
        self.cfg.snapshot_every = n;
        self
    }

    /// Sets the runtime scheduler.
    pub fn scheduler(mut self, s: SchedulerKind) -> Self {
        self.cfg.scheduler = s;
        self
    }

    /// Sets the parallel scheduler's worker count (0 = all cores).
    pub fn threads(mut self, t: usize) -> Self {
        self.cfg.threads = t;
        self
    }

    /// Sets the kernel backend behind the hot loops.
    pub fn kernel(mut self, k: KernelKind) -> Self {
        self.cfg.kernel = k;
        self
    }

    /// Sets the solver step representation (dense reference vs. scaled
    /// fast path).
    pub fn step(mut self, s: StepKind) -> Self {
        self.cfg.step = s;
        self
    }

    /// Sets the inference service's shard replica count (0 = all cores).
    pub fn serve_shards(mut self, s: usize) -> Self {
        self.cfg.serve_shards = s;
        self
    }

    /// Sets the inference service's rows-per-batch.
    pub fn serve_batch(mut self, b: usize) -> Self {
        self.cfg.serve_batch = b;
        self
    }

    /// Sets the HTTP front end's listen address.
    pub fn serve_http(mut self, addr: impl Into<String>) -> Self {
        self.cfg.serve_http = Some(addr.into());
        self
    }

    /// Sets the HTTP request-queue bound.
    pub fn serve_queue_depth(mut self, n: usize) -> Self {
        self.cfg.serve_queue_depth = n;
        self
    }

    /// Sets the per-HTTP-request deadline budget in milliseconds.
    pub fn serve_deadline_ms(mut self, ms: u64) -> Self {
        self.cfg.serve_deadline_ms = ms;
        self
    }

    /// Sets the HTTP worker thread count (0 = auto: shard count).
    pub fn serve_workers(mut self, n: usize) -> Self {
        self.cfg.serve_workers = n;
        self
    }

    /// Sets the streaming ingestion rate (rows/iteration; 0 = static).
    pub fn stream_rate(mut self, r: f64) -> Self {
        self.cfg.stream_rate = r;
        self
    }

    /// Sets the streaming arrival schedule.
    pub fn stream_schedule(mut self, s: StreamSchedule) -> Self {
        self.cfg.stream_schedule = s;
        self
    }

    /// Sets the total-ingest cap (0 = unlimited).
    pub fn stream_max_rows(mut self, n: usize) -> Self {
        self.cfg.stream_max_rows = n;
        self
    }

    /// Sets the initial split fraction for the pool schedules.
    pub fn stream_initial(mut self, f: f64) -> Self {
        self.cfg.stream_initial = f;
        self
    }

    /// Sets the shard-store backend.
    pub fn store(mut self, s: StoreKind) -> Self {
        self.cfg.store = s;
        self
    }

    /// Sets the consensus mixing backend.
    pub fn mixer(mut self, m: MixerKind) -> Self {
        self.cfg.mixer = m;
        self
    }

    /// Sets the async engine's per-link latency in cycles.
    pub fn link_latency(mut self, l: usize) -> Self {
        self.cfg.link_latency = l;
        self
    }

    /// Sets the async engine's per-message drop probability.
    pub fn link_drop(mut self, p: f64) -> Self {
        self.cfg.link_drop = p;
        self
    }

    /// Validates and returns the config.
    pub fn build(self) -> Result<ExperimentConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_are_valid() {
        let cfg = ExperimentConfig::builder().build().unwrap();
        assert_eq!(cfg.nodes, 10);
        assert_eq!(cfg.trials, 5);
    }

    #[test]
    fn toml_roundtrip_of_all_keys() {
        let text = r#"
# paper Table 3 setup
dataset = "synthetic-adult"
scale = 0.25
nodes = 10
topology = "ring"
weights = "max-degree"
lambda = 3.07e-5
epsilon = 0.001
max_iterations = 500
batch_size = 4
local_steps = 2
gossip_rounds = 7
gamma = 0.05
project_local = true
project_consensus = false
trials = 3
seed = 99
backend = "native"
snapshot_every = 10
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.dataset, "synthetic-adult");
        assert_eq!(cfg.scale, 0.25);
        assert_eq!(cfg.topology, TopologyKind::Ring);
        assert_eq!(cfg.weights, WeightScheme::MaxDegree);
        assert_eq!(cfg.lambda, Some(3.07e-5));
        assert_eq!(cfg.max_iterations, 500);
        assert_eq!(cfg.batch_size, 4);
        assert_eq!(cfg.local_steps, 2);
        assert_eq!(cfg.gossip_rounds, 7);
        assert!(!cfg.project_consensus);
        assert_eq!(cfg.trials, 3);
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.snapshot_every, 10);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml("typo_key = 1").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ExperimentConfig::from_toml("nodes = 0").is_err());
        assert!(ExperimentConfig::from_toml("scale = 2.0").is_err());
        assert!(ExperimentConfig::from_toml("epsilon = 0").is_err());
        assert!(ExperimentConfig::from_toml("gamma = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("lambda = -1").is_err());
        let trials_err = ExperimentConfig::from_toml("trials = 0").unwrap_err();
        assert!(trials_err.to_string().contains("trials"), "{trials_err}");
    }

    #[test]
    fn builder_setters_apply() {
        let cfg = ExperimentConfig::builder()
            .dataset("synthetic-usps")
            .nodes(4)
            .lambda(1e-3)
            .epsilon(0.01)
            .max_iterations(100)
            .batch_size(2)
            .trials(1)
            .seed(7)
            .backend(Backend::Native)
            .build()
            .unwrap();
        assert_eq!(cfg.dataset, "synthetic-usps");
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.lambda, Some(1e-3));
    }

    #[test]
    fn backend_parse() {
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Xla);
        assert!("tpu".parse::<Backend>().is_err());
    }

    #[test]
    fn scheduler_parse_and_display() {
        assert_eq!("parallel".parse::<SchedulerKind>().unwrap(), SchedulerKind::Parallel);
        assert_eq!("seq".parse::<SchedulerKind>().unwrap(), SchedulerKind::Sequential);
        assert_eq!("async".parse::<SchedulerKind>().unwrap(), SchedulerKind::Async);
        assert!("gpu".parse::<SchedulerKind>().is_err());
        assert_eq!(SchedulerKind::Parallel.to_string(), "parallel");
    }

    #[test]
    fn runtime_section_round_trips() {
        let cfg = ExperimentConfig::from_toml(
            "dataset = \"synthetic-usps\"\n[runtime]\nscheduler = \"parallel\"\nthreads = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.scheduler, SchedulerKind::Parallel);
        assert_eq!(cfg.threads, 4);
        // flat spellings accepted too
        let flat = ExperimentConfig::from_toml("scheduler = \"async\"").unwrap();
        assert_eq!(flat.scheduler, SchedulerKind::Async);
        // defaults
        let d = ExperimentConfig::default();
        assert_eq!(d.scheduler, SchedulerKind::Sequential);
        assert_eq!(d.threads, 0);
        // bad value rejected
        assert!(ExperimentConfig::from_toml("[runtime]\nscheduler = \"warp\"").is_err());
    }

    #[test]
    fn kernel_key_round_trips() {
        let cfg = ExperimentConfig::from_toml(
            "dataset = \"synthetic-usps\"\n[runtime]\nkernel = \"scalar\"\n",
        )
        .unwrap();
        assert_eq!(cfg.kernel, KernelKind::Scalar);
        // flat spelling, and the other variants, parse too
        assert_eq!(
            ExperimentConfig::from_toml("kernel = \"auto\"").unwrap().kernel,
            KernelKind::Auto
        );
        assert_eq!(
            ExperimentConfig::from_toml("kernel = \"simd\"").unwrap().kernel,
            KernelKind::Simd
        );
        // default + builder
        assert_eq!(ExperimentConfig::default().kernel, KernelKind::Scalar);
        let b = ExperimentConfig::builder().kernel(KernelKind::Auto).build().unwrap();
        assert_eq!(b.kernel, KernelKind::Auto);
        // bad value rejected at parse (feature availability is checked at
        // resolution, not here — a scalar-build must still *parse* simd
        // configs so the error can name the missing feature)
        assert!(ExperimentConfig::from_toml("[runtime]\nkernel = \"avx\"").is_err());
    }

    #[test]
    fn step_key_round_trips() {
        let cfg = ExperimentConfig::from_toml(
            "dataset = \"synthetic-usps\"\n[runtime]\nstep = \"dense\"\n",
        )
        .unwrap();
        assert_eq!(cfg.step, StepKind::Dense);
        // flat spelling, and the other variants, parse too
        assert_eq!(
            ExperimentConfig::from_toml("step = \"scaled\"").unwrap().step,
            StepKind::Scaled
        );
        assert_eq!(
            ExperimentConfig::from_toml("step = \"auto\"").unwrap().step,
            StepKind::Auto
        );
        // default + builder
        assert_eq!(ExperimentConfig::default().step, StepKind::Auto);
        let b = ExperimentConfig::builder().step(StepKind::Dense).build().unwrap();
        assert_eq!(b.step, StepKind::Dense);
        // bad value rejected at parse
        assert!(ExperimentConfig::from_toml("[runtime]\nstep = \"sparse\"").is_err());
    }

    #[test]
    fn stream_section_round_trips() {
        let cfg = ExperimentConfig::from_toml(
            "dataset = \"synthetic-usps\"\n[stream]\nrate = 2.5\nschedule = \"random\"\n\
             max-rows = 500\ninitial = 0.25\n",
        )
        .unwrap();
        assert_eq!(cfg.stream_rate, 2.5);
        assert_eq!(cfg.stream_schedule, StreamSchedule::Random);
        assert_eq!(cfg.stream_max_rows, 500);
        assert_eq!(cfg.stream_initial, 0.25);
        assert!(cfg.streaming_enabled());
        // tail schedule carries its path
        let tail = ExperimentConfig::from_toml(
            "[stream]\nrate = 1\nschedule = \"tail:feed.libsvm\"\n",
        )
        .unwrap();
        assert_eq!(tail.stream_schedule, StreamSchedule::Tail("feed.libsvm".into()));
        // defaults: streaming off, uniform schedule, half-initial
        let d = ExperimentConfig::default();
        assert_eq!(d.stream_rate, 0.0);
        assert!(!d.streaming_enabled());
        assert_eq!(d.stream_schedule, StreamSchedule::Uniform);
        assert_eq!(d.stream_max_rows, 0);
        assert_eq!(d.stream_initial, 0.5);
        // builder setters
        let b = ExperimentConfig::builder()
            .stream_rate(1.5)
            .stream_schedule(StreamSchedule::Random)
            .stream_max_rows(9)
            .stream_initial(0.75)
            .build()
            .unwrap();
        assert_eq!(b.stream_rate, 1.5);
        assert_eq!(b.stream_schedule, StreamSchedule::Random);
        assert_eq!(b.stream_max_rows, 9);
        assert_eq!(b.stream_initial, 0.75);
        // invalid values rejected
        assert!(ExperimentConfig::from_toml("[stream]\nrate = -1").is_err());
        assert!(ExperimentConfig::from_toml("[stream]\nschedule = \"poisson\"").is_err());
        assert!(ExperimentConfig::from_toml("[stream]\ninitial = 0").is_err());
        assert!(ExperimentConfig::from_toml("[stream]\ninitial = 1.5").is_err());
        // stream options without a rate would be silently ignored —
        // rejected loudly instead of running an unlabeled static pipeline
        let e = ExperimentConfig::from_toml("[stream]\nschedule = \"random\"").unwrap_err();
        assert!(e.to_string().contains("rate = 0"), "{e}");
        assert!(ExperimentConfig::from_toml("[stream]\nmax-rows = 10").is_err());
        // initial = 1 with a pool schedule leaves an empty arrival pool
        let e1 = ExperimentConfig::from_toml("[stream]\nrate = 2\ninitial = 1.0").unwrap_err();
        assert!(e1.to_string().contains("empty arrival pool"), "{e1}");
        // a non-default initial is ignored by tail: — rejected, not dropped
        let e2 = ExperimentConfig::from_toml(
            "[stream]\nrate = 1\nschedule = \"tail:f.libsvm\"\ninitial = 0.25\n",
        )
        .unwrap_err();
        assert!(e2.to_string().contains("ignored by the tail"), "{e2}");
        // the default initial is fine with tail (nothing was overridden)
        assert!(ExperimentConfig::from_toml(
            "[stream]\nrate = 1\nschedule = \"tail:f.libsvm\"\n"
        )
        .is_ok());
    }

    #[test]
    fn data_store_key_round_trips() {
        // auto is the default and parses from both spellings
        assert_eq!(ExperimentConfig::default().store, StoreKind::Auto);
        let cfg = ExperimentConfig::from_toml(
            "dataset = \"pack:train.gpack\"\n[data]\nstore = \"mmap\"\n",
        )
        .unwrap();
        assert_eq!(cfg.store, StoreKind::Mmap);
        let flat = ExperimentConfig::from_toml("store = \"auto\"").unwrap();
        assert_eq!(flat.store, StoreKind::Auto);
        // static on a pack is the bitwise A/B side — allowed
        let ab = ExperimentConfig::from_toml(
            "dataset = \"pack:train.gpack\"\nstore = \"static\"\n",
        )
        .unwrap();
        assert_eq!(ab.store, StoreKind::Static);
        // builder setter
        let b = ExperimentConfig::builder()
            .dataset("pack:train.gpack")
            .store(StoreKind::Mmap)
            .build()
            .unwrap();
        assert_eq!(b.store, StoreKind::Mmap);
        // bad value rejected at parse
        assert!(ExperimentConfig::from_toml("store = \"disk\"").is_err());
        // mmap without a pack: dataset has nothing to map
        let e = ExperimentConfig::from_toml("store = \"mmap\"").unwrap_err();
        assert!(e.to_string().contains("pack:"), "{e}");
        // pack datasets are the static plane: streaming and async rejected
        let e = ExperimentConfig::from_toml(
            "dataset = \"pack:t.gpack\"\n[stream]\nrate = 1\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("cannot stream"), "{e}");
        let e = ExperimentConfig::from_toml(
            "dataset = \"pack:t.gpack\"\nscheduler = \"async\"\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("async"), "{e}");
    }

    #[test]
    fn mixing_section_round_trips() {
        let cfg = ExperimentConfig::from_toml(
            "dataset = \"synthetic-usps\"\n[mixing]\nbackend = \"gradient-flow\"\n\
             topology = \"power-law\"\n",
        )
        .unwrap();
        assert_eq!(cfg.mixer, MixerKind::GradientFlow);
        assert_eq!(cfg.topology, TopologyKind::PowerLaw);
        // flat spelling: `mixer` (bare `backend` is the compute backend)
        let flat = ExperimentConfig::from_toml("mixer = \"flow\"").unwrap();
        assert_eq!(flat.mixer, MixerKind::GradientFlow);
        let compute = ExperimentConfig::from_toml("backend = \"native\"").unwrap();
        assert_eq!(compute.backend, Backend::Native);
        assert_eq!(compute.mixer, MixerKind::PushSum);
        // link schedules require the async scheduler
        let link = ExperimentConfig::from_toml(
            "scheduler = \"async\"\n[mixing]\nlink-latency = 3\nlink-drop = 0.1\n",
        )
        .unwrap();
        assert_eq!(link.link_latency, 3);
        assert_eq!(link.link_drop, 0.1);
        // defaults
        let d = ExperimentConfig::default();
        assert_eq!(d.mixer, MixerKind::PushSum);
        assert_eq!(d.link_latency, 0);
        assert_eq!(d.link_drop, 0.0);
        // builder setters
        let b = ExperimentConfig::builder()
            .mixer(MixerKind::GradientFlow)
            .scheduler(SchedulerKind::Async)
            .link_latency(2)
            .link_drop(0.05)
            .build()
            .unwrap();
        assert_eq!(b.mixer, MixerKind::GradientFlow);
        assert_eq!((b.link_latency, b.link_drop), (2, 0.05));
        // bad mixer name rejected at parse
        assert!(ExperimentConfig::from_toml("[mixing]\nbackend = \"telepathy\"").is_err());
        // drop probability outside [0, 1) rejected
        assert!(ExperimentConfig::from_toml(
            "scheduler = \"async\"\n[mixing]\nlink-drop = 1.0\n"
        )
        .is_err());
        // link options on a cycle-driven scheduler would be silently
        // ignored — rejected loudly instead
        let e = ExperimentConfig::from_toml("[mixing]\nlink-latency = 3").unwrap_err();
        assert!(e.to_string().contains("async"), "{e}");
        let e = ExperimentConfig::from_toml("[mixing]\nlink-drop = 0.2").unwrap_err();
        assert!(e.to_string().contains("async"), "{e}");
    }

    #[test]
    fn serve_section_round_trips() {
        let cfg = ExperimentConfig::from_toml(
            "dataset = \"synthetic-usps\"\n[serve]\nshards = 4\nbatch = 128\n",
        )
        .unwrap();
        assert_eq!(cfg.serve_shards, 4);
        assert_eq!(cfg.serve_batch, 128);
        // flat spellings accepted too
        let flat = ExperimentConfig::from_toml("shards = 2\nbatch = 16").unwrap();
        assert_eq!(flat.serve_shards, 2);
        assert_eq!(flat.serve_batch, 16);
        // defaults: auto shards, 256-row batches
        let d = ExperimentConfig::default();
        assert_eq!(d.serve_shards, 0);
        assert_eq!(d.serve_batch, 256);
        // builder setters
        let b = ExperimentConfig::builder().serve_shards(3).serve_batch(7).build().unwrap();
        assert_eq!((b.serve_shards, b.serve_batch), (3, 7));
        // a zero-row batch can never make progress
        let err = ExperimentConfig::from_toml("[serve]\nbatch = 0").unwrap_err();
        assert!(err.to_string().contains("serve batch"), "{err}");
    }

    #[test]
    fn serve_http_section_round_trips() {
        let cfg = ExperimentConfig::from_toml(
            "[serve]\nhttp = \"127.0.0.1:8080\"\nqueue-depth = 8\ndeadline-ms = 250\nworkers = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.serve_http.as_deref(), Some("127.0.0.1:8080"));
        assert_eq!(cfg.serve_queue_depth, 8);
        assert_eq!(cfg.serve_deadline_ms, 250);
        assert_eq!(cfg.serve_workers, 4);
        // flat and underscore spellings accepted too
        let flat = ExperimentConfig::from_toml(
            "http = \"0.0.0.0:0\"\nqueue_depth = 2\ndeadline_ms = 9\nworkers = 1",
        )
        .unwrap();
        assert_eq!(flat.serve_http.as_deref(), Some("0.0.0.0:0"));
        assert_eq!((flat.serve_queue_depth, flat.serve_deadline_ms), (2, 9));
        assert_eq!(flat.serve_workers, 1);
        // defaults: stdin serving, depth 64, 5 s budget, auto workers
        let d = ExperimentConfig::default();
        assert_eq!(d.serve_http, None);
        assert_eq!((d.serve_queue_depth, d.serve_deadline_ms), (64, 5_000));
        assert_eq!(d.serve_workers, 0);
        // builder setters
        let b = ExperimentConfig::builder()
            .serve_http("127.0.0.1:0")
            .serve_queue_depth(3)
            .serve_deadline_ms(77)
            .serve_workers(2)
            .build()
            .unwrap();
        assert_eq!(b.serve_http.as_deref(), Some("127.0.0.1:0"));
        assert_eq!((b.serve_queue_depth, b.serve_deadline_ms), (3, 77));
        assert_eq!(b.serve_workers, 2);
        // degenerate transport knobs are rejected, not clamped
        let e = ExperimentConfig::from_toml("[serve]\nqueue-depth = 0").unwrap_err();
        assert!(e.to_string().contains("queue-depth"), "{e}");
        let e = ExperimentConfig::from_toml("[serve]\ndeadline-ms = 0").unwrap_err();
        assert!(e.to_string().contains("deadline-ms"), "{e}");
        let e = ExperimentConfig::from_toml("[serve]\nhttp = \"\"").unwrap_err();
        assert!(e.to_string().contains("bind address"), "{e}");
    }
}
