//! # GADGET SVM
//!
//! A gossip-based sub-gradient solver for linear Support Vector Machines,
//! reproducing *GADGET SVM: A Gossip-bAseD sub-GradiEnT Solver for Linear
//! SVMs* (Dutta & Nataraj, 2018).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer
//! rust + JAX + Pallas stack:
//!
//! * [`coordinator`] — the paper's contribution: the GADGET algorithm
//!   (Algorithm 2) on a unified node-parallel runtime
//!   ([`coordinator::sched`]): one shared per-node protocol step behind a
//!   `Scheduler` abstraction with sequential (Peersim-equivalent
//!   cycle-driven), parallel (persistent parked worker pool,
//!   bitwise-identical) and asynchronous (thread-per-node message
//!   passing) execution, plus node state management, ε-convergence and
//!   churn.
//! * [`pool`] — the persistent parked worker pool every parallel phase
//!   dispatches through (node fan-out, mixing-round column panels,
//!   trial fan-out).
//! * [`gossip`] — the Push-Sum / Push-Vector consensus protocols
//!   (Kempe et al. 2003, Algorithm 1 of the paper).
//! * [`topology`] — overlay graphs and doubly-stochastic transition
//!   matrices `B`, with spectral mixing-time estimates.
//! * [`data`] — sample storage (dense + sparse), LIBSVM I/O, synthetic
//!   stand-ins for the paper's corpora, horizontal partitioning, and
//!   the streaming data plane: one `ShardStore` abstraction (static
//!   bitwise-reference split, or per-node append buffers fed by a
//!   seeded arrival schedule / tailed LIBSVM file) behind every
//!   consumer of training rows.
//! * [`solver`] — native baselines: centralized Pegasos, SVM-SGD,
//!   a cutting-plane SVM-Perf equivalent, and a dual coordinate-descent
//!   reference optimizer.
//! * [`runtime`] — the PJRT bridge that loads AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and runs them from the hot path.
//! * [`serve`] — the sharded batch-inference subsystem: versioned model
//!   artifacts (`train --save` / `serve --model`) scored by per-shard
//!   warm replicas over the worker pool.
//! * [`experiments`] — drivers regenerating every table and figure of the
//!   paper's evaluation section.
//!
//! Python (JAX + Pallas) exists only on the compile path (`make artifacts`);
//! it is never on the training path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use gadget::config::ExperimentConfig;
//! use gadget::coordinator::GadgetRunner;
//!
//! let cfg = ExperimentConfig::builder()
//!     .dataset("synthetic-reuters")
//!     .nodes(10)
//!     .lambda(1.29e-4)
//!     .epsilon(1e-3)
//!     .build()
//!     .unwrap();
//! let report = GadgetRunner::new(cfg).unwrap().run().unwrap();
//! println!("accuracy = {:.2}%", 100.0 * report.test_accuracy);
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod gossip;
pub mod harness;
pub mod linalg;
pub mod metrics;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod topology;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
