//! `gadget` — the GADGET SVM command-line launcher.
//!
//! Subcommands:
//!   train        run GADGET on one dataset, print the report (--save persists
//!                the consensus model as a serve artifact)
//!   pack         convert a LIBSVM text file into a mapped columnar artifact
//!                (train on it out-of-core with --dataset pack:<file>)
//!   serve        batch-score rows from stdin against a saved model artifact
//!   baseline     run a centralized/per-node baseline solver
//!   experiment   regenerate a paper table/figure (table3|table4|table5|figures|mixing|bound|rounds)
//!   inspect      dataset/topology/artifact diagnostics
//!   help         this text
//!
//! Examples:
//!   gadget train --dataset synthetic-usps --scale 0.1 --nodes 10
//!   gadget train --config configs/reuters.toml --save model.json
//!   gadget pack --input a9a.txt
//!   gadget train --dataset pack:a9a.gpack --nodes 10
//!   gadget serve --model model.json --shards 4 < batch.libsvm
//!   gadget serve --model model.json --http 127.0.0.1:8080
//!   gadget train --dataset synthetic-usps --trials 1 --http-ingest 127.0.0.1:8081
//!   gadget experiment table3 --scale 0.05 --out results
//!   gadget experiment figures --only usps,reuters
//!   gadget inspect --dataset synthetic-ccat --scale 0.01

use gadget::cli::Args;
use gadget::config::ExperimentConfig;
use gadget::coordinator::GadgetRunner;
use gadget::experiments::{self, ExperimentOpts};
use gadget::solver::Solver;
use gadget::util::Stopwatch;
use gadget::Result;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv).map_err(|e| anyhow::anyhow!(e))?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "pack" => cmd_pack(&args),
        "serve" => cmd_serve(&args),
        "baseline" => cmd_baseline(&args),
        "experiment" => cmd_experiment(&args),
        "inspect" => cmd_inspect(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?} (try `gadget help`)"),
    }
}

fn print_help() {
    println!(
        "gadget — Gossip-bAseD sub-GradiEnT solver for linear SVMs\n\
         \n\
         USAGE: gadget <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
         \x20 train        run GADGET (options: --config FILE | --dataset NAME --scale F\n\
         \x20              --nodes N --lambda F --epsilon F --max-iterations N --trials N\n\
         \x20              --topology complete|ring|torus|k-regular|small-world|\n\
         \x20              power-law|partition --mixer push-sum|gradient-flow\n\
         \x20              --backend native|xla --batch-size N --local-steps N --seed N\n\
         \x20              --scheduler sequential|parallel|async --threads N\n\
         \x20              --link-latency N --link-drop F (async network scenarios)\n\
         \x20              --kernel scalar|simd|auto (simd needs --features simd)\n\
         \x20              --step dense|scaled|auto (solver step representation;\n\
         \x20              auto = the O(nnz) scaled fast path, dense = the O(d)\n\
         \x20              reference loop)\n\
         \x20              --stream (or --stream-rate F --stream-schedule\n\
         \x20              uniform|random|tail:<file> --stream-max-rows N\n\
         \x20              --stream-initial F) for online per-node ingestion\n\
         \x20              --http-ingest ADDR to accept arrival rows over HTTP\n\
         \x20              (POST /ingest, POST /shutdown; trials must be 1;\n\
         \x20              --queue-depth N --deadline-ms N --workers N tune the\n\
         \x20              transport)\n\
         \x20              --store auto|static|mmap for the pack: data plane\n\
         \x20              --save FILE to persist the consensus model artifact)\n\
         \x20 pack         convert LIBSVM text to a mapped columnar artifact\n\
         \x20              (--input FILE required; --output FILE, default\n\
         \x20              <input>.gpack; --dim N to fix the feature space,\n\
         \x20              default infer; --shuffle SEED for a seeded row\n\
         \x20              permutation recorded in the header flags;\n\
         \x20              then train --dataset pack:<file>)\n\
         \x20 serve        batch-score stdin rows against a saved model\n\
         \x20              (--model FILE required; --shards N --batch N\n\
         \x20              --format auto|libsvm|dense --kernel scalar|simd|auto\n\
         \x20              --scores; one prediction per input line on stdout;\n\
         \x20              --http ADDR serves POST /score over a socket instead\n\
         \x20              (HTTP/1.1 keep-alive), byte-identical to the stdin\n\
         \x20              path — --queue-depth N --deadline-ms N bound the\n\
         \x20              request queue and budget, --workers N sets the\n\
         \x20              concurrent request executors, default = shards)\n\
         \x20 baseline     run a solver centrally (--solver pegasos|svm-sgd|svm-perf|dcd,\n\
         \x20              --kernel scalar|simd|auto --step dense|scaled|auto,\n\
         \x20              same dataset options)\n\
         \x20 experiment   regenerate paper artifacts: table3 | table4 | table5 | figures |\n\
         \x20              mixing | bound | rounds | topology | churn  (--scale F --nodes N --trials N\n\
         \x20              --only a,b,... --out DIR --max-iterations N)\n\
         \x20 inspect      print dataset statistics / topology spectra / artifact registry\n\
         \n\
         Datasets: synthetic-adult, synthetic-ccat, synthetic-mnist, synthetic-reuters,\n\
         \x20        synthetic-usps, synthetic-webspam, synthetic-gisette,\n\
         \x20        path:<libsvm file>, pack:<gadget pack artifact>\n\
         \x20        (file stems containing a9a/adult, rcv1/ccat, mnist, reuters,\n\
         \x20        usps, webspam or gisette pick up the paper's Table-2 lambda)\n"
    );
}

/// Builds an ExperimentConfig from CLI options (or a --config TOML base).
fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.to_string();
    }
    cfg.scale = args.get_parsed("scale", cfg.scale).map_err(err)?;
    cfg.nodes = args.get_parsed("nodes", cfg.nodes).map_err(err)?;
    cfg.epsilon = args.get_parsed("epsilon", cfg.epsilon).map_err(err)?;
    cfg.max_iterations = args.get_parsed("max-iterations", cfg.max_iterations).map_err(err)?;
    cfg.batch_size = args.get_parsed("batch-size", cfg.batch_size).map_err(err)?;
    cfg.local_steps = args.get_parsed("local-steps", cfg.local_steps).map_err(err)?;
    cfg.gossip_rounds = args.get_parsed("gossip-rounds", cfg.gossip_rounds).map_err(err)?;
    cfg.trials = args.get_parsed("trials", cfg.trials).map_err(err)?;
    cfg.seed = args.get_parsed("seed", cfg.seed).map_err(err)?;
    cfg.snapshot_every = args.get_parsed("snapshot-every", cfg.snapshot_every).map_err(err)?;
    if let Some(l) = args.get("lambda") {
        cfg.lambda = Some(l.parse().map_err(|e| anyhow::anyhow!("--lambda: {e}"))?);
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = t.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = s.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    cfg.threads = args.get_parsed("threads", cfg.threads).map_err(err)?;
    if let Some(k) = args.get("kernel") {
        cfg.kernel = k.parse().map_err(|e: String| anyhow::anyhow!("--kernel: {e}"))?;
    }
    if let Some(s) = args.get("step") {
        cfg.step = s.parse().map_err(|e: String| anyhow::anyhow!("--step: {e}"))?;
    }
    if let Some(s) = args.get("store") {
        cfg.store = s.parse().map_err(|e: String| anyhow::anyhow!("--store: {e}"))?;
    }
    if let Some(m) = args.get("mixer") {
        cfg.mixer = m.parse().map_err(|e: String| anyhow::anyhow!("--mixer: {e}"))?;
    }
    cfg.link_latency = args.get_parsed("link-latency", cfg.link_latency).map_err(err)?;
    cfg.link_drop = args.get_parsed("link-drop", cfg.link_drop).map_err(err)?;
    // `[stream]` section: `--stream` alone enables the streaming data
    // plane at the default rate; the explicit options override.
    let explicit_rate = args.get("stream-rate").is_some();
    cfg.stream_rate = args.get_parsed("stream-rate", cfg.stream_rate).map_err(err)?;
    if let Some(s) = args.get("stream-schedule") {
        cfg.stream_schedule =
            s.parse().map_err(|e: String| anyhow::anyhow!("--stream-schedule: {e}"))?;
    }
    cfg.stream_max_rows =
        args.get_parsed("stream-max-rows", cfg.stream_max_rows).map_err(err)?;
    cfg.stream_initial =
        args.get_parsed("stream-initial", cfg.stream_initial).map_err(err)?;
    if args.has_flag("stream") && cfg.stream_rate == 0.0 {
        // `--stream --stream-rate 0` is a contradiction, not a default.
        anyhow::ensure!(
            !explicit_rate,
            "--stream contradicts --stream-rate 0 (drop one of them)"
        );
        cfg.stream_rate = 1.0;
    }
    // Stream options without a rate would silently run the static
    // pipeline while the user believes they benchmarked online
    // ingestion — the mislabeled-run case this codebase forbids.
    if cfg.stream_rate == 0.0 {
        for opt in ["stream-schedule", "stream-max-rows", "stream-initial"] {
            anyhow::ensure!(
                args.get(opt).is_none(),
                "--{opt} has no effect while streaming is off — pass --stream \
                 or --stream-rate F to enable the streaming data plane"
            );
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

fn err(e: String) -> anyhow::Error {
    anyhow::anyhow!(e)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let scale = cfg.scale;
    // drift reporting covers both arrival planes: the [stream] schedules
    // and live HTTP ingestion
    let streaming = cfg.streaming_enabled() || args.get("http-ingest").is_some();
    println!(
        "GADGET: dataset={} scale={} nodes={} topology={} backend={:?} scheduler={} kernel={} step={} trials={}",
        cfg.dataset,
        cfg.scale,
        cfg.nodes,
        cfg.topology,
        cfg.backend,
        cfg.scheduler,
        cfg.kernel,
        cfg.step,
        cfg.trials
    );
    // Echo the resolved consensus scenario: the trial-0 overlay (seeded
    // exactly as the runner seeds it), its spectral figures, and the
    // mixing rounds each iteration will actually use.
    {
        let g = gadget::topology::Graph::generate(
            cfg.topology,
            cfg.nodes,
            cfg.seed ^ gadget::coordinator::GRAPH_SEED,
        );
        let b = gadget::topology::TransitionMatrix::from_graph(&g, cfg.weights);
        let tau = gadget::topology::mixing_time(&b, cfg.gamma);
        let rounds =
            if cfg.gossip_rounds > 0 { cfg.gossip_rounds } else { tau.min(10_000) };
        println!(
            "mixing: mixer={} topology={} rounds/iter={} tau(gamma={})={} lambda2={:.4}",
            cfg.mixer,
            cfg.topology,
            rounds,
            cfg.gamma,
            tau,
            gadget::topology::second_eigenvalue(&b, 300)
        );
        if cfg.link_latency > 0 || cfg.link_drop > 0.0 {
            println!(
                "links: latency<={} cycles, drop={:.3}",
                cfg.link_latency, cfg.link_drop
            );
        }
    }
    if cfg.streaming_enabled() {
        println!(
            "stream: rate={} schedule={} max-rows={} initial={}",
            cfg.stream_rate,
            cfg.stream_schedule,
            cfg.stream_max_rows,
            cfg.stream_initial
        );
    }
    // `--http-ingest ADDR`: arrival rows come over HTTP instead of a
    // held-out pool or tailed file. Capture the transport knobs before
    // cfg moves into the runner.
    let http_ingest = args.get("http-ingest").map(str::to_string);
    let http_cfg = gadget::serve::HttpConfig {
        queue_depth: args.get_parsed("queue-depth", cfg.serve_queue_depth).map_err(err)?,
        deadline_ms: args.get_parsed("deadline-ms", cfg.serve_deadline_ms).map_err(err)?,
        workers: args.get_parsed("workers", cfg.serve_workers).map_err(err)?,
    };
    let runner = GadgetRunner::new(cfg)?;
    println!(
        "data: {} train / {} test samples, d={}, lambda={:.3e}",
        runner.train_len(),
        runner.test_data().len(),
        runner.train_dim(),
        runner.lambda(),
    );
    let (runner, http_server) = match &http_ingest {
        Some(addr) => {
            // The queue validates dimensions at admission, so it must be
            // built against the loaded training plane's feature space.
            let queue = gadget::data::ArrivalQueue::bounded(
                http_cfg.queue_depth,
                runner.train_dim(),
            );
            let server = gadget::serve::HttpServer::start(
                addr,
                http_cfg,
                None,
                Some(queue.clone()),
            )?;
            println!(
                "http-ingest: POST rows to http://{}/ingest; POST /shutdown closes \
                 the stream (convergence is vetoed while it is open)",
                server.local_addr()
            );
            (runner.with_http_ingest(queue), Some(server))
        }
        None => (runner, None),
    };
    let report = runner.run()?;
    if let Some(server) = http_server {
        let stats = server.shutdown_and_join()?;
        println!(
            "http-ingest     : {} rows accepted over {} requests ({} refused)",
            stats.ingested_rows, stats.requests, stats.refused
        );
    }
    println!("\n== GADGET report ==");
    println!(
        "test accuracy   : {:.2}% (±{:.2})",
        100.0 * report.test_accuracy,
        100.0 * report.test_accuracy_std
    );
    println!("train time      : {:.3}s (±{:.3})", report.train_secs, report.train_secs_std);
    println!("primal objective: {:.6}", report.objective);
    println!("iterations      : {:.1} (mean over trials)", report.iterations);
    println!("eps@convergence : {:.6}", report.epsilon_final);
    let g = report.trials[0].gossip;
    println!(
        "gossip (trial 0): {} rounds, {} messages, {:.2} MB{}",
        g.rounds,
        g.messages,
        g.bytes as f64 / 1e6,
        if g.dropped > 0 { format!(", {} dropped", g.dropped) } else { String::new() }
    );
    if streaming {
        let drift = &report.trials[0].drift;
        let total: usize = drift.iter().map(|e| e.added).sum();
        match drift.last() {
            Some(last) => println!(
                "drift (trial 0) : {} arrival events, {} rows; last @iter {} node {}: \
                 label-balance {:.2}, mean ||x|| {:.3}",
                drift.len(),
                total,
                last.iteration,
                last.node,
                last.label_balance,
                last.mean_norm
            ),
            None => println!("drift (trial 0) : no rows arrived"),
        }
    }
    if let Some(path) = args.get("save") {
        let artifact = gadget::serve::ModelArtifact::from_report(&report, scale)?;
        artifact.save(path)?;
        println!(
            "model saved     : {path} (format {} v{}, dim {})",
            gadget::serve::FORMAT_NAME,
            gadget::serve::FORMAT_VERSION,
            artifact.dim
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model_path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("serve: --model FILE is required"))?;
    // `[serve]` config section as the baseline, CLI flags override — the
    // same precedence `train` gives `[runtime]`.
    let cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(path)?,
        None => ExperimentConfig::default(),
    };
    let opts = gadget::serve::ServeOptions {
        shards: args.get_parsed("shards", cfg.serve_shards).map_err(err)?,
        batch: args.get_parsed("batch", cfg.serve_batch).map_err(err)?,
        format: args
            .get("format")
            .unwrap_or("auto")
            .parse()
            .map_err(|e: String| anyhow::anyhow!("--format: {e}"))?,
        emit_scores: args.has_flag("scores"),
        kernel: match args.get("kernel") {
            Some(k) => k.parse().map_err(|e: String| anyhow::anyhow!("--kernel: {e}"))?,
            None => cfg.kernel,
        },
    };
    let artifact = gadget::serve::ModelArtifact::load(model_path)?;
    // (run_serve emits the self-describing startup line on stderr — it is
    // where shards/kernel are resolved; only the path is known just here.)
    eprintln!("serve: model={model_path}");
    // `--http ADDR` (or `[serve] http`) swaps the stdin transport for the
    // HTTP front end; scoring itself is the same loop either way.
    let http_addr =
        args.get("http").map(str::to_string).or_else(|| cfg.serve_http.clone());
    if let Some(addr) = http_addr {
        let http = gadget::serve::HttpConfig {
            queue_depth: args
                .get_parsed("queue-depth", cfg.serve_queue_depth)
                .map_err(err)?,
            deadline_ms: args
                .get_parsed("deadline-ms", cfg.serve_deadline_ms)
                .map_err(err)?,
            workers: args.get_parsed("workers", cfg.serve_workers).map_err(err)?,
        };
        let shards = gadget::coordinator::sched::resolve_threads(opts.shards);
        let kernel = opts.kernel.build()?;
        eprintln!(
            "serve: dim={} classes={} shards={} batch={} kernel={}",
            artifact.dim,
            artifact.classes(),
            shards,
            opts.batch,
            kernel.name()
        );
        let scorer = gadget::serve::ShardedScorer::with_kernel(artifact, shards, kernel);
        let opts = gadget::serve::ServeOptions { shards, ..opts };
        let server = gadget::serve::HttpServer::start(&addr, http, Some((scorer, opts)), None)?;
        // Blocks until a `POST /shutdown` triggers the graceful drain.
        let stats = server.join()?;
        eprintln!(
            "served {} rows over {} requests ({} ingested, {} refused)",
            stats.scored_rows, stats.requests, stats.ingested_rows, stats.refused
        );
        return Ok(());
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let stats = gadget::serve::run_serve(
        artifact,
        &opts,
        &mut stdin.lock(),
        &mut std::io::BufWriter::new(stdout.lock()),
    )?;
    eprintln!(
        "served {} rows in {} batches (shards = {})",
        stats.rows, stats.batches, stats.shards
    );
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let which = args.get("solver").unwrap_or("pegasos").to_string();
    let runner = GadgetRunner::new(cfg.clone())?;
    let lambda = runner.lambda();
    // The borrowed view works for every data plane — a `pack:` corpus
    // trains the baselines straight off the mapped artifact.
    let train = runner.train_view();
    let test = runner.test_data();
    // `--kernel` / `--step` reach the centralized baselines too, so kernel
    // and step A/B numbers can be taken on the exact solvers the tables
    // use.
    let kernel = cfg.kernel.build()?;
    let mut solver: Box<dyn Solver> = match which.as_str() {
        "pegasos" => Box::new(gadget::solver::Pegasos::with_options(
            gadget::solver::PegasosParams {
                lambda,
                iterations: experiments::table3::centralized_iterations(runner.train_len()),
                batch_size: cfg.batch_size,
                project: true,
                seed: cfg.seed,
            },
            kernel,
            cfg.step,
        )),
        "svm-sgd" => Box::new(gadget::solver::SvmSgd::with_options(
            gadget::solver::SvmSgdParams { lambda, epochs: 10, seed: cfg.seed },
            kernel,
            cfg.step,
        )),
        "svm-perf" => {
            // The cutting-plane solver runs on the scalar reference loops;
            // accepting --kernel simd here would silently measure scalar —
            // the fallback the kernel layer forbids.
            anyhow::ensure!(
                kernel.name() == "scalar",
                "--solver svm-perf supports only --kernel scalar"
            );
            Box::new(gadget::solver::SvmPerf::new(gadget::solver::SvmPerfParams {
                lambda,
                ..Default::default()
            }))
        }
        "dcd" => Box::new(
            gadget::solver::DualCoordinateDescent::new(lambda, 200, 1e-8, cfg.seed)
                .with_kernel(kernel),
        ),
        other => anyhow::bail!("unknown solver {other:?}"),
    };
    let sw = Stopwatch::new();
    let model = solver.fit_view(train);
    let secs = sw.secs();
    println!("== {} on {} ==", solver.name(), cfg.dataset);
    println!("train time      : {secs:.3}s");
    println!("test accuracy   : {:.2}%", 100.0 * gadget::metrics::accuracy(&model.w, test));
    println!(
        "primal objective: {:.6}",
        gadget::metrics::objective_view(&model.w, train, lambda)
    );
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    let input = args
        .get("input")
        .ok_or_else(|| anyhow::anyhow!("pack: --input FILE (LIBSVM text) is required"))?;
    let output = match args.get("output") {
        Some(o) => std::path::PathBuf::from(o),
        None => std::path::Path::new(input).with_extension("gpack"),
    };
    let dim = args.get_parsed("dim", 0usize).map_err(err)?;
    let shuffle = match args.get("shuffle") {
        Some(s) => {
            Some(s.parse::<u64>().map_err(|e| anyhow::anyhow!("--shuffle: {e}"))?)
        }
        None => None,
    };
    let sw = Stopwatch::new();
    let summary = gadget::data::pack::pack_libsvm_opts(
        std::path::Path::new(input),
        &output,
        dim,
        shuffle,
    )?;
    println!("packed {} -> {}", input, output.display());
    println!("  rows     : {}", summary.rows);
    println!("  features : {}", summary.dim);
    println!("  nnz      : {}", summary.nnz);
    println!("  bytes    : {} ({:.2} MB)", summary.bytes, summary.bytes as f64 / 1e6);
    if let Some(seed) = shuffle {
        println!("  shuffle  : seeded row permutation (seed {seed}; header flag set)");
    }
    println!("  took     : {:.3}s", sw.secs());
    println!("train with: gadget train --dataset pack:{}", output.display());
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("table3");
    let opts = ExperimentOpts {
        scale: args.get_parsed("scale", 0.05).map_err(err)?,
        nodes: args.get_parsed("nodes", 10).map_err(err)?,
        trials: args.get_parsed("trials", 5).map_err(err)?,
        seed: args.get_parsed("seed", 17u64).map_err(err)?,
        out_dir: args.get("out").unwrap_or("results").into(),
        only: args.get_list("only"),
        max_iterations: args.get_parsed("max-iterations", 1_500).map_err(err)?,
    };
    match which {
        "table3" => {
            let rows = experiments::table3::run(&opts)?;
            let table = experiments::table3::render(&rows);
            println!("\nTable 3 — GADGET vs centralized Pegasos (model-build time only)\n");
            print!("{}", table.render());
            experiments::write_output(&opts.out_file("table3.csv")?, &table.to_csv())?;
            experiments::write_output(
                &opts.out_file("table3.json")?,
                &experiments::table3::to_json(&rows).to_pretty(),
            )?;
        }
        "table4" => {
            let rows = experiments::table4::run(&opts)?;
            let table = experiments::table4::render(&rows);
            println!("\nTable 4 — GADGET vs SVM-Perf vs SVM-SGD (per-node)\n");
            print!("{}", table.render());
            experiments::write_output(&opts.out_file("table4.csv")?, &table.to_csv())?;
            experiments::write_output(
                &opts.out_file("table4.json")?,
                &experiments::table4::to_json(&rows).to_pretty(),
            )?;
        }
        "table5" => {
            let rows = experiments::table5::run(&opts)?;
            let table = experiments::table5::render(&rows);
            println!("\nTable 5 — including data-loading time; Speedup = T_dist / T_central\n");
            print!("{}", table.render());
            experiments::write_output(&opts.out_file("table5.csv")?, &table.to_csv())?;
            experiments::write_output(
                &opts.out_file("table5.json")?,
                &experiments::table5::to_json(&rows).to_pretty(),
            )?;
        }
        "figures" => {
            let series = experiments::figures::run(&opts)?;
            for s in &series {
                println!("\n{}", experiments::figures::ascii_plot(s, 76, 14));
                let name = s.dataset.replace("synthetic-", "");
                experiments::write_output(
                    &opts.out_file(&format!("figure_{name}.csv"))?,
                    &experiments::figures::to_csv(s),
                )?;
            }
        }
        "mixing" => {
            let m = args.get_parsed("m", 24usize).map_err(err)?;
            let gamma = args.get_parsed("gamma", 1e-3).map_err(err)?;
            let rows = experiments::ablation::pushsum_topology(m, gamma, opts.seed)?;
            println!("\nPush-Sum mixing: measured vs spectral prediction (γ = {gamma})\n");
            print!("{}", experiments::ablation::render_mixing(&rows).render());
        }
        "bound" => {
            let cfg = ExperimentConfig::builder()
                .dataset(args.get("dataset").unwrap_or("synthetic-usps"))
                .scale(opts.scale)
                .nodes(opts.nodes.min(6))
                .seed(opts.seed)
                .build()?;
            let rows = experiments::ablation::bound_check(&cfg, &[50, 200, 800])?;
            println!("\nTheorem 2 sub-optimality bound check\n");
            print!("{}", experiments::ablation::render_bound(&rows).render());
        }
        "topology" => {
            let rows = experiments::topology::run(&opts)?;
            let table = experiments::topology::render(&rows);
            println!("\nConvergence vs topology — mixing backends over overlay scenarios\n");
            print!("{}", table.render());
            experiments::write_output(&opts.out_file("topology.csv")?, &table.to_csv())?;
            experiments::write_output(
                &opts.out_file("topology.json")?,
                &experiments::topology::to_json(&rows).to_pretty(),
            )?;
        }
        "churn" => {
            let cfg = ExperimentConfig::builder()
                .dataset(args.get("dataset").unwrap_or("synthetic-usps"))
                .scale(opts.scale)
                .nodes(opts.nodes)
                .max_iterations(opts.max_iterations.min(600))
                .seed(opts.seed)
                .build()?;
            let rows =
                experiments::ablation::churn_resilience(&cfg, &[0.0, 0.005, 0.02, 0.05])?;
            println!("\nNode-failure resilience (paper §5 future work)\n");
            print!("{}", experiments::ablation::render_churn(&rows).render());
        }
        "rounds" => {
            let cfg = ExperimentConfig::builder()
                .dataset(args.get("dataset").unwrap_or("synthetic-usps"))
                .scale(opts.scale)
                .nodes(opts.nodes)
                .trials(1)
                .max_iterations(opts.max_iterations.min(300))
                .seed(opts.seed)
                .build()?;
            let rows = experiments::ablation::gossip_rounds_sweep(&cfg, &[1, 2, 4, 8, 16])?;
            println!("\nGossip rounds per iteration sweep\n");
            print!("{}", experiments::ablation::render_sweep(&rows).render());
        }
        other => anyhow::bail!(
            "unknown experiment {other:?} (table3|table4|table5|figures|mixing|bound|rounds|topology|churn)"
        ),
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if args.has_flag("artifacts") || args.get("dataset").is_none() {
        match gadget::runtime::ArtifactRegistry::load(gadget::runtime::artifacts_dir()) {
            Ok(reg) => {
                println!(
                    "artifact registry ({}):",
                    gadget::runtime::artifacts_dir().display()
                );
                for e in reg.entries() {
                    println!(
                        "  {} d={} batch={} steps={} -> {}",
                        e.kernel,
                        e.d,
                        e.batch,
                        e.steps,
                        e.path.display()
                    );
                }
                reg.check_files()?;
                println!("all artifact files present");
            }
            Err(e) => println!("no artifacts: {e}"),
        }
        if args.get("dataset").is_none() {
            return Ok(());
        }
    }
    let cfg = config_from_args(args)?;
    if let Some(path) = cfg.dataset.strip_prefix("pack:") {
        // Inspect reads the artifact header + mapped columns directly —
        // no training split is materialized.
        let pack = gadget::data::PackFile::open(path)?;
        let n = pack.len();
        let n_train = n * 2 / 3;
        let pos = pack.labels().iter().filter(|&&y| y > 0).count();
        println!("pack {}:", pack.name());
        println!("  rows          : {n} ({n_train} train / {} test, contiguous 2:1)", n - n_train);
        println!("  features      : {}", pack.dim());
        println!("  stored nnz    : {}", pack.nnz());
        println!(
            "  row order     : {}",
            if pack.is_shuffled() { "shuffled at pack time (header flag)" } else { "source order" }
        );
        println!(
            "  density       : {:.4}%",
            100.0 * pack.nnz() as f64 / (n as f64 * pack.dim() as f64)
        );
        println!("  positive rate : {:.3}", pos as f64 / n as f64);
        match cfg.lambda.or(gadget::coordinator::lambda_for_corpus(path)) {
            Some(l) => println!("  lambda        : {l:.3e}"),
            None => println!("  lambda        : none (stem not in Table 2 — pass --lambda)"),
        }
    } else {
        let runner = GadgetRunner::new(cfg.clone())?;
        let ds = runner.train_data();
        println!("dataset {}:", ds.name);
        println!("  train samples : {}", ds.len());
        println!("  test samples  : {}", runner.test_data().len());
        println!("  features      : {}", ds.dim);
        println!("  density       : {:.4}%", 100.0 * ds.density());
        println!("  positive rate : {:.3}", ds.positive_rate());
        println!("  lambda        : {:.3e}", runner.lambda());
    }
    let g = gadget::topology::Graph::generate(cfg.topology, cfg.nodes, cfg.seed);
    let b = gadget::topology::TransitionMatrix::from_graph(
        &g,
        gadget::topology::stochastic::WeightScheme::MetropolisHastings,
    );
    println!("topology {} (m={}):", cfg.topology, cfg.nodes);
    println!("  edges    : {}", g.edge_count());
    println!("  diameter : {}", g.diameter());
    println!("  lambda2  : {:.4}", gadget::topology::second_eigenvalue(&b, 300));
    println!(
        "  tau(gamma={}) : {} rounds",
        cfg.gamma,
        gadget::topology::mixing_time(&b, cfg.gamma)
    );
    Ok(())
}
