//! Scaled-vector representation `w = a·v`.
//!
//! Pegasos/SVM-SGD multiply the whole weight vector by `(1 − λαₜ)` every
//! step; done naively that is `O(d)` per step and dominates on the CCAT
//! stand-in (d = 47 236, batch nnz ≈ 76). Storing `w` as a scalar `a` times
//! a dense `v` turns the shrink into `a ← a·(1−λαₜ)` — O(1) — while sparse
//! sub-gradient adds become `v[i] += (c/a)·x_i` — O(nnz). This is the
//! classic trick from the SVM-SGD code and Pegasos §4; it is the single
//! biggest native-path optimization (see EXPERIMENTS.md §Perf).

/// A dense vector with a multiplicative scale factor.
#[derive(Clone, Debug)]
pub struct ScaledVector {
    scale: f64,
    v: Vec<f64>,
    /// Cached ‖w‖² = scale²·‖v‖², maintained incrementally so projection
    /// (which Pegasos does every step) is O(1) too.
    norm_sq_v: f64,
}

impl ScaledVector {
    /// Zero vector of dimension `d`.
    pub fn zeros(d: usize) -> Self {
        Self { scale: 1.0, v: vec![0.0; d], norm_sq_v: 0.0 }
    }

    /// Dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.v.len()
    }

    /// Current scale factor.
    #[inline]
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// `‖w‖²` in O(1).
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        self.scale * self.scale * self.norm_sq_v
    }

    /// `⟨w, x⟩` for sparse `x` — O(nnz), on the scalar reference kernel.
    /// Accepts `&SparseVec` or a zero-copy [`crate::linalg::RowRef`].
    #[inline]
    pub fn dot_sparse<'a>(&self, x: impl Into<crate::linalg::RowRef<'a>>) -> f64 {
        self.scale * x.into().dot_dense(&self.v)
    }

    /// `⟨w, x⟩` on an explicit kernel backend — the hot-path variant the
    /// solvers use ([`Self::dot_sparse`] ≡ this on the scalar kernel).
    #[inline]
    pub fn dot_sparse_k<'a>(
        &self,
        x: impl Into<crate::linalg::RowRef<'a>>,
        kernel: &dyn crate::linalg::Kernel,
    ) -> f64 {
        self.scale * kernel.dot_row(x.into(), &self.v)
    }

    /// The raw (unscaled) dense storage `v` — what kernel-backed batch
    /// operations (e.g. [`crate::linalg::Kernel::hinge_subgrad_accum`])
    /// read together with [`Self::scale`].
    #[inline]
    pub fn storage(&self) -> &[f64] {
        &self.v
    }

    /// `w ← c·w` — O(1). Re-densifies if the scale underflows (the
    /// numerical hazard the SVM-SGD readme warns about).
    #[inline]
    pub fn scale_by(&mut self, c: f64) {
        assert!(c != 0.0, "scale_by(0) would lose the direction; use set_zero");
        self.scale *= c;
        if self.scale.abs() < 1e-120 {
            self.rescale();
        }
    }

    /// `w ← w + c·x` for sparse `x` — O(nnz), maintaining the norm cache.
    /// Accepts `&SparseVec` or a zero-copy [`crate::linalg::RowRef`].
    pub fn add_sparse<'a>(&mut self, c: f64, x: impl Into<crate::linalg::RowRef<'a>>) {
        let x = x.into();
        let ci = c / self.scale;
        for (&i, &xv) in x.indices.iter().zip(x.values) {
            let slot = &mut self.v[i as usize];
            let old = *slot;
            let new = old + ci * xv as f64;
            *slot = new;
            self.norm_sq_v += new * new - old * old;
        }
    }

    /// Projects onto the ball of radius `r`: `w ← min{1, r/‖w‖}·w` — O(1).
    pub fn project_to_ball(&mut self, r: f64) {
        let n = self.norm_sq().sqrt();
        if n > r && n > 0.0 {
            self.scale_by(r / n);
        }
    }

    /// Sets to zero, resetting the scale.
    pub fn set_zero(&mut self) {
        self.scale = 1.0;
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.norm_sq_v = 0.0;
    }

    /// Folds the scale into the storage (`scale = 1` afterwards).
    pub fn rescale(&mut self) {
        if self.scale != 1.0 {
            for x in self.v.iter_mut() {
                *x *= self.scale;
            }
            self.norm_sq_v *= self.scale * self.scale;
            self.scale = 1.0;
        }
    }

    /// Materializes `w` as a plain dense vector.
    pub fn to_dense(&self) -> Vec<f64> {
        self.v.iter().map(|&x| x * self.scale).collect()
    }

    /// Writes `w` into an existing slice (allocation-free hot-path variant).
    pub fn to_dense_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.v.len(), "to_dense_into: dim mismatch");
        for (o, &x) in out.iter_mut().zip(&self.v) {
            *o = x * self.scale;
        }
    }

    /// Loads from a dense vector.
    pub fn from_dense(w: &[f64]) -> Self {
        Self { scale: 1.0, v: w.to_vec(), norm_sq_v: crate::linalg::l2_norm_sq(w) }
    }

    /// Reloads from a dense slice in place, reusing the storage
    /// (allocation-free counterpart of [`Self::from_dense`]).
    pub fn load_dense(&mut self, w: &[f64]) {
        assert_eq!(w.len(), self.v.len(), "load_dense: dim mismatch");
        self.v.copy_from_slice(w);
        self.scale = 1.0;
        self.norm_sq_v = crate::linalg::l2_norm_sq(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SparseVec;

    #[test]
    fn matches_naive_sequence() {
        // Interleave scales and sparse adds; compare against a plain vector.
        let mut sv = ScaledVector::zeros(6);
        let mut naive = vec![0.0f64; 6];
        let x1 = SparseVec::new(vec![0, 3], vec![1.0, -2.0]);
        let x2 = SparseVec::new(vec![1, 3, 5], vec![0.5, 0.5, 4.0]);
        let ops: Vec<(f64, Option<&SparseVec>)> =
            vec![(1.0, Some(&x1)), (0.9, None), (-0.5, Some(&x2)), (0.99, None), (2.0, Some(&x1))];
        for (c, x) in ops {
            match x {
                Some(x) => {
                    sv.add_sparse(c, x);
                    x.axpy_into(c, &mut naive);
                }
                None => {
                    sv.scale_by(c);
                    crate::linalg::scale_assign(c, &mut naive);
                }
            }
        }
        let dense = sv.to_dense();
        for i in 0..6 {
            assert!((dense[i] - naive[i]).abs() < 1e-12, "slot {i}");
        }
        assert!((sv.norm_sq() - crate::linalg::l2_norm_sq(&naive)).abs() < 1e-12);
    }

    #[test]
    fn dot_respects_scale() {
        let mut sv = ScaledVector::from_dense(&[1.0, 2.0, 0.0]);
        sv.scale_by(0.5);
        let x = SparseVec::new(vec![0, 1], vec![2.0, 1.0]);
        assert!((sv.dot_sparse(&x) - (0.5 * (2.0 + 2.0))).abs() < 1e-12);
    }

    #[test]
    fn projection_caps_norm() {
        let mut sv = ScaledVector::from_dense(&[3.0, 4.0]);
        sv.project_to_ball(2.5);
        assert!((sv.norm_sq().sqrt() - 2.5).abs() < 1e-12);
        // inside the ball: unchanged
        let before = sv.to_dense();
        sv.project_to_ball(10.0);
        assert_eq!(sv.to_dense(), before);
    }

    #[test]
    fn underflow_triggers_rescale() {
        let mut sv = ScaledVector::from_dense(&[1.0]);
        for _ in 0..5000 {
            sv.scale_by(0.9);
        }
        // value underflows to ~0 but the representation stays finite
        assert!(sv.scale().abs() >= 1e-130);
        assert!(sv.to_dense()[0].is_finite());
    }

    #[test]
    fn set_zero_resets() {
        let mut sv = ScaledVector::from_dense(&[1.0, -2.0]);
        sv.scale_by(0.5);
        sv.set_zero();
        assert_eq!(sv.to_dense(), vec![0.0, 0.0]);
        assert_eq!(sv.norm_sq(), 0.0);
        assert_eq!(sv.scale(), 1.0);
    }

    #[test]
    fn rescale_is_identity_on_values() {
        let mut sv = ScaledVector::from_dense(&[2.0, 3.0]);
        sv.scale_by(0.25);
        let before = sv.to_dense();
        sv.rescale();
        assert_eq!(sv.scale(), 1.0);
        for (a, b) in sv.to_dense().iter().zip(&before) {
            assert!((a - b).abs() < 1e-15);
        }
    }
}
