//! SVM-SGD (Bottou, 1998/2010): plain stochastic gradient descent on the
//! regularized hinge objective with the `η_t = 1/(λ(t + t₀))` schedule —
//! the second online baseline of Table 4.
//!
//! Differences from Pegasos, mirroring Bottou's published solver:
//! * no projection step;
//! * the `t₀` offset is calibrated on a small sample so the first steps are
//!   not wildly too large (Bottou's `determineEta0` heuristic, simplified);
//! * samples are visited in epoch order over a shuffled permutation rather
//!   than i.i.d. draws.

use super::{LinearModel, ScaledVector, Solver, StepKind};
use crate::data::ShardView;
use crate::rng::Rng;

/// SVM-SGD hyper-parameters.
#[derive(Clone, Debug)]
pub struct SvmSgdParams {
    /// Regularization λ.
    pub lambda: f64,
    /// Number of epochs over the training set.
    pub epochs: usize,
    /// RNG seed (shuffling + t₀ calibration sample).
    pub seed: u64,
}

impl Default for SvmSgdParams {
    fn default() -> Self {
        Self { lambda: 1e-4, epochs: 5, seed: 0 }
    }
}

/// The SVM-SGD solver.
#[derive(Clone, Debug)]
pub struct SvmSgd {
    /// Parameters.
    pub params: SvmSgdParams,
    /// Kernel backend for the margin dots (scalar reference by default).
    kernel: &'static dyn crate::linalg::Kernel,
    /// Step representation (`auto` resolves to the scaled fast path).
    step: StepKind,
}

impl SvmSgd {
    /// Creates a solver with the given parameters (scalar kernel).
    pub fn new(params: SvmSgdParams) -> Self {
        Self { params, kernel: crate::linalg::kernel::scalar(), step: StepKind::Auto }
    }

    /// Creates a solver whose margin dots run on `kernel`.
    pub fn with_kernel(params: SvmSgdParams, kernel: &'static dyn crate::linalg::Kernel) -> Self {
        Self { params, kernel, step: StepKind::Auto }
    }

    /// Creates a solver with an explicit kernel backend *and* step
    /// representation (`[runtime] step` / `--step` plumb through here).
    pub fn with_options(
        params: SvmSgdParams,
        kernel: &'static dyn crate::linalg::Kernel,
        step: StepKind,
    ) -> Self {
        Self { params, kernel, step }
    }

    /// Bottou's skip-ahead heuristic for `t₀`: pick it so the initial step
    /// size `η₀ = 1/(λ·t₀)` is about 1 / (typical ‖x‖²) — keeping the first
    /// update from overshooting. We estimate the typical squared row norm
    /// from ≤ 64 samples.
    fn calibrate_t0(&self, ds: ShardView<'_>, rng: &mut Rng) -> f64 {
        let probes = ds.len().min(64);
        let mut s = 0.0;
        for _ in 0..probes {
            s += ds.rows.row(rng.below(ds.len())).l2_norm_sq();
        }
        let typical = (s / probes as f64).max(1e-12);
        // η₀ = 1/(λ t₀) = 1/typical  ⇒  t₀ = typical/λ
        (typical / self.params.lambda).max(1.0)
    }
}

impl SvmSgd {
    /// The scaled-iterate epoch loop (O(1) shrink, O(nnz) update).
    fn fit_scaled(&self, ds: ShardView<'_>, t0: f64, rng: &mut Rng) -> LinearModel {
        let p = &self.params;
        let mut w = ScaledVector::zeros(ds.dim);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        let mut t = 0.0f64;
        for _ in 0..p.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let eta = 1.0 / (p.lambda * (t + t0));
                let (x, y) = ds.sample(i);
                let margin = y * w.dot_sparse_k(x, self.kernel);
                // regularization shrink: w ← (1 − ηλ)·w
                let shrink = 1.0 - eta * p.lambda;
                if shrink > 0.0 {
                    w.scale_by(shrink);
                } else {
                    w.set_zero();
                }
                // hinge part
                if margin < 1.0 {
                    w.add_sparse(eta * y, x);
                }
                t += 1.0;
            }
        }
        LinearModel { w: w.to_dense() }
    }

    /// The O(d) dense reference loop — same shuffles, same step schedule, a
    /// plain `Vec<f64>` instead of the scaled representation (pinned
    /// against [`Self::fit_scaled`] in `rust/tests/step_equivalence.rs`).
    fn fit_dense(&self, ds: ShardView<'_>, t0: f64, rng: &mut Rng) -> LinearModel {
        let p = &self.params;
        let mut w = vec![0.0f64; ds.dim];
        let mut order: Vec<usize> = (0..ds.len()).collect();
        let mut t = 0.0f64;
        for _ in 0..p.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let eta = 1.0 / (p.lambda * (t + t0));
                let (x, y) = ds.sample(i);
                let margin = y * self.kernel.dot_row(x.into(), &w);
                let shrink = 1.0 - eta * p.lambda;
                if shrink > 0.0 {
                    crate::linalg::scale_assign(shrink, &mut w);
                } else {
                    w.fill(0.0);
                }
                if margin < 1.0 {
                    self.kernel.axpy_row(eta * y, x.into(), &mut w);
                }
                t += 1.0;
            }
        }
        LinearModel { w }
    }
}

impl Solver for SvmSgd {
    fn fit_view(&mut self, ds: ShardView<'_>) -> LinearModel {
        let p = &self.params;
        assert!(p.lambda > 0.0, "SvmSgd: lambda must be positive");
        assert!(!ds.is_empty(), "SvmSgd: empty dataset");
        let mut rng = Rng::new(p.seed);
        let t0 = self.calibrate_t0(ds, &mut rng);
        if self.step.is_scaled() {
            self.fit_scaled(ds, t0, &mut rng)
        } else {
            self.fit_dense(ds, t0, &mut rng)
        }
    }

    fn name(&self) -> &'static str {
        "svm-sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::objective;
    use crate::solver::testutil::{accuracy, easy_problem};

    #[test]
    fn learns_separable_problem() {
        let (train, test) = easy_problem(21);
        let mut s = SvmSgd::new(SvmSgdParams { lambda: 1e-3, epochs: 20, seed: 1 });
        let m = s.fit(&train);
        let acc = accuracy(&m, &test);
        assert!(acc > 0.9, "accuracy {acc}");
    }

    #[test]
    fn more_epochs_reduce_objective() {
        let (train, _) = easy_problem(22);
        let lambda = 1e-3;
        let obj = |epochs| {
            let mut s = SvmSgd::new(SvmSgdParams { lambda, epochs, seed: 2 });
            objective(&s.fit(&train).w, &train, lambda)
        };
        assert!(obj(20) < obj(1));
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, _) = easy_problem(23);
        let m1 = SvmSgd::new(SvmSgdParams { lambda: 1e-3, epochs: 3, seed: 5 }).fit(&train);
        let m2 = SvmSgd::new(SvmSgdParams { lambda: 1e-3, epochs: 3, seed: 5 }).fit(&train);
        assert_eq!(m1.w, m2.w);
    }

    #[test]
    fn dense_reference_tracks_scaled() {
        let (train, _) = easy_problem(25);
        let kernel = crate::linalg::kernel::scalar();
        let p = SvmSgdParams { lambda: 1e-3, epochs: 2, seed: 4 };
        let md =
            SvmSgd::with_options(p.clone(), kernel, crate::linalg::StepKind::Dense).fit(&train);
        let ms = SvmSgd::with_options(p, kernel, crate::linalg::StepKind::Scaled).fit(&train);
        for (a, b) in md.w.iter().zip(&ms.w) {
            assert!((a - b).abs() <= 1e-10 * (1.0 + a.abs().max(b.abs())), "{a} vs {b}");
        }
    }

    #[test]
    fn comparable_to_pegasos_on_same_budget() {
        let (train, test) = easy_problem(24);
        let lambda = 1e-3;
        let sgd = SvmSgd::new(SvmSgdParams { lambda, epochs: 10, seed: 3 }).fit(&train);
        let mut peg = crate::solver::Pegasos::new(crate::solver::PegasosParams {
            lambda,
            iterations: 10 * train.len(),
            batch_size: 1,
            project: true,
            seed: 3,
        });
        let pm = crate::solver::Solver::fit(&mut peg, &train);
        let a_sgd = accuracy(&sgd, &test);
        let a_peg = accuracy(&pm, &test);
        assert!((a_sgd - a_peg).abs() < 0.08, "sgd {a_sgd} vs pegasos {a_peg}");
    }
}
