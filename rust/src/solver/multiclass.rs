//! Multi-class linear SVM via one-vs-rest reduction — the first item on
//! the paper's §5 future-work list ("extension to multi-class variants of
//! SVMs").
//!
//! A `MulticlassDataset` carries labels in `0..K`; training builds one
//! binary task per class (`+1` = class k, `−1` = rest) and fits any
//! binary [`super::Solver`] — including the distributed GADGET runner via
//! [`crate::coordinator::multiclass::MulticlassGadget`] — producing a
//! `K×d` score matrix with argmax decoding.

use super::LinearModel;
use crate::data::Dataset;
use crate::linalg::SparseVec;

/// A dataset with labels in `0..num_classes`.
#[derive(Clone, Debug, Default)]
pub struct MulticlassDataset {
    /// Class count `K`.
    pub num_classes: usize,
    /// Feature dimension.
    pub dim: usize,
    /// Feature rows.
    pub rows: Vec<SparseVec>,
    /// Labels in `0..num_classes`.
    pub labels: Vec<u32>,
    /// Name for reports.
    pub name: String,
}

impl MulticlassDataset {
    /// Builds and validates.
    pub fn new(
        name: impl Into<String>,
        num_classes: usize,
        dim: usize,
        rows: Vec<SparseVec>,
        labels: Vec<u32>,
    ) -> Self {
        assert_eq!(rows.len(), labels.len(), "Multiclass: rows/labels mismatch");
        assert!(num_classes >= 2, "Multiclass: need at least 2 classes");
        for r in &rows {
            assert!(r.min_dim() <= dim, "Multiclass: row exceeds dim");
        }
        for &y in &labels {
            assert!((y as usize) < num_classes, "Multiclass: label out of range");
        }
        Self { name: name.into(), num_classes, dim, rows, labels }
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The binary one-vs-rest view for class `k`.
    pub fn binary_view(&self, k: u32) -> Dataset {
        Dataset::new(
            format!("{}-ovr{}", self.name, k),
            self.dim,
            self.rows.clone(),
            self.labels.iter().map(|&y| if y == k { 1 } else { -1 }).collect(),
        )
    }
}

/// A trained one-vs-rest model: `K` weight vectors, argmax decoding.
#[derive(Clone, Debug, Default)]
pub struct MulticlassModel {
    /// Per-class scorers.
    pub models: Vec<LinearModel>,
}

impl MulticlassModel {
    /// Predicted class = argmax_k ⟨w_k, x⟩.
    pub fn predict(&self, x: &SparseVec) -> u32 {
        let mut best = 0u32;
        let mut best_score = f64::NEG_INFINITY;
        for (k, m) in self.models.iter().enumerate() {
            let s = m.score(x);
            if s > best_score {
                best_score = s;
                best = k as u32;
            }
        }
        best
    }

    /// Accuracy on a multiclass dataset.
    pub fn accuracy(&self, ds: &MulticlassDataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let correct = ds
            .rows
            .iter()
            .zip(&ds.labels)
            .filter(|(x, &y)| self.predict(x) == y)
            .count();
        correct as f64 / ds.len() as f64
    }

    /// Per-class confusion matrix (`row = truth, col = prediction`).
    pub fn confusion(&self, ds: &MulticlassDataset) -> Vec<Vec<usize>> {
        let k = self.models.len();
        let mut cm = vec![vec![0usize; k]; k];
        for (x, &y) in ds.rows.iter().zip(&ds.labels) {
            cm[y as usize][self.predict(x) as usize] += 1;
        }
        cm
    }
}

/// Trains one-vs-rest with a solver factory (one fresh solver per class).
pub fn train_one_vs_rest<S: super::Solver>(
    ds: &MulticlassDataset,
    mut make: impl FnMut(u32) -> S,
) -> MulticlassModel {
    let models = (0..ds.num_classes as u32)
        .map(|k| {
            let view = ds.binary_view(k);
            make(k).fit(&view)
        })
        .collect();
    MulticlassModel { models }
}

/// Seeded synthetic multiclass problem: `K` Gaussian class means on the
/// unit sphere, rows `x = (z + SNR·√(d/nnz)·μ_y)/√nnz` — the multiclass
/// generalization of the binary stand-in generator.
pub fn generate_multiclass(
    num_classes: usize,
    n: usize,
    dim: usize,
    nnz_per_row: usize,
    noise: f64,
    seed: u64,
) -> MulticlassDataset {
    use crate::rng::Rng;
    assert!(num_classes >= 2);
    let mut rng = Rng::new(seed ^ 0x6d63);
    // class means: unit gaussian directions
    let mut means: Vec<Vec<f64>> = Vec::with_capacity(num_classes);
    for _ in 0..num_classes {
        let mut mu: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let norm = crate::linalg::l2_norm(&mu);
        mu.iter_mut().for_each(|v| *v /= norm);
        means.push(mu);
    }
    let nnz = if nnz_per_row == 0 { dim } else { nnz_per_row.min(dim) };
    let snr = 3.0;
    let shift = snr * (dim as f64 / nnz as f64).sqrt();
    let inv = 1.0 / (nnz as f64).sqrt();
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let mut y = rng.below(num_classes) as u32;
        let idx: Vec<u32> =
            if nnz == dim { (0..dim as u32).collect() } else { rng.sorted_subset(dim, nnz) };
        let vals: Vec<f32> = idx
            .iter()
            .map(|&j| ((rng.normal() + shift * means[y as usize][j as usize]) * inv) as f32)
            .collect();
        if rng.flip(noise) {
            y = rng.below(num_classes) as u32;
        }
        rows.push(SparseVec::new(idx, vals));
        labels.push(y);
    }
    MulticlassDataset::new(format!("multiclass-{num_classes}"), num_classes, dim, rows, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{Pegasos, PegasosParams};

    fn problem(seed: u64) -> (MulticlassDataset, MulticlassDataset) {
        (
            generate_multiclass(4, 1200, 48, 12, 0.03, seed),
            generate_multiclass(4, 400, 48, 12, 0.03, seed + 1000),
        )
    }

    #[test]
    fn binary_view_maps_labels() {
        let ds = generate_multiclass(3, 50, 8, 4, 0.0, 1);
        let v = ds.binary_view(2);
        for (orig, mapped) in ds.labels.iter().zip(&v.labels) {
            assert_eq!(*mapped == 1, *orig == 2);
        }
    }

    #[test]
    fn one_vs_rest_learns_four_classes() {
        let (train, _) = problem(7);
        // NOTE: test sets drawn with a different seed use different class
        // means — evaluate on a held-out split of the SAME generation
        let test = MulticlassDataset::new(
            "held",
            train.num_classes,
            train.dim,
            train.rows[900..].to_vec(),
            train.labels[900..].to_vec(),
        );
        let train_part = MulticlassDataset::new(
            "tr",
            train.num_classes,
            train.dim,
            train.rows[..900].to_vec(),
            train.labels[..900].to_vec(),
        );
        let model = train_one_vs_rest(&train_part, |k| {
            Pegasos::new(PegasosParams {
                lambda: 1e-3,
                iterations: 8_000,
                batch_size: 1,
                project: true,
                seed: 11 + k as u64,
            })
        });
        let acc = model.accuracy(&test);
        assert!(acc > 0.80, "multiclass accuracy {acc}");
        // confusion matrix sums to the test size with a dominant diagonal
        let cm = model.confusion(&test);
        let total: usize = cm.iter().flatten().sum();
        assert_eq!(total, test.len());
        let diag: usize = (0..4).map(|k| cm[k][k]).sum();
        assert!(diag as f64 / total as f64 > 0.80);
    }

    #[test]
    fn predict_is_argmax() {
        let m = MulticlassModel {
            models: vec![
                LinearModel { w: vec![1.0, 0.0] },
                LinearModel { w: vec![0.0, 2.0] },
            ],
        };
        assert_eq!(m.predict(&SparseVec::new(vec![0], vec![1.0])), 0);
        assert_eq!(m.predict(&SparseVec::new(vec![1], vec![1.0])), 1);
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn label_range_checked() {
        MulticlassDataset::new("x", 2, 1, vec![SparseVec::default()], vec![5]);
    }
}
