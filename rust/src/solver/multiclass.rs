//! Multi-class linear SVM via one-vs-rest reduction — the first item on
//! the paper's §5 future-work list ("extension to multi-class variants of
//! SVMs").
//!
//! A `MulticlassDataset` carries labels in `0..K`; training builds one
//! binary task per class (`+1` = class k, `−1` = rest) and fits any
//! binary [`super::Solver`] — including the distributed GADGET runner via
//! [`crate::coordinator::multiclass::MulticlassGadget`] — producing a
//! `K×d` score matrix with argmax decoding.

use super::LinearModel;
use crate::data::Dataset;
use crate::linalg::SparseVec;

/// A dataset with labels in `0..num_classes`.
#[derive(Clone, Debug, Default)]
pub struct MulticlassDataset {
    /// Class count `K`.
    pub num_classes: usize,
    /// Feature dimension.
    pub dim: usize,
    /// Feature rows.
    pub rows: Vec<SparseVec>,
    /// Labels in `0..num_classes`.
    pub labels: Vec<u32>,
    /// Name for reports.
    pub name: String,
}

impl MulticlassDataset {
    /// Builds and validates.
    pub fn new(
        name: impl Into<String>,
        num_classes: usize,
        dim: usize,
        rows: Vec<SparseVec>,
        labels: Vec<u32>,
    ) -> Self {
        assert_eq!(rows.len(), labels.len(), "Multiclass: rows/labels mismatch");
        assert!(num_classes >= 2, "Multiclass: need at least 2 classes");
        for r in &rows {
            assert!(r.min_dim() <= dim, "Multiclass: row exceeds dim");
        }
        for &y in &labels {
            assert!((y as usize) < num_classes, "Multiclass: label out of range");
        }
        Self { name: name.into(), num_classes, dim, rows, labels }
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The binary one-vs-rest view for class `k`.
    pub fn binary_view(&self, k: u32) -> Dataset {
        Dataset::new(
            format!("{}-ovr{}", self.name, k),
            self.dim,
            self.rows.clone(),
            self.labels.iter().map(|&y| if y == k { 1 } else { -1 }).collect(),
        )
    }
}

/// A trained one-vs-rest model: `K` weight vectors, argmax decoding.
#[derive(Clone, Debug, Default)]
pub struct MulticlassModel {
    /// Per-class scorers.
    pub models: Vec<LinearModel>,
}

/// Decodes per-class scores to `(class, winning score)` — the first
/// strict maximum wins, so ties resolve to the lowest class index.
///
/// This is the **single** argmax decoder of the codebase: both
/// [`MulticlassModel::predict`] and the serve-path
/// [`crate::serve::ModelArtifact`] decode through it, so training-time
/// evaluation and the inference service can never disagree on a
/// tie-break. Under the one-vs-rest output code ([`ovr_code_matrix`])
/// argmax equals max-correlation decoding: the code-correlation of class
/// `k` is `2·s_k − Σ_j s_j`, a per-row monotone transform of `s_k`.
///
/// Returns `None` for an empty score set.
pub fn argmax_decode(scores: impl IntoIterator<Item = f64>) -> Option<(u32, f64)> {
    let mut best: Option<(u32, f64)> = None;
    for (k, s) in scores.into_iter().enumerate() {
        // Strict >, with NaN demoted below every finite score — including
        // a NaN in slot 0, which a naive first-element seed would let win
        // (the historical loop seeded with NEG_INFINITY, so a leading NaN
        // never beat a later finite score).
        let take = match best {
            None => true,
            Some((_, bs)) => s > bs || (bs.is_nan() && !s.is_nan()),
        };
        if take {
            best = Some((k as u32, s));
        }
    }
    best
}

/// The `K×K` one-vs-rest output code: `+1` on the diagonal, `-1`
/// elsewhere — the code matrix persisted into multiclass model artifacts.
pub fn ovr_code_matrix(num_classes: usize) -> Vec<Vec<i8>> {
    (0..num_classes)
        .map(|k| (0..num_classes).map(|j| if j == k { 1 } else { -1 }).collect())
        .collect()
}

impl MulticlassModel {
    /// Per-class raw scores `⟨w_k, x⟩`.
    pub fn scores(&self, x: &SparseVec) -> Vec<f64> {
        self.models.iter().map(|m| m.score(x)).collect()
    }

    /// Predicted class = argmax_k ⟨w_k, x⟩.
    pub fn predict(&self, x: &SparseVec) -> u32 {
        argmax_decode(self.models.iter().map(|m| m.score(x)))
            .expect("MulticlassModel: no class scorers")
            .0
    }

    /// Batch scoring: one predicted class per row, in row order — the
    /// decoder shape the sharded inference service fans across replicas.
    pub fn predict_batch(&self, rows: &[SparseVec]) -> Vec<u32> {
        rows.iter().map(|x| self.predict(x)).collect()
    }

    /// Accuracy on a multiclass dataset.
    pub fn accuracy(&self, ds: &MulticlassDataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let correct = ds
            .rows
            .iter()
            .zip(&ds.labels)
            .filter(|(x, &y)| self.predict(x) == y)
            .count();
        correct as f64 / ds.len() as f64
    }

    /// Per-class confusion matrix (`row = truth, col = prediction`).
    pub fn confusion(&self, ds: &MulticlassDataset) -> Vec<Vec<usize>> {
        let k = self.models.len();
        let mut cm = vec![vec![0usize; k]; k];
        for (x, &y) in ds.rows.iter().zip(&ds.labels) {
            cm[y as usize][self.predict(x) as usize] += 1;
        }
        cm
    }
}

/// Trains one-vs-rest with a solver factory (one fresh solver per class).
pub fn train_one_vs_rest<S: super::Solver>(
    ds: &MulticlassDataset,
    mut make: impl FnMut(u32) -> S,
) -> MulticlassModel {
    let models = (0..ds.num_classes as u32)
        .map(|k| {
            let view = ds.binary_view(k);
            make(k).fit(&view)
        })
        .collect();
    MulticlassModel { models }
}

/// Seeded synthetic multiclass problem: `K` Gaussian class means on the
/// unit sphere, rows `x = (z + SNR·√(d/nnz)·μ_y)/√nnz` — the multiclass
/// generalization of the binary stand-in generator.
pub fn generate_multiclass(
    num_classes: usize,
    n: usize,
    dim: usize,
    nnz_per_row: usize,
    noise: f64,
    seed: u64,
) -> MulticlassDataset {
    use crate::rng::Rng;
    assert!(num_classes >= 2);
    let mut rng = Rng::new(seed ^ 0x6d63);
    // class means: unit gaussian directions
    let mut means: Vec<Vec<f64>> = Vec::with_capacity(num_classes);
    for _ in 0..num_classes {
        let mut mu: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let norm = crate::linalg::l2_norm(&mu);
        mu.iter_mut().for_each(|v| *v /= norm);
        means.push(mu);
    }
    let nnz = if nnz_per_row == 0 { dim } else { nnz_per_row.min(dim) };
    let snr = 3.0;
    let shift = snr * (dim as f64 / nnz as f64).sqrt();
    let inv = 1.0 / (nnz as f64).sqrt();
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let mut y = rng.below(num_classes) as u32;
        let idx: Vec<u32> =
            if nnz == dim { (0..dim as u32).collect() } else { rng.sorted_subset(dim, nnz) };
        let vals: Vec<f32> = idx
            .iter()
            .map(|&j| ((rng.normal() + shift * means[y as usize][j as usize]) * inv) as f32)
            .collect();
        if rng.flip(noise) {
            y = rng.below(num_classes) as u32;
        }
        rows.push(SparseVec::new(idx, vals));
        labels.push(y);
    }
    MulticlassDataset::new(format!("multiclass-{num_classes}"), num_classes, dim, rows, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{Pegasos, PegasosParams};

    fn problem(seed: u64) -> (MulticlassDataset, MulticlassDataset) {
        (
            generate_multiclass(4, 1200, 48, 12, 0.03, seed),
            generate_multiclass(4, 400, 48, 12, 0.03, seed + 1000),
        )
    }

    #[test]
    fn binary_view_maps_labels() {
        let ds = generate_multiclass(3, 50, 8, 4, 0.0, 1);
        let v = ds.binary_view(2);
        for (orig, mapped) in ds.labels.iter().zip(&v.labels) {
            assert_eq!(*mapped == 1, *orig == 2);
        }
    }

    #[test]
    fn one_vs_rest_learns_four_classes() {
        let (train, _) = problem(7);
        // NOTE: test sets drawn with a different seed use different class
        // means — evaluate on a held-out split of the SAME generation
        let test = MulticlassDataset::new(
            "held",
            train.num_classes,
            train.dim,
            train.rows[900..].to_vec(),
            train.labels[900..].to_vec(),
        );
        let train_part = MulticlassDataset::new(
            "tr",
            train.num_classes,
            train.dim,
            train.rows[..900].to_vec(),
            train.labels[..900].to_vec(),
        );
        let model = train_one_vs_rest(&train_part, |k| {
            Pegasos::new(PegasosParams {
                lambda: 1e-3,
                iterations: 8_000,
                batch_size: 1,
                project: true,
                seed: 11 + k as u64,
            })
        });
        let acc = model.accuracy(&test);
        assert!(acc > 0.80, "multiclass accuracy {acc}");
        // confusion matrix sums to the test size with a dominant diagonal
        let cm = model.confusion(&test);
        let total: usize = cm.iter().flatten().sum();
        assert_eq!(total, test.len());
        let diag: usize = (0..4).map(|k| cm[k][k]).sum();
        assert!(diag as f64 / total as f64 > 0.80);
    }

    #[test]
    fn predict_is_argmax() {
        let m = MulticlassModel {
            models: vec![
                LinearModel { w: vec![1.0, 0.0] },
                LinearModel { w: vec![0.0, 2.0] },
            ],
        };
        assert_eq!(m.predict(&SparseVec::new(vec![0], vec![1.0])), 0);
        assert_eq!(m.predict(&SparseVec::new(vec![1], vec![1.0])), 1);
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn label_range_checked() {
        MulticlassDataset::new("x", 2, 1, vec![SparseVec::default()], vec![5]);
    }

    #[test]
    fn argmax_decode_first_max_wins_and_empty_is_none() {
        assert_eq!(argmax_decode([1.0, 3.0, 3.0, 2.0]), Some((1, 3.0)));
        assert_eq!(argmax_decode([-5.0]), Some((0, -5.0)));
        assert_eq!(argmax_decode(std::iter::empty::<f64>()), None);
        // a NaN score never beats a finite one — in any slot, including 0
        assert_eq!(argmax_decode([0.5, f64::NAN, 1.5]), Some((2, 1.5)));
        assert_eq!(argmax_decode([f64::NAN, 0.5]), Some((1, 0.5)));
        assert_eq!(argmax_decode([f64::NAN, f64::NEG_INFINITY]), Some((1, f64::NEG_INFINITY)));
        // all-NaN degenerates to the first class, like the historical loop
        assert_eq!(argmax_decode([f64::NAN, f64::NAN]).unwrap().0, 0);
    }

    #[test]
    fn ovr_code_matrix_shape() {
        let c = ovr_code_matrix(3);
        assert_eq!(c.len(), 3);
        for (k, row) in c.iter().enumerate() {
            assert_eq!(row.len(), 3);
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, if j == k { 1 } else { -1 });
            }
        }
    }

    #[test]
    fn predict_batch_matches_per_row_predict() {
        let ds = generate_multiclass(3, 40, 8, 4, 0.0, 13);
        let model = train_one_vs_rest(&ds, |k| {
            Pegasos::new(PegasosParams {
                lambda: 1e-2,
                iterations: 500,
                batch_size: 1,
                project: true,
                seed: k as u64,
            })
        });
        let batch = model.predict_batch(&ds.rows);
        assert_eq!(batch.len(), ds.len());
        for (x, &b) in ds.rows.iter().zip(&batch) {
            assert_eq!(model.predict(x), b);
            let scores = model.scores(x);
            assert_eq!(argmax_decode(scores.iter().copied()).unwrap().0, b);
        }
        assert!(model.predict_batch(&[]).is_empty());
    }
}
