//! Dual coordinate descent for L1-loss linear SVM (Hsieh et al., ICML 2008
//! — the LIBLINEAR solver). Converges to the *exact* optimum of the paper's
//! Eq. 1, so the experiment harness uses it to compute the reference
//! `f(w*)` in sub-optimality plots and the Theorem-2 bound check.
//!
//! Mapping to the paper's objective: Eq. 1 is
//! `(λ/2)‖w‖² + (1/N)Σ hinge`, which equals `C`-parameterized
//! `½‖w‖² + C·Σ hinge` scaled by λ, with `C = 1/(λN)`.
//!
//! Dual: `min_α ½ αᵀQα − 𝟙ᵀα` s.t. `0 ≤ αᵢ ≤ C`, with
//! `Q_ij = yᵢyⱼ xᵢᵀxⱼ` — solved coordinate-wise keeping `w = Σ αᵢyᵢxᵢ`.

use super::{LinearModel, Solver};
use crate::data::ShardView;
use crate::rng::Rng;

/// Dual coordinate-descent solver.
#[derive(Clone, Debug)]
pub struct DualCoordinateDescent {
    lambda: f64,
    max_epochs: usize,
    tol: f64,
    seed: u64,
    /// Filled by `fit`: number of epochs actually run.
    pub epochs_run: usize,
    /// Kernel backend for the coordinate dots/axpys (scalar by default —
    /// DCD is the reference optimizer, so swapping its kernel moves the
    /// "exact optimum" within the kernel's ULP bound too).
    kernel: &'static dyn crate::linalg::Kernel,
}

impl DualCoordinateDescent {
    /// Creates a solver for regularization `lambda`, stopping after
    /// `max_epochs` or when the maximal projected-gradient violation over
    /// an epoch falls below `tol` (scalar kernel).
    pub fn new(lambda: f64, max_epochs: usize, tol: f64, seed: u64) -> Self {
        assert!(lambda > 0.0, "DCD: lambda must be positive");
        Self {
            lambda,
            max_epochs,
            tol,
            seed,
            epochs_run: 0,
            kernel: crate::linalg::kernel::scalar(),
        }
    }

    /// Switches the coordinate dots/axpys onto `kernel`.
    pub fn with_kernel(mut self, kernel: &'static dyn crate::linalg::Kernel) -> Self {
        self.kernel = kernel;
        self
    }
}

impl Solver for DualCoordinateDescent {
    fn fit_view(&mut self, ds: ShardView<'_>) -> LinearModel {
        assert!(!ds.is_empty(), "DCD: empty dataset");
        let n = ds.len();
        let c_upper = 1.0 / (self.lambda * n as f64);
        let mut alpha = vec![0.0f64; n];
        let mut w = vec![0.0f64; ds.dim];
        // Q_ii = ‖x_i‖² (y² = 1)
        let qii: Vec<f64> = ds.rows.iter().map(|r| r.l2_norm_sq()).collect();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(self.seed);

        self.epochs_run = 0;
        for _ in 0..self.max_epochs {
            rng.shuffle(&mut order);
            let mut max_violation = 0.0f64;
            for &i in &order {
                if qii[i] <= 0.0 {
                    continue;
                }
                let (x, y) = ds.sample(i);
                // G = y·⟨w,x⟩ − 1 (gradient of the dual coordinate)
                let g = y * self.kernel.dot_row(x, &w) - 1.0;
                // projected gradient
                let pg = if alpha[i] <= 0.0 {
                    g.min(0.0)
                } else if alpha[i] >= c_upper {
                    g.max(0.0)
                } else {
                    g
                };
                max_violation = max_violation.max(pg.abs());
                if pg.abs() > 1e-14 {
                    let old = alpha[i];
                    let new = (old - g / qii[i]).clamp(0.0, c_upper);
                    if (new - old).abs() > 0.0 {
                        alpha[i] = new;
                        self.kernel.axpy_row((new - old) * y, x, &mut w);
                    }
                }
            }
            self.epochs_run += 1;
            if max_violation < self.tol {
                break;
            }
        }
        // Rescale: the C-parameterized primal is (1/λ)·Eq.1 with w shared,
        // so w is already the Eq.1 minimizer — no rescale needed.
        LinearModel { w }
    }

    fn name(&self) -> &'static str {
        "dcd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::objective;
    use crate::solver::testutil::{accuracy, easy_problem};

    #[test]
    fn reaches_low_objective() {
        let (train, test) = easy_problem(31);
        let lambda = 1e-2;
        let mut dcd = DualCoordinateDescent::new(lambda, 100, 1e-8, 1);
        let m = dcd.fit(&train);
        assert!(accuracy(&m, &test) > 0.9);
        assert!(dcd.epochs_run <= 100);
    }

    #[test]
    fn beats_or_matches_every_other_solver() {
        // DCD is the reference optimum: nothing may achieve a lower Eq.1
        // objective (modulo tolerance).
        let (train, _) = easy_problem(32);
        let lambda = 1e-2;
        let f_dcd = {
            let mut s = DualCoordinateDescent::new(lambda, 300, 1e-10, 2);
            objective(&s.fit(&train).w, &train, lambda)
        };
        let f_peg = {
            let mut s = crate::solver::Pegasos::new(crate::solver::PegasosParams {
                lambda,
                iterations: 30_000,
                batch_size: 1,
                project: true,
                seed: 2,
            });
            objective(&s.fit(&train).w, &train, lambda)
        };
        let f_sgd = {
            let mut s =
                crate::solver::SvmSgd::new(crate::solver::SvmSgdParams { lambda, epochs: 30, seed: 2 });
            objective(&s.fit(&train).w, &train, lambda)
        };
        assert!(f_dcd <= f_peg + 1e-6, "dcd {f_dcd} vs pegasos {f_peg}");
        assert!(f_dcd <= f_sgd + 1e-6, "dcd {f_dcd} vs sgd {f_sgd}");
    }

    #[test]
    fn kkt_conditions_hold_at_convergence() {
        let (train, _) = easy_problem(33);
        let lambda = 5e-2;
        let mut dcd = DualCoordinateDescent::new(lambda, 500, 1e-10, 3);
        let m = dcd.fit(&train);
        // At the optimum: margin > 1 ⇒ no loss contribution; margin < 1
        // samples must be "support"-active. Check the sub-gradient optimality
        // residual ‖λw − (1/N)Σ_{violators} y x‖ is small in the span sense:
        // compute the primal objective and verify perturbations don't help.
        let f0 = objective(&m.w, &train, lambda);
        let mut rng = crate::rng::Rng::new(7);
        for _ in 0..10 {
            let mut w2 = m.w.clone();
            for v in w2.iter_mut() {
                *v += 1e-3 * rng.normal();
            }
            assert!(objective(&w2, &train, lambda) > f0 - 1e-9);
        }
    }

    #[test]
    fn early_stop_on_tolerance() {
        let (train, _) = easy_problem(34);
        let mut dcd = DualCoordinateDescent::new(1e-1, 10_000, 1e-3, 4);
        dcd.fit(&train);
        assert!(dcd.epochs_run < 10_000, "never hit tolerance");
    }
}
