//! SVM-Perf stand-in: a cutting-plane solver for Joachims' *structural*
//! SVM formulation with one shared slack (paper Eq. 6; Joachims KDD'06).
//!
//!   min_{w, ξ≥0}  ½‖w‖² + C·ξ
//!   s.t. ∀c ∈ {0,1}ⁿ :  (1/n)·wᵀ Σᵢ cᵢyᵢxᵢ  ≥  (1/n)·Σᵢ cᵢ − ξ
//!
//! Per cutting-plane iteration:
//! 1. find the most-violated constraint at the current `w`:
//!    `cᵢ = 1 ⇔ yᵢ⟨w,xᵢ⟩ < 1`;
//! 2. add its aggregate feature `g_c = (1/n)Σ cᵢyᵢxᵢ` and offset
//!    `Δ_c = (1/n)Σ cᵢ` to the working set;
//! 3. re-solve the reduced dual QP over the working set
//!    (`max_{α≥0, Σα≤C} Σ Δ_cα_c − ½‖Σ α_c g_c‖²`) by projected
//!    coordinate ascent;
//! 4. stop when the new constraint is violated by less than `eps`.
//!
//! This reproduces SVM-Perf's qualitative profile from Table 4: excellent
//! on small/medium dense data, increasingly slow per unit accuracy on very
//! large sparse corpora (each iteration is a full pass to find the cut).

use super::{LinearModel, Solver};
use crate::data::ShardView;
use crate::linalg;

/// Cutting-plane hyper-parameters.
#[derive(Clone, Debug)]
pub struct SvmPerfParams {
    /// Regularization λ of the paper's Eq. 1; converted internally to
    /// `C = 1/λ` for the structural program (error-rate scaling absorbed by
    /// the 1/n in the aggregate features).
    pub lambda: f64,
    /// Cutting-plane tolerance ε (constraint violation threshold).
    pub epsilon: f64,
    /// Maximum cutting-plane iterations.
    pub max_cuts: usize,
    /// Inner QP coordinate-ascent sweeps per cut.
    pub qp_sweeps: usize,
}

impl Default for SvmPerfParams {
    fn default() -> Self {
        Self { lambda: 1e-4, epsilon: 1e-3, max_cuts: 200, qp_sweeps: 100 }
    }
}

/// The cutting-plane solver.
#[derive(Clone, Debug)]
pub struct SvmPerf {
    /// Parameters.
    pub params: SvmPerfParams,
    /// Filled by `fit`: number of cuts generated.
    pub cuts_used: usize,
}

impl SvmPerf {
    /// Creates a solver with the given parameters.
    pub fn new(params: SvmPerfParams) -> Self {
        Self { params, cuts_used: 0 }
    }

    /// Most-violated constraint at `w`: select every sample with margin < 1.
    /// Returns `(g_c, Δ_c, violation ξ_c(w))`.
    fn most_violated(&self, ds: ShardView<'_>, w: &[f64]) -> (Vec<f64>, f64, f64) {
        let n = ds.len() as f64;
        let mut g = vec![0.0; ds.dim];
        let mut delta = 0.0;
        for i in 0..ds.len() {
            let (x, y) = ds.sample(i);
            if y * x.dot_dense(w) < 1.0 {
                x.axpy_into(y / n, &mut g);
                delta += 1.0 / n;
            }
        }
        let violation = delta - linalg::dot(w, &g);
        (g, delta, violation)
    }

    /// Solves the reduced dual over the working set by projected coordinate
    /// ascent: variables `α_c ≥ 0` with `Σ α_c ≤ C`, objective
    /// `Σ Δ_c α_c − ½ αᵀ H α`, `H_cd = ⟨g_c, g_d⟩`.
    fn solve_reduced_qp(
        &self,
        h: &[Vec<f64>],
        delta: &[f64],
        c_total: f64,
        alpha: &mut Vec<f64>,
    ) {
        let k = delta.len();
        alpha.resize(k, 0.0);
        // Sweep until the working-set QP is solved to high precision — an
        // under-solved inner QP stalls the outer cutting-plane loop (the
        // classic CPA failure mode), so the cap scales with the set size.
        //
        // Two move types are needed: single-coordinate steps (enough while
        // the budget Σα ≤ C is slack) and SMO-style *pairwise* transfers
        // (α_i += δ, α_j -= δ), without which coordinate ascent stalls at a
        // non-optimal point as soon as the budget binds.
        let max_sweeps = self.params.qp_sweeps.max(20 * k + 100);
        // Cached dual gradient g = Δ − Hα, updated incrementally in O(k)
        // per coordinate move so a full sweep (singles + pairs) is O(k²).
        let mut grad: Vec<f64> = (0..k)
            .map(|i| {
                let mut g = delta[i];
                for j in 0..k {
                    g -= h[i][j] * alpha[j];
                }
                g
            })
            .collect();
        let mut budget_used: f64 = alpha.iter().sum();
        for _ in 0..max_sweeps {
            let mut changed = 0.0f64;
            // single-coordinate pass (projects onto the remaining budget)
            for i in 0..k {
                if h[i][i] <= 1e-300 {
                    continue;
                }
                let mut new = alpha[i] + grad[i] / h[i][i];
                new = new.max(0.0);
                new = new.min((c_total - (budget_used - alpha[i])).max(0.0));
                let d = new - alpha[i];
                if d != 0.0 {
                    alpha[i] = new;
                    budget_used += d;
                    for (gj, hij) in grad.iter_mut().zip(&h[i]) {
                        *gj -= hij * d;
                    }
                    changed = changed.max(d.abs());
                }
            }
            // pairwise pass: budget-preserving transfers α_i += δ, α_j −= δ
            for i in 0..k {
                for j in (i + 1)..k {
                    let curv = h[i][i] - 2.0 * h[i][j] + h[j][j];
                    if curv <= 1e-300 {
                        continue;
                    }
                    // d/dδ of D(α + δ(e_i − e_j)) at δ = 0
                    let d = ((grad[i] - grad[j]) / curv).clamp(-alpha[i], alpha[j]);
                    if d != 0.0 {
                        alpha[i] += d;
                        alpha[j] -= d;
                        for (l, gl) in grad.iter_mut().enumerate() {
                            *gl -= (h[i][l] - h[j][l]) * d;
                        }
                        changed = changed.max(d.abs());
                    }
                }
            }
            if changed < 1e-12 * (1.0 + c_total) {
                break;
            }
        }
    }
}

impl Solver for SvmPerf {
    fn fit_view(&mut self, ds: ShardView<'_>) -> LinearModel {
        let p = self.params.clone();
        assert!(p.lambda > 0.0, "SvmPerf: lambda must be positive");
        assert!(!ds.is_empty(), "SvmPerf: empty dataset");
        let c_total = 1.0 / p.lambda;

        let mut w = vec![0.0; ds.dim];
        let mut cuts: Vec<Vec<f64>> = Vec::new(); // g_c features
        let mut deltas: Vec<f64> = Vec::new();
        let mut h: Vec<Vec<f64>> = Vec::new(); // gram matrix of cuts
        let mut alpha: Vec<f64> = Vec::new();

        self.cuts_used = 0;
        for _ in 0..p.max_cuts {
            let (g, delta, violation) = self.most_violated(ds, &w);
            // current slack ξ = max over working set of (Δ_c − ⟨w, g_c⟩))⁺
            let xi = deltas
                .iter()
                .zip(&cuts)
                .map(|(&d, gc)| d - linalg::dot(&w, gc))
                .fold(0.0f64, f64::max);
            if violation <= xi + p.epsilon {
                break; // no constraint violated by more than ε beyond ξ
            }
            // extend gram matrix
            let mut row: Vec<f64> = cuts.iter().map(|gc| linalg::dot(gc, &g)).collect();
            row.push(linalg::dot(&g, &g));
            for (hi, &rij) in h.iter_mut().zip(&row) {
                hi.push(rij);
            }
            h.push(row);
            cuts.push(g);
            deltas.push(delta);
            self.cuts_used += 1;

            self.solve_reduced_qp(&h, &deltas, c_total, &mut alpha);
            // w = Σ α_c g_c
            w.iter_mut().for_each(|x| *x = 0.0);
            for (a, gc) in alpha.iter().zip(&cuts) {
                linalg::axpy(*a, gc, &mut w);
            }
        }
        LinearModel { w }
    }

    fn name(&self) -> &'static str {
        "svm-perf"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::objective;
    use crate::solver::testutil::{accuracy, easy_problem};

    #[test]
    fn learns_separable_problem() {
        let (train, test) = easy_problem(41);
        let mut s = SvmPerf::new(SvmPerfParams {
            lambda: 1e-3,
            epsilon: 1e-4,
            max_cuts: 300,
            qp_sweeps: 200,
        });
        let m = s.fit(&train);
        let acc = accuracy(&m, &test);
        assert!(acc > 0.9, "accuracy {acc}");
        assert!(s.cuts_used > 0 && s.cuts_used <= 300);
    }

    #[test]
    fn few_cuts_suffice() {
        // Cutting-plane's selling point: # iterations independent of n.
        let (train, _) = easy_problem(42);
        let mut s = SvmPerf::new(SvmPerfParams {
            lambda: 1e-2,
            epsilon: 1e-3,
            max_cuts: 500,
            qp_sweeps: 200,
        });
        s.fit(&train);
        assert!(s.cuts_used < 100, "used {} cuts", s.cuts_used);
    }

    #[test]
    fn tighter_epsilon_lowers_objective() {
        let (train, _) = easy_problem(43);
        let lambda = 1e-2;
        let run = |eps: f64| {
            let mut s = SvmPerf::new(SvmPerfParams {
                lambda,
                epsilon: eps,
                max_cuts: 500,
                qp_sweeps: 300,
            });
            objective(&s.fit(&train).w, &train, lambda)
        };
        let loose = run(0.2);
        let tight = run(1e-4);
        assert!(tight <= loose + 1e-9, "tight {tight} vs loose {loose}");
    }

    #[test]
    fn approaches_dcd_optimum() {
        let (train, _) = easy_problem(44);
        let lambda = 1e-2;
        let mut s = SvmPerf::new(SvmPerfParams {
            lambda,
            epsilon: 1e-5,
            max_cuts: 1000,
            qp_sweeps: 500,
        });
        let f_cp = objective(&s.fit(&train).w, &train, lambda);
        let mut dcd = crate::solver::DualCoordinateDescent::new(lambda, 300, 1e-10, 1);
        let f_opt = objective(&crate::solver::Solver::fit(&mut dcd, &train).w, &train, lambda);
        assert!(f_cp - f_opt < 0.05 * f_opt.max(1e-3), "cp {f_cp} vs opt {f_opt}");
    }

    #[test]
    fn empty_working_set_edge_case() {
        // A trivially-satisfiable dataset (all margins ≥ 1 from w = 0 is
        // impossible — hinge at w=0 is 1 — so at least one cut fires).
        let (train, _) = easy_problem(45);
        let mut s = SvmPerf::new(SvmPerfParams::default());
        s.fit(&train);
        assert!(s.cuts_used >= 1);
    }
}
