//! Native linear-SVM solvers: the local learner and every baseline the
//! paper compares against.
//!
//! * [`pegasos`] — mini-batch Pegasos (Shalev-Shwartz et al. 2007): the
//!   centralized baseline of Tables 3/5 and GADGET's local update rule.
//! * [`svm_sgd`] — Bottou's SVM-SGD: the second online baseline of Table 4.
//! * [`svm_perf`] — a cutting-plane solver for Joachims' structural
//!   formulation (Eq. 6 of the paper): the SVM-Perf stand-in of Table 4.
//! * [`dcd`] — dual coordinate descent (Hsieh et al. 2008): not in the
//!   paper's comparison, but used as the high-precision reference optimum
//!   `f(w*)` when reporting sub-optimality in the figures and the
//!   Theorem-2 bound checks.
//!
//! All solvers optimize the same primal objective (paper Eq. 1):
//! `F(w) = (λ/2)‖w‖² + (1/N) Σ max{0, 1 − y⟨w,x⟩}` — no bias term, exactly
//! as in Pegasos and the paper's experiments.

pub mod dcd;
pub mod multiclass;
pub mod pegasos;
pub mod svm_perf;
pub mod svm_sgd;

pub use dcd::DualCoordinateDescent;
pub use multiclass::{MulticlassDataset, MulticlassModel};
pub use pegasos::{Pegasos, PegasosParams};
pub use svm_perf::{SvmPerf, SvmPerfParams};
pub use svm_sgd::{SvmSgd, SvmSgdParams};

// The scaled-iterate representation moved to `linalg::scaled` (it is a
// linear-algebra primitive behind the kernel seam, not a solver); the old
// `solver::ScaledVector` path keeps working.
pub use crate::linalg::scaled::{ScaledIterate, ScaledVector, StepKind};

use crate::data::{Dataset, ShardView};

/// A trained linear model `f(x) = ⟨w, x⟩` (the paper's formulation carries
/// no intercept; the synthetic generators plant the bias into the data).
#[derive(Clone, Debug, Default)]
pub struct LinearModel {
    /// Weight vector.
    pub w: Vec<f64>,
}

impl LinearModel {
    /// Zero model of dimension `d`.
    pub fn zeros(d: usize) -> Self {
        Self { w: vec![0.0; d] }
    }

    /// Serializes to the project's JSON model format
    /// (`{"format": "gadget-linear-v1", "dim": d, "w": [...]}`).
    pub fn to_json(&self) -> crate::util::Json {
        crate::util::Json::obj(vec![
            ("format", crate::util::Json::Str("gadget-linear-v1".into())),
            ("dim", crate::util::Json::Num(self.w.len() as f64)),
            ("w", crate::util::Json::nums(&self.w)),
        ])
    }

    /// Writes the model to a JSON file.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> crate::Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())?;
        Ok(())
    }

    /// Loads a model written by [`Self::save`], validating format and dim.
    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        use anyhow::Context;
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read model {}", path.as_ref().display()))?;
        let doc = crate::util::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("model parse: {e}"))?;
        anyhow::ensure!(
            doc.get("format").and_then(crate::util::Json::as_str) == Some("gadget-linear-v1"),
            "not a gadget-linear-v1 model file"
        );
        let w: Vec<f64> = doc
            .get("w")
            .and_then(crate::util::Json::as_arr)
            .context("model: missing w array")?
            .iter()
            .map(|v| v.as_f64().context("model: non-numeric weight"))
            .collect::<crate::Result<_>>()?;
        let dim = doc.get("dim").and_then(crate::util::Json::as_usize).unwrap_or(w.len());
        anyhow::ensure!(dim == w.len(), "model: dim {} != weights {}", dim, w.len());
        Ok(Self { w })
    }

    /// Raw score `⟨w, x⟩`.
    #[inline]
    pub fn score(&self, x: &crate::linalg::SparseVec) -> f64 {
        x.dot_dense(&self.w)
    }

    /// Predicted label in {−1, +1}.
    #[inline]
    pub fn predict(&self, x: &crate::linalg::SparseVec) -> i8 {
        if self.score(x) >= 0.0 {
            1
        } else {
            -1
        }
    }
}

/// Common interface over the native solvers (used by the Table-4 harness to
/// run each baseline per node under an identical protocol).
///
/// Solvers iterate a borrowed [`ShardView`] — the streaming data plane's
/// row window — so the same implementation trains on an owned `Dataset`,
/// a static shard, or a snapshot of a streaming shard without cloning.
pub trait Solver {
    /// Trains on the borrowed row window and returns the model.
    fn fit_view(&mut self, view: ShardView<'_>) -> LinearModel;

    /// Convenience: trains on a whole dataset (borrows it as a view).
    fn fit(&mut self, ds: &Dataset) -> LinearModel {
        self.fit_view(ds.view())
    }

    /// Human-readable solver name for reports.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod model_io_tests {
    use super::LinearModel;

    #[test]
    fn save_load_roundtrip() {
        let tmp = crate::util::TempDir::new().unwrap();
        let p = tmp.path().join("model.json");
        let m = LinearModel { w: vec![1.5, -2.25, 0.0, 1e-9] };
        m.save(&p).unwrap();
        let back = LinearModel::load(&p).unwrap();
        assert_eq!(back.w, m.w);
    }

    #[test]
    fn load_rejects_garbage_and_wrong_format() {
        let tmp = crate::util::TempDir::new().unwrap();
        let p = tmp.path().join("bad.json");
        std::fs::write(&p, "{not json").unwrap();
        assert!(LinearModel::load(&p).is_err());
        std::fs::write(&p, r#"{"format": "other", "w": [1]}"#).unwrap();
        assert!(LinearModel::load(&p).is_err());
        std::fs::write(&p, r#"{"format": "gadget-linear-v1", "dim": 3, "w": [1]}"#).unwrap();
        assert!(LinearModel::load(&p).is_err());
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::data::Dataset;

    /// A small, clearly separable problem every solver must crack.
    pub fn easy_problem(seed: u64) -> (Dataset, Dataset) {
        let spec = DatasetSpec {
            name: "easy".into(),
            train_size: 800,
            test_size: 400,
            features: 32,
            nnz_per_row: 8,
            noise: 0.02,
            positive_rate: 0.5,
            lambda: 1e-3,
        };
        let s = generate(&spec, seed, 1.0);
        (s.train, s.test)
    }

    pub fn accuracy(model: &super::LinearModel, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..ds.len() {
            let (x, y) = ds.sample(i);
            if model.score(x) * y > 0.0 {
                correct += 1;
            }
        }
        correct as f64 / ds.len() as f64
    }
}
