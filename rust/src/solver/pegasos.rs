//! Mini-batch Pegasos: Primal Estimated sub-GrAdient SOlver for SVM
//! (Shalev-Shwartz, Singer & Srebro, ICML 2007).
//!
//! This is both the paper's *centralized baseline* (Tables 3/5, the figures)
//! and the local update rule inside GADGET (Algorithm 2 steps (a)–(f)).
//!
//! Per step `t`:
//! 1. draw a mini-batch `A_t` of `k` samples uniformly from the data;
//! 2. violators `A_t⁺ = {(x,y) ∈ A_t : y⟨w,x⟩ < 1}`;
//! 3. `αₜ = 1/(λt)`; `w ← (1 − λαₜ)·w + (αₜ/k)·Σ_{A_t⁺} y·x`;
//! 4. optionally project onto the ball of radius `1/√λ`.
//!
//! By default the shrink uses the O(1) scaled representation
//! ([`crate::linalg::scaled`]), so a step costs `O(k·nnz)` independent of
//! `d`; `[runtime] step = "dense"` / [`Pegasos::with_options`] selects the
//! plain O(d) loop instead — the independently-written reference the scaled
//! fast path is pinned against (`rust/tests/step_equivalence.rs`).

use super::{LinearModel, ScaledVector, Solver, StepKind};
use crate::data::ShardView;
use crate::rng::Rng;

/// Pegasos hyper-parameters.
#[derive(Clone, Debug)]
pub struct PegasosParams {
    /// Regularization λ (paper Table 2 values per dataset).
    pub lambda: f64,
    /// Number of sub-gradient steps `T`.
    pub iterations: usize,
    /// Mini-batch size `k` (1 = the paper's single-sample variant).
    pub batch_size: usize,
    /// Project onto the `1/√λ` ball each step (Algorithm 2 step (f)).
    pub project: bool,
    /// RNG seed for batch sampling.
    pub seed: u64,
}

impl Default for PegasosParams {
    fn default() -> Self {
        Self { lambda: 1e-4, iterations: 10_000, batch_size: 1, project: true, seed: 0 }
    }
}

/// The solver object (holds parameters; state is per-`fit`).
#[derive(Clone, Debug)]
pub struct Pegasos {
    /// Parameters.
    pub params: PegasosParams,
    /// Kernel backend for the margin dots (scalar reference by default).
    kernel: &'static dyn crate::linalg::Kernel,
    /// Step representation (`auto` resolves to the scaled fast path).
    step: StepKind,
}

impl Pegasos {
    /// Creates a solver with the given parameters (scalar kernel).
    pub fn new(params: PegasosParams) -> Self {
        Self { params, kernel: crate::linalg::kernel::scalar(), step: StepKind::Auto }
    }

    /// Creates a solver whose margin dots run on `kernel`.
    pub fn with_kernel(params: PegasosParams, kernel: &'static dyn crate::linalg::Kernel) -> Self {
        Self { params, kernel, step: StepKind::Auto }
    }

    /// Creates a solver with an explicit kernel backend *and* step
    /// representation (`[runtime] step` / `--step` plumb through here).
    pub fn with_options(
        params: PegasosParams,
        kernel: &'static dyn crate::linalg::Kernel,
        step: StepKind,
    ) -> Self {
        Self { params, kernel, step }
    }

    /// Runs `fit` but also invokes `snapshot(t, w)` every `every` steps —
    /// how the figure harness collects objective-vs-time traces without
    /// re-training. Iterates a borrowed [`ShardView`] (pass
    /// `ds.view()` for a whole dataset).
    pub fn fit_with_snapshots<F: FnMut(usize, &[f64])>(
        &self,
        ds: ShardView<'_>,
        every: usize,
        mut snapshot: F,
    ) -> LinearModel {
        let p = &self.params;
        assert!(p.lambda > 0.0, "Pegasos: lambda must be positive");
        assert!(p.batch_size >= 1, "Pegasos: batch size must be ≥ 1");
        assert!(!ds.is_empty(), "Pegasos: empty dataset");
        if !self.step.is_scaled() {
            return self.fit_dense(ds, every, snapshot);
        }
        let mut rng = Rng::new(p.seed);
        let mut w = ScaledVector::zeros(ds.dim);
        let radius = 1.0 / p.lambda.sqrt();
        // Batch scratch reused across iterations (allocation-free loop).
        let mut batch_idx: Vec<usize> = Vec::with_capacity(p.batch_size);
        let mut violators: Vec<usize> = Vec::with_capacity(p.batch_size);

        for t in 1..=p.iterations {
            let alpha = 1.0 / (p.lambda * t as f64);
            // Accumulate the violator sub-gradient for this batch *before*
            // shrinking (the update uses wₜ, not the shrunk vector).
            let shrink = 1.0 - p.lambda * alpha; // = 1 - 1/t
            let step = alpha / p.batch_size as f64;
            if p.batch_size == 1 {
                let i = rng.below(ds.len());
                let (x, y) = ds.sample(i);
                let margin = y * w.dot_sparse_k(x, self.kernel);
                if shrink != 0.0 {
                    w.scale_by(shrink);
                } else {
                    w.set_zero(); // t = 1: (1 - 1/t) = 0
                }
                if margin < 1.0 {
                    w.add_sparse(step * y, x);
                }
            } else {
                // batch: sample indices (same draw order as the per-sample
                // loop), flag violators at wₜ in one kernel call, update.
                batch_idx.clear();
                for _ in 0..p.batch_size {
                    batch_idx.push(rng.below(ds.len()));
                }
                violators.clear();
                self.kernel.hinge_subgrad_accum(
                    w.storage(),
                    w.scale(),
                    ds.rows,
                    ds.labels,
                    &batch_idx,
                    &mut violators,
                );
                if shrink != 0.0 {
                    w.scale_by(shrink);
                } else {
                    w.set_zero();
                }
                for &i in &violators {
                    let (x, y) = ds.sample(i);
                    w.add_sparse(step * y, x);
                }
            }
            if p.project {
                w.project_to_ball(radius);
            }
            if every > 0 && t % every == 0 {
                snapshot(t, &w.to_dense());
            }
        }
        LinearModel { w: w.to_dense() }
    }

    /// The O(d) dense reference loop: a plain `Vec<f64>` carries the
    /// weights, the regularization shrink multiplies every coordinate and
    /// the projection recomputes `‖w‖` from scratch each step. Batch
    /// sampling draws in exactly the same RNG order as the scaled path, so
    /// the two trajectories differ only by the representation's rounding
    /// (pinned in `rust/tests/step_equivalence.rs`).
    fn fit_dense<F: FnMut(usize, &[f64])>(
        &self,
        ds: ShardView<'_>,
        every: usize,
        mut snapshot: F,
    ) -> LinearModel {
        let p = &self.params;
        let mut rng = Rng::new(p.seed);
        let mut w = vec![0.0f64; ds.dim];
        let radius = 1.0 / p.lambda.sqrt();
        let mut batch_idx: Vec<usize> = Vec::with_capacity(p.batch_size);
        let mut violators: Vec<usize> = Vec::with_capacity(p.batch_size);

        for t in 1..=p.iterations {
            let alpha = 1.0 / (p.lambda * t as f64);
            let shrink = 1.0 - p.lambda * alpha; // = 1 - 1/t
            let step = alpha / p.batch_size as f64;
            if p.batch_size == 1 {
                let i = rng.below(ds.len());
                let (x, y) = ds.sample(i);
                let margin = y * self.kernel.dot_row(x.into(), &w);
                if shrink != 0.0 {
                    crate::linalg::scale_assign(shrink, &mut w);
                } else {
                    w.fill(0.0); // t = 1: (1 - 1/t) = 0
                }
                if margin < 1.0 {
                    self.kernel.axpy_row(step * y, x.into(), &mut w);
                }
            } else {
                batch_idx.clear();
                for _ in 0..p.batch_size {
                    batch_idx.push(rng.below(ds.len()));
                }
                violators.clear();
                self.kernel.hinge_subgrad_accum(
                    &w,
                    1.0,
                    ds.rows,
                    ds.labels,
                    &batch_idx,
                    &mut violators,
                );
                if shrink != 0.0 {
                    crate::linalg::scale_assign(shrink, &mut w);
                } else {
                    w.fill(0.0);
                }
                for &i in &violators {
                    let (x, y) = ds.sample(i);
                    self.kernel.axpy_row(step * y, x.into(), &mut w);
                }
            }
            if p.project {
                crate::linalg::project_to_ball(&mut w, radius);
            }
            if every > 0 && t % every == 0 {
                snapshot(t, &w);
            }
        }
        LinearModel { w }
    }
}

impl Solver for Pegasos {
    fn fit_view(&mut self, view: ShardView<'_>) -> LinearModel {
        self.fit_with_snapshots(view, 0, |_, _| {})
    }

    fn name(&self) -> &'static str {
        "pegasos"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::objective;
    use crate::solver::testutil::{accuracy, easy_problem};

    fn params(iters: usize) -> PegasosParams {
        PegasosParams { lambda: 1e-3, iterations: iters, batch_size: 1, project: true, seed: 42 }
    }

    #[test]
    fn learns_separable_problem() {
        let (train, test) = easy_problem(1);
        let mut s = Pegasos::new(params(20_000));
        let model = s.fit(&train);
        let acc = accuracy(&model, &test);
        assert!(acc > 0.9, "test accuracy {acc}");
    }

    #[test]
    fn objective_decreases_with_more_iterations() {
        let (train, _) = easy_problem(2);
        let lambda = 1e-3;
        let obj_at = |iters: usize| {
            let mut s = Pegasos::new(params(iters));
            let m = s.fit(&train);
            objective(&m.w, &train, lambda)
        };
        let o_short = obj_at(200);
        let o_long = obj_at(20_000);
        assert!(
            o_long < o_short,
            "objective did not improve: {o_short} -> {o_long}"
        );
    }

    #[test]
    fn batch_variant_also_learns() {
        let (train, test) = easy_problem(3);
        let mut p = params(4_000);
        p.batch_size = 8;
        let model = Pegasos::new(p).fit(&train);
        assert!(accuracy(&model, &test) > 0.9);
    }

    #[test]
    fn projection_keeps_norm_bounded() {
        let (train, _) = easy_problem(4);
        let p = params(2_000);
        let radius = 1.0 / p.lambda.sqrt();
        let s = Pegasos::new(p);
        let mut max_norm = 0.0f64;
        s.fit_with_snapshots(train.view(), 100, |_, w| {
            max_norm = max_norm.max(crate::linalg::l2_norm(w));
        });
        assert!(max_norm <= radius * (1.0 + 1e-9), "norm {max_norm} > radius {radius}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, _) = easy_problem(5);
        let a = Pegasos::new(params(500)).fit(&train);
        let b = Pegasos::new(params(500)).fit(&train);
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn dense_reference_learns_and_tracks_scaled() {
        let (train, test) = easy_problem(8);
        let kernel = crate::linalg::kernel::scalar();
        let mut dense =
            Pegasos::with_options(params(20_000), kernel, crate::linalg::StepKind::Dense);
        let md = dense.fit(&train);
        assert!(accuracy(&md, &test) > 0.9);
        let md2 = dense.fit(&train);
        assert_eq!(md.w, md2.w, "dense path must be deterministic");
        // short horizon: representations agree to rounding (the full
        // adversarial pin lives in rust/tests/step_equivalence.rs)
        let mut a = Pegasos::with_options(params(200), kernel, crate::linalg::StepKind::Dense);
        let mut b = Pegasos::with_options(params(200), kernel, crate::linalg::StepKind::Scaled);
        let (wa, wb) = (a.fit(&train).w, b.fit(&train).w);
        for (x, y) in wa.iter().zip(&wb) {
            assert!((x - y).abs() <= 1e-10 * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn snapshots_fire_at_requested_cadence() {
        let (train, _) = easy_problem(6);
        let mut steps = Vec::new();
        Pegasos::new(params(1000)).fit_with_snapshots(train.view(), 250, |t, _| steps.push(t));
        assert_eq!(steps, vec![250, 500, 750, 1000]);
    }

    #[test]
    fn near_optimal_vs_dcd_reference() {
        // Pegasos must approach the DCD optimum on a small problem.
        let (train, _) = easy_problem(7);
        let lambda = 1e-2;
        let mut peg = Pegasos::new(PegasosParams {
            lambda,
            iterations: 60_000,
            batch_size: 1,
            project: true,
            seed: 9,
        });
        let m = peg.fit(&train);
        let mut dcd = crate::solver::DualCoordinateDescent::new(lambda, 200, 1e-8, 11);
        let opt = crate::solver::Solver::fit(&mut dcd, &train);
        let f_peg = objective(&m.w, &train, lambda);
        let f_opt = objective(&opt.w, &train, lambda);
        assert!(
            f_peg - f_opt < 0.05 * f_opt.max(0.01),
            "pegasos {f_peg} vs optimum {f_opt}"
        );
    }
}
