//! Spectral estimates: second-largest eigenvalue modulus of `B` and the
//! derived mixing-time bound `τ_mix ≈ ln(2/γ) / (1 − λ₂)`.
//!
//! The GADGET runner uses this to size the number of Push-Sum rounds per
//! iteration (`R = ceil(τ_mix · ln(1/γ))` in the paper's notation); the
//! mixing benches compare the estimate against measured rounds-to-γ.

use super::TransitionMatrix;

/// Second-largest eigenvalue modulus of a doubly-stochastic `B`, by power
/// iteration on the component orthogonal to the all-ones vector (the Perron
/// vector of a doubly-stochastic matrix).
///
/// Deterministic: starts from a fixed seed vector; deflation is re-applied
/// every step so round-off cannot reintroduce the 𝟙 component.
pub fn second_eigenvalue(b: &TransitionMatrix, iters: usize) -> f64 {
    let m = b.m;
    if m <= 1 {
        return 0.0;
    }
    // Fixed pseudo-random start, orthogonal to 1.
    let mut v: Vec<f64> = (0..m)
        .map(|i| {
            let x = ((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as f64;
            x / (1u64 << 31) as f64 - 1.0
        })
        .collect();
    deflate_ones(&mut v);
    normalize(&mut v);

    let mut w = vec![0.0; m];
    let mut lambda = 0.0;
    for _ in 0..iters {
        // w = Bᵀ v  (B symmetric in our constructions, but use Bᵀ to match
        // the mass-propagation semantics; eigenvalues agree for symmetric B)
        b.transpose_apply(&v, &mut w);
        deflate_ones(&mut w);
        lambda = crate::linalg::l2_norm(&w);
        if lambda < 1e-300 {
            return 0.0;
        }
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / lambda;
        }
    }
    lambda
}

/// Mixing-time estimate in rounds for relative error `gamma`.
///
/// Synchronous `Bᵀ` mixing contracts the disagreement *geometrically*:
/// `err_t ≤ λ₂ᵗ · err₀`, so `τ(γ) = ln(m/γ) / (−ln λ₂)` — the sharp form.
/// (The textbook `ln(m/γ)/(1−λ₂)` upper-bounds this and over-provisions
/// badly for well-connected graphs: a complete graph with MH weights has
/// `λ₂ = 0` and mixes in ONE round, not `ln(m/γ)` rounds — that single
/// change cut end-to-end GADGET time ~5× on the complete overlay; see
/// EXPERIMENTS.md §Perf.) Returns at least 1; disconnected or
/// non-contracting chains (`λ₂ ≥ 1`) return `usize::MAX`.
pub fn mixing_time(b: &TransitionMatrix, gamma: f64) -> usize {
    assert!(gamma > 0.0 && gamma < 1.0, "gamma must be in (0,1)");
    if b.m <= 1 {
        return 1; // a single node is already exact
    }
    let l2 = second_eigenvalue(b, 200);
    if l2 >= 1.0 - 1e-12 {
        return usize::MAX;
    }
    if l2 <= 1e-9 {
        return 1; // exact average in one round (complete graph + MH)
    }
    (((b.m as f64 / gamma).ln() / -l2.ln()).ceil() as usize).max(1)
}

fn deflate_ones(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

fn normalize(v: &mut [f64]) {
    let n = crate::linalg::l2_norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::stochastic::WeightScheme;
    use crate::topology::Graph;

    fn mh(g: &Graph) -> TransitionMatrix {
        TransitionMatrix::from_graph(g, WeightScheme::MetropolisHastings)
    }

    #[test]
    fn complete_graph_has_tiny_lambda2() {
        // K_m with MH weights: B = (1/m)·𝟙𝟙ᵀ exactly ⇒ λ₂ = 0.
        let b = mh(&Graph::complete(6));
        assert!(second_eigenvalue(&b, 100) < 1e-10);
    }

    #[test]
    fn ring_lambda2_matches_closed_form() {
        // Ring with MH: b_{i,i±1} = 1/3, self 1/3 ⇒ λ₂ = 1/3 + 2/3·cos(2π/m).
        let m = 12;
        let b = mh(&Graph::ring(m));
        let expect = 1.0 / 3.0 + (2.0 / 3.0) * (2.0 * std::f64::consts::PI / m as f64).cos();
        let got = second_eigenvalue(&b, 500);
        assert!((got - expect).abs() < 1e-6, "got {got}, expect {expect}");
    }

    #[test]
    fn mixing_time_orders_topologies() {
        // complete < torus < ring, the qualitative claim benched in A1.
        let m = 16;
        let t_complete = mixing_time(&mh(&Graph::complete(m)), 0.01);
        let t_torus = mixing_time(&mh(&Graph::torus(m)), 0.01);
        let t_ring = mixing_time(&mh(&Graph::ring(m)), 0.01);
        assert!(t_complete < t_torus, "{t_complete} !< {t_torus}");
        assert!(t_torus < t_ring, "{t_torus} !< {t_ring}");
    }

    #[test]
    fn single_node_mixes_instantly() {
        let b = mh(&Graph::complete(1));
        assert_eq!(second_eigenvalue(&b, 10), 0.0);
        assert_eq!(mixing_time(&b, 0.01), 1);
    }

    #[test]
    fn disconnected_graph_never_mixes() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        let b = mh(&g);
        assert_eq!(mixing_time(&b, 0.01), usize::MAX);
    }

    #[test]
    #[should_panic(expected = "gamma must be in (0,1)")]
    fn bad_gamma_panics() {
        mixing_time(&mh(&Graph::ring(4)), 0.0);
    }
}
