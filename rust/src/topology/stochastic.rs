//! Doubly-stochastic transition matrices `B` over an overlay graph.
//!
//! Algorithm 2 takes `B` as input: `b_{ij} > 0` only along graph edges (plus
//! self loops), rows and columns sum to one. On an undirected graph two
//! standard symmetric constructions exist:
//!
//! * **Metropolis–Hastings**: `b_{ij} = 1 / (1 + max(deg i, deg j))` for an
//!   edge `ij`, self loop takes the slack. Doubly stochastic on any graph,
//!   no global knowledge beyond neighbor degrees.
//! * **Max-degree**: `b_{ij} = 1 / (Δ + 1)` with `Δ` the max degree.
//!
//! The paper suggests the simple random walk `b_{ij} = 1/deg(i)` — which is
//! only doubly stochastic on regular graphs; we expose it for the mixing
//! benches but the GADGET runner defaults to Metropolis–Hastings so the
//! consensus limit is the *uniform* average required by Theorem 1.

use super::Graph;

/// Weighting schemes for building `B` from a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightScheme {
    /// Metropolis–Hastings weights (doubly stochastic on any graph).
    MetropolisHastings,
    /// Uniform `1/(Δ+1)` weights (doubly stochastic on any graph).
    MaxDegree,
    /// Simple random walk `1/deg(i)` (row-stochastic only; kept for the
    /// mixing-time benches that reproduce the paper's `b_{ij} = 1/deg i`
    /// suggestion).
    RandomWalk,
}

impl std::str::FromStr for WeightScheme {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "metropolis-hastings" | "mh" => Ok(Self::MetropolisHastings),
            "max-degree" => Ok(Self::MaxDegree),
            "random-walk" => Ok(Self::RandomWalk),
            other => Err(format!("unknown weight scheme {other:?}")),
        }
    }
}

/// A dense row-major `m×m` transition matrix.
#[derive(Clone, Debug)]
pub struct TransitionMatrix {
    /// Number of nodes.
    pub m: usize,
    /// Row-major entries.
    pub b: Vec<f64>,
}

impl TransitionMatrix {
    /// Builds `B` from a graph with the given scheme.
    pub fn from_graph(g: &Graph, scheme: WeightScheme) -> Self {
        let m = g.n;
        let mut b = vec![0.0; m * m];
        match scheme {
            WeightScheme::MetropolisHastings => {
                for i in 0..m {
                    let mut slack = 1.0;
                    for &j in &g.adj[i] {
                        let w = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                        b[i * m + j] = w;
                        slack -= w;
                    }
                    b[i * m + i] = slack;
                }
            }
            WeightScheme::MaxDegree => {
                let w = 1.0 / (g.max_degree() as f64 + 1.0);
                for i in 0..m {
                    for &j in &g.adj[i] {
                        b[i * m + j] = w;
                    }
                    b[i * m + i] = 1.0 - w * g.degree(i) as f64;
                }
            }
            WeightScheme::RandomWalk => {
                for i in 0..m {
                    let deg = g.degree(i) as f64;
                    if deg == 0.0 {
                        b[i * m + i] = 1.0;
                    } else {
                        for &j in &g.adj[i] {
                            b[i * m + j] = 1.0 / deg;
                        }
                    }
                }
            }
        }
        Self { m, b }
    }

    /// Entry accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.b[i * self.m + j]
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.b[i * self.m..(i + 1) * self.m]
    }

    /// `max_i |Σ_j b_ij − 1|` — row-stochasticity violation.
    pub fn row_error(&self) -> f64 {
        (0..self.m)
            .map(|i| (self.row(i).iter().sum::<f64>() - 1.0).abs())
            .fold(0.0, f64::max)
    }

    /// `max_j |Σ_i b_ij − 1|` — column-stochasticity violation.
    pub fn col_error(&self) -> f64 {
        (0..self.m)
            .map(|j| ((0..self.m).map(|i| self.get(i, j)).sum::<f64>() - 1.0).abs())
            .fold(0.0, f64::max)
    }

    /// True when doubly stochastic to tolerance `tol` and non-negative.
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        self.b.iter().all(|&v| v >= -tol)
            && self.row_error() <= tol
            && self.col_error() <= tol
    }

    /// Validates that support(B) ⊆ edges(g) ∪ self-loops.
    pub fn respects_graph(&self, g: &Graph) -> bool {
        for i in 0..self.m {
            for j in 0..self.m {
                if i != j && self.get(i, j) != 0.0 && !g.adj[i].contains(&j) {
                    return false;
                }
            }
        }
        true
    }

    /// Returns `Some(1/m)` when every entry equals `1/m` — the complete
    /// graph with MH/max-degree weights. Rank-1 `B` lets the vector-mixing
    /// hot path replace the O(m²·d) pairwise pass with a mean + broadcast
    /// (O(2m·d)); see `gossip::PushVector::round` and EXPERIMENTS.md §Perf.
    pub fn uniform_value(&self) -> Option<f64> {
        let u = 1.0 / self.m as f64;
        if self.b.iter().all(|&v| (v - u).abs() < 1e-15) {
            Some(u)
        } else {
            None
        }
    }

    /// `y = Bᵀ x` — one synchronous Push-Sum round moves mass `x` by `Bᵀ`
    /// (entry `j` receives `Σ_i b_{ij} x_i`).
    pub fn transpose_apply(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.m);
        assert_eq!(y.len(), self.m);
        y.fill(0.0);
        for i in 0..self.m {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for j in 0..self.m {
                y[j] += row[j] * xi;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyKind;

    #[test]
    fn mh_is_doubly_stochastic_on_irregular_graph() {
        // star graph: maximally irregular
        let g = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        assert!(b.is_doubly_stochastic(1e-12));
        assert!(b.respects_graph(&g));
    }

    #[test]
    fn max_degree_is_doubly_stochastic() {
        let g = Graph::generate(TopologyKind::SmallWorld, 12, 5);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MaxDegree);
        assert!(b.is_doubly_stochastic(1e-12));
        assert!(b.respects_graph(&g));
    }

    #[test]
    fn random_walk_row_stochastic_only() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::RandomWalk);
        assert!(b.row_error() < 1e-12);
        assert!(b.col_error() > 0.1); // path graph: not column stochastic
    }

    #[test]
    fn random_walk_on_regular_graph_is_doubly_stochastic() {
        let g = Graph::ring(6);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::RandomWalk);
        assert!(b.is_doubly_stochastic(1e-12));
    }

    #[test]
    fn transpose_apply_preserves_mass() {
        let g = Graph::generate(TopologyKind::Torus, 9, 1);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let x = vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.5, 0.0, 0.0, 1.5];
        let mut y = vec![0.0; 9];
        b.transpose_apply(&x, &mut y);
        let mass_in: f64 = x.iter().sum();
        let mass_out: f64 = y.iter().sum();
        assert!((mass_in - mass_out).abs() < 1e-12);
    }

    #[test]
    fn complete_graph_mixes_in_one_step() {
        let g = Graph::complete(4);
        let b = TransitionMatrix::from_graph(&g, WeightScheme::MetropolisHastings);
        let x = vec![4.0, 0.0, 0.0, 0.0];
        let mut y = vec![0.0; 4];
        b.transpose_apply(&x, &mut y);
        // K4 MH: off-diagonal 1/4, diagonal 1/4 — exactly uniform after one step.
        for v in y {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }
}
