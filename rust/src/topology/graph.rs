//! Undirected overlay graphs for the gossip network.
//!
//! Peersim (the paper's substrate) wires nodes with a static overlay; we
//! provide the standard families used in the gossip literature (Boyd et al.
//! 2006) so the Push-Sum mixing benchmarks can sweep topology classes:
//! complete, ring, 2-D torus, random k-regular, Watts–Strogatz small world
//! and connected Erdős–Rényi.

use crate::rng::Rng;

/// Supported overlay families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Every pair connected (Peersim's default "idle" overlay; the paper's
    /// experiments gossip with uniformly random peers, i.e. complete).
    Complete,
    /// Cycle over the nodes — the slowest-mixing connected family.
    Ring,
    /// 2-D torus on the nearest square grid.
    Torus,
    /// Random k-regular graph (expander with high probability).
    KRegular,
    /// Watts–Strogatz small world (ring + rewiring).
    SmallWorld,
    /// Erdős–Rényi G(n, p), retried until connected.
    ErdosRenyi,
    /// Barabási–Albert preferential attachment (power-law degrees — the
    /// "scale-free" overlays of real P2P deployments; a few hubs carry
    /// most of the mixing).
    PowerLaw,
    /// Two dense clusters joined by exactly one bridge edge — the
    /// partition-prone scenario: cut [`Graph::partition_bridge`] and the
    /// network splits; re-add it and it heals.
    Partition,
}

impl std::str::FromStr for TopologyKind {
    type Err = String;

    /// Parses the kebab-case names used in configs and on the CLI.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "complete" => Ok(Self::Complete),
            "ring" => Ok(Self::Ring),
            "torus" | "grid" => Ok(Self::Torus),
            "k-regular" | "kregular" | "expander" => Ok(Self::KRegular),
            "small-world" | "watts-strogatz" => Ok(Self::SmallWorld),
            "erdos-renyi" | "random" => Ok(Self::ErdosRenyi),
            "power-law" | "powerlaw" | "scale-free" => Ok(Self::PowerLaw),
            "partition" | "partition-prone" => Ok(Self::Partition),
            other => Err(format!("unknown topology {other:?}")),
        }
    }
}

impl std::fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::Complete => "complete",
            Self::Ring => "ring",
            Self::Torus => "torus",
            Self::KRegular => "k-regular",
            Self::SmallWorld => "small-world",
            Self::ErdosRenyi => "erdos-renyi",
            Self::PowerLaw => "power-law",
            Self::Partition => "partition",
        };
        f.write_str(s)
    }
}

/// An undirected graph as sorted adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Number of vertices.
    pub n: usize,
    /// `adj[i]` = sorted neighbors of vertex `i` (no self loops).
    pub adj: Vec<Vec<usize>>,
}

impl Graph {
    /// Builds a graph from an edge list, deduplicating and sorting.
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut adj = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n, "edge out of range");
            if a == b {
                continue;
            }
            adj[a].push(b);
            adj[b].push(a);
        }
        for l in &mut adj {
            l.sort_unstable();
            l.dedup();
        }
        Self { n, adj }
    }

    /// Degree of vertex `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Maximum degree over all vertices.
    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Total number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &self.adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.n
    }

    /// Graph diameter by BFS from every vertex (fine for gossip-scale n).
    /// Returns `usize::MAX` when disconnected.
    pub fn diameter(&self) -> usize {
        let mut diam = 0usize;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            dist[s] = 0;
            let mut queue = std::collections::VecDeque::from([s]);
            while let Some(v) = queue.pop_front() {
                for &u in &self.adj[v] {
                    if dist[u] == usize::MAX {
                        dist[u] = dist[v] + 1;
                        queue.push_back(u);
                    }
                }
            }
            let far = *dist.iter().max().unwrap();
            if far == usize::MAX {
                return usize::MAX;
            }
            diam = diam.max(far);
        }
        diam
    }

    /// Generates a graph of the given family. All generators return a
    /// connected graph; random families retry with derived seeds.
    pub fn generate(kind: TopologyKind, n: usize, seed: u64) -> Self {
        assert!(n >= 1, "graph needs at least one vertex");
        match kind {
            TopologyKind::Complete => Self::complete(n),
            TopologyKind::Ring => Self::ring(n),
            TopologyKind::Torus => Self::torus(n),
            TopologyKind::KRegular => Self::k_regular(n, 4.min(n.saturating_sub(1)), seed),
            TopologyKind::SmallWorld => Self::small_world(n, 4.min(n.saturating_sub(1)), 0.1, seed),
            TopologyKind::ErdosRenyi => {
                let p = (2.0 * (n as f64).ln() / n as f64).min(1.0);
                Self::erdos_renyi(n, p, seed)
            }
            TopologyKind::PowerLaw => Self::power_law(n, seed),
            TopologyKind::Partition => Self::partition_prone(n, seed),
        }
    }

    /// Complete graph K_n.
    pub fn complete(n: usize) -> Self {
        let adj = (0..n).map(|i| (0..n).filter(|&j| j != i).collect()).collect();
        Self { n, adj }
    }

    /// Ring (cycle) C_n; for n ≤ 2 degenerates to a path/point.
    pub fn ring(n: usize) -> Self {
        if n == 1 {
            return Self { n, adj: vec![vec![]] };
        }
        if n == 2 {
            return Self::from_edges(2, &[(0, 1)]);
        }
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Self::from_edges(n, &edges)
    }

    /// 2-D torus on an `r×c` grid with `r·c = n`, `r` the largest divisor
    /// ≤ √n (falls back to ring when n is prime).
    pub fn torus(n: usize) -> Self {
        let mut r = (n as f64).sqrt() as usize;
        while r > 1 && n % r != 0 {
            r -= 1;
        }
        if r <= 1 {
            return Self::ring(n);
        }
        let c = n / r;
        let mut edges = Vec::new();
        for i in 0..r {
            for j in 0..c {
                let v = i * c + j;
                edges.push((v, i * c + (j + 1) % c));
                edges.push((v, ((i + 1) % r) * c + j));
            }
        }
        Self::from_edges(n, &edges)
    }

    /// Random k-regular graph via the pairing model, retried until simple
    /// and connected.
    pub fn k_regular(n: usize, k: usize, seed: u64) -> Self {
        assert!(k < n, "k_regular: k must be < n");
        if k == 0 {
            assert_eq!(n, 1, "k=0 only valid for a single vertex");
            return Self { n, adj: vec![vec![]] };
        }
        assert!(n * k % 2 == 0, "k_regular: n·k must be even");
        'attempt: for attempt in 0..1000u64 {
            let mut rng = Rng::new(seed.wrapping_add(attempt * 0x9e37));
            let mut stubs: Vec<usize> = (0..n).flat_map(|v| std::iter::repeat(v).take(k)).collect();
            rng.shuffle(&mut stubs);
            let mut edges = Vec::with_capacity(n * k / 2);
            let mut seen = std::collections::HashSet::new();
            for pair in stubs.chunks(2) {
                let (a, b) = (pair[0], pair[1]);
                if a == b || !seen.insert((a.min(b), a.max(b))) {
                    continue 'attempt; // multi-edge or loop: resample
                }
                edges.push((a, b));
            }
            let g = Self::from_edges(n, &edges);
            if g.is_connected() {
                return g;
            }
        }
        panic!("k_regular: failed to generate a simple connected graph");
    }

    /// Watts–Strogatz: ring lattice with `k` nearest neighbors (k even),
    /// each edge rewired with probability `beta`; retried until connected.
    pub fn small_world(n: usize, k: usize, beta: f64, seed: u64) -> Self {
        let k = k.max(2) & !1; // even, ≥2
        assert!(k < n, "small_world: k must be < n");
        for attempt in 0..1000u64 {
            let mut rng = Rng::new(seed.wrapping_add(attempt * 0x51f3));
            let mut edges = Vec::new();
            for i in 0..n {
                for j in 1..=k / 2 {
                    let mut tgt = (i + j) % n;
                    if rng.flip(beta) {
                        tgt = rng.below(n);
                        if tgt == i {
                            tgt = (i + j) % n;
                        }
                    }
                    edges.push((i, tgt));
                }
            }
            let g = Self::from_edges(n, &edges);
            if g.is_connected() {
                return g;
            }
        }
        panic!("small_world: failed to generate a connected graph");
    }

    /// Barabási–Albert preferential attachment: start from the edge
    /// `(0, 1)`, then each new node attaches to 2 *distinct* existing
    /// nodes sampled degree-proportionally (via the stub list — every
    /// edge endpoint appears once, so a uniform stub draw is exactly
    /// preferential attachment). Connected by construction (every node
    /// attaches to the existing component); no rejection loop needed.
    /// `n ≤ 2` degenerates to the ring.
    pub fn power_law(n: usize, seed: u64) -> Self {
        if n <= 2 {
            return Self::ring(n);
        }
        let mut rng = Rng::new(seed);
        let mut edges = vec![(0usize, 1usize)];
        let mut stubs = vec![0usize, 1];
        for v in 2..n {
            let mut targets: Vec<usize> = Vec::with_capacity(2);
            while targets.len() < 2.min(v) {
                let t = stubs[rng.below(stubs.len())];
                if !targets.contains(&t) {
                    targets.push(t);
                }
            }
            for &t in &targets {
                edges.push((v, t));
                stubs.push(v);
                stubs.push(t);
            }
        }
        Self::from_edges(n, &edges)
    }

    /// Partition-prone overlay: two clusters `[0, n/2)` and `[n/2, n)` —
    /// each a ring plus `len/4` seeded chords (a single edge for a
    /// 2-node cluster) — joined by exactly **one** deterministic bridge
    /// edge, [`Graph::partition_bridge`]. Removing the bridge partitions
    /// the network (the failure scenario the gossip literature worries
    /// about); re-adding it heals. `n < 4` degenerates to the ring
    /// (too few nodes for two clusters).
    pub fn partition_prone(n: usize, seed: u64) -> Self {
        if n < 4 {
            return Self::ring(n);
        }
        fn cluster(lo: usize, hi: usize, rng: &mut Rng, edges: &mut Vec<(usize, usize)>) {
            let len = hi - lo;
            if len == 2 {
                edges.push((lo, lo + 1));
                return;
            }
            for i in 0..len {
                edges.push((lo + i, lo + (i + 1) % len));
            }
            for _ in 0..len / 4 {
                let a = lo + rng.below(len);
                let b = lo + rng.below(len);
                if a != b {
                    edges.push((a.min(b), a.max(b)));
                }
            }
        }
        let half = n / 2;
        let mut rng = Rng::new(seed);
        let mut edges = Vec::new();
        cluster(0, half, &mut rng, &mut edges);
        cluster(half, n, &mut rng, &mut edges);
        edges.push(Self::partition_bridge(n));
        Self::from_edges(n, &edges)
    }

    /// The single inter-cluster edge of [`Graph::partition_prone`] —
    /// deterministic (independent of the seed) so failure-scenario tests
    /// and churn experiments can cut and heal exactly this link.
    pub fn partition_bridge(n: usize) -> (usize, usize) {
        (0, n / 2)
    }

    /// Connected Erdős–Rényi G(n, p) by rejection.
    pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Self {
        for attempt in 0..1000u64 {
            let mut rng = Rng::new(seed.wrapping_add(attempt * 0xabcd));
            let mut edges = Vec::new();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.flip(p) {
                        edges.push((i, j));
                    }
                }
            }
            let g = Self::from_edges(n, &edges);
            if g.is_connected() {
                return g;
            }
        }
        panic!("erdos_renyi: failed to generate a connected graph (p too small?)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_properties() {
        let g = Graph::complete(5);
        assert_eq!(g.edge_count(), 10);
        assert_eq!(g.max_degree(), 4);
        assert!(g.is_connected());
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn ring_properties() {
        let g = Graph::ring(8);
        assert_eq!(g.edge_count(), 8);
        assert!(g.adj.iter().all(|l| l.len() == 2));
        assert_eq!(g.diameter(), 4);
    }

    #[test]
    fn ring_small_cases() {
        assert_eq!(Graph::ring(1).edge_count(), 0);
        assert_eq!(Graph::ring(2).edge_count(), 1);
        assert_eq!(Graph::ring(3).edge_count(), 3);
    }

    #[test]
    fn torus_regular_degree() {
        let g = Graph::torus(16); // 4x4
        assert!(g.adj.iter().all(|l| l.len() == 4));
        assert!(g.is_connected());
    }

    #[test]
    fn torus_prime_falls_back_to_ring() {
        let g = Graph::torus(7);
        assert!(g.adj.iter().all(|l| l.len() == 2));
    }

    #[test]
    fn k_regular_is_regular_and_connected() {
        let g = Graph::k_regular(10, 4, 3);
        assert!(g.adj.iter().all(|l| l.len() == 4));
        assert!(g.is_connected());
    }

    #[test]
    fn small_world_connected() {
        let g = Graph::small_world(20, 4, 0.2, 9);
        assert!(g.is_connected());
        assert!(g.edge_count() >= 20);
    }

    #[test]
    fn erdos_renyi_connected() {
        let g = Graph::erdos_renyi(15, 0.4, 1);
        assert!(g.is_connected());
    }

    #[test]
    fn generate_dispatch_all_kinds() {
        for kind in [
            TopologyKind::Complete,
            TopologyKind::Ring,
            TopologyKind::Torus,
            TopologyKind::KRegular,
            TopologyKind::SmallWorld,
            TopologyKind::ErdosRenyi,
            TopologyKind::PowerLaw,
            TopologyKind::Partition,
        ] {
            let g = Graph::generate(kind, 10, 1);
            assert_eq!(g.n, 10);
            assert!(g.is_connected(), "{kind:?} not connected");
        }
    }

    #[test]
    fn power_law_grows_hubs() {
        let g = Graph::power_law(60, 7);
        assert!(g.is_connected());
        // every node past the seed pair attaches with 2 edges
        assert_eq!(g.edge_count(), 1 + 2 * 58);
        // preferential attachment concentrates degree: some hub must beat
        // the attachment minimum by a wide margin
        assert!(g.max_degree() >= 6, "max degree {}", g.max_degree());
    }

    #[test]
    fn partition_prone_has_exactly_one_bridge() {
        let n = 12;
        let g = Graph::partition_prone(n, 3);
        assert!(g.is_connected());
        let (a, b) = Graph::partition_bridge(n);
        // the bridge is the only inter-cluster edge
        let half = n / 2;
        let crossing: Vec<(usize, usize)> = (0..n)
            .flat_map(|i| g.adj[i].iter().map(move |&j| (i, j)))
            .filter(|&(i, j)| i < j && (i < half) != (j < half))
            .collect();
        assert_eq!(crossing, vec![(a, b)]);
    }

    #[test]
    fn from_edges_dedup_and_no_self_loop() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (2, 2)]);
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn disconnected_diameter_is_max() {
        let g = Graph::from_edges(3, &[(0, 1)]);
        assert!(!g.is_connected());
        assert_eq!(g.diameter(), usize::MAX);
    }
}
