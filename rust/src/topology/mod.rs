//! Network topology substrate: overlay graphs, doubly-stochastic transition
//! matrices `B`, and spectral mixing-time estimates.
//!
//! GADGET's Push-Sum converges to a γ-relative-error average in
//! `O(τ_mix · log 1/γ)` rounds, where `τ_mix` is the mixing time of the
//! Markov chain defined by `B` (paper §3). This module builds the graphs
//! the experiments run on, the `B` matrices (Metropolis–Hastings or
//! max-degree weights — both doubly stochastic on undirected graphs), and
//! estimates `τ_mix` from the second-largest eigenvalue modulus.

pub mod graph;
pub mod spectral;
pub mod stochastic;

pub use graph::{Graph, TopologyKind};
pub use spectral::{mixing_time, second_eigenvalue};
pub use stochastic::TransitionMatrix;
