//! The sharded batch-inference subsystem: persisted model artifacts plus
//! a pool-backed scoring service.
//!
//! GADGET's anytime guarantee (ROADMAP north-star: serve the consensus
//! model, not just train it) means every node holds a usable model at all
//! times; this module turns that model into a first-class inference
//! artifact, mirroring how *Distributed Inference for Linear SVM*
//! (arXiv:1811.11922) treats the trained separator and how
//! *High-Performance SVMs* (arXiv:1905.00331) emphasizes
//! throughput-oriented batch scoring:
//!
//! * [`artifact`] — the versioned JSON model format ([`ModelArtifact`]):
//!   weight rows, biases, the one-vs-rest code matrix, feature dim and
//!   scaling metadata; save/load constructors for both the binary
//!   ([`crate::coordinator::GadgetReport`]) and multiclass
//!   ([`crate::coordinator::MulticlassReport`]) trainers. The text round
//!   trip is bitwise exact for every finite f64.
//! * [`shard`] — [`ShardedScorer`]: per-shard scoring tasks over one
//!   shared warm model, request batches fanned over the persistent
//!   [`crate::pool::WorkerPool`] as disjoint row chunks (in-process
//!   shards are logical replicas — the consensus model is identical
//!   everywhere, so cloning it per shard would buy nothing). Bitwise
//!   shard-count-invariant by construction, pinned by
//!   `rust/tests/property_invariants.rs` and the `ci.sh` serve smoke
//!   test.
//! * [`service`] — the `gadget serve` loop: line-delimited LIBSVM or
//!   dense rows on stdin, one prediction per line on stdout, batched per
//!   the `[serve]` config section (`shards`, `batch`) or the
//!   `--shards`/`--batch` CLI flags. [`score_stream`] inside it is the
//!   *only* scoring loop — every transport drives it.
//! * [`http`] — the train-while-serving HTTP front end ([`HttpServer`]):
//!   HTTP/1.1 keep-alive connections served by `[serve] workers`
//!   concurrent executors over the shared warm scorer, `POST /score`
//!   byte-identical to the stdin path (and worker-count-invariant) by
//!   construction, `POST /ingest` staging labeled rows into a training
//!   run's [`crate::data::ArrivalQueue`], explicit backpressure over
//!   [`queue::BoundedQueue`] (`503` + `Retry-After` from a bounded
//!   responder pool, never a silent drop or an unbounded thread),
//!   per-request deadline budgets, per-connection reusable arenas (a
//!   warm keep-alive `/score` request allocates nothing), graceful
//!   drain (DESIGN.md §HTTP data plane).
//!
//! The full pipeline: `gadget train --save model.json` → `gadget serve
//! --model model.json --shards 4 < batch.libsvm` (DESIGN.md §Serving),
//! or over a socket: `gadget serve --model model.json --http
//! 127.0.0.1:8080`, with live ingestion via `gadget train --http-ingest`.
//!
//! [`score_stream`]: service::score_stream

pub mod artifact;
pub mod http;
pub mod queue;
pub mod service;
pub mod shard;

pub use artifact::{ModelArtifact, Prediction, ScalingMeta, FORMAT_NAME, FORMAT_VERSION};
pub use http::{HttpConfig, HttpServer, HttpStats};
pub use service::{run_serve, parse_row, RowFormat, ServeOptions, ServeStats};
pub use shard::ShardedScorer;
