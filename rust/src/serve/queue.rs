//! A bounded MPSC work queue with *explicit* overflow — the backpressure
//! seam between the HTTP acceptor and the scoring worker.
//!
//! The serving contract is "never a silent drop": when the queue is
//! full, [`BoundedQueue::push`] hands the item **back** to the caller
//! (so the acceptor can answer `503` + `Retry-After` on the still-open
//! connection) instead of blocking the accept loop or discarding the
//! connection. [`BoundedQueue::pop`] blocks until an item arrives or
//! the queue is closed *and* drained — which is exactly the graceful
//! shutdown semantics: `close()` stops admissions immediately while the
//! worker keeps answering everything already admitted.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a [`BoundedQueue::push`] was refused; the item comes back in
/// both cases so the caller can still respond on it.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at capacity — retry later (HTTP: `503` +
    /// `Retry-After`).
    Full(T),
    /// The queue is closed — the server is draining (HTTP: `503`).
    Closed(T),
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer queue with a blocking consumer side.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    takeable: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    /// A queue admitting at most `cap` queued items (≥ 1).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "BoundedQueue: capacity must be ≥ 1");
        Self {
            inner: Mutex::new(Inner { items: VecDeque::with_capacity(cap), closed: false }),
            takeable: Condvar::new(),
            cap,
        }
    }

    /// Admits `item`, or returns it inside the error when the queue is
    /// full or closed. Never blocks.
    pub fn push(&self, item: T) -> std::result::Result<(), PushError<T>> {
        let mut inner = self.inner.lock().expect("BoundedQueue poisoned");
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        inner.items.push_back(item);
        drop(inner);
        self.takeable.notify_one();
        Ok(())
    }

    /// Takes the oldest admitted item, blocking while the queue is open
    /// but empty. Returns `None` only when the queue is closed *and*
    /// fully drained — every item admitted before `close()` is still
    /// delivered.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().expect("BoundedQueue poisoned");
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.takeable.wait(inner).expect("BoundedQueue poisoned");
        }
    }

    /// Stops admissions; already-queued items remain poppable. Idempotent.
    pub fn close(&self) {
        let mut inner = self.inner.lock().expect("BoundedQueue poisoned");
        inner.closed = true;
        drop(inner);
        self.takeable.notify_all();
    }

    /// True once [`Self::close`] has run.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("BoundedQueue poisoned").closed
    }

    /// Currently queued items.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("BoundedQueue poisoned").items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn overflow_returns_the_item_instead_of_dropping() {
        let q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        match q.push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        // room frees up once the consumer takes one
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn close_drains_admitted_items_then_ends() {
        let q = BoundedQueue::new(4);
        q.push("a").unwrap();
        q.push("b").unwrap();
        q.close();
        assert!(q.is_closed());
        match q.push("c") {
            Err(PushError::Closed(item)) => assert_eq!(item, "c"),
            other => panic!("expected Closed, got {other:?}"),
        }
        // everything admitted before close still comes out, in order
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None); // stays terminal
    }

    #[test]
    fn pop_blocks_until_an_item_or_close_arrives() {
        let q = Arc::new(BoundedQueue::new(1));
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let first = q.pop();
                let second = q.pop();
                (first, second)
            })
        };
        q.push(7).unwrap();
        q.close();
        let (first, second) = consumer.join().unwrap();
        assert_eq!(first, Some(7));
        assert_eq!(second, None);
    }
}
