//! Pool-backed sharded batch scoring.
//!
//! The paper's anytime guarantee makes the consensus model identical on
//! every node, so inference shards are pure replicas. Within one process
//! the replicas are *logical*: every shard task scores against the same
//! immutable [`ModelArtifact`] — a deep clone per shard would cost
//! `K·d` f64s each (tens of MB for a wide one-vs-rest model at 16
//! shards) and buy nothing in a single address space; the persisted
//! artifact (DESIGN.md §Serving) is what enables real per-process
//! replicas. Each request batch fans over the persistent [`WorkerPool`]
//! (the same dispatch substrate the training runtime uses — DESIGN.md
//! §Worker-pool dispatch), one contiguous row chunk per shard.
//!
//! Scoring a row reads only the row and the model's immutable
//! parameters, so the shard count can only move work, never change
//! results: predictions are **bitwise identical** at any shard count,
//! including `shards > rows` (surplus shards idle) and empty batches
//! (no dispatch at all). `rust/tests/property_invariants.rs` pins this,
//! and `ci.sh` re-runs the pin at pool sizes 1 and 4 like the
//! scheduler-equivalence matrix.

use super::artifact::{ModelArtifact, Prediction};
use crate::linalg::{Kernel, SparseVec};
use crate::pool::{ParallelExec, WorkerPool, SERIAL_EXEC};
use crate::Result;
use anyhow::ensure;
use std::sync::Mutex;

/// `Send`/`Sync` wrapper for shipping the output base pointer into shard
/// tasks. The wrapper proves nothing — soundness comes from the tasks'
/// pairwise-disjoint row ranges (see [`ShardedScorer::score_batch_into`]).
struct SendPtr(*mut Prediction);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// A batch scorer fanning row chunks across `shards` pool workers, all
/// scoring one shared warm model.
pub struct ShardedScorer {
    /// The model every shard task scores against.
    model: ModelArtifact,
    /// Shard (= maximum concurrent chunk) count, clamped to ≥ 1.
    shards: usize,
    /// The dispatch pool; `None` at one shard — scoring runs inline on
    /// the caller thread with no worker threads spawned at all.
    pool: Option<WorkerPool>,
    /// The kernel backend every shard task's margin dots run on.
    kernel: &'static dyn Kernel,
    /// Per-shard margins scratch, one cell per shard slot, reused across
    /// batches — once each cell has grown to its largest chunk, the warm
    /// serve path performs no per-batch allocation. Mutex-guarded so
    /// [`Self::score_batch_into`] stays `&self`: chunk `c` of one
    /// dispatch is run by exactly one thread, so the lock is uncontended
    /// within a batch; concurrent batches on the same scorer — the
    /// normal case now that the HTTP front end runs `[serve] workers`
    /// executors over one shared scorer — block briefly on the cell
    /// instead of racing. Blocking never reorders arithmetic, so
    /// responses stay bitwise identical at any worker count.
    scratch: Vec<Mutex<Vec<f64>>>,
}

impl ShardedScorer {
    /// Builds a scorer with `shards` shard slots (clamped to ≥ 1) and,
    /// for `shards > 1`, the worker pool they score on; margins run on
    /// the scalar reference kernel (see [`Self::with_kernel`]).
    pub fn new(model: ModelArtifact, shards: usize) -> Self {
        Self::with_kernel(model, shards, crate::linalg::kernel::scalar())
    }

    /// [`Self::new`] with an explicit kernel backend (`[serve]` /
    /// `--kernel` resolve here via [`super::run_serve`]).
    pub fn with_kernel(
        model: ModelArtifact,
        shards: usize,
        kernel: &'static dyn Kernel,
    ) -> Self {
        let shards = shards.max(1);
        let pool = if shards > 1 { Some(WorkerPool::new(shards)) } else { None };
        let scratch = (0..shards).map(|_| Mutex::new(Vec::new())).collect();
        Self { model, shards, pool, kernel, scratch }
    }

    /// Shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The kernel backend scoring runs on.
    pub fn kernel(&self) -> &'static dyn Kernel {
        self.kernel
    }

    /// The model being served.
    pub fn model(&self) -> &ModelArtifact {
        &self.model
    }

    /// The executor batches dispatch on.
    fn exec(&self) -> &dyn ParallelExec {
        match &self.pool {
            Some(pool) => pool,
            None => &SERIAL_EXEC,
        }
    }

    /// Scores `rows`, one [`Prediction`] per row in input order.
    ///
    /// Allocates the output vector; the serve loop's warm path is
    /// [`Self::score_batch_into`], which reuses one.
    pub fn score_batch(&self, rows: &[SparseVec]) -> Result<Vec<Prediction>> {
        let mut out = Vec::new();
        self.score_batch_into(rows, &mut out)?;
        Ok(out)
    }

    /// Scores `rows` into the reusable `out` buffer (cleared and resized
    /// to `rows.len()`), one [`Prediction`] per row in input order.
    ///
    /// Rows are validated against the model dimension up front (errors
    /// name the offending row index), then split into one contiguous
    /// chunk per shard by index arithmetic and fanned over the pool's
    /// allocation-free indexed dispatch
    /// ([`ParallelExec::run_indexed`]); each index writes its disjoint
    /// slice of `out` and scores through its own reusable per-shard
    /// margins scratch cell. With a caller-retained buffer the warm
    /// serve path performs no per-batch heap allocation once `out`'s
    /// capacity and each scratch cell have grown to the largest batch
    /// seen. Empty batches clear `out` without touching the pool.
    pub fn score_batch_into(
        &self,
        rows: &[SparseVec],
        out: &mut Vec<Prediction>,
    ) -> Result<()> {
        let dim = self.model.dim;
        for (i, row) in rows.iter().enumerate() {
            ensure!(
                row.min_dim() <= dim,
                "row {i}: feature index {} out of range for model dim {dim}",
                row.min_dim() - 1
            );
        }
        out.clear();
        out.resize(rows.len(), Prediction::default());
        if rows.is_empty() {
            return Ok(());
        }
        let model = &self.model;
        let kernel = self.kernel;
        let scratch = &self.scratch;
        let n = rows.len();
        let chunk = (n + self.shards - 1) / self.shards;
        let tasks_n = (n + chunk - 1) / chunk;
        let out_ptr = SendPtr(out.as_mut_ptr());
        self.exec().run_indexed(tasks_n, &move |c| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            // SAFETY: the indices' `[lo, hi)` ranges partition `[0, n)`
            // — pairwise disjoint slices of `out` — and `run_indexed`
            // returns only after every index finished, so the buffer
            // outlives all writes.
            let out_chunk =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo), hi - lo) };
            // `tasks_n = ceil(n / chunk) ≤ shards`, so index `c` always
            // has a scratch cell.
            let mut margins =
                scratch[c].lock().unwrap_or_else(|poisoned| poisoned.into_inner());
            model.predict_batch_scratch(kernel, &rows[lo..hi], out_chunk, &mut margins);
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::artifact::ScalingMeta;

    fn model(dim: usize) -> ModelArtifact {
        let w: Vec<f64> = (0..dim)
            .map(|j| (j as f64 + 1.0) * if j % 2 == 0 { 1.0 } else { -0.5 })
            .collect();
        ModelArtifact::new(dim, vec![w], vec![0.0], ScalingMeta::default()).unwrap()
    }

    fn rows(n: usize, dim: usize) -> Vec<SparseVec> {
        (0..n)
            .map(|i| {
                let j = (i % dim) as u32;
                SparseVec::new(vec![j], vec![1.0 + i as f32 * 0.25])
            })
            .collect()
    }

    #[test]
    fn shard_counts_agree_bitwise() {
        let batch = rows(23, 7);
        let reference = ShardedScorer::new(model(7), 1).score_batch(&batch).unwrap();
        for shards in [2usize, 3, 5, 23, 40] {
            let scorer = ShardedScorer::new(model(7), shards);
            assert_eq!(scorer.shards(), shards);
            let got = scorer.score_batch(&batch).unwrap();
            assert_eq!(got.len(), reference.len());
            for (a, b) in reference.iter().zip(&got) {
                assert_eq!(a.label, b.label, "shards={shards}");
                assert_eq!(a.score.to_bits(), b.score.to_bits(), "shards={shards}");
            }
        }
    }

    #[test]
    fn empty_batch_and_zero_shards_clamp() {
        let scorer = ShardedScorer::new(model(4), 0);
        assert_eq!(scorer.shards(), 1);
        assert!(scorer.score_batch(&[]).unwrap().is_empty());
        let scorer = ShardedScorer::new(model(4), 6);
        assert!(scorer.score_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn out_of_range_row_names_its_index() {
        let scorer = ShardedScorer::new(model(4), 2);
        let batch = vec![
            SparseVec::new(vec![0], vec![1.0]),
            SparseVec::new(vec![9], vec![1.0]),
        ];
        let err = scorer.score_batch(&batch).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("row 1"), "{msg}");
        assert!(msg.contains("model dim 4"), "{msg}");
    }

    #[test]
    fn batched_scoring_matches_per_row_predict_bitwise() {
        // The kernel-batched chunk scorer must reproduce the per-row
        // `predict` loop exactly on the scalar (default) backend.
        let batch = rows(17, 7);
        let scorer = ShardedScorer::new(model(7), 3);
        assert_eq!(scorer.kernel().name(), "scalar");
        let got = scorer.score_batch(&batch).unwrap();
        for (g, r) in got.iter().zip(&batch) {
            let p = scorer.model().predict(r);
            assert_eq!(g.label, p.label);
            assert_eq!(g.score.to_bits(), p.score.to_bits());
        }
    }

    #[test]
    fn simd_kernel_scorer_agrees_on_labels() {
        // Cross-backend smoke: scores may differ in low bits, decoded
        // labels on comfortably-margined rows may not.
        let batch = rows(29, 7);
        let scalar = ShardedScorer::new(model(7), 2);
        let simd =
            ShardedScorer::with_kernel(model(7), 2, crate::linalg::kernel::simd());
        assert_eq!(simd.kernel().name(), "simd");
        let a = scalar.score_batch(&batch).unwrap();
        let b = simd.score_batch(&batch).unwrap();
        for (x, y) in a.iter().zip(&b) {
            // every margin in `rows()` is far from the decision boundary
            assert!(x.score.abs() > 1e-6);
            assert_eq!(x.label, y.label);
            assert!((x.score - y.score).abs() <= 1e-9 * (1.0 + x.score.abs()));
        }
    }

    #[test]
    fn score_batch_into_reuses_buffer_and_matches() {
        // The warm serve path: one caller-retained buffer across batches
        // of varying size must give exactly score_batch's results, and
        // shrink/regrow correctly (stale tail entries cleared).
        let scorer = ShardedScorer::new(model(5), 3);
        let mut out = Vec::new();
        for n in [9usize, 64, 3, 0, 17] {
            let batch = rows(n, 5);
            scorer.score_batch_into(&batch, &mut out).unwrap();
            assert_eq!(out, scorer.score_batch(&batch).unwrap(), "n={n}");
            assert_eq!(out.len(), n);
        }
        // once capacity covers the largest batch, reuse never reallocates
        let cap = out.capacity();
        assert!(cap >= 64);
        scorer.score_batch_into(&rows(64, 5), &mut out).unwrap();
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn multiclass_scratch_reuse_matches_per_row_predict() {
        // The k·n margins scratch path, across growing and shrinking
        // batch sizes on one scorer (per-shard scratch cells resized and
        // reused between batches), must reproduce the per-row `predict`
        // loop bitwise on the scalar backend.
        let dim = 6;
        let weights: Vec<Vec<f64>> = (0..3)
            .map(|c| (0..dim).map(|j| (c as f64 + 1.0) * 0.3 - j as f64 * 0.1).collect())
            .collect();
        let model =
            ModelArtifact::new(dim, weights, vec![0.1, -0.2, 0.0], ScalingMeta::default())
                .unwrap();
        let scorer = ShardedScorer::new(model, 3);
        let mut out = Vec::new();
        for n in [11usize, 40, 5] {
            let batch = rows(n, dim);
            scorer.score_batch_into(&batch, &mut out).unwrap();
            for (g, r) in out.iter().zip(&batch) {
                let p = scorer.model().predict(r);
                assert_eq!(g.label, p.label, "n={n}");
                assert_eq!(g.score.to_bits(), p.score.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn scorer_stays_warm_across_batches() {
        let scorer = ShardedScorer::new(model(5), 3);
        let a = scorer.score_batch(&rows(9, 5)).unwrap();
        let b = scorer.score_batch(&rows(9, 5)).unwrap();
        assert_eq!(a, b);
        let big = scorer.score_batch(&rows(64, 5)).unwrap();
        assert_eq!(big.len(), 64);
    }
}
